"""AOT-lower the Layer-2 graphs to HLO *text* artifacts for the Rust
PJRT runtime.

HLO text, NOT ``lowered.compile()`` / ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
Writes one ``<name>.hlo.txt`` per exported graph plus a manifest.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed AOT batch shapes: the Rust coordinator streams row batches of
# BATCH x FEATURES through the executables.
BATCH = 4096
FEATURES = 20
K = 8

SPEC_X = jax.ShapeDtypeStruct((BATCH, FEATURES), jnp.float32)
SPEC_C = jax.ShapeDtypeStruct((K, FEATURES), jnp.float32)
SPEC_Y = jax.ShapeDtypeStruct((BATCH,), jnp.float32)

EXPORTS = {
    "pairwise": (model.pairwise, (SPEC_X, SPEC_C)),
    "kmeans_step": (model.kmeans_step, (SPEC_X, SPEC_C)),
    "gram_xty": (model.gram_xty, (SPEC_X, SPEC_Y)),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--out", default=None, help="legacy single-file alias")
    args = p.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"batch": BATCH, "features": FEATURES, "k": K, "artifacts": {}}
    for name, (fn, specs) in EXPORTS.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "n_outputs": len(jax.tree_util.tree_leaves(jax.eval_shape(fn, *specs))),
            "chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    # legacy alias expected by the original Makefile rule
    legacy = os.path.join(out_dir, "model.hlo.txt")
    with open(os.path.join(out_dir, "kmeans_step.hlo.txt")) as f:
        open(legacy, "w").write(f.read())
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {legacy} and manifest.json")


if __name__ == "__main__":
    main()
