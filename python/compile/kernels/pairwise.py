"""Layer-1 Pallas kernels: the numeric hot-spots of the traditional-ML
workloads.

Two kernels cover the suite's compute cores:

- ``pairwise_sq_dists`` — blocked ||x_i - c_j||² distance matrix, the
  inner loop of KMeans / KNN / DBSCAN / GMM / t-SNE.
- ``gram`` — blocked Xᵀ X accumulation (SYRK), the inner loop of
  Ridge / Lasso / PCA / linear SVM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
optimizations block for cache lines and DRAM row buffers on x86; here
the same blocking idea is expressed as an HBM↔VMEM schedule via
``BlockSpec``: each grid step stages one (block_n × M) row panel in
VMEM and contracts it on the MXU (`dot_general` over the feature
axis), with the rank-1 ||·||² corrections fused in-register.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the AOT artifacts
execute on the Rust CPU runtime. Real-TPU performance is *estimated*
structurally in DESIGN.md §Perf-estimates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_kernel(x_ref, c_ref, o_ref):
    """One grid step: distances of a row panel against all centroids."""
    xb = x_ref[...]  # (block_n, m) panel staged in VMEM
    cb = c_ref[...]  # (k, m) — small, revisited every step
    x2 = jnp.sum(xb * xb, axis=1, keepdims=True)  # (block_n, 1)
    c2 = jnp.sum(cb * cb, axis=1)[None, :]  # (1, k)
    # MXU contraction over the feature axis: (block_n, m) x (k, m)^T
    xc = jax.lax.dot_general(
        xb, cb, dimension_numbers=(((1,), (1,)), ((), ()))
    )  # (block_n, k)
    o_ref[...] = x2 + c2 - 2.0 * xc


@functools.partial(jax.jit, static_argnames=("block_n",))
def pairwise_sq_dists(x, c, block_n: int = 128):
    """Squared Euclidean distance matrix D[i, j] = ||x_i - c_j||².

    ``x``: (n, m) float32, ``c``: (k, m) float32, n divisible by block_n.
    """
    n, m = x.shape
    k = c.shape[0]
    assert n % block_n == 0, f"n={n} must be divisible by block_n={block_n}"
    return pl.pallas_call(
        _pairwise_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((k, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
        interpret=True,
    )(x, c)


def _gram_kernel(x_ref, o_ref):
    """Accumulate one row panel's Xᵀ X contribution into the output."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...]  # (block_n, m)
    o_ref[...] += jax.lax.dot_general(
        xb, xb, dimension_numbers=(((0,), (0,)), ((), ()))
    )  # (m, m)


@functools.partial(jax.jit, static_argnames=("block_n",))
def gram(x, block_n: int = 128):
    """Gram matrix G = Xᵀ X, accumulated panel by panel (SYRK)."""
    n, m = x.shape
    assert n % block_n == 0, f"n={n} must be divisible by block_n={block_n}"
    return pl.pallas_call(
        _gram_kernel,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((m, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), x.dtype),
        interpret=True,
    )(x)
