"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: pytest checks kernel == ref to float tolerance before anything
is exported for the Rust runtime)."""

import jax.numpy as jnp


def pairwise_sq_dists_ref(x, c):
    """||x_i - c_j||^2 by explicit broadcasting."""
    diff = x[:, None, :] - c[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def gram_ref(x):
    """X^T X directly."""
    return x.T @ x


def kmeans_step_ref(x, c):
    """One Lloyd iteration: returns (new_centroids, inertia)."""
    d = pairwise_sq_dists_ref(x, c)
    assign = jnp.argmin(d, axis=1)
    k = c.shape[0]
    onehot = jnp.eye(k, dtype=x.dtype)[assign]  # (n, k)
    counts = onehot.sum(axis=0)
    sums = onehot.T @ x
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], c)
    inertia = jnp.sum(jnp.min(d, axis=1))
    return new_c, inertia
