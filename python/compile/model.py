"""Layer-2 JAX compute graphs: the workloads' numeric cores, built on the
Layer-1 Pallas kernels. These are the functions `aot.py` lowers to HLO
text for the Rust runtime — Python never runs on the request path.

Exported graphs (shapes fixed at AOT time, f32):

- ``kmeans_step(x, c)``       -> (new_centroids, inertia)   [Lloyd E+M]
- ``gram_xty(x, y)``          -> (X^T X, X^T y)             [normal eqs]
- ``pairwise(x, c)``          -> distance matrix            [kernel direct]

The Rust coordinator composes them: e.g. streaming `gram_xty` over row
batches, summing, and Cholesky-solving in Rust gives exact Ridge; looping
`kmeans_step` over batches with centroid averaging gives minibatch KMeans.
"""

import jax
import jax.numpy as jnp

from .kernels import pairwise as k


def pairwise(x, c):
    """Distance matrix via the Pallas kernel (direct L1 exposure)."""
    return (k.pairwise_sq_dists(x, c),)


def kmeans_step(x, c):
    """One Lloyd iteration over a batch: assignment via the Pallas
    distance kernel, centroid update via a one-hot contraction."""
    d = k.pairwise_sq_dists(x, c)
    assign = jnp.argmin(d, axis=1)
    kk = c.shape[0]
    onehot = jnp.eye(kk, dtype=x.dtype)[assign]  # (n, k)
    counts = onehot.sum(axis=0)
    sums = onehot.T @ x
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], c)
    inertia = jnp.sum(jnp.min(d, axis=1))
    return new_c, inertia


def gram_xty(x, y):
    """Normal-equation building blocks for a row batch: (X^T X, X^T y).
    The Gram half runs on the Pallas SYRK kernel."""
    g = k.gram(x)
    xty = x.T @ y
    return g, xty
