"""Kernel-vs-reference correctness: the CORE build-time signal.

The Pallas kernels (interpret mode) must match the pure-jnp oracles to
float32 tolerance across a hypothesis-driven sweep of shapes and data
distributions before `make artifacts` output is trusted.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pairwise as k
from compile.kernels import ref


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale), dtype=jnp.float32)


class TestPairwise:
    def test_small_exact(self):
        x = jnp.array([[0.0, 0.0], [3.0, 4.0]] * 64, dtype=jnp.float32)
        c = jnp.array([[0.0, 0.0], [3.0, 4.0]], dtype=jnp.float32)
        d = k.pairwise_sq_dists(x, c, block_n=64)
        np.testing.assert_allclose(d[0], [0.0, 25.0], rtol=1e-5)
        np.testing.assert_allclose(d[1], [25.0, 0.0], rtol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        n_blocks=st.integers(1, 4),
        block_n=st.sampled_from([32, 64, 128]),
        m=st.integers(1, 40),
        kk=st.integers(1, 16),
        seed=st.integers(0, 2**31),
        scale=st.sampled_from([0.1, 1.0, 100.0]),
    )
    def test_matches_ref_swept(self, n_blocks, block_n, m, kk, seed, scale):
        x = rand((n_blocks * block_n, m), seed, scale)
        c = rand((kk, m), seed + 1, scale)
        got = k.pairwise_sq_dists(x, c, block_n=block_n)
        want = ref.pairwise_sq_dists_ref(x, c)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3 * scale * scale)

    def test_distances_nonnegative(self):
        x = rand((256, 20), 7)
        c = rand((8, 20), 8)
        d = k.pairwise_sq_dists(x, c)
        assert float(jnp.min(d)) > -1e-3

    def test_rejects_misaligned_batch(self):
        with pytest.raises(AssertionError):
            k.pairwise_sq_dists(rand((100, 4), 0), rand((2, 4), 1), block_n=64)


class TestGram:
    @settings(max_examples=20, deadline=None)
    @given(
        n_blocks=st.integers(1, 4),
        block_n=st.sampled_from([32, 128]),
        m=st.integers(1, 32),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref_swept(self, n_blocks, block_n, m, seed):
        x = rand((n_blocks * block_n, m), seed)
        got = k.gram(x, block_n=block_n)
        want = ref.gram_ref(x)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-2)

    def test_gram_symmetric_psd(self):
        x = rand((256, 10), 3)
        g = np.asarray(k.gram(x))
        np.testing.assert_allclose(g, g.T, rtol=1e-5)
        eig = np.linalg.eigvalsh(g)
        assert eig.min() > -1e-2


class TestKMeansStep:
    def test_matches_ref(self):
        from compile import model

        x = rand((512, 20), 11)
        c = rand((8, 20), 12)
        got_c, got_i = model.kmeans_step(x, c)
        want_c, want_i = ref.kmeans_step_ref(x, c)
        np.testing.assert_allclose(got_c, want_c, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got_i, want_i, rtol=1e-4)

    def test_inertia_decreases_over_steps(self):
        from compile import model

        rng = np.random.default_rng(0)
        centers = rng.normal(size=(8, 20), scale=5.0)
        x = jnp.asarray(
            centers[rng.integers(0, 8, 4096)] + rng.normal(size=(4096, 20)),
            dtype=jnp.float32,
        )
        c = jnp.asarray(rng.normal(size=(8, 20)), dtype=jnp.float32)
        inertias = []
        for _ in range(6):
            c, inertia = model.kmeans_step(x, c)
            inertias.append(float(inertia))
        assert inertias[-1] < inertias[0] * 0.8, inertias

    def test_empty_cluster_keeps_centroid(self):
        from compile import model

        x = jnp.zeros((128, 4), dtype=jnp.float32)
        c = jnp.asarray([[0.0] * 4, [100.0] * 4], dtype=jnp.float32)
        new_c, _ = model.kmeans_step(x, c)
        np.testing.assert_allclose(new_c[1], c[1])


class TestGramXty:
    def test_normal_equations_recover_weights(self):
        from compile import model

        rng = np.random.default_rng(5)
        w_true = rng.normal(size=20)
        x = rng.normal(size=(4096, 20))
        y = x @ w_true + rng.normal(size=4096) * 0.01
        g, xty = model.gram_xty(
            jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32)
        )
        w = np.linalg.solve(np.asarray(g) + 1e-3 * np.eye(20), np.asarray(xty))
        np.testing.assert_allclose(w, w_true, atol=0.05)
