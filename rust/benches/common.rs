// Each bench target includes this file via `#[path]`, so any one target
// uses only a subset of it — silence per-target dead-code noise.
#![allow(dead_code)]
//! Shared bench-harness plumbing. Every bench target regenerates one
//! paper table/figure; they all accept
//! `cargo bench --bench <name> -- --scale 0.5 --iterations 3` and honour
//! the `MLPERF_SCALE` environment variable (default 0.15 keeps the full
//! `cargo bench` suite in CI-friendly time; EXPERIMENTS.md records the
//! scale each committed result used).

use mlperf::coordinator::ExperimentConfig;
use mlperf::util::Args;

pub fn args() -> Args {
    Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
}

pub fn config() -> ExperimentConfig {
    let a = args();
    let env_scale = std::env::var("MLPERF_SCALE").ok().and_then(|s| s.parse().ok());
    ExperimentConfig {
        scale: a.get_parsed_or("scale", env_scale.unwrap_or(0.15)),
        iterations: a.get_parsed_or("iterations", 2),
        seed: a.get_parsed_or("seed", 0xDA7Au64),
        ..Default::default()
    }
}

/// The eight workloads of Table VII / Figs. 20–24 (the paper's
/// reordering study set).
pub fn reorder_workloads() -> [&'static str; 8] {
    [
        "Adaboost",
        "DBSCAN",
        "Decision Tree",
        "GMM",
        "KMeans",
        "KNN",
        "Random Forests",
        "t-SNE",
    ]
}

/// The neighbour+tree set used by the software-prefetch study
/// (Section V-C limits it to these; matrix workloads already saturate
/// bandwidth).
pub fn prefetch_workloads() -> [&'static str; 8] {
    [
        "KMeans", "GMM", "KNN", "DBSCAN", "t-SNE", "Decision Tree", "Random Forests", "Adaboost",
    ]
}

pub fn banner(what: &str) {
    let cfg = config();
    println!(
        "# {what} | scale={} iterations={} seed={:#x}",
        cfg.scale, cfg.iterations, cfg.seed
    );
}

/// Wall-clock a closure, printing the duration (benches report their own
/// harness cost so regressions in the simulator itself are visible).
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t0 = std::time::Instant::now();
    let out = f();
    println!("[{label}: {:.1}s]", t0.elapsed().as_secs_f64());
    out
}
