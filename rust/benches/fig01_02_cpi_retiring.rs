//! Figures 1 & 2: CPI and retiring ratio for all workloads, in both the
//! scikit-learn and mlpack implementation profiles.
//!
//! Paper shape to reproduce: CPI between ~0.4 and ~1.75 everywhere;
//! retiring 15-40% for all workloads except GMM/KMeans (higher under
//! mlpack); sklearn bars worse than mlpack bars.
//!
//! Characterizations are independent, so the bench fans them out over the
//! parallel experiment driver (one job per workload × profile) instead of
//! looping sequentially; result order stays the registry order.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{pct, r2, Table};
use mlperf::coordinator::{run_jobs, Job, Scenario};
use mlperf::workloads::{registry, LibraryProfile};

fn main() {
    common::banner("Figs 1-2: CPI + retiring ratio");
    let mut cfg = common::config();

    let names: Vec<&'static str> = registry().iter().map(|w| w.name()).collect();
    let ml_names: Vec<&'static str> = registry()
        .iter()
        .filter(|w| w.in_mlpack())
        .map(|w| w.name())
        .collect();

    cfg.profile = LibraryProfile::Sklearn;
    let sk_jobs: Vec<Job> = names.iter().map(|n| Job::new(*n, Scenario::Baseline)).collect();
    let sk = common::timed("sklearn grid", || run_jobs(&cfg, &sk_jobs, 0));

    cfg.profile = LibraryProfile::Mlpack;
    let ml_jobs: Vec<Job> = ml_names.iter().map(|n| Job::new(*n, Scenario::Baseline)).collect();
    let ml = common::timed("mlpack grid", || run_jobs(&cfg, &ml_jobs, 0));

    let mut t = Table::new(
        "fig01_02",
        "CPI and retiring ratio (sklearn vs mlpack)",
        &["workload", "CPI sk", "CPI ml", "retiring% sk", "retiring% ml"],
    );
    for (i, name) in names.iter().enumerate() {
        let m_sk = &sk.outputs[i].metrics;
        let m_ml = ml_names
            .iter()
            .position(|n| n == name)
            .map(|j| &ml.outputs[j].metrics);
        t.row(vec![
            (*name).into(),
            r2(m_sk.cpi),
            m_ml.map(|m| r2(m.cpi)).unwrap_or_else(|| "-".into()),
            pct(m_sk.retiring_pct),
            m_ml.map(|m| pct(m.retiring_pct)).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.emit();
}
