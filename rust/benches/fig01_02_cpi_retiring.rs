//! Figures 1 & 2: CPI and retiring ratio for all workloads, in both the
//! scikit-learn and mlpack implementation profiles.
//!
//! Paper shape to reproduce: CPI between ~0.4 and ~1.75 everywhere;
//! retiring 15-40% for all workloads except GMM/KMeans (higher under
//! mlpack); sklearn bars worse than mlpack bars.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{pct, r2, Table};
use mlperf::coordinator::characterize;
use mlperf::workloads::{registry, LibraryProfile};

fn main() {
    common::banner("Figs 1-2: CPI + retiring ratio");
    let mut cfg = common::config();
    let mut t = Table::new(
        "fig01_02",
        "CPI and retiring ratio (sklearn vs mlpack)",
        &["workload", "CPI sk", "CPI ml", "retiring% sk", "retiring% ml"],
    );
    for w in registry() {
        let (cpi_sk, ret_sk) = common::timed(w.name(), || {
            cfg.profile = LibraryProfile::Sklearn;
            let m = characterize(w.as_ref(), &cfg).metrics;
            (m.cpi, m.retiring_pct)
        });
        let (cpi_ml, ret_ml) = if w.in_mlpack() {
            cfg.profile = LibraryProfile::Mlpack;
            let m = characterize(w.as_ref(), &cfg).metrics;
            (Some(m.cpi), Some(m.retiring_pct))
        } else {
            (None, None)
        };
        t.row(vec![
            w.name().into(),
            r2(cpi_sk),
            cpi_ml.map(r2).unwrap_or_else(|| "-".into()),
            pct(ret_sk),
            ret_ml.map(pct).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.emit();
}
