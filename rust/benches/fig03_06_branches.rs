//! Figures 3-6: bad-speculation bound, branch misprediction ratio,
//! branch-instruction fraction, conditional-branch percentage.
//!
//! Paper shape: tree-based workloads dominate bad-speculation (17-28%)
//! with high mispredict ratios; neighbour+tree workloads have ~20-25%
//! branch instructions; 80-95% of branches are conditional everywhere.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{pct, r3, Table};
use mlperf::coordinator::characterize;
use mlperf::workloads::registry;

fn main() {
    common::banner("Figs 3-6: branch behaviour");
    let cfg = common::config();
    let mut t = Table::new(
        "fig03_06",
        "bad-speculation & branch statistics (sklearn profile)",
        &["workload", "category", "bad spec %", "mispredict", "branch frac", "cond %"],
    );
    for w in registry() {
        let m = common::timed(w.name(), || characterize(w.as_ref(), &cfg).metrics);
        t.row(vec![
            w.name().into(),
            w.category().to_string(),
            pct(m.bad_spec_pct),
            r3(m.branch_mispredict_ratio),
            r3(m.branch_fraction),
            pct(m.cond_branch_fraction * 100.0),
        ]);
    }
    t.emit();
}
