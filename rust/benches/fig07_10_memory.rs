//! Figures 7-10: DRAM-bound stalls, LLC miss ratio, memory-bandwidth
//! utilization, and core-bound (port) stalls.
//!
//! Paper shape: ~31-37% of cycles DRAM-bound across categories; matrix
//! workloads at ~80% bandwidth utilization vs ~40% for the rest; 15-38%
//! core-bound stalls.
//!
//! One baseline job per workload, fanned out over the parallel experiment
//! driver; outputs come back in registry order.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{pct, r3, Table};
use mlperf::coordinator::{run_jobs, Job, Scenario};
use mlperf::workloads::registry;

fn main() {
    common::banner("Figs 7-10: memory behaviour");
    let cfg = common::config();
    let jobs: Vec<Job> = registry()
        .iter()
        .map(|w| Job::new(w.name(), Scenario::Baseline))
        .collect();
    let report = common::timed("baseline grid", || run_jobs(&cfg, &jobs, 0));
    println!("[{} jobs on {} threads]", report.outputs.len(), report.threads_used);

    let mut t = Table::new(
        "fig07_10",
        "DRAM bound, LLC miss, bandwidth utilization, core bound",
        &["workload", "category", "dram bound %", "LLC miss", "bw util %", "core bound %", "p0/p1/p2/p3+"],
    );
    for (w, out) in registry().iter().zip(&report.outputs) {
        let m = &out.metrics;
        t.row(vec![
            w.name().into(),
            w.category().to_string(),
            pct(m.dram_bound_pct),
            r3(m.llc_miss_ratio),
            pct(m.bandwidth_utilization_pct()),
            pct(m.core_bound_pct),
            format!(
                "{:.2}/{:.2}/{:.2}/{:.2}",
                m.port_dist[0], m.port_dist[1], m.port_dist[2], m.port_dist[3]
            ),
        ]);
    }
    t.emit();
}
