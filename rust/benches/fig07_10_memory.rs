//! Figures 7-10: DRAM-bound stalls, LLC miss ratio, memory-bandwidth
//! utilization, and core-bound (port) stalls.
//!
//! Paper shape: ~31-37% of cycles DRAM-bound across categories; matrix
//! workloads at ~80% bandwidth utilization vs ~40% for the rest; 15-38%
//! core-bound stalls.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{pct, r3, Table};
use mlperf::coordinator::characterize;
use mlperf::workloads::registry;

fn main() {
    common::banner("Figs 7-10: memory behaviour");
    let cfg = common::config();
    let mut t = Table::new(
        "fig07_10",
        "DRAM bound, LLC miss, bandwidth utilization, core bound",
        &["workload", "category", "dram bound %", "LLC miss", "bw util %", "core bound %", "p0/p1/p2/p3+"],
    );
    for w in registry() {
        let m = common::timed(w.name(), || characterize(w.as_ref(), &cfg).metrics);
        t.row(vec![
            w.name().into(),
            w.category().to_string(),
            pct(m.dram_bound_pct),
            r3(m.llc_miss_ratio),
            pct(m.bandwidth_utilization_pct()),
            pct(m.core_bound_pct),
            format!(
                "{:.2}/{:.2}/{:.2}/{:.2}",
                m.port_dist[0], m.port_dist[1], m.port_dist[2], m.port_dist[3]
            ),
        ]);
    }
    t.emit();
}
