//! Figure 12: IPC improvement with a perfect L2 and a perfect LLC
//! (the Sniper-style idealization study that motivates prefetching).
//!
//! Paper shape: perfect LLC buys ~25-36% IPC on average per category;
//! perfect L2 buys more (~31-41%); neighbour workloads gain the most
//! from perfect LLC.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{pct, Table};
use mlperf::coordinator::perfect_cache_study;
use mlperf::util::stats::geomean;
use mlperf::workloads::{registry, Category};

fn main() {
    common::banner("Fig 12: perfect-cache IPC improvements");
    let cfg = common::config();
    let mut t = Table::new(
        "fig12",
        "IPC improvement with perfect L2 / perfect LLC",
        &["workload", "category", "perfect LLC %", "perfect L2 %"],
    );
    let mut per_cat: std::collections::BTreeMap<String, (Vec<f64>, Vec<f64>)> = Default::default();
    for w in registry() {
        let s = common::timed(w.name(), || perfect_cache_study(w.as_ref(), &cfg));
        let llc_gain = (s.perfect_llc.ipc / s.base.ipc - 1.0) * 100.0;
        let l2_gain = (s.perfect_l2.ipc / s.base.ipc - 1.0) * 100.0;
        let e = per_cat.entry(w.category().to_string()).or_default();
        e.0.push(1.0 + llc_gain / 100.0);
        e.1.push(1.0 + l2_gain / 100.0);
        t.row(vec![w.name().into(), w.category().to_string(), pct(llc_gain), pct(l2_gain)]);
    }
    for (cat, (llc, l2)) in &per_cat {
        t.row(vec![
            format!("[{cat} mean]"),
            cat.clone(),
            pct((geomean(llc) - 1.0) * 100.0),
            pct((geomean(l2) - 1.0) * 100.0),
        ]);
    }
    t.emit();

    let mut ord_ok = true;
    for (_, (llc, l2)) in per_cat {
        if geomean(&l2) + 1e-9 < geomean(&llc) {
            ord_ok = false;
        }
    }
    println!("perfect-L2 >= perfect-LLC per category: {}", if ord_ok { "YES (matches paper)" } else { "NO" });
}
