//! Figure 13: fraction of useless hardware prefetches per workload.
//!
//! Paper shape: ~42% of HW prefetches useless for the neighbour- and
//! tree-based workloads (irregular A[B[i]] streams); far lower for the
//! streaming matrix workloads.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{r3, Table};
use mlperf::coordinator::characterize;
use mlperf::util::stats::mean;
use mlperf::workloads::{registry, Category};

fn main() {
    common::banner("Fig 13: useless hardware prefetch fraction");
    let cfg = common::config();
    let mut t = Table::new(
        "fig13",
        "useless HW prefetch fraction",
        &["workload", "category", "hw issued", "useless frac"],
    );
    let mut irregular = Vec::new();
    let mut regular = Vec::new();
    for w in registry() {
        let m = common::timed(w.name(), || characterize(w.as_ref(), &cfg).metrics);
        let f = m.prefetch.hw_useless_fraction();
        match w.category() {
            Category::MatrixBased => regular.push(f),
            _ => irregular.push(f),
        }
        t.row(vec![
            w.name().into(),
            w.category().to_string(),
            format!("{}", m.prefetch.hw_issued),
            r3(f),
        ]);
    }
    t.emit();
    println!(
        "mean useless fraction: matrix {:.3} vs neighbour+tree {:.3} (paper: latter ~0.42)",
        mean(&regular),
        mean(&irregular)
    );
}
