//! Figures 14-18: the software-prefetching study on the neighbour- and
//! tree-based workloads — L2 miss ratio, DRAM bound, bad-speculation
//! bound, 2+ uops/cycle fraction, and speedup, before vs after.
//!
//! Paper shape: L2 miss down 10-35% (except KMeans/SVM), DRAM bound down
//! 5-26%, bad-spec down 8-10% on tree workloads, 2+f uops up ~12.8%,
//! speedup 5.2-27.1% (except SVM-RBF and KMeans).
//!
//! Each workload contributes two independent jobs (baseline, prefetched)
//! to the parallel experiment driver; pairs are re-joined by index.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{pct, r3, Table};
use mlperf::coordinator::{run_jobs, Job, Scenario};

fn main() {
    common::banner("Figs 14-18: software prefetching");
    let cfg = common::config();
    let names = common::prefetch_workloads();
    let jobs: Vec<Job> = names
        .iter()
        .flat_map(|n| {
            [Job::new(*n, Scenario::Baseline), Job::new(*n, Scenario::SwPrefetch)]
        })
        .collect();
    let report = common::timed("prefetch grid", || run_jobs(&cfg, &jobs, 0));

    let mut t = Table::new(
        "fig14_18",
        "software prefetching before/after (neighbour + tree workloads)",
        &[
            "workload", "L2miss pre", "L2miss post", "dram% pre", "dram% post",
            "bspec% pre", "bspec% post", "2+uops pre", "2+uops post", "speedup",
        ],
    );
    let mut speedups = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let base = &report.outputs[2 * i].metrics;
        let pf = &report.outputs[2 * i + 1].metrics;
        let sp = pf.speedup_vs(base);
        speedups.push((name, sp));
        t.row(vec![
            (*name).into(),
            r3(base.l2_miss_ratio),
            r3(pf.l2_miss_ratio),
            pct(base.dram_bound_pct),
            pct(pf.dram_bound_pct),
            pct(base.bad_spec_pct),
            pct(pf.bad_spec_pct),
            r3(base.two_plus_uops_fraction()),
            r3(pf.two_plus_uops_fraction()),
            format!("{:.3}x", sp),
        ]);
    }
    t.emit();
    let wins = speedups.iter().filter(|(_, s)| *s > 1.0).count();
    println!("{wins}/{} workloads sped up (paper: all but SVM-RBF & KMeans, 5.2-27.1%)", speedups.len());
}
