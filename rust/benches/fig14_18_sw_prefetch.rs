//! Figures 14-18: the software-prefetching study on the neighbour- and
//! tree-based workloads — L2 miss ratio, DRAM bound, bad-speculation
//! bound, 2+ uops/cycle fraction, and speedup, before vs after.
//!
//! Paper shape: L2 miss down 10-35% (except KMeans/SVM), DRAM bound down
//! 5-26%, bad-spec down 8-10% on tree workloads, 2+f uops up ~12.8%,
//! speedup 5.2-27.1% (except SVM-RBF and KMeans).

#[path = "common.rs"]
mod common;

use mlperf::analysis::{pct, r3, Table};
use mlperf::coordinator::prefetch_study;
use mlperf::workloads::by_name;

fn main() {
    common::banner("Figs 14-18: software prefetching");
    let cfg = common::config();
    let mut t = Table::new(
        "fig14_18",
        "software prefetching before/after (neighbour + tree workloads)",
        &[
            "workload", "L2miss pre", "L2miss post", "dram% pre", "dram% post",
            "bspec% pre", "bspec% post", "2+uops pre", "2+uops post", "speedup",
        ],
    );
    let mut speedups = Vec::new();
    for name in common::prefetch_workloads() {
        let w = by_name(name).unwrap();
        let s = common::timed(name, || prefetch_study(w.as_ref(), &cfg));
        let sp = s.prefetched.speedup_vs(&s.base);
        speedups.push((name, sp));
        t.row(vec![
            name.into(),
            r3(s.base.l2_miss_ratio),
            r3(s.prefetched.l2_miss_ratio),
            pct(s.base.dram_bound_pct),
            pct(s.prefetched.dram_bound_pct),
            pct(s.base.bad_spec_pct),
            pct(s.prefetched.bad_spec_pct),
            r3(s.base.two_plus_uops_fraction()),
            r3(s.prefetched.two_plus_uops_fraction()),
            format!("{:.3}x", sp),
        ]);
    }
    t.emit();
    let wins = speedups.iter().filter(|(_, s)| *s > 1.0).count();
    println!("{wins}/{} workloads sped up (paper: all but SVM-RBF & KMeans, 5.2-27.1%)", speedups.len());
}
