//! Figures 20-22: row-buffer hit ratio, average access latency and
//! bad-speculation bound for every reordering algorithm on every
//! reorder-study workload.
//!
//! Paper shape: every reordering improves hit ratio (up to 3-4x on
//! DBSCAN/kNN); avg latency falls 4.4-25.1% (GMM can regress); SFC
//! reorderings cut tree-workload bad-spec by 8-12%.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{pct, r2, r3, Table};
use mlperf::coordinator::reorder_study;
use mlperf::reorder::ReorderKind;
use mlperf::workloads::by_name;

fn main() {
    common::banner("Figs 20-22: reordering vs DRAM behaviour");
    let mut cfg = common::config();
    cfg.scale *= 0.5; // 8 workloads x up-to-6 reorderings
    let mut t = Table::new(
        "fig20_22",
        "row-buffer hit ratio / avg latency / bad-spec per reordering",
        &["workload", "method", "hit base", "hit reord", "lat base", "lat reord", "bspec% base", "bspec% reord"],
    );
    for name in common::reorder_workloads() {
        let w = by_name(name).unwrap();
        for kind in ReorderKind::ALL {
            if !kind.applicable_to(w.as_ref()) {
                continue;
            }
            let s = common::timed(&format!("{name}/{kind}"), || {
                reorder_study(w.as_ref(), kind, &cfg)
            });
            t.row(vec![
                name.into(),
                kind.name().into(),
                r3(s.baseline.dram.row_hit_ratio()),
                r3(s.reordered.dram.row_hit_ratio()),
                r2(s.baseline.dram.avg_latency_ns()),
                r2(s.reordered.dram.avg_latency_ns()),
                pct(s.baseline.bad_spec_pct),
                pct(s.reordered.bad_spec_pct),
            ]);
        }
    }
    t.emit();
}
