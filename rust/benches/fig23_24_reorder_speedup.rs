//! Figures 23 & 24 (+ Table IX): end-to-end speedup of every reordering
//! algorithm, without (Fig. 23) and with (Fig. 24) the reordering
//! overhead, plus the qualitative overhead/gain summary.
//!
//! Paper shape: 4-60% speedups ignoring overhead; up to ~35% including
//! it, with Hilbert on Adaboost/DBSCAN turning into slowdowns;
//! computation reordering wins on neighbour workloads, data-layout
//! reordering on tree workloads.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{r3, Table};
use mlperf::coordinator::reorder_study;
use mlperf::reorder::ReorderKind;
use mlperf::workloads::{by_name, Category};

fn main() {
    common::banner("Figs 23-24: reordering speedups");
    let mut cfg = common::config();
    cfg.scale *= 0.5;
    let mut t = Table::new(
        "fig23_24",
        "speedup without (Fig 23) and with (Fig 24) reorder overhead",
        &["workload", "method", "speedup no-ovh", "speedup with-ovh", "overhead Mcycles"],
    );
    let mut best: std::collections::BTreeMap<&str, (String, f64)> = Default::default();
    for name in common::reorder_workloads() {
        let w = by_name(name).unwrap();
        for kind in ReorderKind::ALL {
            if !kind.applicable_to(w.as_ref()) {
                continue;
            }
            let s = common::timed(&format!("{name}/{kind}"), || {
                reorder_study(w.as_ref(), kind, &cfg)
            });
            let no = s.speedup_no_overhead();
            let with = s.speedup_with_overhead();
            t.row(vec![
                name.into(),
                kind.name().into(),
                r3(no),
                r3(with),
                format!("{:.1}", s.overhead_cycles / 1e6),
            ]);
            let e = best.entry(name).or_insert((kind.name().into(), with));
            if with > e.1 {
                *e = (kind.name().into(), with);
            }
        }
    }
    t.emit();

    // Table IX-style qualitative summary
    let mut t9 = Table::new("tab09", "best method per workload (with overhead)", &[
        "workload", "category", "best method", "speedup",
    ]);
    for name in common::reorder_workloads() {
        let w = by_name(name).unwrap();
        let cat = match w.category() {
            Category::NeighbourBased => "neighbour",
            Category::TreeBased => "tree",
            Category::MatrixBased => "matrix",
        };
        if let Some((m, s)) = best.get(name) {
            t9.row(vec![name.into(), cat.into(), m.clone(), r3(*s)]);
        }
    }
    t9.emit();
}
