//! Record-once/replay-many grid benchmark, in four acts:
//!
//! 1. **direct vs replay** — the same 4-scenario × 4-workload grid run
//!    with per-cell re-execution and in record-once/replay-many mode,
//!    with a parity checksum proving replay is bit-identical.
//! 2. **grouped vs fan-out scheduling** — a few-workload × many-scenario
//!    grid (the shape that convoys: one worker per capture group) run
//!    under the pre-fan-out scheduler (`run_jobs_replayed_grouped`,
//!    "synchronous") and the intra-capture fan-out scheduler
//!    (`run_jobs_replayed`, "pipelined"), same checksum discipline.
//! 3. **file-ingest throughput** — each workload's `.mlt` trace replayed
//!    through `PipelineSim` with synchronous ingest (`--ingest-threads
//!    1`) and staged/overlapped ingest (auto threads), asserting metric
//!    parity and reporting events/sec.
//! 4. **cache-geometry sweep** — the full default sweep (40 geometries)
//!    priced once per geometry by full hierarchy replay versus a single
//!    reuse-distance `StackProfiler` pass over the same capture.
//!
//! ```bash
//! cargo bench --bench grid_replay                       # tables only
//! cargo bench --bench grid_replay -- --json             # + BENCH_*.json
//! cargo bench --bench grid_replay -- --json --assert-speedup 1.3 \
//!     --assert-sweep-speedup 5
//! ```
//!
//! `--json` writes `BENCH_replay_ingest.json` and `BENCH_cache_sweep.json`
//! at the repository root (override with `--json-out` / `--sweep-json-out`);
//! CI uploads both as artifacts and gates on `--assert-speedup` (fan-out
//! grid must beat the grouped grid by the given factor) and
//! `--assert-sweep-speedup` (single-pass sweep must beat per-geometry
//! replay by the given factor). `--assert-telemetry-overhead <pct>` adds
//! the telemetry spine's inertness gate: two telemetry-off grid batches
//! must agree within `pct` percent of wall (the off path *is* the only
//! cost an untelemetered run can pay), and an armed run must keep the
//! parity checksum bit-identical.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{r2, Table};
use mlperf::coordinator::{
    replay_characterize, replay_file, run_jobs, run_jobs_replayed, run_jobs_replayed_grouped,
    DriverReport, ExperimentConfig, Job, Scenario,
};
use mlperf::sim::{default_sweep, StackProfiler};
use mlperf::util::json::Json;
use mlperf::workloads::by_name;
use std::time::Instant;

fn checksum(report: &DriverReport) -> u64 {
    // a bench grid must run clean — a quarantined cell would silently
    // shrink the checksum domain and fake a parity pass
    assert!(
        report.failed.is_empty(),
        "bench grid quarantined {} cell(s): {:?}",
        report.failed.len(),
        report.failed
    );
    // integer event/instruction counts fold into a stable parity witness
    report
        .outputs
        .iter()
        .fold(0u64, |h, o| h.wrapping_mul(31).wrapping_add(o.metrics.instructions))
}

/// Act 1: direct re-execution vs record-once/replay-many, with parity.
fn direct_vs_replay(cfg: &ExperimentConfig) {
    let scenarios = [
        Scenario::Baseline,
        Scenario::PerfectL2,
        Scenario::PerfectLlc,
        Scenario::DramIdealRows,
    ];
    let workloads = ["KMeans", "KNN", "DBSCAN", "Decision Tree"];
    let jobs: Vec<Job> = workloads
        .iter()
        .flat_map(|w| scenarios.iter().map(move |s| Job::new(*w, *s)))
        .collect();

    let direct = common::timed("direct grid", || run_jobs(cfg, &jobs, 0));
    let replayed = common::timed("replay grid", || run_jobs_replayed(cfg, &jobs, 0));

    assert_eq!(
        checksum(&direct),
        checksum(&replayed),
        "replay mode diverged from direct execution"
    );

    let mut t = Table::new(
        "grid_replay",
        &format!(
            "{} cells ({} workloads x {} scenarios), parity checksum {:#x}",
            jobs.len(),
            workloads.len(),
            scenarios.len(),
            checksum(&direct)
        ),
        &["mode", "workload executions", "wall (s)", "speedup"],
    );
    t.row(vec![
        "direct".into(),
        format!("{}", direct.workload_executions),
        format!("{:.2}", direct.wall_seconds),
        "1.00".into(),
    ]);
    t.row(vec![
        "replay".into(),
        format!("{}", replayed.workload_executions),
        format!("{:.2}", replayed.wall_seconds),
        r2(direct.wall_seconds / replayed.wall_seconds.max(1e-9)),
    ]);
    t.emit();
}

struct GridResult {
    workloads: usize,
    cells: usize,
    events: u64,
    grouped_wall: f64,
    fanout_wall: f64,
}

impl GridResult {
    fn speedup(&self) -> f64 {
        self.grouped_wall / self.fanout_wall.max(1e-9)
    }
}

/// Act 2: the convoy-shaped grid (few workloads × many scenario
/// columns) under grouped ("synchronous") vs fan-out ("pipelined")
/// scheduling. One workload is the purest convoy — the grouped
/// scheduler pins the capture *and all five* scenario replays on a
/// single thread while every other core idles, so on an N-core machine
/// fan-out approaches (capture + 5·replay) / (capture + ⌈5/N⌉·replay)
/// and the 1.3× gate has margin even when capture costs several
/// replays. Events counted once per workload so throughput is
/// comparable across modes.
fn grouped_vs_fanout(cfg: &ExperimentConfig) -> GridResult {
    let workloads = ["KMeans"];
    let scenarios = [
        Scenario::Baseline,
        Scenario::PerfectL2,
        Scenario::PerfectLlc,
        Scenario::NoHwPrefetch,
        Scenario::DramIdealRows,
    ];
    let jobs: Vec<Job> = workloads
        .iter()
        .flat_map(|w| scenarios.iter().map(move |s| Job::new(*w, *s)))
        .collect();

    // events per workload (counted outside the timed region)
    let events: u64 = workloads
        .iter()
        .map(|name| {
            let w = by_name(name).unwrap();
            mlperf::coordinator::capture_trace(w.as_ref(), cfg, false).trace.events()
                * scenarios.len() as u64
        })
        .sum();

    // best-of-2 per scheduler: a single wall sample on a shared/noisy
    // machine could sink the CI gate on an unchanged tree; every run's
    // checksum must agree (parity is per-run, not best-effort)
    let time2 = |label: &str, run: &dyn Fn() -> DriverReport| {
        let a = run();
        let b = run();
        assert_eq!(checksum(&a), checksum(&b), "{label}: nondeterministic grid");
        let wall = a.wall_seconds.min(b.wall_seconds);
        println!("[{label}: {:.2}s best-of-2]", wall);
        (b, wall)
    };
    let (grouped, grouped_wall) =
        time2("grouped replay grid (synchronous)", &|| {
            run_jobs_replayed_grouped(cfg, &jobs, 0)
        });
    let (fanout, fanout_wall) =
        time2("fan-out replay grid (pipelined)", &|| run_jobs_replayed(cfg, &jobs, 0));
    assert_eq!(
        checksum(&grouped),
        checksum(&fanout),
        "fan-out scheduling diverged from grouped scheduling"
    );
    assert_eq!(grouped.workload_executions, fanout.workload_executions);

    let r = GridResult {
        workloads: workloads.len(),
        cells: jobs.len(),
        events,
        grouped_wall,
        fanout_wall,
    };
    let mut t = Table::new(
        "grid_fanout",
        &format!(
            "{} cells ({} workloads x {} scenario columns), {} replayed events",
            r.cells,
            r.workloads,
            scenarios.len(),
            r.events
        ),
        &["scheduling", "wall (s)", "M events/s", "speedup"],
    );
    t.row(vec![
        "grouped (convoy)".into(),
        format!("{:.2}", r.grouped_wall),
        format!("{:.1}", r.events as f64 / r.grouped_wall.max(1e-9) / 1e6),
        "1.00".into(),
    ]);
    t.row(vec![
        "fan-out".into(),
        format!("{:.2}", r.fanout_wall),
        format!("{:.1}", r.events as f64 / r.fanout_wall.max(1e-9) / 1e6),
        r2(r.speedup()),
    ]);
    t.emit();
    r
}

struct IngestRow {
    name: &'static str,
    events: u64,
    sync_eps: f64,
    pipelined_eps: f64,
}

/// Best-of-2 wall seconds of `f`.
fn best_wall(mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Act 3: per-workload file-ingest throughput, synchronous vs staged.
fn ingest_rows(cfg: &ExperimentConfig) -> Vec<IngestRow> {
    let dir = std::env::temp_dir().join("mlperf-bench-ingest");
    std::fs::create_dir_all(&dir).expect("create bench temp dir");
    let sync_cfg = ExperimentConfig { ingest_threads: 1, ..cfg.clone() };
    let pipe_cfg = ExperimentConfig { ingest_threads: 0, ..cfg.clone() };

    let mut rows = Vec::new();
    for name in ["KMeans", "KNN", "DBSCAN"] {
        let w = by_name(name).unwrap();
        let path = dir.join(format!("{}.mlt", name.to_lowercase()));
        let recorded = mlperf::coordinator::capture_trace(w.as_ref(), cfg, false);
        recorded.trace.write_to(&path, &recorded.meta).expect("write bench trace");

        // parity is asserted on the first timed sample of each mode —
        // no dedicated (untimed) replay pair needed
        let mut sync_out = None;
        let sync_wall = best_wall(|| {
            let (_, m, stats) = replay_file(&path, &sync_cfg, |_| {}).unwrap();
            sync_out.get_or_insert((m, stats));
        });
        let (sync_metrics, stats) = sync_out.expect("best_wall runs at least once");
        let mut pipe_out = None;
        let pipe_wall = best_wall(|| {
            let (_, m, _) = replay_file(&path, &pipe_cfg, |_| {}).unwrap();
            pipe_out.get_or_insert(m);
        });
        assert_eq!(
            sync_metrics,
            pipe_out.expect("best_wall runs at least once"),
            "{name}: pipelined ingest diverged from synchronous"
        );

        let events = stats.events;
        rows.push(IngestRow {
            name,
            events,
            sync_eps: events as f64 / sync_wall.max(1e-9),
            pipelined_eps: events as f64 / pipe_wall.max(1e-9),
        });
    }

    let mut t = Table::new(
        "replay_ingest",
        "file-trace ingest into PipelineSim: synchronous vs staged I/O/decode overlap",
        &["workload", "events", "sync M events/s", "pipelined M events/s", "speedup"],
    );
    for r in &rows {
        t.row(vec![
            r.name.into(),
            format!("{}", r.events),
            format!("{:.1}", r.sync_eps / 1e6),
            format!("{:.1}", r.pipelined_eps / 1e6),
            r2(r.pipelined_eps / r.sync_eps.max(1e-9)),
        ]);
    }
    t.emit();
    rows
}

struct SweepResult {
    workload: &'static str,
    geometries: usize,
    accesses: u64,
    per_cell_wall: f64,
    sweep_wall: f64,
}

impl SweepResult {
    fn speedup(&self) -> f64 {
        self.per_cell_wall / self.sweep_wall.max(1e-9)
    }
}

/// Act 4: one trace pass, every cache geometry. The baseline prices
/// what the sweep replaces — a full hierarchy replay per LLC geometry
/// (the only way to get a miss curve without the profiler); the sweep
/// side derives every geometry's exact-LRU misses from one
/// reuse-distance pass over the same capture. The two models answer
/// different questions (filtered hierarchy vs standalone exact LRU), so
/// no cross-checksum here; bit-exactness of the stack-derived counts
/// against a simulated cache is gated in `tests/stack_parity.rs`.
fn cache_sweep(cfg: &ExperimentConfig) -> SweepResult {
    let workload = "KMeans";
    let geometries = default_sweep();
    // the swept geometry IS the experiment — auto_shrink would resize
    // the LLC underneath it
    let cell_cfg = ExperimentConfig { auto_shrink: false, ..cfg.clone() };
    let w = by_name(workload).unwrap();
    let rec = common::timed("sweep capture", || {
        mlperf::coordinator::capture_trace(w.as_ref(), &cell_cfg, false)
    });

    // per-cell baseline: one replay per geometry, single sample (the
    // replays dominate this act's runtime); fold a witness so the work
    // cannot be optimized away
    let t0 = Instant::now();
    let mut cell_witness = 0u64;
    for g in &geometries {
        let m = replay_characterize(&rec, &cell_cfg, |c| {
            c.cache.l3_bytes = g.bytes;
            c.cache.l3_ways = g.ways;
        });
        cell_witness = cell_witness.wrapping_mul(31).wrapping_add(m.instructions);
    }
    let per_cell_wall = t0.elapsed().as_secs_f64();

    // single-pass sweep: best-of-2, both runs must agree bit-exactly
    let sweep_once = || {
        let mut prof = StackProfiler::new(&geometries);
        rec.trace.replay_into(&mut prof);
        let check = prof
            .curves()
            .iter()
            .fold(0u64, |h, c| h.wrapping_mul(31).wrapping_add(c.misses));
        (prof.accesses(), check)
    };
    let ta = Instant::now();
    let (accesses, check_a) = sweep_once();
    let wall_a = ta.elapsed().as_secs_f64();
    let tb = Instant::now();
    let (_, check_b) = sweep_once();
    let sweep_wall = wall_a.min(tb.elapsed().as_secs_f64());
    assert_eq!(check_a, check_b, "nondeterministic sweep pass");
    assert!(accesses > 0, "trivial demand stream");

    let r = SweepResult {
        workload,
        geometries: geometries.len(),
        accesses,
        per_cell_wall,
        sweep_wall,
    };
    let mut t = Table::new(
        "cache_sweep",
        &format!(
            "{} on {} geometries x {} demand accesses; replay witness {:#x}, \
             sweep checksum {:#x}",
            r.workload, r.geometries, r.accesses, cell_witness, check_a
        ),
        &["mode", "geometries priced", "wall (s)", "speedup"],
    );
    t.row(vec![
        "per-cell replay".into(),
        format!("{}", r.geometries),
        format!("{:.2}", r.per_cell_wall),
        "1.00".into(),
    ]);
    t.row(vec![
        "single-pass sweep".into(),
        format!("{}", r.geometries),
        format!("{:.2}", r.sweep_wall),
        r2(r.speedup()),
    ]);
    t.emit();
    r
}

fn write_json(path: &str, cfg: &ExperimentConfig, grid: &GridResult, rows: &[IngestRow]) {
    // built on util/json.rs (the ledger's serializer) — deterministic
    // field order, correct escaping, no hand-rolled braces
    let field = |k: &str, v: Json| (k.to_string(), v);
    let doc = Json::Obj(vec![
        field("bench", Json::Str("replay_ingest".into())),
        field("provenance", mlperf::obs::provenance_json()),
        field("scale", Json::num(cfg.scale)),
        field(
            "ingest_threads_auto",
            Json::num(mlperf::trace::resolve_ingest_threads(0) as f64),
        ),
        field(
            "workloads",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::Obj(vec![
                            field("name", Json::Str(r.name.into())),
                            field("events", Json::num(r.events as f64)),
                            field("synchronous_eps", Json::num(r.sync_eps)),
                            field("pipelined_eps", Json::num(r.pipelined_eps)),
                        ])
                    })
                    .collect(),
            ),
        ),
        field(
            "grid",
            Json::Obj(vec![
                field("workloads", Json::num(grid.workloads as f64)),
                field("cells", Json::num(grid.cells as f64)),
                field("events", Json::num(grid.events as f64)),
                field("synchronous_wall_s", Json::num(grid.grouped_wall)),
                field("pipelined_wall_s", Json::num(grid.fanout_wall)),
                field(
                    "synchronous_eps",
                    Json::num(grid.events as f64 / grid.grouped_wall.max(1e-9)),
                ),
                field(
                    "pipelined_eps",
                    Json::num(grid.events as f64 / grid.fanout_wall.max(1e-9)),
                ),
                field("speedup", Json::num(grid.speedup())),
            ]),
        ),
    ]);
    std::fs::write(path, doc.render())
        .unwrap_or_else(|e| panic!("cannot write {path}: {e}"));
    println!("\nwrote {path}");
}

fn write_sweep_json(path: &str, cfg: &ExperimentConfig, sweep: &SweepResult) {
    let field = |k: &str, v: Json| (k.to_string(), v);
    let doc = Json::Obj(vec![
        field("bench", Json::Str("cache_sweep".into())),
        field("provenance", mlperf::obs::provenance_json()),
        field("scale", Json::num(cfg.scale)),
        field("workload", Json::Str(sweep.workload.into())),
        field("geometries", Json::num(sweep.geometries as f64)),
        field("demand_accesses", Json::num(sweep.accesses as f64)),
        field("per_cell_wall_s", Json::num(sweep.per_cell_wall)),
        field("sweep_wall_s", Json::num(sweep.sweep_wall)),
        field("speedup", Json::num(sweep.speedup())),
    ]);
    std::fs::write(path, doc.render())
        .unwrap_or_else(|e| panic!("cannot write {path}: {e}"));
    println!("wrote {path}");
}

/// `--assert-telemetry-overhead <pct>`: prove the telemetry spine's
/// off path is harness noise, not a tax. A single binary cannot diff
/// itself against a telemetry-free build, but it can prove the two
/// things that matter:
///
/// 1. **Off-mode wall reproducibility.** Two best-of-2 batches of the
///    same fan-out grid — both running the disarmed probes, which is
///    the entire cost an untelemetered user can ever pay — must agree
///    within `pct` percent (differences under 50 ms pass regardless:
///    below timer/scheduler noise on shared runners).
/// 2. **Arming is observational.** A run with the collector installed
///    must reproduce the off-mode parity checksum bit-identically.
///
/// The armed/off wall ratio is reported informationally (the armed
/// path is allowed to cost; the off path is not).
fn telemetry_overhead_gate(cfg: &ExperimentConfig, pct: f64, cores: usize) {
    let scenarios = [Scenario::Baseline, Scenario::PerfectL2, Scenario::PerfectLlc];
    let jobs: Vec<Job> = ["KMeans", "KNN"]
        .iter()
        .flat_map(|w| scenarios.iter().map(move |s| Job::new(*w, *s)))
        .collect();
    let run = || run_jobs_replayed(cfg, &jobs, 0);

    assert!(!mlperf::util::telemetry::armed(), "telemetry unexpectedly armed in bench");
    let best2 = |label: &str| {
        let a = run();
        let b = run();
        assert_eq!(checksum(&a), checksum(&b), "{label}: nondeterministic grid");
        (checksum(&a), a.wall_seconds.min(b.wall_seconds))
    };
    let (check_off, wall_a) = best2("telemetry-off batch A");
    let (_, wall_b) = best2("telemetry-off batch B");
    let drift_s = (wall_a - wall_b).abs();
    let drift_pct = drift_s / wall_a.max(wall_b).max(1e-9) * 100.0;

    // armed run: collector live, but nothing exported (the bench never
    // calls obs::export_all) — results must not move either way
    mlperf::util::telemetry::install(Some(std::env::temp_dir().join("mlperf-bench-telemetry")));
    let armed_report = run();
    mlperf::util::telemetry::install(None);
    assert_eq!(check_off, checksum(&armed_report), "arming telemetry changed grid results");

    println!(
        "telemetry off-mode walls: {wall_a:.3}s / {wall_b:.3}s best-of-2 \
         (drift {drift_pct:.2}%), armed wall {:.3}s ({:.2}x off)",
        armed_report.wall_seconds,
        armed_report.wall_seconds / wall_a.min(wall_b).max(1e-9)
    );
    if cores < 4 {
        println!(
            "telemetry overhead gate skipped on {cores} core(s) \
             (drift {drift_pct:.2}%, cap {pct}%)"
        );
    } else {
        assert!(
            drift_pct <= pct || drift_s <= 0.05,
            "off-mode wall drift {drift_pct:.2}% ({drift_s:.3}s) exceeds the {pct}% cap"
        );
        println!("telemetry overhead gate passed: {drift_pct:.2}% <= {pct}% (or < 50 ms)");
    }
}

fn main() {
    common::banner("grid replay: record-once/replay-many, scheduling, ingest, and sweeps");
    let cfg = common::config();
    let args = common::args();

    direct_vs_replay(&cfg);
    let grid = grouped_vs_fanout(&cfg);
    let rows = ingest_rows(&cfg);
    let sweep = cache_sweep(&cfg);

    println!(
        "\nmulti-scenario grid speedup (fan-out / grouped): {:.2}x",
        grid.speedup()
    );
    println!(
        "cache-sweep speedup (single pass / per-cell replay): {:.2}x over {} geometries",
        sweep.speedup(),
        sweep.geometries
    );

    if args.has("json") {
        let default_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_replay_ingest.json");
        let path = args.get_or("json-out", default_path);
        write_json(&path, &cfg, &grid, &rows);
        let sweep_default = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_cache_sweep.json");
        let sweep_path = args.get_or("sweep-json-out", sweep_default);
        write_sweep_json(&sweep_path, &cfg, &sweep);
    }

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if let Some(min) = args.get("assert-speedup") {
        let min: f64 = min.parse().expect("--assert-speedup expects a number");
        // The convoy only exists when workers outnumber capture groups:
        // on <= 2 cores the grouped scheduler already keeps every core
        // busy (2 groups), so the gate is only meaningful with >= 4
        // cores (CI's ubuntu-latest runners have 4).
        if cores < 4 {
            println!(
                "speedup gate skipped: {cores} core(s) cannot expose the convoy \
                 (measured {:.2}x, floor {min}x)",
                grid.speedup()
            );
        } else {
            assert!(
                grid.speedup() >= min,
                "fan-out replay grid speedup {:.2}x is below the acceptance floor {min}x",
                grid.speedup()
            );
            println!("speedup gate passed: {:.2}x >= {min}x", grid.speedup());
        }
    }

    if let Some(min) = args.get("assert-sweep-speedup") {
        let min: f64 = min.parse().expect("--assert-sweep-speedup expects a number");
        // Both sides of the sweep act are serial, but runners below 4
        // cores are the small shared boxes whose wall clocks are too
        // noisy to gate on; hard-assert only where CI actually runs.
        if cores < 4 {
            println!(
                "sweep speedup gate skipped on {cores} core(s) \
                 (measured {:.2}x, floor {min}x)",
                sweep.speedup()
            );
        } else {
            assert!(
                sweep.speedup() >= min,
                "single-pass sweep speedup {:.2}x is below the acceptance floor {min}x",
                sweep.speedup()
            );
            println!("sweep speedup gate passed: {:.2}x >= {min}x", sweep.speedup());
        }
    }

    if let Some(pct) = args.get("assert-telemetry-overhead") {
        let pct: f64 =
            pct.parse().expect("--assert-telemetry-overhead expects a percentage");
        telemetry_overhead_gate(&cfg, pct, cores);
    }
}
