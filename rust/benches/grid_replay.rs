//! Record-once/replay-many grid benchmark: the same 4-scenario ×
//! N-workload grid run in direct mode (every cell re-executes its
//! workload) and in replay mode (one capture per workload, replays for
//! every cell), printing wall clocks, workload-execution counts, a
//! parity checksum, and the speedup.
//!
//! Replay mode must be bit-identical — the checksum proves it on every
//! run — so the speedup is pure win: scenario count stops multiplying
//! workload execution time, which is what lets the grid grow toward the
//! paper's full 14-workload × many-configuration sweeps.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{r2, Table};
use mlperf::coordinator::{run_jobs, run_jobs_replayed, DriverReport, Job, Scenario};

fn checksum(report: &DriverReport) -> u64 {
    // integer event/instruction counts fold into a stable parity witness
    report
        .outputs
        .iter()
        .fold(0u64, |h, o| h.wrapping_mul(31).wrapping_add(o.metrics.instructions))
}

fn main() {
    common::banner("grid replay: record-once/replay-many vs direct re-execution");
    let cfg = common::config();

    let scenarios = [
        Scenario::Baseline,
        Scenario::PerfectL2,
        Scenario::PerfectLlc,
        Scenario::DramIdealRows,
    ];
    let workloads = ["KMeans", "KNN", "DBSCAN", "Decision Tree"];
    let jobs: Vec<Job> = workloads
        .iter()
        .flat_map(|w| scenarios.iter().map(move |s| Job::new(*w, *s)))
        .collect();

    let direct = common::timed("direct grid", || run_jobs(&cfg, &jobs, 0));
    let replayed = common::timed("replay grid", || run_jobs_replayed(&cfg, &jobs, 0));

    assert_eq!(
        checksum(&direct),
        checksum(&replayed),
        "replay mode diverged from direct execution"
    );

    let mut t = Table::new(
        "grid_replay",
        &format!(
            "{} cells ({} workloads x {} scenarios), parity checksum {:#x}",
            jobs.len(),
            workloads.len(),
            scenarios.len(),
            checksum(&direct)
        ),
        &["mode", "workload executions", "wall (s)", "speedup"],
    );
    t.row(vec![
        "direct".into(),
        format!("{}", direct.workload_executions),
        format!("{:.2}", direct.wall_seconds),
        "1.00".into(),
    ]);
    t.row(vec![
        "replay".into(),
        format!("{}", replayed.workload_executions),
        format!("{:.2}", replayed.wall_seconds),
        r2(direct.wall_seconds / replayed.wall_seconds.max(1e-9)),
    ]);
    t.emit();
}
