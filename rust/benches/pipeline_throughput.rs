//! Micro-benchmark: trace-delivery throughput (events/sec) of the legacy
//! per-event `dyn Sink` path versus the batched columnar block pipeline,
//! for both a cheap counting consumer (isolates delivery overhead — the
//! quantity the refactor targets) and the full pipeline simulator (end to
//! end). Numbers and methodology are recorded in DESIGN.md §Block
//! pipeline.
//!
//! ```bash
//! cargo bench --bench pipeline_throughput            # default 2M elements
//! PIPELINE_BENCH_ELEMS=500000 cargo bench --bench pipeline_throughput
//! ```

use mlperf::sim::{CpuConfig, PipelineSim};
use mlperf::trace::{BlockSink, Event, InstructionMix, Recorder, Sink};
use mlperf::util::Pcg64;
use std::hint::black_box;
use std::time::Instant;

const NS: u32 = 1;

/// Pre-generated logical stream: each element expands to three events
/// (load, compute, branch) — the shape of a neighbour-workload inner loop.
struct Stream {
    addrs: Vec<u64>,
    outcomes: Vec<bool>,
}

fn make_stream(n: usize) -> Stream {
    let mut rng = Pcg64::new(0xB10C);
    Stream {
        // 1-in-4 random far accesses amid sequential walking, as in the
        // paper's index-array access patterns
        addrs: (0..n)
            .map(|i| {
                if i % 4 == 0 {
                    rng.below(1 << 28) & !7
                } else {
                    (i as u64 * 8) % (1 << 22)
                }
            })
            .collect(),
        outcomes: (0..n).map(|_| rng.next_f64() < 0.3).collect(),
    }
}

/// Seed path: one virtual call + enum match per event.
fn drive_dyn(sink: &mut dyn Sink, s: &Stream) -> u64 {
    for i in 0..s.addrs.len() {
        sink.event(Event::Load { addr: s.addrs[i], size: 8, feeds_branch: false });
        sink.event(Event::Compute { int_ops: 1, fp_ops: 2 });
        sink.event(Event::Branch { site: NS << 16 | 1, taken: s.outcomes[i], conditional: true });
    }
    sink.finish();
    3 * s.addrs.len() as u64
}

/// Block path: lane appends in the recorder, one block delivery per 4K
/// events. Generic so the same code measures the erased and the
/// monomorphized pipeline.
fn drive_block<S: BlockSink + ?Sized>(rec: &mut Recorder<S>, s: &Stream) -> u64 {
    for i in 0..s.addrs.len() {
        rec.load(s.addrs[i], 8);
        rec.compute(1, 2);
        rec.branch(1, s.outcomes[i]);
    }
    rec.finish();
    rec.events_emitted()
}

/// Best-of-`reps` events/sec for one mode; `f` returns (events, checksum).
fn measure(label: &str, reps: usize, mut f: impl FnMut() -> (u64, u64)) -> f64 {
    let mut best_per_event = f64::INFINITY;
    let mut check = 0;
    for _ in 0..reps {
        let t0 = Instant::now();
        let (events, chk) = f();
        let dt = t0.elapsed().as_secs_f64();
        best_per_event = best_per_event.min(dt / events as f64);
        check = chk;
    }
    let eps = 1.0 / best_per_event;
    println!("{label:>34}: {:>8.1} M events/s   (checksum {check})", eps / 1e6);
    eps
}

fn main() {
    let n: usize = std::env::var("PIPELINE_BENCH_ELEMS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2_000_000);
    let s = make_stream(n);
    println!("# pipeline_throughput | {} elements -> {} events per mode", n, 3 * n);

    // --- delivery-layer isolation: counting consumer ---
    let dyn_mix = measure("dyn Sink -> InstructionMix", 3, || {
        let mut mix = InstructionMix::default();
        let events = drive_dyn(black_box(&mut mix), &s);
        (events, mix.instructions())
    });
    let block_dyn_mix = measure("blocks (dyn) -> InstructionMix", 3, || {
        let mut mix = InstructionMix::default();
        let events = {
            let mut rec = Recorder::new(&mut mix, NS);
            drive_block(black_box(&mut rec), &s)
        };
        (events, mix.instructions())
    });
    let block_typed_mix = measure("blocks (typed) -> InstructionMix", 3, || {
        let mut mix = InstructionMix::default();
        let events = {
            let mut rec = Recorder::typed(&mut mix, NS);
            drive_block(black_box(&mut rec), &s)
        };
        (events, mix.instructions())
    });

    // --- end to end: full pipeline simulator ---
    let dyn_sim = measure("dyn Sink -> PipelineSim", 2, || {
        let mut sim = PipelineSim::new(CpuConfig::default());
        let events = drive_dyn(black_box(&mut sim), &s);
        (events, sim.metrics().instructions)
    });
    let block_sim = measure("blocks (dyn) -> PipelineSim", 2, || {
        let mut sim = PipelineSim::new(CpuConfig::default());
        let events = {
            let mut rec = Recorder::new(&mut sim, NS);
            drive_block(black_box(&mut rec), &s)
        };
        (events, sim.metrics().instructions)
    });

    println!();
    println!("delivery speedup (blocks dyn   / per-event dyn): {:.2}x", block_dyn_mix / dyn_mix);
    println!("delivery speedup (blocks typed / per-event dyn): {:.2}x", block_typed_mix / dyn_mix);
    println!("end-to-end sim speedup (blocks / per-event dyn): {:.2}x", block_sim / dyn_sim);
}
