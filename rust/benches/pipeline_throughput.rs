//! Micro-benchmark: simulation throughput (events/sec) across the
//! delivery *and* consumption layers:
//!
//! - legacy per-event `dyn Sink` vs batched columnar blocks (delivery,
//!   PR 1);
//! - seed-layout reference hierarchy ([`RefPipelineSim`]) vs the packed
//!   hot-path hierarchy (consumption, PR 3) — the two run the identical
//!   timeline, so the ratio isolates the packed-set/MRU-filter/block-lane
//!   rework;
//! - per-workload direct execution vs trace replay (record-once/
//!   replay-many, PR 2).
//!
//! Numbers and methodology are recorded in DESIGN.md §Simulator hot path.
//!
//! ```bash
//! cargo bench --bench pipeline_throughput             # default 2M elements
//! PIPELINE_BENCH_ELEMS=500000 cargo bench --bench pipeline_throughput
//! cargo bench --bench pipeline_throughput -- --json   # + BENCH_sim_throughput.json
//! ```
//!
//! `--json` writes `BENCH_sim_throughput.json` at the repository root
//! (override with `--json-out <path>`); CI uploads it as an artifact so
//! the events/sec trajectory is tracked per commit.

use mlperf::coordinator::{capture_trace, characterize_with, replay_characterize, ExperimentConfig};
use mlperf::sim::{CpuConfig, PipelineSim, RefPipelineSim};
use mlperf::trace::{BlockSink, Event, InstructionMix, Recorder, Sink};
use mlperf::util::{Args, Pcg64};
use mlperf::workloads::by_name;
use std::hint::black_box;
use std::time::Instant;

const NS: u32 = 1;

/// Pre-generated logical stream: each element expands to three events
/// (load, compute, branch) — the shape of a neighbour-workload inner loop.
struct Stream {
    addrs: Vec<u64>,
    outcomes: Vec<bool>,
}

fn make_stream(n: usize) -> Stream {
    let mut rng = Pcg64::new(0xB10C);
    Stream {
        // 1-in-4 random far accesses amid sequential walking, as in the
        // paper's index-array access patterns
        addrs: (0..n)
            .map(|i| {
                if i % 4 == 0 {
                    rng.below(1 << 28) & !7
                } else {
                    (i as u64 * 8) % (1 << 22)
                }
            })
            .collect(),
        outcomes: (0..n).map(|_| rng.next_f64() < 0.3).collect(),
    }
}

/// Seed path: one virtual call + enum match per event.
fn drive_dyn(sink: &mut dyn Sink, s: &Stream) -> u64 {
    for i in 0..s.addrs.len() {
        sink.event(Event::Load { addr: s.addrs[i], size: 8, feeds_branch: false });
        sink.event(Event::Compute { int_ops: 1, fp_ops: 2 });
        sink.event(Event::Branch { site: NS << 16 | 1, taken: s.outcomes[i], conditional: true });
    }
    sink.finish();
    3 * s.addrs.len() as u64
}

/// Block path: lane appends in the recorder, one block delivery per 4K
/// events. Generic so the same code measures the erased and the
/// monomorphized pipeline.
fn drive_block<S: BlockSink + ?Sized>(rec: &mut Recorder<S>, s: &Stream) -> u64 {
    for i in 0..s.addrs.len() {
        rec.load(s.addrs[i], 8);
        rec.compute(1, 2);
        rec.branch(1, s.outcomes[i]);
    }
    rec.finish();
    rec.events_emitted()
}

/// Best-of-`reps` events/sec for one mode; `f` returns (events, checksum).
fn measure(label: &str, reps: usize, mut f: impl FnMut() -> (u64, u64)) -> f64 {
    let mut best_per_event = f64::INFINITY;
    let mut check = 0;
    for _ in 0..reps {
        let t0 = Instant::now();
        let (events, chk) = f();
        let dt = t0.elapsed().as_secs_f64();
        best_per_event = best_per_event.min(dt / events as f64);
        check = chk;
    }
    let eps = 1.0 / best_per_event;
    println!("{label:>34}: {:>8.1} M events/s   (checksum {check})", eps / 1e6);
    eps
}

/// One workload's direct-vs-replay throughput row.
struct WorkloadRow {
    name: &'static str,
    events: u64,
    direct_eps: f64,
    replay_eps: f64,
}

/// Best-of-2 events/sec of `f` over a fixed event count.
fn best_eps(events: u64, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    events as f64 / best
}

/// Direct execution (workload + simulation) vs replay (simulation only)
/// of the same captured trace — both reported as simulated events/sec.
fn measure_workloads(cfg: &ExperimentConfig) -> Vec<WorkloadRow> {
    let mut rows = Vec::new();
    for name in ["KMeans", "KNN", "Ridge"] {
        let w = by_name(name).unwrap();
        let recorded = capture_trace(w.as_ref(), cfg, false);
        let events = recorded.trace.events();
        // dataset generated once outside the timed region: neither mode
        // under comparison includes synthesis time
        let ds = w.make_dataset(cfg.rows_for(w.as_ref()), cfg.features, cfg.seed);
        let direct_eps = best_eps(events, || {
            let c = characterize_with(w.as_ref(), cfg, false, None, Some(&ds), |_| {});
            black_box(c.metrics.instructions);
        });
        let replay_eps = best_eps(events, || {
            black_box(replay_characterize(&recorded, cfg, |_| {}).instructions);
        });
        println!(
            "{name:>34}: {:>8.1} M events/s direct, {:>8.1} M events/s replay ({events} events)",
            direct_eps / 1e6,
            replay_eps / 1e6
        );
        rows.push(WorkloadRow { name, events, direct_eps, replay_eps });
    }
    rows
}

fn write_json(path: &str, elems: usize, modes: &[(&str, f64)], rows: &[WorkloadRow]) {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"sim_throughput\",\n");
    // host/toolchain provenance so blessed numbers stay attributable
    s.push_str(&format!("  \"provenance\": {},\n", mlperf::obs::provenance_json().render()));
    s.push_str(&format!("  \"elements\": {elems},\n"));
    s.push_str("  \"events_per_sec\": {\n");
    for (i, (k, v)) in modes.iter().enumerate() {
        let sep = if i + 1 < modes.len() { "," } else { "" };
        s.push_str(&format!("    \"{k}\": {v:.1}{sep}\n"));
    }
    s.push_str("  },\n");
    s.push_str("  \"workloads\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"events\": {}, \"direct_eps\": {:.1}, \
             \"replay_eps\": {:.1}}}{sep}\n",
            r.name, r.events, r.direct_eps, r.replay_eps
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s).unwrap_or_else(|e| panic!("cannot write {path}: {e}"));
    println!("\nwrote {path}");
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n: usize = std::env::var("PIPELINE_BENCH_ELEMS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2_000_000);
    let s = make_stream(n);
    println!("# pipeline_throughput | {} elements -> {} events per mode", n, 3 * n);

    // --- delivery-layer isolation: counting consumer ---
    let dyn_mix = measure("dyn Sink -> InstructionMix", 3, || {
        let mut mix = InstructionMix::default();
        let events = drive_dyn(black_box(&mut mix), &s);
        (events, mix.instructions())
    });
    let block_dyn_mix = measure("blocks (dyn) -> InstructionMix", 3, || {
        let mut mix = InstructionMix::default();
        let events = {
            let mut rec = Recorder::new(&mut mix, NS);
            drive_block(black_box(&mut rec), &s)
        };
        (events, mix.instructions())
    });
    let block_typed_mix = measure("blocks (typed) -> InstructionMix", 3, || {
        let mut mix = InstructionMix::default();
        let events = {
            let mut rec = Recorder::typed(&mut mix, NS);
            drive_block(black_box(&mut rec), &s)
        };
        (events, mix.instructions())
    });

    // --- end to end: full pipeline simulator ---
    let dyn_sim = measure("dyn Sink -> PipelineSim", 2, || {
        let mut sim = PipelineSim::new(CpuConfig::default());
        let events = drive_dyn(black_box(&mut sim), &s);
        (events, sim.metrics().instructions)
    });
    let seed_sim = measure("blocks -> PipelineSim (seed cache)", 2, || {
        let mut sim = RefPipelineSim::with_cache_model(CpuConfig::default());
        let events = {
            let mut rec = Recorder::new(&mut sim, NS);
            drive_block(black_box(&mut rec), &s)
        };
        (events, sim.metrics().instructions)
    });
    let block_sim = measure("blocks -> PipelineSim (packed)", 2, || {
        let mut sim = PipelineSim::new(CpuConfig::default());
        let events = {
            let mut rec = Recorder::new(&mut sim, NS);
            drive_block(black_box(&mut rec), &s)
        };
        (events, sim.metrics().instructions)
    });

    // --- real workloads: direct execution vs trace replay ---
    println!();
    let wl_cfg = ExperimentConfig {
        scale: args.get_parsed_or(
            "scale",
            std::env::var("MLPERF_SCALE").ok().and_then(|v| v.parse().ok()).unwrap_or(0.05),
        ),
        iterations: 1,
        ..Default::default()
    };
    let rows = measure_workloads(&wl_cfg);

    println!();
    println!("delivery speedup (blocks dyn   / per-event dyn): {:.2}x", block_dyn_mix / dyn_mix);
    println!("delivery speedup (blocks typed / per-event dyn): {:.2}x", block_typed_mix / dyn_mix);
    println!("end-to-end sim speedup (blocks / per-event dyn): {:.2}x", block_sim / dyn_sim);
    println!("hot-path speedup (packed / seed cache layout)  : {:.2}x", block_sim / seed_sim);

    if args.has("json") {
        let default_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim_throughput.json");
        let path = args.get_or("json-out", default_path);
        let modes = [
            ("dyn_sink_mix", dyn_mix),
            ("blocks_dyn_mix", block_dyn_mix),
            ("blocks_typed_mix", block_typed_mix),
            ("dyn_sink_sim", dyn_sim),
            ("blocks_sim_seed_cache", seed_sim),
            ("blocks_sim_packed", block_sim),
        ];
        write_json(&path, n, &modes, &rows);
    }
}
