//! Sampled-replay benchmark: periodic detailed windows + functional
//! warming (`--sample`) versus full detailed replay, per cell.
//!
//! Each memory-bound workload is captured once in memory, then replayed
//! twice: full `PipelineSim` (ground truth) and `SampledSim` at the
//! given `--sample <detail>:<period>` (default 2:256, 0.78% detail).
//! Correctness is hard-asserted on every run — the CPI truth must fall
//! inside the estimate's own 95% interval and every state-derived
//! metric (miss ratios, branch stats, prefetch stats, mix) must be
//! bit-exact — while the wall-clock ratio is the reported/gated number.
//!
//! ```bash
//! cargo bench --bench sample                        # table only
//! cargo bench --bench sample -- --json              # + BENCH_sample.json
//! cargo bench --bench sample -- --sample 4:512 \
//!     --json --assert-sample-speedup 10
//! ```
//!
//! `--json` writes `BENCH_sample.json` at the repository root (override
//! with `--json-out`); CI uploads it and gates `--assert-sample-speedup`
//! on the *minimum* per-cell speedup (the ISSUE's bar is per cell, not
//! an average that a single fast cell could carry).

#[path = "common.rs"]
mod common;

use mlperf::analysis::{r2, Table};
use mlperf::coordinator::{
    capture_trace, replay_characterize, replay_characterize_sampled, ExperimentConfig,
};
use mlperf::sim::SampleConfig;
use mlperf::util::json::Json;
use mlperf::workloads::by_name;
use std::time::Instant;

/// The paper's memory-bound set: large strided working sets where the
/// detailed timeline (MSHR occupancy, DRAM queueing) dominates replay
/// cost and functional warming has the most to skip. Cache-resident
/// workloads sample too, but their speedup ceiling is the much smaller
/// detailed/warm cost ratio of a hit-dominated stream.
const WORKLOADS: [&str; 3] = ["KMeans", "KNN", "GMM"];

struct CellResult {
    name: &'static str,
    events: u64,
    full_wall: f64,
    sampled_wall: f64,
    cpi_full: f64,
    cpi_est: f64,
    cpi_ci95: f64,
    windows: usize,
    blocks_total: u64,
    blocks_detailed: u64,
}

impl CellResult {
    fn speedup(&self) -> f64 {
        self.full_wall / self.sampled_wall.max(1e-9)
    }
}

/// Best-of-2 wall seconds of `f` (shared-runner noise protection).
fn best_wall(mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn run_cell(name: &'static str, cfg: &ExperimentConfig, sample: SampleConfig) -> CellResult {
    let w = by_name(name).unwrap();
    let rec = common::timed(&format!("{name} capture"), || {
        capture_trace(w.as_ref(), cfg, false)
    });
    let events = rec.trace.events();

    let mut full = None;
    let full_wall = best_wall(|| {
        let m = replay_characterize(&rec, cfg, |_| {});
        full.get_or_insert(m);
    });
    let full = full.expect("best_wall runs at least once");

    let mut rep = None;
    let sampled_wall = best_wall(|| {
        let r = replay_characterize_sampled(&rec, cfg, sample, |_| {});
        rep.get_or_insert(r);
    });
    let rep = rep.expect("best_wall runs at least once");

    // correctness gates run unconditionally — a fast wrong answer is
    // not a benchmark result
    assert!(
        rep.cpi_within_ci(full.cpi),
        "{name}: estimate {} ± {} does not cover true CPI {}",
        rep.estimate.cpi,
        rep.cpi_ci95,
        full.cpi
    );
    assert_eq!(rep.estimate.instructions, full.instructions, "{name}: instructions");
    assert_eq!(rep.estimate.mix, full.mix, "{name}: instruction mix");
    assert_eq!(rep.estimate.branch, full.branch, "{name}: branch stats");
    assert_eq!(rep.estimate.prefetch, full.prefetch, "{name}: prefetch stats");
    assert_eq!(rep.estimate.l1_miss_ratio, full.l1_miss_ratio, "{name}: L1");
    assert_eq!(rep.estimate.l2_miss_ratio, full.l2_miss_ratio, "{name}: L2");
    assert_eq!(rep.estimate.llc_miss_ratio, full.llc_miss_ratio, "{name}: LLC");

    CellResult {
        name,
        events,
        full_wall,
        sampled_wall,
        cpi_full: full.cpi,
        cpi_est: rep.estimate.cpi,
        cpi_ci95: rep.cpi_ci95,
        windows: rep.windows,
        blocks_total: rep.blocks_total,
        blocks_detailed: rep.blocks_detailed,
    }
}

fn write_json(path: &str, cfg: &ExperimentConfig, sample: SampleConfig, cells: &[CellResult]) {
    let field = |k: &str, v: Json| (k.to_string(), v);
    let min_speedup = cells.iter().map(CellResult::speedup).fold(f64::INFINITY, f64::min);
    let doc = Json::Obj(vec![
        field("bench", Json::Str("sample".into())),
        field("provenance", mlperf::obs::provenance_json()),
        field("scale", Json::num(cfg.scale)),
        field("sample", Json::Str(sample.to_string())),
        field("detailed_fraction", Json::num(sample.detailed_fraction())),
        field("min_speedup", Json::num(min_speedup)),
        field(
            "cells",
            Json::Arr(
                cells
                    .iter()
                    .map(|c| {
                        Json::Obj(vec![
                            field("name", Json::Str(c.name.into())),
                            field("events", Json::num(c.events as f64)),
                            field("blocks_total", Json::num(c.blocks_total as f64)),
                            field("blocks_detailed", Json::num(c.blocks_detailed as f64)),
                            field("windows", Json::num(c.windows as f64)),
                            field("full_wall_s", Json::num(c.full_wall)),
                            field("sampled_wall_s", Json::num(c.sampled_wall)),
                            field("speedup", Json::num(c.speedup())),
                            field("cpi_full", Json::num(c.cpi_full)),
                            field("cpi_estimate", Json::num(c.cpi_est)),
                            field("cpi_ci95", Json::num(c.cpi_ci95)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(path, doc.render())
        .unwrap_or_else(|e| panic!("cannot write {path}: {e}"));
    println!("\nwrote {path}");
}

fn main() {
    common::banner("sampled replay: detailed windows + functional warming vs full replay");
    let cfg = common::config();
    let args = common::args();
    let sample = match args.get("sample") {
        Some(spec) => SampleConfig::parse(&spec)
            .unwrap_or_else(|| panic!("--sample expects <detail>:<period>, got {spec:?}")),
        None => SampleConfig::default(),
    };

    let cells: Vec<CellResult> =
        WORKLOADS.iter().map(|name| run_cell(name, &cfg, sample)).collect();

    let mut t = Table::new(
        "sample",
        &format!(
            "sampled replay at {sample} ({:.2}% detail) vs full replay",
            sample.detailed_fraction() * 100.0
        ),
        &[
            "workload",
            "events",
            "windows",
            "full (s)",
            "sampled (s)",
            "speedup",
            "CPI true",
            "CPI est",
            "+-CI95",
        ],
    );
    for c in &cells {
        t.row(vec![
            c.name.into(),
            format!("{}", c.events),
            format!("{}", c.windows),
            format!("{:.2}", c.full_wall),
            format!("{:.2}", c.sampled_wall),
            r2(c.speedup()),
            format!("{:.3}", c.cpi_full),
            format!("{:.3}", c.cpi_est),
            format!("{:.3}", c.cpi_ci95),
        ]);
    }
    t.emit();

    let min_speedup = cells.iter().map(CellResult::speedup).fold(f64::INFINITY, f64::min);
    println!(
        "\nper-cell sampled-replay speedup: min {:.2}x over {} cells at {sample}",
        min_speedup,
        cells.len()
    );

    if args.has("json") {
        let default_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sample.json");
        let path = args.get_or("json-out", default_path);
        write_json(&path, &cfg, sample, &cells);
    }

    if let Some(min) = args.get("assert-sample-speedup") {
        let min: f64 = min.parse().expect("--assert-sample-speedup expects a number");
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        // both sides are serial, but sub-4-core runners are the small
        // shared boxes whose wall clocks are too noisy to gate on —
        // same policy as grid_replay's gates (correctness asserts above
        // already ran regardless)
        if cores < 4 {
            println!(
                "sample speedup gate skipped on {cores} core(s) \
                 (measured min {min_speedup:.2}x, floor {min}x)"
            );
        } else {
            assert!(
                min_speedup >= min,
                "sampled replay min speedup {min_speedup:.2}x is below the \
                 acceptance floor {min}x",
            );
            println!("sample speedup gate passed: min {min_speedup:.2}x >= {min}x");
        }
    }
}
