//! Serve-daemon load generator: queries/sec and latency percentiles
//! against an in-process `mlperf serve` daemon, in three phases:
//!
//! 1. **cold** — every cell queried once, serially: miss latency (each
//!    query pays its simulation).
//! 2. **warm** — concurrent client threads re-query the same cells for
//!    several rounds: hit latency, p50/p99, and queries/sec, with a
//!    zero-re-simulation assertion (the daemon's execution counter must
//!    not move).
//! 3. **overload** — 2× `queue_depth` clients fire cold queries through
//!    one barrier: measures the shed rate, proving saturation degrades
//!    into typed `overloaded` rejections while every admitted query
//!    still completes.
//!
//! ```bash
//! cargo bench --bench serve_load                 # tables only
//! cargo bench --bench serve_load -- --json       # + BENCH_serve.json
//! ```
//!
//! `--json` writes `BENCH_serve.json` at the repository root (override
//! with `--json-out`); CI uploads it as an artifact.

#[path = "common.rs"]
mod common;

use std::sync::{Arc, Barrier};
use std::time::Instant;

use mlperf::analysis::Table;
use mlperf::serve::{Client, ServeOptions, Server};
use mlperf::util::json::Json;

/// Deadline used by every bench query: long enough that only the
/// overload phase (which wants admission rejections, not deadline
/// rejections) ever races the clock.
const DEADLINE_MS: u64 = 120_000;

const QUEUE_DEPTH: usize = 4;

fn pctl(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn query_ok(client: &mut Client, workload: &str, scenario: &str) -> f64 {
    let t0 = Instant::now();
    let resp = client.query(workload, scenario, Some(DEADLINE_MS)).expect("query");
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(
        resp.get("ok").and_then(Json::as_bool),
        Some(true),
        "query {workload}/{scenario} failed: {}",
        resp.render()
    );
    ms
}

fn executions(client: &mut Client) -> f64 {
    let stats = client.op("stats").expect("stats");
    stats.get("workload_executions").and_then(Json::as_f64).expect("stats field")
}

struct Phase {
    queries: usize,
    wall_s: f64,
    p50_ms: f64,
    p99_ms: f64,
}

impl Phase {
    fn from_latencies(mut lat: Vec<f64>, wall_s: f64) -> Phase {
        lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
        Phase {
            queries: lat.len(),
            wall_s,
            p50_ms: pctl(&lat, 0.50),
            p99_ms: pctl(&lat, 0.99),
        }
    }

    fn qps(&self) -> f64 {
        self.queries as f64 / self.wall_s.max(1e-9)
    }
}

fn main() {
    common::banner("serve load: cold/warm latency, throughput, and overload shedding");
    let cfg = common::config();
    let args = common::args();

    let dir = std::env::temp_dir().join(format!("mlperf-serve-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = ServeOptions {
        dir: dir.clone(),
        queue_depth: QUEUE_DEPTH,
        default_deadline_ms: DEADLINE_MS,
        cfg: cfg.clone(),
        ..ServeOptions::default()
    };
    let server = Server::bind(opts).expect("bind serve daemon");
    let addr = server.addr().to_string();
    let daemon = std::thread::spawn(move || server.run().expect("daemon run"));

    let workloads = ["KMeans", "KNN", "DBSCAN", "Decision Tree"];
    let warm_cells: Vec<(String, String)> = workloads
        .iter()
        .flat_map(|w| {
            ["baseline", "ideal-rows"].iter().map(move |s| (w.to_string(), s.to_string()))
        })
        .collect();

    // phase 1: cold — every cell is a miss, queried serially
    let mut probe = Client::connect(&addr).expect("connect");
    let t0 = Instant::now();
    let cold_lat: Vec<f64> =
        warm_cells.iter().map(|(w, s)| query_ok(&mut probe, w, s)).collect();
    let cold = Phase::from_latencies(cold_lat, t0.elapsed().as_secs_f64());
    let executed_cold = executions(&mut probe);
    assert!(executed_cold > 0.0, "cold phase must simulate");

    // phase 2: warm — concurrent clients, several rounds, zero sims
    let threads = 4;
    let rounds = 25;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..threads)
        .map(|_| {
            let addr = addr.clone();
            let cells = warm_cells.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                let mut lat = Vec::new();
                for _ in 0..rounds {
                    for (w, s) in &cells {
                        lat.push(query_ok(&mut client, w, s));
                    }
                }
                lat
            })
        })
        .collect();
    let warm_lat: Vec<f64> =
        handles.into_iter().flat_map(|h| h.join().expect("warm client")).collect();
    let warm = Phase::from_latencies(warm_lat, t0.elapsed().as_secs_f64());
    assert_eq!(
        executions(&mut probe),
        executed_cold,
        "warm queries must be served from the shards with zero re-simulation"
    );

    // phase 3: overload — 2x queue_depth cold queries through a barrier
    let offered = 2 * QUEUE_DEPTH;
    let overload_cells: Vec<(String, String)> = workloads
        .iter()
        .flat_map(|w| {
            ["perfect-l2", "perfect-llc"].iter().map(move |s| (w.to_string(), s.to_string()))
        })
        .collect();
    assert_eq!(overload_cells.len(), offered);
    let barrier = Arc::new(Barrier::new(offered));
    let handles: Vec<_> = overload_cells
        .into_iter()
        .map(|(w, s)| {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                barrier.wait();
                let resp = client.query(&w, &s, Some(DEADLINE_MS)).expect("overload query");
                if resp.get("ok").and_then(Json::as_bool) == Some(true) {
                    "ok"
                } else {
                    match resp.get("kind").and_then(Json::as_str) {
                        Some("overloaded") => "shed",
                        _ => "other",
                    }
                }
            })
        })
        .collect();
    let outcomes: Vec<&str> = handles.into_iter().map(|h| h.join().expect("client")).collect();
    let completed = outcomes.iter().filter(|o| **o == "ok").count();
    let shed = outcomes.iter().filter(|o| **o == "shed").count();
    let other = outcomes.iter().filter(|o| **o == "other").count();
    assert_eq!(other, 0, "overload produced a non-overloaded failure: {outcomes:?}");
    assert!(completed > 0, "saturation must not starve every query");
    assert!(shed > 0, "offering 2x queue_depth concurrently should shed something");

    let mut client = Client::connect(&addr).expect("connect");
    client.op("shutdown").expect("drain");
    daemon.join().expect("daemon thread");
    let _ = std::fs::remove_dir_all(&dir);

    let mut t = Table::new(
        "serve_load",
        &format!(
            "serve daemon over {} warm cells, queue_depth {QUEUE_DEPTH}, {threads} clients x {rounds} rounds",
            warm_cells.len()
        ),
        &["phase", "queries", "p50 (ms)", "p99 (ms)", "queries/s"],
    );
    for (name, p) in [("cold (miss)", &cold), ("warm (hit)", &warm)] {
        t.row(vec![
            name.into(),
            format!("{}", p.queries),
            format!("{:.3}", p.p50_ms),
            format!("{:.3}", p.p99_ms),
            format!("{:.0}", p.qps()),
        ]);
    }
    t.emit();
    println!(
        "cold/warm p50 ratio: {:.1}x; overload at {offered} concurrent cold queries \
         (capacity {QUEUE_DEPTH}): {completed} completed, {shed} shed ({:.0}% shed rate)",
        cold.p50_ms / warm.p50_ms.max(1e-9),
        shed as f64 / offered as f64 * 100.0
    );

    if args.has("json") {
        let field = |k: &str, v: Json| (k.to_string(), v);
        let phase_json = |p: &Phase| {
            Json::Obj(vec![
                field("queries", Json::num(p.queries as f64)),
                field("wall_s", Json::num(p.wall_s)),
                field("p50_ms", Json::num(p.p50_ms)),
                field("p99_ms", Json::num(p.p99_ms)),
                field("qps", Json::num(p.qps())),
            ])
        };
        let doc = Json::Obj(vec![
            field("bench", Json::Str("serve_load".into())),
            field("provenance", mlperf::obs::provenance_json()),
            field("scale", Json::num(cfg.scale)),
            field("queue_depth", Json::num(QUEUE_DEPTH as f64)),
            field("client_threads", Json::num(threads as f64)),
            field("cold", phase_json(&cold)),
            field("warm", phase_json(&warm)),
            field(
                "overload",
                Json::Obj(vec![
                    field("offered", Json::num(offered as f64)),
                    field("completed", Json::num(completed as f64)),
                    field("shed", Json::num(shed as f64)),
                    field("shed_rate", Json::num(shed as f64 / offered as f64)),
                ]),
            ),
        ]);
        let default_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
        let path = args.get_or("json-out", default_path);
        std::fs::write(&path, doc.render())
            .unwrap_or_else(|e| panic!("cannot write {path}: {e}"));
        println!("wrote {path}");
    }
}
