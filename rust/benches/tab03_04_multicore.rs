//! Tables III & IV: 1/4/8-core top-down characterization for the
//! workloads with parallel implementations, in both library profiles.
//!
//! Paper shape: single-core bottleneck structure persists at 4 and 8
//! cores — CPI stays >=0.7-ish, bad speculation and DRAM bound comparable.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{pct, r2, Table};
use mlperf::coordinator::multicore_characterize;
use mlperf::workloads::{by_name, multicore_names, LibraryProfile};

fn main() {
    common::banner("Tables III-IV: multicore top-down");
    let mut cfg = common::config();
    // multicore triples the simulation count: trim scale further
    cfg.scale *= 0.5;
    for (profile, id, label) in [
        (LibraryProfile::Sklearn, "tab03", "Table III (scikit-learn)"),
        (LibraryProfile::Mlpack, "tab04", "Table IV (mlpack)"),
    ] {
        cfg.profile = profile;
        let mut t = Table::new(id, label, &[
            "workload", "CPI 1c", "CPI 4c", "CPI 8c", "ret% 1c", "ret% 4c", "ret% 8c",
            "bspec% 1c", "bspec% 4c", "bspec% 8c", "dram% 1c", "dram% 4c", "dram% 8c",
        ]);
        for name in multicore_names(profile) {
            let w = by_name(name).unwrap();
            let ms: Vec<_> = [1usize, 4, 8]
                .iter()
                .map(|&n| {
                    common::timed(&format!("{name}@{n}c"), || {
                        multicore_characterize(w.as_ref(), &cfg, n)
                    })
                })
                .collect();
            let mut row = vec![name.to_string()];
            row.extend(ms.iter().map(|m| r2(m.cpi)));
            row.extend(ms.iter().map(|m| pct(m.retiring_pct)));
            row.extend(ms.iter().map(|m| pct(m.bad_spec_pct)));
            row.extend(ms.iter().map(|m| pct(m.dram_bound_pct)));
            t.row(row);
        }
        t.emit();
    }
}
