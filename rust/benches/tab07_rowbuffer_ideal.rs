//! Table VII: DRAM row-buffer hit ratio, average access latency, and the
//! latency under an ideal (always-hit) row buffer, per workload.
//!
//! Paper shape: KNN/t-SNE/DBSCAN have very poor hit ratios (<0.25);
//! Adaboost best (~0.64); ideal-hit latency sits at ~68-73 ns giving
//! 11.8-25.6% improvement headroom.

#[path = "common.rs"]
mod common;

use mlperf::analysis::{pct, r2, r3, Table};
use mlperf::coordinator::dram_study;
use mlperf::workloads::by_name;

fn main() {
    common::banner("Table VII: row-buffer headroom");
    let cfg = common::config();
    let mut t = Table::new(
        "tab07",
        "original vs ideal row-buffer hit latencies",
        &["benchmark", "hit ratio", "avg latency ns", "ideal latency ns", "improvement %"],
    );
    for name in common::reorder_workloads() {
        let w = by_name(name).unwrap();
        let (real, ideal) = common::timed(name, || {
            (
                dram_study(w.as_ref(), &cfg, false),
                dram_study(w.as_ref(), &cfg, true),
            )
        });
        let improv = (1.0 - ideal.avg_latency_ns() / real.avg_latency_ns()) * 100.0;
        t.row(vec![
            name.into(),
            r3(real.row_hit_ratio()),
            r2(real.avg_latency_ns()),
            r2(ideal.avg_latency_ns()),
            pct(improv),
        ]);
    }
    t.emit();
}
