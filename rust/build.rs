//! Build-time provenance probes for BENCH_*.json / telemetry.json
//! attribution (`obs::provenance_json`). Both probes are best-effort:
//! a container without `git` (or a future toolchain that renames the
//! version flag) degrades to `"unknown"` rather than failing the
//! build — provenance is attribution metadata, never a build gate.

use std::process::Command;

fn probe(cmd: &str, args: &[&str]) -> String {
    Command::new(cmd)
        .args(args)
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn main() {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    println!("cargo:rustc-env=MLPERF_RUSTC_VERSION={}", probe(&rustc, &["--version"]));
    println!("cargo:rustc-env=MLPERF_GIT_REV={}", probe("git", &["rev-parse", "--short=12", "HEAD"]));
    // the git rev is sampled when the build script runs; a new commit
    // alone does not trigger a rerun, which is acceptable for
    // attribution (CI always builds from a fresh checkout)
    println!("cargo:rerun-if-changed=build.rs");
}
