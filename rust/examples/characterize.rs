//! Full single-workload characterization, mirroring the paper's Section
//! III methodology: top-down bounds, branch behaviour, cache/DRAM
//! behaviour, and the same workload under the mlpack profile.
//!
//! ```bash
//! cargo run --release --example characterize -- --workload dbscan --scale 0.3
//! ```

use mlperf::analysis::{pct, r2, r3, Table};
use mlperf::coordinator::{characterize, ExperimentConfig};
use mlperf::util::Args;
use mlperf::workloads::{by_name, LibraryProfile};

fn main() {
    let args = Args::from_env();
    let name = args.get_or("workload", "dbscan");
    let w = by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown workload {name}");
        std::process::exit(2);
    });
    let mut cfg = ExperimentConfig {
        scale: args.get_parsed_or("scale", 0.3),
        iterations: args.get_parsed_or("iterations", 2),
        ..Default::default()
    };

    let mut t = Table::new(
        "characterize_example",
        &format!("{} — single-core characterization", w.name()),
        &["metric", "sklearn", "mlpack"],
    );
    cfg.profile = LibraryProfile::Sklearn;
    let sk = characterize(w.as_ref(), &cfg).metrics;
    let ml = if w.in_mlpack() {
        cfg.profile = LibraryProfile::Mlpack;
        Some(characterize(w.as_ref(), &cfg).metrics)
    } else {
        None
    };
    let cell = |f: &dyn Fn(&mlperf::sim::Metrics) -> String, m: &Option<mlperf::sim::Metrics>| {
        m.as_ref().map(|m| f(m)).unwrap_or_else(|| "-".into())
    };
    let rows: Vec<(&str, Box<dyn Fn(&mlperf::sim::Metrics) -> String>)> = vec![
        ("CPI", Box::new(|m: &mlperf::sim::Metrics| r2(m.cpi))),
        ("retiring %", Box::new(|m: &mlperf::sim::Metrics| pct(m.retiring_pct))),
        ("bad speculation %", Box::new(|m: &mlperf::sim::Metrics| pct(m.bad_spec_pct))),
        ("DRAM bound %", Box::new(|m: &mlperf::sim::Metrics| pct(m.dram_bound_pct))),
        ("core bound %", Box::new(|m: &mlperf::sim::Metrics| pct(m.core_bound_pct))),
        ("branch fraction", Box::new(|m: &mlperf::sim::Metrics| r3(m.branch_fraction))),
        ("mispredict ratio", Box::new(|m: &mlperf::sim::Metrics| r3(m.branch_mispredict_ratio))),
        ("LLC miss ratio", Box::new(|m: &mlperf::sim::Metrics| r3(m.llc_miss_ratio))),
        ("row-buffer hit ratio", Box::new(|m: &mlperf::sim::Metrics| r3(m.dram.row_hit_ratio()))),
        ("bandwidth util %", Box::new(|m: &mlperf::sim::Metrics| pct(m.bandwidth_utilization_pct()))),
    ];
    for (label, f) in rows {
        t.row(vec![label.into(), f(&sk), cell(&|m| f(m), &ml)]);
    }
    println!("{}", t.render());
}
