//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! Layer 1 (Pallas pairwise/SYRK kernels) + Layer 2 (JAX kmeans_step /
//! gram_xty graphs) were AOT-compiled by `make artifacts`; this Rust
//! binary (Layer 3) loads them through PJRT and — with Python nowhere on
//! the path — trains:
//!
//!   1. KMeans on a 64k x 20 synthetic blob dataset by streaming row
//!      batches through the `kmeans_step` executable (mini-batch Lloyd
//!      with per-batch centroid averaging), logging the inertia curve;
//!   2. Ridge regression on 64k x 20 synthetic linear data by
//!      accumulating `gram_xty` over batches and Cholesky-solving the
//!      normal equations in Rust, reporting R².
//!
//! Reports wall-clock latency/throughput per executable call. Recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use mlperf::data::{make_blobs, make_regression};
use mlperf::runtime::{default_artifacts_dir, Runtime, BATCH, FEATURES, K};
use mlperf::util::{solve_spd, Matrix, Pcg64};
use std::time::Instant;

fn main() -> mlperf::util::error::Result<()> {
    let dir = default_artifacts_dir();
    let t0 = Instant::now();
    let rt = Runtime::load(&dir)?;
    println!(
        "loaded artifacts from {} on {} in {:.2}s",
        dir.display(),
        rt.platform(),
        t0.elapsed().as_secs_f64()
    );

    kmeans_e2e(&rt)?;
    ridge_e2e(&rt)?;
    Ok(())
}

fn kmeans_e2e(rt: &Runtime) -> mlperf::util::error::Result<()> {
    const ROWS: usize = 65_536; // 16 batches of 4096
    let ds = make_blobs(ROWS, FEATURES, K, 1.0, 42);
    println!("\n== KMeans end-to-end: {} rows x {} features, k={} ==", ROWS, FEATURES, K);

    // init centroids from random rows
    let mut rng = Pcg64::new(7);
    let mut c: Vec<f32> = (0..K)
        .flat_map(|_| {
            let r = rng.index(ROWS);
            ds.x.row(r).iter().map(|&v| v as f32).collect::<Vec<f32>>()
        })
        .collect();

    // pre-batch the data as f32
    let batches: Vec<Vec<f32>> = (0..ROWS / BATCH)
        .map(|b| {
            (0..BATCH * FEATURES)
                .map(|i| ds.x.as_slice()[b * BATCH * FEATURES + i] as f32)
                .collect()
        })
        .collect();

    let mut calls = 0u64;
    let mut call_time = 0.0f64;
    let t_train = Instant::now();
    for epoch in 0..8 {
        let mut inertia_sum = 0.0f64;
        // average the per-batch centroid updates (mini-batch Lloyd)
        let mut acc = vec![0.0f64; K * FEATURES];
        for x in &batches {
            let t = Instant::now();
            let (new_c, inertia) = rt.kmeans_step(x, &c)?;
            call_time += t.elapsed().as_secs_f64();
            calls += 1;
            inertia_sum += inertia as f64;
            for (a, v) in acc.iter_mut().zip(&new_c) {
                *a += *v as f64;
            }
        }
        let nb = batches.len() as f64;
        for (ci, a) in c.iter_mut().zip(&acc) {
            *ci = (*a / nb) as f32;
        }
        println!("  epoch {epoch}: total inertia {:.0}", inertia_sum);
    }
    let wall = t_train.elapsed().as_secs_f64();
    println!(
        "  trained in {:.2}s wall | {} executable calls | {:.2} ms/call | {:.1} Mrows/s",
        wall,
        calls,
        1000.0 * call_time / calls as f64,
        (calls as f64 * BATCH as f64) / wall / 1e6
    );
    Ok(())
}

fn ridge_e2e(rt: &Runtime) -> mlperf::util::error::Result<()> {
    const ROWS: usize = 65_536;
    let (ds, w_true) = make_regression(ROWS, FEATURES, FEATURES, 0.5, 43);
    println!("\n== Ridge end-to-end: {} rows x {} features ==", ROWS, FEATURES);

    let mut gram = vec![0.0f64; FEATURES * FEATURES];
    let mut xty = vec![0.0f64; FEATURES];
    let t0 = Instant::now();
    let mut calls = 0;
    for b in 0..ROWS / BATCH {
        let x: Vec<f32> = (0..BATCH * FEATURES)
            .map(|i| ds.x.as_slice()[b * BATCH * FEATURES + i] as f32)
            .collect();
        let y: Vec<f32> = (0..BATCH).map(|i| ds.y[b * BATCH + i] as f32).collect();
        let (g, xy) = rt.gram_xty(&x, &y)?;
        calls += 1;
        for (acc, v) in gram.iter_mut().zip(&g) {
            *acc += *v as f64;
        }
        for (acc, v) in xty.iter_mut().zip(&xy) {
            *acc += *v as f64;
        }
    }
    // solve (G + aI) w = X^T y in Rust
    let mut a = Matrix::zeros(FEATURES, FEATURES);
    for i in 0..FEATURES {
        for j in 0..FEATURES {
            a[(i, j)] = gram[i * FEATURES + j];
        }
        a[(i, i)] += 1.0;
    }
    let w = solve_spd(&a, &xty).expect("SPD");
    let max_err = w
        .iter()
        .zip(&w_true)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    // R^2 on the training data
    let mean_y: f64 = ds.y.iter().sum::<f64>() / ROWS as f64;
    let (mut ss_res, mut ss_tot) = (0.0, 0.0);
    for i in 0..ROWS {
        let pred: f64 = (0..FEATURES).map(|f| ds.x[(i, f)] * w[f]).sum();
        ss_res += (ds.y[i] - pred) * (ds.y[i] - pred);
        ss_tot += (ds.y[i] - mean_y) * (ds.y[i] - mean_y);
    }
    println!(
        "  R² = {:.6} | max |w - w_true| = {:.4} | {} calls in {:.2}s",
        1.0 - ss_res / ss_tot,
        max_err,
        calls,
        t0.elapsed().as_secs_f64()
    );
    assert!(1.0 - ss_res / ss_tot > 0.99, "ridge failed to fit");
    println!("  end_to_end OK");
    Ok(())
}
