//! Profiling probe used by the §Perf pass (EXPERIMENTS.md): times one
//! KNN characterization with and without software prefetching under
//! `perf record`.
use mlperf::coordinator::*;
use mlperf::workloads::by_name;
fn main() {
    let cfg = ExperimentConfig { scale: 0.15, iterations: 2, ..Default::default() };
    let w = by_name("knn").unwrap();
    for (label, pf) in [("base", false), ("sw-prefetch", true)] {
        let t0 = std::time::Instant::now();
        let c = characterize_with(w.as_ref(), &cfg, pf, None, None, |_| {});
        println!("{label}: {:.2}s, {} instr, {} sw-pf", t0.elapsed().as_secs_f64(),
                 c.metrics.instructions, c.metrics.mix.sw_prefetches);
    }
}
