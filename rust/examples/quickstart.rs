//! Quickstart: characterize one workload in ~5 seconds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Runs KMeans on a small synthetic blob dataset, streams its trace
//! through the cache/DRAM/pipeline simulators, and prints the paper's
//! headline metrics.

use mlperf::coordinator::{characterize, ExperimentConfig};
use mlperf::workloads::by_name;

fn main() {
    let cfg = ExperimentConfig { scale: 0.2, iterations: 2, ..Default::default() };
    for name in ["KMeans", "KNN", "Decision Tree"] {
        let w = by_name(name).unwrap();
        let c = characterize(w.as_ref(), &cfg);
        let m = &c.metrics;
        println!(
            "{:>14}: CPI {:.2} | retiring {:>4.1}% | bad-spec {:>4.1}% | DRAM-bound {:>4.1}% | \
             LLC miss {:.3} | quality {}",
            name, m.cpi, m.retiring_pct, m.bad_spec_pct, m.dram_bound_pct, m.llc_miss_ratio,
            c.result.detail
        );
    }
    println!("\nNext: `cargo run --release -- report` for the full figure suite,");
    println!("or `cargo bench` to regenerate every paper table/figure.");
}
