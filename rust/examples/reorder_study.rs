//! Mini reordering study: one workload under all six reordering
//! algorithms (paper Section VI), printing row-buffer hit ratio, latency
//! and speedups with/without overhead — Figs. 20-24 for a single
//! workload.
//!
//! ```bash
//! cargo run --release --example reorder_study -- --workload knn --scale 0.2
//! ```

use mlperf::analysis::{r2, r3, Table};
use mlperf::coordinator::{reorder_study, ExperimentConfig};
use mlperf::reorder::ReorderKind;
use mlperf::util::Args;
use mlperf::workloads::by_name;

fn main() {
    let args = Args::from_env();
    let name = args.get_or("workload", "knn");
    let w = by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown workload {name}");
        std::process::exit(2);
    });
    let cfg = ExperimentConfig {
        scale: args.get_parsed_or("scale", 0.2),
        iterations: args.get_parsed_or("iterations", 2),
        ..Default::default()
    };
    let mut t = Table::new(
        "reorder_example",
        &format!("{} — all reordering algorithms", w.name()),
        &["method", "hit-ratio base→reord", "latency ns base→reord", "speedup", "w/ overhead"],
    );
    for kind in ReorderKind::ALL {
        if !kind.applicable_to(w.as_ref()) {
            t.row(vec![kind.name().into(), "n/a".into(), "n/a".into(), "-".into(), "-".into()]);
            continue;
        }
        let s = reorder_study(w.as_ref(), kind, &cfg);
        t.row(vec![
            kind.name().into(),
            format!(
                "{} → {}",
                r3(s.baseline.dram.row_hit_ratio()),
                r3(s.reordered.dram.row_hit_ratio())
            ),
            format!(
                "{} → {}",
                r2(s.baseline.dram.avg_latency_ns()),
                r2(s.reordered.dram.avg_latency_ns())
            ),
            format!("{:.3}x", s.speedup_no_overhead()),
            format!("{:.3}x", s.speedup_with_overhead()),
        ]);
    }
    println!("{}", t.render());
}
