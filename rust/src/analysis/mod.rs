//! Result aggregation and table/figure formatting.
//!
//! Every bench target renders its results through [`Table`] — an ASCII
//! table for the terminal plus CSV and JSON artifacts, all three from
//! the same header/row source — so the output rows can be compared
//! one-to-one with the paper's figures and consumed by scripts without
//! table scraping.

use crate::sim::Metrics;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// A named results table (one per paper figure/table).
#[derive(Debug, Clone)]
pub struct Table {
    pub id: String,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(id: &str, title: &str, headers: &[&str]) -> Self {
        Self {
            id: id.to_string(),
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (for plotting / EXPERIMENTS.md extraction).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// JSON rendering — the same headers/rows the ASCII and CSV forms
    /// use, so the three artifacts can never disagree.
    pub fn to_json(&self) -> String {
        Json::Obj(vec![
            ("id".to_string(), Json::Str(self.id.clone())),
            ("title".to_string(), Json::Str(self.title.clone())),
            (
                "headers".to_string(),
                Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
            ),
            (
                "rows".to_string(),
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
        .render()
    }

    /// Persist `<dir>/<id>.csv` and `<dir>/<id>.json`.
    pub fn save_artifacts(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let csv = dir.join(format!("{}.csv", self.id));
        std::fs::write(&csv, self.to_csv())
            .with_context(|| format!("writing {}", csv.display()))?;
        let json = dir.join(format!("{}.json", self.id));
        std::fs::write(&json, self.to_json())
            .with_context(|| format!("writing {}", json.display()))?;
        Ok(())
    }

    /// Print to stdout and persist CSV + JSON under `results/`. A failed
    /// write is reported on stderr (a full disk or read-only checkout
    /// must not silently drop the artifact trail), but does not abort —
    /// the table already reached stdout.
    pub fn emit(&self) {
        println!("{}", self.render());
        self.persist();
    }

    /// [`Table::emit`] with the rendered table on **stderr** instead of
    /// stdout — for commands whose stdout carries a machine-readable
    /// artifact (`mlperf grid --json -`) that must pipe clean through a
    /// JSON parser. Artifacts persist exactly as with `emit`.
    pub fn emit_stderr(&self) {
        eprintln!("{}", self.render());
        self.persist();
    }

    fn persist(&self) {
        if let Err(e) = self.save_artifacts(std::path::Path::new("results")) {
            crate::util::diag::warn(format!(
                "table {:?} artifacts not persisted: {e:#}",
                self.id
            ));
        }
    }
}

/// Percent with one decimal.
pub fn pct(x: f64) -> String {
    format!("{x:.1}")
}

/// Ratio with two decimals.
pub fn r2(x: f64) -> String {
    format!("{x:.2}")
}

/// Ratio with three decimals.
pub fn r3(x: f64) -> String {
    format!("{x:.3}")
}

/// The standard top-down row used by Tables III/IV and several figures.
pub fn topdown_cells(m: &Metrics) -> Vec<String> {
    vec![
        r2(m.cpi),
        pct(m.retiring_pct),
        pct(m.bad_spec_pct),
        pct(m.dram_bound_pct),
        pct(m.core_bound_pct),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("t1", "demo", &["name", "v"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["longname".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("t2", "x", &["a,b", "c"]);
        t.row(vec!["v\"q".into(), "2".into()]);
        let csv = t.to_csv();
        assert!(csv.starts_with("\"a,b\",c\n"));
        assert!(csv.contains("\"v\"\"q\""));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn row_width_checked() {
        let mut t = Table::new("t3", "x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn topdown_cells_shape() {
        let m = Metrics::default();
        assert_eq!(topdown_cells(&m).len(), 5);
    }

    #[test]
    fn json_mirrors_table_content() {
        let mut t = Table::new("t4", "json demo", &["name", "v"]);
        t.row(vec!["a\"b".into(), "1.5".into()]);
        let v = Json::parse(&t.to_json()).unwrap();
        assert_eq!(v.get("id").unwrap().as_str(), Some("t4"));
        assert_eq!(v.get("headers").unwrap().as_arr().unwrap().len(), 2);
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].as_arr().unwrap()[0].as_str(), Some("a\"b"));
    }

    #[test]
    fn save_artifacts_writes_csv_and_json_and_reports_failure() {
        let mut t = Table::new("t5", "artifacts", &["a"]);
        t.row(vec!["1".into()]);
        let dir = std::env::temp_dir().join("mlperf-analysis-tests");
        t.save_artifacts(&dir).unwrap();
        assert!(dir.join("t5.csv").exists());
        assert!(dir.join("t5.json").exists());
        // a file where the directory should be must surface as an error
        let bad = dir.join("t5.csv");
        assert!(t.save_artifacts(&bad).is_err());
    }
}
