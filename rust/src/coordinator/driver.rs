//! Parallel experiment driver: run independent (workload × scenario)
//! characterizations across OS threads with deterministic result order.
//!
//! Every cell of the paper's figure/table grid is an independent,
//! deterministic simulation — embarrassingly parallel at the experiment
//! level even though each individual trace must stay sequential. The
//! driver fans a [`Job`] list out over a work-stealing index, runs each
//! job through the block-pipeline coordinator entry points, and writes
//! results into per-job slots, so the output order always equals the
//! input order no matter how the scheduler interleaves completions.
//! Workload objects are constructed inside the worker thread (via
//! [`by_name`]) because `Box<dyn Workload>` is deliberately not `Send`.
//!
//! [`by_name`]: crate::workloads::by_name

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::{
    characterize_with, multicore_characterize, reorder_study, ExperimentConfig,
};
use crate::reorder::ReorderKind;
use crate::sim::Metrics;
use crate::workloads::{by_name, multicore_names, registry};

/// One experiment scenario — the column dimension of the job grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scenario {
    /// Figs. 1–10 baseline characterization.
    Baseline,
    /// Figs. 14–18: software prefetching enabled.
    SwPrefetch,
    /// Fig. 12: perfect (always-hit) L2.
    PerfectL2,
    /// Fig. 12: perfect (always-hit) LLC.
    PerfectLlc,
    /// Fig. 13 companion: hardware prefetchers disabled.
    NoHwPrefetch,
    /// Tables III/IV: sharded run over `n` cores with LLC/bus contention.
    Multicore(usize),
    /// Table VII: ideal row-buffer DRAM.
    DramIdealRows,
    /// Figs. 20–24: one reordering optimization (reordered-run metrics).
    Reorder(ReorderKind),
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scenario::Baseline => write!(f, "baseline"),
            Scenario::SwPrefetch => write!(f, "sw-prefetch"),
            Scenario::PerfectL2 => write!(f, "perfect-L2"),
            Scenario::PerfectLlc => write!(f, "perfect-LLC"),
            Scenario::NoHwPrefetch => write!(f, "no-hw-prefetch"),
            Scenario::Multicore(n) => write!(f, "{n}-core"),
            Scenario::DramIdealRows => write!(f, "ideal-rows"),
            Scenario::Reorder(k) => write!(f, "reorder:{k}"),
        }
    }
}

/// One unit of driver work: a workload (by paper name) under a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub workload: String,
    pub scenario: Scenario,
}

impl Job {
    pub fn new(workload: impl Into<String>, scenario: Scenario) -> Self {
        Self { workload: workload.into(), scenario }
    }
}

/// Result slot for one job, in input order.
#[derive(Debug, Clone)]
pub struct JobOutput {
    pub job: Job,
    pub metrics: Metrics,
    /// Workload quality scalar where the scenario produces one
    /// (multicore aggregation does not).
    pub quality: Option<f64>,
}

/// What [`run_jobs`] hands back.
#[derive(Debug)]
pub struct DriverReport {
    /// One output per input job, **in input order** (deterministic
    /// regardless of thread interleaving).
    pub outputs: Vec<JobOutput>,
    pub threads_used: usize,
    pub wall_seconds: f64,
}

/// The standard characterization grid for `cfg`'s profile: a baseline
/// cell per workload plus the multicore cells of Tables III/IV.
pub fn standard_grid(cfg: &ExperimentConfig) -> Vec<Job> {
    let mut jobs: Vec<Job> = registry()
        .iter()
        .map(|w| Job::new(w.name(), Scenario::Baseline))
        .collect();
    for name in multicore_names(cfg.profile) {
        for cores in [4usize, 8] {
            jobs.push(Job::new(name, Scenario::Multicore(cores)));
        }
    }
    jobs
}

/// Run one job synchronously on the current thread.
///
/// Panics on an unknown workload name or a reordering scenario that the
/// workload does not support — grid builders only emit valid cells.
pub fn run_job(cfg: &ExperimentConfig, job: &Job) -> JobOutput {
    let w = by_name(&job.workload)
        .unwrap_or_else(|| panic!("driver: unknown workload {:?}", job.workload));
    let w = w.as_ref();
    let (metrics, quality) = match job.scenario {
        Scenario::Baseline => {
            let c = characterize_with(w, cfg, false, None, None, |_| {});
            (c.metrics, Some(c.result.quality))
        }
        Scenario::SwPrefetch => {
            let c = characterize_with(w, cfg, true, None, None, |_| {});
            (c.metrics, Some(c.result.quality))
        }
        Scenario::PerfectL2 => {
            let c = characterize_with(w, cfg, false, None, None, |c| c.cache.perfect_l2 = true);
            (c.metrics, Some(c.result.quality))
        }
        Scenario::PerfectLlc => {
            let c = characterize_with(w, cfg, false, None, None, |c| c.cache.perfect_llc = true);
            (c.metrics, Some(c.result.quality))
        }
        Scenario::NoHwPrefetch => {
            let c = characterize_with(w, cfg, false, None, None, |c| c.cache.hw_prefetch = false);
            (c.metrics, Some(c.result.quality))
        }
        Scenario::Multicore(n) => (multicore_characterize(w, cfg, n), None),
        Scenario::DramIdealRows => {
            let c = characterize_with(w, cfg, false, None, None, |c| {
                c.dram.ideal_row_hits = true;
            });
            (c.metrics, Some(c.result.quality))
        }
        Scenario::Reorder(kind) => {
            assert!(
                kind.applicable_to(w),
                "driver: {kind} is not applicable to {}",
                w.name()
            );
            let s = reorder_study(w, kind, cfg);
            (s.reordered, Some(s.reordered_quality))
        }
    };
    JobOutput { job: job.clone(), metrics, quality }
}

/// Run `jobs` across up to `threads` OS threads (`0` = one per available
/// core). Jobs are claimed from a shared atomic cursor (work stealing by
/// index), so long simulations do not convoy behind short ones; results
/// land in per-job slots and come back in input order.
pub fn run_jobs(cfg: &ExperimentConfig, jobs: &[Job], threads: usize) -> DriverReport {
    let t0 = std::time::Instant::now();
    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let requested = if threads == 0 { auto } else { threads };
    let threads_used = requested.min(jobs.len()).max(1);

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<JobOutput>>> = jobs.iter().map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads_used {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let out = run_job(cfg, &jobs[i]);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });

    let outputs = slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every job slot filled"))
        .collect();
    DriverReport { outputs, threads_used, wall_seconds: t0.elapsed().as_secs_f64() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExperimentConfig {
        ExperimentConfig { scale: 0.02, iterations: 1, ..Default::default() }
    }

    #[test]
    fn outputs_follow_input_order() {
        let cfg = tiny();
        let jobs = vec![
            Job::new("KMeans", Scenario::Baseline),
            Job::new("KNN", Scenario::SwPrefetch),
            Job::new("Ridge", Scenario::Baseline),
        ];
        let report = run_jobs(&cfg, &jobs, 3);
        assert_eq!(report.outputs.len(), 3);
        for (job, out) in jobs.iter().zip(&report.outputs) {
            assert_eq!(*job, out.job);
            assert!(out.metrics.instructions > 0, "{job:?}");
        }
    }

    #[test]
    fn parallel_results_equal_sequential() {
        let cfg = tiny();
        let jobs = vec![
            Job::new("KMeans", Scenario::Baseline),
            Job::new("DBSCAN", Scenario::Baseline),
            Job::new("KNN", Scenario::PerfectLlc),
            Job::new("GMM", Scenario::Multicore(2)),
        ];
        let seq = run_jobs(&cfg, &jobs, 1);
        let par = run_jobs(&cfg, &jobs, 4);
        assert_eq!(par.threads_used, 4);
        for (a, b) in seq.outputs.iter().zip(&par.outputs) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.metrics, b.metrics, "{:?}", a.job);
            assert_eq!(a.quality, b.quality);
        }
    }

    #[test]
    fn standard_grid_covers_every_workload() {
        let cfg = tiny();
        let jobs = standard_grid(&cfg);
        for w in crate::workloads::registry() {
            assert!(
                jobs.iter().any(|j| j.workload == w.name()),
                "missing {}",
                w.name()
            );
        }
        assert!(jobs.iter().any(|j| matches!(j.scenario, Scenario::Multicore(8))));
    }

    #[test]
    fn zero_threads_means_auto() {
        let cfg = tiny();
        let jobs = vec![Job::new("Lasso", Scenario::Baseline)];
        let report = run_jobs(&cfg, &jobs, 0);
        assert_eq!(report.threads_used, 1, "capped at job count");
        assert!(report.outputs[0].quality.is_some());
    }
}
