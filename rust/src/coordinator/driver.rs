//! Parallel experiment driver: run independent (workload × scenario)
//! characterizations across OS threads with deterministic result order.
//!
//! Every cell of the paper's figure/table grid is an independent,
//! deterministic simulation — embarrassingly parallel at the experiment
//! level even though each individual trace must stay sequential. The
//! driver fans a [`Job`] list out over a work-stealing index, runs each
//! job through the block-pipeline coordinator entry points, and writes
//! results into per-job slots, so the output order always equals the
//! input order no matter how the scheduler interleaves completions.
//! Workload objects are constructed inside the worker thread (via
//! [`by_name`]) because `Box<dyn Workload>` is deliberately not `Send`.
//!
//! Two execution modes share that skeleton:
//!
//! - [`run_jobs`] — direct mode: every cell re-executes its workload.
//! - [`run_jobs_replayed`] — record-once/replay-many mode: cells whose
//!   scenario only varies the *simulator* configuration (perfect caches,
//!   prefetcher toggles, ideal DRAM rows — see
//!   [`Scenario::trace_variant`]) are grouped per (workload, prefetch
//!   variant); the workload executes once into an in-memory
//!   [`CapturedTrace`], which is then shared via `Arc` and replayed into
//!   a fresh `PipelineSim` per cell, with each (capture ×
//!   scenario-cell) unit scheduled independently across the worker pool
//!   (intra-capture fan-out — a few-workload × many-scenario grid no
//!   longer convoys behind one thread per group; at most `threads`
//!   captures stay resident). When ready cells outnumber the pool,
//!   same-capture cells are claimed as [`Broadcast`] batches — one walk
//!   of the captured stream feeds several simulators
//!   ([`super::replay_characterize_many`]) — so scenario columns beyond
//!   the core count cost a fan-out, not a re-walk, per cell. Replay
//!   delivers the identical block
//!   stream the recording produced, so every cell's `Metrics` are
//!   bit-identical to direct mode — scenario count no longer multiplies
//!   workload execution time, which is what lets the grid grow toward
//!   the paper's full 14-workload × many-configuration sweeps.
//!   Scenarios that change execution itself (multicore sharding,
//!   reordering) fall back to direct cells inside the same run.
//!   [`run_jobs_replayed_grouped`] keeps the pre-fan-out group-at-a-time
//!   scheduler as the bench baseline and parity witness.
//!
//! [`by_name`]: crate::workloads::by_name
//! [`CapturedTrace`]: crate::trace::CapturedTrace
//! [`Broadcast`]: crate::trace::Broadcast

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::{
    capture_trace, characterize_with, multicore_characterize, reorder_study, replay_characterize,
    replay_characterize_many, replay_characterize_many_sampled, ExperimentConfig, RecordedRun,
};
use crate::ledger::{cell_fingerprint, Fingerprint, Ledger, LedgerRecord, Provenance};
use crate::obs::progress;
use crate::reorder::ReorderKind;
use crate::sim::{CpuConfig, Metrics, SampleReport};
use crate::util::error::{panic_message, Result};
use crate::util::fault;
use crate::util::telemetry::{self, Counter, Stage};
use crate::workloads::{by_name, multicore_names, registry};

/// One experiment scenario — the column dimension of the job grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scenario {
    /// Figs. 1–10 baseline characterization.
    Baseline,
    /// Figs. 14–18: software prefetching enabled.
    SwPrefetch,
    /// Fig. 12: perfect (always-hit) L2.
    PerfectL2,
    /// Fig. 12: perfect (always-hit) LLC.
    PerfectLlc,
    /// Fig. 13 companion: hardware prefetchers disabled.
    NoHwPrefetch,
    /// Tables III/IV: sharded run over `n` cores with LLC/bus contention.
    Multicore(usize),
    /// Table VII: ideal row-buffer DRAM.
    DramIdealRows,
    /// Figs. 20–24: one reordering optimization (reordered-run metrics).
    Reorder(ReorderKind),
}

impl Scenario {
    /// The recorded-trace variant this scenario can replay, expressed as
    /// the `sw_prefetch` flag of the recording it needs (prefetch events
    /// are part of the trace, so the on/off variants are distinct
    /// recordings). `None` means the scenario changes workload execution
    /// itself — sharded multicore runs, reordered visit orders — and must
    /// run directly.
    pub fn trace_variant(self) -> Option<bool> {
        match self {
            Scenario::SwPrefetch => Some(true),
            Scenario::Baseline
            | Scenario::PerfectL2
            | Scenario::PerfectLlc
            | Scenario::NoHwPrefetch
            | Scenario::DramIdealRows => Some(false),
            Scenario::Multicore(_) | Scenario::Reorder(_) => None,
        }
    }

    /// Apply this scenario's CPU-configuration mutation. Direct execution
    /// ([`run_job`]) and trace replay ([`run_jobs_replayed`]) both go
    /// through here, so the two modes cannot drift apart.
    pub fn apply_cpu(self, cpu: &mut CpuConfig) {
        match self {
            Scenario::PerfectL2 => cpu.cache.perfect_l2 = true,
            Scenario::PerfectLlc => cpu.cache.perfect_llc = true,
            Scenario::NoHwPrefetch => cpu.cache.hw_prefetch = false,
            Scenario::DramIdealRows => cpu.dram.ideal_row_hits = true,
            Scenario::Baseline
            | Scenario::SwPrefetch
            | Scenario::Multicore(_)
            | Scenario::Reorder(_) => {}
        }
    }
}

impl Scenario {
    /// Inverse of `Display` (case-insensitive) — how ledger provenance
    /// and baseline JSON cells round-trip back into runnable jobs.
    pub fn parse(s: &str) -> Option<Scenario> {
        let lower = s.trim().to_lowercase();
        match lower.as_str() {
            "baseline" => return Some(Scenario::Baseline),
            "sw-prefetch" => return Some(Scenario::SwPrefetch),
            "perfect-l2" => return Some(Scenario::PerfectL2),
            "perfect-llc" => return Some(Scenario::PerfectLlc),
            "no-hw-prefetch" => return Some(Scenario::NoHwPrefetch),
            "ideal-rows" => return Some(Scenario::DramIdealRows),
            _ => {}
        }
        if let Some(n) = lower.strip_suffix("-core") {
            // 0 cores would divide by zero in multicore_characterize
            return n.parse::<usize>().ok().filter(|&n| n >= 1).map(Scenario::Multicore);
        }
        if let Some(kind) = lower.strip_prefix("reorder:") {
            return ReorderKind::ALL
                .into_iter()
                .find(|k| k.name().to_lowercase() == kind)
                .map(Scenario::Reorder);
        }
        None
    }
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scenario::Baseline => write!(f, "baseline"),
            Scenario::SwPrefetch => write!(f, "sw-prefetch"),
            Scenario::PerfectL2 => write!(f, "perfect-L2"),
            Scenario::PerfectLlc => write!(f, "perfect-LLC"),
            Scenario::NoHwPrefetch => write!(f, "no-hw-prefetch"),
            Scenario::Multicore(n) => write!(f, "{n}-core"),
            Scenario::DramIdealRows => write!(f, "ideal-rows"),
            Scenario::Reorder(k) => write!(f, "reorder:{k}"),
        }
    }
}

/// One unit of driver work: a workload (by paper name) under a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub workload: String,
    pub scenario: Scenario,
}

impl Job {
    pub fn new(workload: impl Into<String>, scenario: Scenario) -> Self {
        Self { workload: workload.into(), scenario }
    }
}

/// Sampling diagnostics attached to a cell that ran under `--sample`
/// (the estimate itself lives in [`JobOutput::metrics`]). A run-time
/// artifact, not part of the ledgered result: cells answered from a warm
/// ledger report `None` here even when the stored metrics came from a
/// sampled run (the fingerprint keys sampled and full cells apart).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampleStat {
    pub windows: usize,
    pub blocks_total: u64,
    pub blocks_detailed: u64,
    /// 95% half-width on the estimated CPI.
    pub cpi_ci95: f64,
}

impl From<&SampleReport> for SampleStat {
    fn from(r: &SampleReport) -> Self {
        Self {
            windows: r.windows,
            blocks_total: r.blocks_total,
            blocks_detailed: r.blocks_detailed,
            cpi_ci95: r.cpi_ci95,
        }
    }
}

/// Result slot for one job, in input order.
#[derive(Debug, Clone)]
pub struct JobOutput {
    pub job: Job,
    pub metrics: Metrics,
    /// Workload quality scalar where the scenario produces one
    /// (multicore aggregation does not).
    pub quality: Option<f64>,
    /// Present when this cell's metrics are a sampled-replay estimate.
    pub sample: Option<SampleStat>,
}

/// One quarantined grid cell: a (workload × scenario) unit whose
/// execution, capture, or replay failed. The rest of the grid completes
/// unaffected (degrade-not-die); `--strict` restores fail-fast.
#[derive(Debug, Clone)]
pub struct FailedCell {
    /// Position in the input job list — the stable join key, since
    /// [`DriverReport::outputs`] only holds successes.
    pub index: usize,
    pub job: Job,
    /// The cell's ledger fingerprint, for cross-referencing reports and
    /// `failures.json`.
    pub fingerprint: Fingerprint,
    /// Stable failure-class tag (a [`TraceError::kind_str`] value, or
    /// `"panic"` for a caught workload/simulator panic).
    ///
    /// [`TraceError::kind_str`]: crate::trace::TraceError::kind_str
    pub kind: String,
    /// One-line human-readable cause.
    pub error: String,
    /// Transient-I/O retries spent before the failure was declared
    /// permanent (0 when the failure was not retryable I/O).
    pub retries: u32,
    /// Time-to-failure: wall-clock nanoseconds from when the cell (or
    /// the capture/batch serving it) started executing until the
    /// failure was declared permanent.
    pub wall_nanos: u64,
    /// Nanoseconds spent sleeping in retry backoff before giving up
    /// (0 when no retryable I/O was involved).
    pub backoff_nanos: u64,
}

/// What [`run_jobs`] / [`run_jobs_replayed`] hand back.
#[derive(Debug)]
pub struct DriverReport {
    /// One output per **successfully completed** input job, in input
    /// order (deterministic regardless of thread interleaving). A clean
    /// run has `outputs.len() == jobs.len()`; failures are quarantined
    /// into [`DriverReport::failed`] instead of occupying a slot.
    pub outputs: Vec<JobOutput>,
    pub threads_used: usize,
    pub wall_seconds: f64,
    /// Workload-cell executions the run actually paid for: one per job in
    /// direct mode, one per (workload × trace variant) capture plus one
    /// per non-replayable cell in replay mode. The replay speedup story
    /// is `outputs.len()` vs this number.
    pub workload_executions: usize,
    /// Cells satisfied straight from the experiment ledger without any
    /// execution or simulation ([`run_jobs_ledgered`]); 0 in the other
    /// modes. A fully warmed ledger reports `cached_cells ==
    /// outputs.len()` and `workload_executions == 0`.
    pub cached_cells: usize,
    /// Quarantined cells, sorted by input index; empty on a clean run.
    /// Under `--strict` ([`ExperimentConfig::strict`]) the first failure
    /// aborts the run, so cells the abort skipped appear in *neither*
    /// `outputs` nor here.
    pub failed: Vec<FailedCell>,
}

/// The standard characterization grid for `cfg`'s profile: a baseline
/// cell per workload the profile implements (mlpack lacks SVM-RBF, LDA
/// and t-SNE) plus the multicore cells of Tables III/IV.
pub fn standard_grid(cfg: &ExperimentConfig) -> Vec<Job> {
    let mut jobs: Vec<Job> = registry()
        .iter()
        .filter(|w| cfg.profile.implements(w.as_ref()))
        .map(|w| Job::new(w.name(), Scenario::Baseline))
        .collect();
    for name in multicore_names(cfg.profile) {
        for cores in [4usize, 8] {
            jobs.push(Job::new(name, Scenario::Multicore(cores)));
        }
    }
    jobs
}

/// The full configuration sweep: every CPU-config scenario column of the
/// paper (baseline, SW prefetch, perfect L2/LLC, HW prefetch off, ideal
/// DRAM rows) for every workload the profile implements, plus the
/// multicore cells. Six replayable cells per workload share one or two
/// recordings under [`run_jobs_replayed`], which is what makes this sweep
/// affordable — the reason the trace store exists.
pub fn full_grid(cfg: &ExperimentConfig) -> Vec<Job> {
    let scenarios = [
        Scenario::Baseline,
        Scenario::SwPrefetch,
        Scenario::PerfectL2,
        Scenario::PerfectLlc,
        Scenario::NoHwPrefetch,
        Scenario::DramIdealRows,
    ];
    let mut jobs: Vec<Job> = Vec::new();
    for w in registry() {
        if !cfg.profile.implements(w.as_ref()) {
            continue;
        }
        for s in scenarios {
            jobs.push(Job::new(w.name(), s));
        }
    }
    for name in multicore_names(cfg.profile) {
        for cores in [4usize, 8] {
            jobs.push(Job::new(name, Scenario::Multicore(cores)));
        }
    }
    jobs
}

/// Run one job synchronously on the current thread.
///
/// Panics on an unknown workload name or a reordering scenario that the
/// workload does not support — grid builders only emit valid cells.
pub fn run_job(cfg: &ExperimentConfig, job: &Job) -> JobOutput {
    let w = by_name(&job.workload)
        .unwrap_or_else(|| panic!("driver: unknown workload {:?}", job.workload));
    let w = w.as_ref();
    let (metrics, quality) = match job.scenario {
        Scenario::Multicore(n) => (multicore_characterize(w, cfg, n), None),
        Scenario::Reorder(kind) => {
            assert!(
                kind.applicable_to(w),
                "driver: {kind} is not applicable to {}",
                w.name()
            );
            let s = reorder_study(w, kind, cfg);
            (s.reordered, Some(s.reordered_quality))
        }
        scenario => {
            // every CPU-config-only scenario shares one code path, with
            // the mutation owned by Scenario::apply_cpu (the same one the
            // replay driver applies)
            let sw_prefetch = scenario.trace_variant() == Some(true);
            let c = characterize_with(w, cfg, sw_prefetch, None, None, |c| scenario.apply_cpu(c));
            (c.metrics, Some(c.result.quality))
        }
    };
    JobOutput { job: job.clone(), metrics, quality, sample: None }
}

/// Failure of one cell before it is joined with its grid position.
struct CellFailure {
    kind: &'static str,
    error: String,
    /// Wall nanoseconds the cell burned before the failure surfaced.
    wall_nanos: u64,
}

impl CellFailure {
    fn at(self, cfg: &ExperimentConfig, index: usize, job: &Job) -> FailedCell {
        FailedCell {
            index,
            job: job.clone(),
            fingerprint: cell_fingerprint(cfg, job),
            kind: self.kind.into(),
            error: self.error,
            retries: 0,
            wall_nanos: self.wall_nanos,
            backoff_nanos: 0,
        }
    }
}

/// [`run_job`] behind a panic boundary: a workload or simulator panic
/// comes back as a typed [`CellFailure`] instead of unwinding through
/// the worker pool. `sabotage` is the pre-claimed `cell-panic` fault
/// decision (evaluated at claim time so the nth occurrence is
/// deterministic under any thread count).
fn run_cell(cfg: &ExperimentConfig, job: &Job, sabotage: bool) -> Result<JobOutput, CellFailure> {
    let _sp = telemetry::span_labeled(Stage::CellRun, &job.workload);
    let t0 = std::time::Instant::now();
    catch_unwind(AssertUnwindSafe(|| {
        if sabotage {
            panic!("injected cell panic: {} / {}", job.workload, job.scenario);
        }
        run_job(cfg, job)
    }))
    .map_err(|p| CellFailure {
        kind: "panic",
        error: panic_message(p.as_ref()).to_string(),
        wall_nanos: t0.elapsed().as_nanos() as u64,
    })
}

/// Report one settled cell to the live progress line and, when a
/// telemetry collector is installed, append its per-cell summary row.
/// The fingerprint is only computed on the armed path — with telemetry
/// off this costs two relaxed atomic loads and nothing else.
fn note_cell(
    cfg: &ExperimentConfig,
    job: &Job,
    status: &str,
    wall_nanos: u64,
    blocks: u64,
    retries: u32,
) {
    progress::cell_done(status == "cached", status == "failed");
    if telemetry::armed() {
        telemetry::cell(telemetry::CellRow {
            fingerprint: cell_fingerprint(cfg, job).to_string(),
            workload: job.workload.clone(),
            scenario: job.scenario.to_string(),
            status: status.into(),
            wall_nanos,
            blocks,
            retries,
        });
    }
}

/// Shared worker-pool skeleton of both driver modes (and the cache-sweep
/// runner): claim unit indices `0..units` from an atomic cursor (work
/// stealing by index, so long units do not convoy behind short ones)
/// across up to `threads` OS threads (`0` = one per available core,
/// capped at the unit count). Returns the thread count actually used.
pub(crate) fn fan_out(units: usize, threads: usize, work: impl Fn(usize) + Sync) -> usize {
    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let requested = if threads == 0 { auto } else { threads };
    let threads_used = requested.min(units).max(1);
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads_used {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= units {
                    break;
                }
                work(i);
            });
        }
    });
    threads_used
}

/// Unwrap the per-job result slots in input order. Unfilled slots
/// belong to quarantined (or strict-aborted) cells and are skipped —
/// the caller joins them back via [`FailedCell::index`].
fn collect_slots(slots: Vec<Mutex<Option<JobOutput>>>) -> Vec<JobOutput> {
    slots.into_iter().filter_map(|m| m.into_inner().unwrap()).collect()
}

/// Unwrap the shared failure list, sorted by input index so the report
/// is deterministic regardless of which worker recorded each failure.
fn collect_failures(failures: Mutex<Vec<FailedCell>>) -> Vec<FailedCell> {
    let mut failed = failures.into_inner().unwrap();
    failed.sort_by_key(|f| f.index);
    failed
}

/// Run `jobs` across up to `threads` OS threads (`0` = one per available
/// core). Results land in per-job slots and come back in input order; a
/// failing cell is quarantined into [`DriverReport::failed`] while the
/// rest of the grid completes (or aborts the run under `--strict`).
pub fn run_jobs(cfg: &ExperimentConfig, jobs: &[Job], threads: usize) -> DriverReport {
    let t0 = std::time::Instant::now();
    let slots: Vec<Mutex<Option<JobOutput>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
    let failures: Mutex<Vec<FailedCell>> = Mutex::new(Vec::new());
    let abort = AtomicBool::new(false);
    let threads_used = fan_out(jobs.len(), threads, |i| {
        if abort.load(Ordering::Relaxed) {
            return;
        }
        let sabotage = fault::fired(fault::Site::CellPanic).is_some();
        let t0 = std::time::Instant::now();
        match run_cell(cfg, &jobs[i], sabotage) {
            Ok(out) => {
                note_cell(cfg, &jobs[i], "run", t0.elapsed().as_nanos() as u64, 0, 0);
                *slots[i].lock().unwrap() = Some(out);
            }
            Err(f) => {
                note_cell(cfg, &jobs[i], "failed", t0.elapsed().as_nanos() as u64, 0, 0);
                failures.lock().unwrap().push(f.at(cfg, i, &jobs[i]));
                if cfg.strict {
                    abort.store(true, Ordering::Relaxed);
                }
            }
        }
    });
    DriverReport {
        outputs: collect_slots(slots),
        threads_used,
        wall_seconds: t0.elapsed().as_secs_f64(),
        workload_executions: jobs.len(),
        cached_cells: 0,
        failed: collect_failures(failures),
    }
}

/// Replay-mode work plan: capture groups (a workload × trace-variant
/// execution serving ≥ 2 scenario cells) plus the cells that run
/// directly (non-replayable scenarios and single-cell groups, where
/// buffering a whole trace would cost RAM and save nothing).
struct ReplayPlan<'j> {
    captures: Vec<((&'j str, bool), Vec<usize>)>,
    direct: Vec<usize>,
}

fn plan_replay(jobs: &[Job]) -> ReplayPlan<'_> {
    let mut captures: Vec<((&str, bool), Vec<usize>)> = Vec::new();
    let mut direct: Vec<usize> = Vec::new();
    let mut by_key: BTreeMap<(&str, bool), usize> = BTreeMap::new();
    for (i, job) in jobs.iter().enumerate() {
        match job.scenario.trace_variant() {
            Some(pf) => {
                let key = (job.workload.as_str(), pf);
                let gi = *by_key.entry(key).or_insert_with(|| {
                    captures.push((key, Vec::new()));
                    captures.len() - 1
                });
                captures[gi].1.push(i);
            }
            None => direct.push(i),
        }
    }
    // A capture only pays off when it serves several cells; a
    // single-cell group streams block-by-block directly (O(one block)
    // memory) to the identical Metrics.
    captures.retain_mut(|(_, idxs)| {
        if idxs.len() == 1 {
            direct.push(idxs[0]);
            false
        } else {
            true
        }
    });
    ReplayPlan { captures, direct }
}

/// Run `jobs` in record-once/replay-many mode: execute each (workload ×
/// trace-variant) once, then satisfy every CPU-config-only scenario cell
/// by replaying the captured trace; non-replayable cells — and groups
/// whose capture would serve only a single cell — run directly. Results
/// are bit-identical to [`run_jobs`] and come back in input order; only
/// `workload_executions` (and the wall clock) differ.
///
/// Scheduling is **intra-capture fan-out**: a finished capture is shared
/// via `Arc` and its (capture × scenario-cell) replay units are claimed
/// independently by any idle worker, so a grid with few workloads × many
/// scenario columns no longer convoys behind one thread per capture
/// group (the scheduling [`run_jobs_replayed_grouped`] retains). The
/// bounded-memory guarantee is unchanged: at most `threads` captures are
/// resident at once — a capture may only start while fewer than that
/// many are live, and a capture is dropped the moment its last cell
/// completes.
pub fn run_jobs_replayed(cfg: &ExperimentConfig, jobs: &[Job], threads: usize) -> DriverReport {
    let t0 = std::time::Instant::now();
    let plan = plan_replay(jobs);

    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let requested = if threads == 0 { auto } else { threads };
    let threads_used = requested.min(jobs.len()).max(1);
    let resident_cap = threads_used;

    let executions = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<JobOutput>>> = jobs.iter().map(|_| Mutex::new(None)).collect();
    let failures: Mutex<Vec<FailedCell>> = Mutex::new(Vec::new());

    /// Scheduler state: claim cursors, the ready-cell queue, and the
    /// resident captures. Guarded by one mutex; workers park on the
    /// condvar when captures are pending but the residency cap is hit.
    struct Sched {
        next_capture: usize,
        next_direct: usize,
        /// `(group, job index)` replay cells whose capture is resident.
        ready: VecDeque<(usize, usize)>,
        recorded: Vec<Option<Arc<RecordedRun>>>,
        /// Unfinished cells per capture group (drop the capture at 0).
        remaining: Vec<usize>,
        resident: usize,
        completed: usize,
        /// A worker panicked: peers must stop waiting and exit so the
        /// panic can propagate out of `thread::scope` instead of the
        /// process wedging on a `Condvar` that will never be notified.
        aborted: bool,
    }
    /// Scheduler-lock acquisition with the wait charged to the
    /// `sched_lock_nanos` contention counter; a plain `lock()` when
    /// telemetry is off.
    fn lock_sched(state: &Mutex<Sched>) -> std::sync::MutexGuard<'_, Sched> {
        if !telemetry::armed() {
            return state.lock().unwrap();
        }
        let t0 = std::time::Instant::now();
        let guard = state.lock().unwrap();
        telemetry::add(Counter::SchedLockNanos, t0.elapsed().as_nanos() as u64);
        guard
    }
    let state = Mutex::new(Sched {
        next_capture: 0,
        next_direct: 0,
        ready: VecDeque::new(),
        recorded: vec![None; plan.captures.len()],
        remaining: plan.captures.iter().map(|(_, idxs)| idxs.len()).collect(),
        resident: 0,
        completed: 0,
        aborted: false,
    });
    let cv = Condvar::new();
    let total_cells = jobs.len();

    /// Raises `Sched::aborted` if the owning worker unwinds (workload
    /// panics surface through `capture_trace`/`run_job`); disarmed on a
    /// normal exit.
    struct AbortOnPanic<'a> {
        state: &'a Mutex<Sched>,
        cv: &'a Condvar,
        armed: bool,
    }
    impl Drop for AbortOnPanic<'_> {
        fn drop(&mut self) {
            if self.armed {
                // ignore poisoning: if the lock is poisoned every peer's
                // own lock().unwrap() already terminates it
                if let Ok(mut st) = self.state.lock() {
                    st.aborted = true;
                }
                self.cv.notify_all();
            }
        }
    }

    std::thread::scope(|scope| {
        for _ in 0..threads_used {
            scope.spawn(|| {
                let mut guard = AbortOnPanic { state: &state, cv: &cv, armed: true };
                let mut st = lock_sched(&state);
                loop {
                    if st.aborted {
                        break;
                    }
                    // 1. replay cells first: they retire resident
                    //    captures, which is what frees residency slots.
                    //    Same-capture cells are claimed as a *broadcast
                    //    batch* sized so the ready backlog spreads over
                    //    the pool: with at least one worker per ready
                    //    cell the batch is a single cell (pure
                    //    intra-capture fan-out, the pre-broadcast
                    //    scheduling), while a many-cells-per-worker
                    //    backlog widens it so one walk of the captured
                    //    stream feeds a whole bank of simulators.
                    if let Some((g, i)) = st.ready.pop_front() {
                        let rec =
                            st.recorded[g].clone().expect("ready cell implies resident capture");
                        let mut batch = vec![i];
                        // cells enqueue in one per-capture burst, so the
                        // group's remaining cells sit contiguously at the
                        // front of the queue
                        let ready_in_group =
                            1 + st.ready.iter().take_while(|&&(g2, _)| g2 == g).count();
                        let take = ready_in_group.div_ceil(threads_used);
                        while batch.len() < take {
                            match st.ready.front() {
                                Some(&(g2, _)) if g2 == g => {
                                    batch.push(st.ready.pop_front().unwrap().1);
                                }
                                _ => break,
                            }
                        }
                        // `cell-panic` is claimed under the lock — one
                        // decision per batch — so the nth occurrence is
                        // deterministic under any thread interleaving
                        let sabotage = fault::fired(fault::Site::CellPanic).is_some();
                        drop(st);
                        telemetry::add(Counter::BatchWidthSum, batch.len() as u64);
                        telemetry::maximize(Counter::BatchWidthMax, batch.len() as u64);
                        telemetry::add(Counter::Batches, 1);
                        let batch_span =
                            telemetry::span_labeled(Stage::CellRun, &jobs[batch[0]].workload);
                        let t_batch = std::time::Instant::now();
                        let scenarios: Vec<Scenario> =
                            batch.iter().map(|&i| jobs[i].scenario).collect();
                        // sampled replay swaps the estimator in per-cell;
                        // scheduling and broadcast batching are identical
                        let cells = catch_unwind(AssertUnwindSafe(|| {
                            if sabotage {
                                panic!(
                                    "injected cell panic replaying {} ({} cells)",
                                    jobs[batch[0]].workload,
                                    batch.len()
                                );
                            }
                            let out: Vec<(Metrics, Option<SampleStat>)> = match cfg.sample {
                                Some(sc) => {
                                    replay_characterize_many_sampled(&rec, cfg, &scenarios, sc)
                                        .into_iter()
                                        .map(|r| {
                                            let stat = SampleStat::from(&r);
                                            (r.estimate, Some(stat))
                                        })
                                        .collect()
                                }
                                None => replay_characterize_many(&rec, cfg, &scenarios)
                                    .into_iter()
                                    .map(|m| (m, None))
                                    .collect(),
                            };
                            out
                        }));
                        drop(batch_span);
                        // the batch pays one wall; amortize it per cell
                        // so the per-cell rows stay order-of-magnitude
                        // honest (same convention as ledger provenance)
                        let cell_wall = t_batch.elapsed().as_nanos() as u64 / batch.len() as u64;
                        let mut batch_failed = false;
                        match cells {
                            Ok(cells) => {
                                for (&i, (m, stat)) in batch.iter().zip(cells) {
                                    note_cell(
                                        cfg,
                                        &jobs[i],
                                        "run",
                                        cell_wall,
                                        rec.trace.blocks() as u64,
                                        0,
                                    );
                                    *slots[i].lock().unwrap() = Some(JobOutput {
                                        job: jobs[i].clone(),
                                        metrics: m,
                                        quality: Some(rec.result.quality),
                                        sample: stat,
                                    });
                                }
                            }
                            Err(p) => {
                                // quarantine exactly this batch: the
                                // capture itself is immutable and keeps
                                // serving the group's other cells
                                batch_failed = true;
                                let msg = panic_message(p.as_ref());
                                let mut fl = failures.lock().unwrap();
                                for &i in &batch {
                                    note_cell(cfg, &jobs[i], "failed", cell_wall, 0, 0);
                                    fl.push(FailedCell {
                                        index: i,
                                        job: jobs[i].clone(),
                                        fingerprint: cell_fingerprint(cfg, &jobs[i]),
                                        kind: "panic".into(),
                                        error: format!("replay failed: {msg}"),
                                        retries: 0,
                                        wall_nanos: cell_wall,
                                        backoff_nanos: 0,
                                    });
                                }
                            }
                        }
                        drop(rec);
                        st = lock_sched(&state);
                        if batch_failed && cfg.strict {
                            st.aborted = true;
                            cv.notify_all();
                        }
                        st.completed += batch.len();
                        st.remaining[g] -= batch.len();
                        if st.remaining[g] == 0 {
                            st.recorded[g] = None;
                            st.resident -= 1;
                            cv.notify_all();
                        }
                        if st.completed == total_cells {
                            cv.notify_all();
                        }
                        continue;
                    }
                    // 2. captures next: each unlocks a batch of cells
                    if st.next_capture < plan.captures.len() && st.resident < resident_cap {
                        let g = st.next_capture;
                        st.next_capture += 1;
                        st.resident += 1;
                        // capture claims are sequential under the lock,
                        // so the nth `capture-panic` occurrence lands on
                        // a deterministic group at any thread count
                        let sabotage = fault::fired(fault::Site::CapturePanic).is_some();
                        drop(st);
                        let (name, sw_prefetch) = plan.captures[g].0;
                        let cap_span = telemetry::span_labeled(Stage::Capture, name);
                        let t_cap = std::time::Instant::now();
                        let captured = catch_unwind(AssertUnwindSafe(|| {
                            if sabotage {
                                panic!("injected capture panic: {name}");
                            }
                            let w = by_name(name)
                                .unwrap_or_else(|| panic!("driver: unknown workload {name:?}"));
                            Arc::new(capture_trace(w.as_ref(), cfg, sw_prefetch))
                        }));
                        drop(cap_span);
                        let cap_wall = t_cap.elapsed().as_nanos() as u64;
                        st = lock_sched(&state);
                        match captured {
                            Ok(rec) => {
                                executions.fetch_add(1, Ordering::Relaxed);
                                st.recorded[g] = Some(rec);
                                for &i in &plan.captures[g].1 {
                                    st.ready.push_back((g, i));
                                }
                            }
                            Err(p) => {
                                // a dead capture takes its whole group
                                // with it: every cell waiting on this
                                // recording is quarantined and the
                                // residency slot is released
                                let msg = panic_message(p.as_ref());
                                let mut fl = failures.lock().unwrap();
                                for &i in &plan.captures[g].1 {
                                    // every cell of the group waited the
                                    // full capture wall for its failure
                                    note_cell(cfg, &jobs[i], "failed", cap_wall, 0, 0);
                                    fl.push(FailedCell {
                                        index: i,
                                        job: jobs[i].clone(),
                                        fingerprint: cell_fingerprint(cfg, &jobs[i]),
                                        kind: "panic".into(),
                                        error: format!("capture failed: {msg}"),
                                        retries: 0,
                                        wall_nanos: cap_wall,
                                        backoff_nanos: 0,
                                    });
                                }
                                drop(fl);
                                st.resident -= 1;
                                st.remaining[g] = 0;
                                st.completed += plan.captures[g].1.len();
                                if cfg.strict {
                                    st.aborted = true;
                                }
                            }
                        }
                        cv.notify_all();
                        continue;
                    }
                    // 3. direct cells last: independent, unlock nothing
                    if st.next_direct < plan.direct.len() {
                        let i = plan.direct[st.next_direct];
                        st.next_direct += 1;
                        let sabotage = fault::fired(fault::Site::CellPanic).is_some();
                        drop(st);
                        let t_cell = std::time::Instant::now();
                        let result = run_cell(cfg, &jobs[i], sabotage);
                        let cell_wall = t_cell.elapsed().as_nanos() as u64;
                        let cell_failed = result.is_err();
                        match result {
                            Ok(out) => {
                                note_cell(cfg, &jobs[i], "run", cell_wall, 0, 0);
                                executions.fetch_add(1, Ordering::Relaxed);
                                *slots[i].lock().unwrap() = Some(out);
                            }
                            Err(f) => {
                                note_cell(cfg, &jobs[i], "failed", cell_wall, 0, 0);
                                failures.lock().unwrap().push(f.at(cfg, i, &jobs[i]));
                            }
                        }
                        st = lock_sched(&state);
                        if cell_failed && cfg.strict {
                            st.aborted = true;
                            cv.notify_all();
                        }
                        st.completed += 1;
                        if st.completed == total_cells {
                            cv.notify_all();
                        }
                        continue;
                    }
                    if st.completed == total_cells {
                        break;
                    }
                    // captures pending behind the residency cap, or
                    // in-flight work that will enqueue more cells
                    if telemetry::armed() {
                        let t_wait = std::time::Instant::now();
                        st = cv.wait(st).unwrap();
                        telemetry::add(
                            Counter::QueueWaitNanos,
                            t_wait.elapsed().as_nanos() as u64,
                        );
                    } else {
                        st = cv.wait(st).unwrap();
                    }
                }
                drop(st);
                guard.armed = false;
            });
        }
    });

    DriverReport {
        outputs: collect_slots(slots),
        threads_used,
        wall_seconds: t0.elapsed().as_secs_f64(),
        workload_executions: executions.into_inner(),
        cached_cells: 0,
        failed: collect_failures(failures),
    }
}

/// The pre-fan-out replay scheduler: work is claimed group-at-a-time (a
/// group = one capture plus **all** the cells it serves, executed by the
/// one worker that claimed it). Kept as the scheduling baseline for
/// `benches/grid_replay.rs` — the convoy it forms on few-workload ×
/// many-scenario grids is exactly what [`run_jobs_replayed`] removes —
/// and as a parity witness: both schedulers must produce bit-identical
/// outputs.
pub fn run_jobs_replayed_grouped(
    cfg: &ExperimentConfig,
    jobs: &[Job],
    threads: usize,
) -> DriverReport {
    let t0 = std::time::Instant::now();
    let plan = plan_replay(jobs);

    // one unit per capture group, then one per direct cell
    let units = plan.captures.len() + plan.direct.len();
    let executions = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<JobOutput>>> = jobs.iter().map(|_| Mutex::new(None)).collect();

    let failures: Mutex<Vec<FailedCell>> = Mutex::new(Vec::new());
    let threads_used = fan_out(units, threads, |u| {
        if let Some((key, idxs)) = plan.captures.get(u) {
            let (name, sw_prefetch) = *key;
            // the whole group shares one panic boundary: a capture or
            // replay panic quarantines every cell the recording serves
            let t_group = std::time::Instant::now();
            let group = catch_unwind(AssertUnwindSafe(|| {
                let w =
                    by_name(name).unwrap_or_else(|| panic!("driver: unknown workload {name:?}"));
                let recorded = capture_trace(w.as_ref(), cfg, sw_prefetch);
                executions.fetch_add(1, Ordering::Relaxed);
                for &i in idxs {
                    let job = &jobs[i];
                    let (metrics, stat) = match cfg.sample {
                        Some(sc) => {
                            let r = super::replay_characterize_sampled(&recorded, cfg, sc, |c| {
                                job.scenario.apply_cpu(c)
                            });
                            let stat = SampleStat::from(&r);
                            (r.estimate, Some(stat))
                        }
                        None => (
                            replay_characterize(&recorded, cfg, |c| job.scenario.apply_cpu(c)),
                            None,
                        ),
                    };
                    *slots[i].lock().unwrap() = Some(JobOutput {
                        job: job.clone(),
                        metrics,
                        quality: Some(recorded.result.quality),
                        sample: stat,
                    });
                }
            }));
            if let Err(p) = group {
                let msg = panic_message(p.as_ref());
                let mut fl = failures.lock().unwrap();
                for &i in idxs {
                    // a cell filled before a mid-group panic must not
                    // appear in both outputs and the quarantine list
                    *slots[i].lock().unwrap() = None;
                    fl.push(FailedCell {
                        index: i,
                        job: jobs[i].clone(),
                        fingerprint: cell_fingerprint(cfg, &jobs[i]),
                        kind: "panic".into(),
                        error: format!("capture group failed: {msg}"),
                        retries: 0,
                        wall_nanos: t_group.elapsed().as_nanos() as u64,
                        backoff_nanos: 0,
                    });
                }
            }
        } else {
            let i = plan.direct[u - plan.captures.len()];
            match run_cell(cfg, &jobs[i], false) {
                Ok(out) => {
                    executions.fetch_add(1, Ordering::Relaxed);
                    *slots[i].lock().unwrap() = Some(out);
                }
                Err(f) => failures.lock().unwrap().push(f.at(cfg, i, &jobs[i])),
            }
        }
    });

    DriverReport {
        outputs: collect_slots(slots),
        threads_used,
        wall_seconds: t0.elapsed().as_secs_f64(),
        workload_executions: executions.into_inner(),
        cached_cells: 0,
        failed: collect_failures(failures),
    }
}

/// Run `jobs` through the experiment ledger: cells whose
/// [`cell_fingerprint`] is already stored are answered from disk without
/// touching a workload or simulator; only the misses run (via
/// [`run_jobs_replayed`], so they still share captures), and their
/// results are appended to the ledger before returning. Results come
/// back in input order either way, and a cached cell's `Metrics` are
/// bit-identical to the run that produced them (the store round-trips
/// `f64`s by bit pattern) — so a warm second run renders byte-identical
/// tables while reporting `workload_executions == 0`.
pub fn run_jobs_ledgered(
    cfg: &ExperimentConfig,
    jobs: &[Job],
    threads: usize,
    ledger: &mut Ledger,
) -> Result<DriverReport> {
    let t0 = std::time::Instant::now();
    let fps: Vec<Fingerprint> = jobs.iter().map(|j| cell_fingerprint(cfg, j)).collect();
    let mut outputs: Vec<Option<JobOutput>> = vec![None; jobs.len()];
    let mut miss_idx: Vec<usize> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        match ledger.get(fps[i]) {
            Some(rec) => {
                // the cell is settled without touching a workload or
                // simulator: count the hit where it becomes a cached
                // output (Counter::LedgerHit == cached_cells by
                // construction) and reuse the already-computed
                // fingerprint for the per-cell telemetry row
                telemetry::add(Counter::LedgerHit, 1);
                progress::cell_done(true, false);
                if telemetry::armed() {
                    telemetry::cell(telemetry::CellRow {
                        fingerprint: fps[i].to_string(),
                        workload: job.workload.clone(),
                        scenario: job.scenario.to_string(),
                        status: "cached".into(),
                        // the wall the original (recorded) run paid, not
                        // this run's lookup time
                        wall_nanos: rec.provenance.wall_nanos,
                        blocks: 0,
                        retries: 0,
                    });
                }
                outputs[i] = Some(JobOutput {
                    job: job.clone(),
                    metrics: rec.metrics.clone(),
                    quality: rec.quality,
                    // the CI is a run-time diagnostic, not a ledgered
                    // result; the fingerprint already keys sampled and
                    // full cells apart so the metrics themselves are
                    // never cross-served
                    sample: None,
                });
            }
            None => miss_idx.push(i),
        }
    }
    let cached_cells = jobs.len() - miss_idx.len();

    let mut workload_executions = 0;
    let mut threads_used = 1;
    let mut failed: Vec<FailedCell> = Vec::new();
    if !miss_idx.is_empty() {
        let missing: Vec<Job> = miss_idx.iter().map(|&i| jobs[i].clone()).collect();
        let sub = run_jobs_replayed(cfg, &missing, threads);
        workload_executions = sub.workload_executions;
        threads_used = sub.threads_used;
        // remap quarantined cells from missing-list positions back to
        // grid positions; failed cells are *not* appended to the ledger
        // (a retry after the fault clears must re-execute them)
        let failed_sub: std::collections::BTreeSet<usize> =
            sub.failed.iter().map(|f| f.index).collect();
        failed = sub
            .failed
            .into_iter()
            .map(|mut f| {
                f.index = miss_idx[f.index];
                f
            })
            .collect();
        if cfg.strict && !failed.is_empty() {
            // fail-fast: the abort may have skipped cells that neither
            // succeeded nor failed, making output positions ambiguous —
            // return what the ledger already held plus the quarantine
            // list, appending nothing from this aborted batch
            return Ok(DriverReport {
                outputs: outputs.into_iter().flatten().collect(),
                threads_used,
                wall_seconds: t0.elapsed().as_secs_f64(),
                workload_executions,
                cached_cells,
                failed,
            });
        }
        // wall time is paid per batch, not per cell — amortize it so the
        // provenance stays order-of-magnitude honest
        let wall_nanos = (sub.wall_seconds * 1e9) as u64 / missing.len().max(1) as u64;
        let unix_secs = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        // sub.outputs holds the successes in missing-list order, so
        // walking the misses and skipping the known failures lines the
        // two back up index-for-index
        let mut out_iter = sub.outputs.into_iter();
        for (k, &i) in miss_idx.iter().enumerate() {
            if failed_sub.contains(&k) {
                continue;
            }
            let out = out_iter.next().expect("one output per non-failed miss");
            ledger.append(LedgerRecord {
                fingerprint: fps[i],
                provenance: cell_provenance(cfg, &out.job, wall_nanos, unix_secs),
                metrics: out.metrics.clone(),
                quality: out.quality,
            })?;
            outputs[i] = Some(out);
        }
    }

    Ok(DriverReport {
        outputs: outputs.into_iter().flatten().collect(),
        threads_used,
        wall_seconds: t0.elapsed().as_secs_f64(),
        workload_executions,
        cached_cells,
        failed,
    })
}

/// Provenance block for a freshly executed cell (shared with the serve
/// daemon's miss path, which appends to its sharded ledger).
pub(crate) fn cell_provenance(
    cfg: &ExperimentConfig,
    job: &Job,
    wall_nanos: u64,
    unix_secs: u64,
) -> Provenance {
    let rows = by_name(&job.workload)
        .map(|w| cfg.rows_for(w.as_ref()) as u64)
        .unwrap_or(0);
    Provenance {
        workload: job.workload.clone(),
        scenario: job.scenario.to_string(),
        profile: format!("{:?}", cfg.profile),
        rows,
        features: cfg.features as u64,
        iterations: cfg.iterations as u64,
        seed: cfg.seed,
        dataset_bytes: rows * cfg.features as u64 * 8,
        wall_nanos,
        unix_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExperimentConfig {
        ExperimentConfig { scale: 0.02, iterations: 1, ..Default::default() }
    }

    #[test]
    fn outputs_follow_input_order() {
        let cfg = tiny();
        let jobs = vec![
            Job::new("KMeans", Scenario::Baseline),
            Job::new("KNN", Scenario::SwPrefetch),
            Job::new("Ridge", Scenario::Baseline),
        ];
        let report = run_jobs(&cfg, &jobs, 3);
        assert_eq!(report.outputs.len(), 3);
        for (job, out) in jobs.iter().zip(&report.outputs) {
            assert_eq!(*job, out.job);
            assert!(out.metrics.instructions > 0, "{job:?}");
        }
    }

    #[test]
    fn parallel_results_equal_sequential() {
        let cfg = tiny();
        let jobs = vec![
            Job::new("KMeans", Scenario::Baseline),
            Job::new("DBSCAN", Scenario::Baseline),
            Job::new("KNN", Scenario::PerfectLlc),
            Job::new("GMM", Scenario::Multicore(2)),
        ];
        let seq = run_jobs(&cfg, &jobs, 1);
        let par = run_jobs(&cfg, &jobs, 4);
        assert_eq!(par.threads_used, 4);
        for (a, b) in seq.outputs.iter().zip(&par.outputs) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.metrics, b.metrics, "{:?}", a.job);
            assert_eq!(a.quality, b.quality);
        }
    }

    #[test]
    fn standard_grid_covers_every_workload() {
        let cfg = tiny();
        let jobs = standard_grid(&cfg);
        for w in crate::workloads::registry() {
            assert!(
                jobs.iter().any(|j| j.workload == w.name()),
                "missing {}",
                w.name()
            );
        }
        assert!(jobs.iter().any(|j| matches!(j.scenario, Scenario::Multicore(8))));
    }

    #[test]
    fn zero_threads_means_auto() {
        let cfg = tiny();
        let jobs = vec![Job::new("Lasso", Scenario::Baseline)];
        let report = run_jobs(&cfg, &jobs, 0);
        assert_eq!(report.threads_used, 1, "capped at job count");
        assert!(report.outputs[0].quality.is_some());
    }

    #[test]
    fn replayed_grid_matches_direct_and_executes_once() {
        let cfg = tiny();
        let jobs = vec![
            Job::new("KMeans", Scenario::Baseline),
            Job::new("KMeans", Scenario::PerfectL2),
            Job::new("KMeans", Scenario::PerfectLlc),
            Job::new("KMeans", Scenario::NoHwPrefetch),
        ];
        let direct = run_jobs(&cfg, &jobs, 2);
        let replayed = run_jobs_replayed(&cfg, &jobs, 2);
        assert_eq!(replayed.workload_executions, 1, "4 scenario cells, one execution");
        assert_eq!(direct.workload_executions, 4);
        assert_eq!(replayed.outputs.len(), 4);
        for (a, b) in direct.outputs.iter().zip(&replayed.outputs) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.metrics, b.metrics, "replay diverged for {:?}", a.job);
            assert_eq!(a.quality, b.quality);
        }
    }

    #[test]
    fn broadcast_batches_match_direct_on_one_thread() {
        // threads = 1 with five ready cells per capture forces the widest
        // broadcast batch — every cell of the group satisfied from one
        // walk of the captured stream — which must stay bit-identical to
        // direct per-cell execution
        let cfg = tiny();
        let jobs = vec![
            Job::new("KNN", Scenario::Baseline),
            Job::new("KNN", Scenario::PerfectL2),
            Job::new("KNN", Scenario::PerfectLlc),
            Job::new("KNN", Scenario::NoHwPrefetch),
            Job::new("KNN", Scenario::DramIdealRows),
        ];
        let direct = run_jobs(&cfg, &jobs, 1);
        let replayed = run_jobs_replayed(&cfg, &jobs, 1);
        assert_eq!(replayed.workload_executions, 1, "five cells, one execution");
        for (a, b) in direct.outputs.iter().zip(&replayed.outputs) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.metrics, b.metrics, "broadcast batch diverged for {:?}", a.job);
            assert_eq!(a.quality, b.quality);
        }
    }

    #[test]
    fn replayed_grid_handles_prefetch_variants_and_direct_cells() {
        let cfg = tiny();
        let jobs = vec![
            Job::new("KNN", Scenario::SwPrefetch),
            Job::new("GMM", Scenario::Multicore(2)),
            Job::new("KNN", Scenario::Baseline),
        ];
        let direct = run_jobs(&cfg, &jobs, 1);
        let replayed = run_jobs_replayed(&cfg, &jobs, 3);
        // KNN needs both trace variants (prefetch on and off) and the
        // multicore cell runs directly: 3 executions either way here, but
        // the outputs must still be bit-identical across modes.
        assert_eq!(replayed.workload_executions, 3);
        for (a, b) in direct.outputs.iter().zip(&replayed.outputs) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.metrics, b.metrics, "replay diverged for {:?}", a.job);
            assert_eq!(a.quality, b.quality);
        }
    }

    #[test]
    fn sampled_replay_grid_is_deterministic_and_reports_ci() {
        // the window schedule is positional over each capture's block
        // stream, and every broadcast batch replays the capture from
        // block 0 — so cell results cannot depend on thread count or
        // batch composition
        let cfg = ExperimentConfig {
            sample: Some(crate::sim::SampleConfig { detail: 2, period: 16 }),
            ..tiny()
        };
        let jobs = vec![
            Job::new("KMeans", Scenario::Baseline),
            Job::new("KMeans", Scenario::PerfectLlc),
            Job::new("KMeans", Scenario::NoHwPrefetch),
            Job::new("GMM", Scenario::Multicore(2)),
        ];
        let a = run_jobs_replayed(&cfg, &jobs, 1);
        let b = run_jobs_replayed(&cfg, &jobs, 3);
        for (x, y) in a.outputs.iter().zip(&b.outputs) {
            assert_eq!(x.job, y.job);
            assert_eq!(x.metrics, y.metrics, "sampled replay nondeterministic: {:?}", x.job);
            assert_eq!(x.sample, y.sample);
        }
        // replayable cells carry the CI; the direct multicore cell is full
        for out in &a.outputs {
            match out.job.scenario {
                Scenario::Multicore(_) => assert!(out.sample.is_none()),
                _ => {
                    let s = out.sample.expect("replay cell must report sampling stats");
                    assert!(s.cpi_ci95 > 0.0);
                    assert!(s.blocks_detailed < s.blocks_total, "{s:?}");
                }
            }
        }
        // and the grouped scheduler agrees bit-for-bit
        let g = run_jobs_replayed_grouped(&cfg, &jobs, 2);
        for (x, y) in a.outputs.iter().zip(&g.outputs) {
            assert_eq!(x.metrics, y.metrics, "grouped sampled replay diverged: {:?}", x.job);
            assert_eq!(x.sample, y.sample);
        }
    }

    #[test]
    fn full_grid_covers_scenarios_and_respects_profile() {
        let cfg = tiny();
        let jobs = full_grid(&cfg);
        let kmeans_replayable = jobs
            .iter()
            .filter(|j| j.workload == "KMeans" && j.scenario.trace_variant().is_some())
            .count();
        assert_eq!(kmeans_replayable, 6, "six CPU-config scenario columns per workload");
        let cfg_ml = ExperimentConfig {
            profile: crate::workloads::LibraryProfile::Mlpack,
            ..tiny()
        };
        assert!(!full_grid(&cfg_ml).iter().any(|j| j.workload == "t-SNE"));
    }

    #[test]
    fn scenario_display_parse_roundtrip() {
        let all = [
            Scenario::Baseline,
            Scenario::SwPrefetch,
            Scenario::PerfectL2,
            Scenario::PerfectLlc,
            Scenario::NoHwPrefetch,
            Scenario::Multicore(4),
            Scenario::Multicore(8),
            Scenario::DramIdealRows,
            Scenario::Reorder(ReorderKind::Hilbert),
            Scenario::Reorder(ReorderKind::ZOrderComp),
        ];
        for s in all {
            assert_eq!(Scenario::parse(&s.to_string()), Some(s), "{s}");
        }
        assert_eq!(Scenario::parse("PERFECT-L2"), Some(Scenario::PerfectL2));
        assert_eq!(Scenario::parse("bogus"), None);
        assert_eq!(Scenario::parse("x-core"), None);
        assert_eq!(Scenario::parse("0-core"), None, "0 cores would divide by zero");
        assert_eq!(Scenario::parse("reorder:bogus"), None);
    }

    #[test]
    fn mlpack_grid_excludes_unimplemented_workloads() {
        let cfg = ExperimentConfig {
            profile: crate::workloads::LibraryProfile::Mlpack,
            ..tiny()
        };
        let jobs = standard_grid(&cfg);
        assert!(!jobs.is_empty());
        for j in &jobs {
            let w = by_name(&j.workload).unwrap();
            assert!(w.in_mlpack(), "{} leaked into the mlpack grid", j.workload);
        }
        for absent in ["SVM-RBF", "LDA", "t-SNE"] {
            assert!(!jobs.iter().any(|j| j.workload == absent), "{absent} present");
        }
    }
}
