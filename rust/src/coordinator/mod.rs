//! Experiment coordination: the glue that runs a workload's trace through
//! the simulator stack under each of the paper's scenarios (baseline,
//! perfect caches, software prefetching, reordering, multicore) and
//! returns the paper's metric set.
//!
//! Every figure/table of the paper maps to one function here (see
//! DESIGN.md's experiment index); the bench targets under `rust/benches/`
//! are thin wrappers that format the results.

pub mod driver;
pub mod sweep;

pub use driver::{
    full_grid, run_job, run_jobs, run_jobs_ledgered, run_jobs_replayed,
    run_jobs_replayed_grouped, standard_grid, DriverReport, FailedCell, Job, JobOutput, SampleStat,
    Scenario,
};
pub use sweep::{run_cache_sweep, SweepCell, SweepReport};

use crate::data::Dataset;
use crate::reorder::{compute_plan, ReorderKind, ReorderPlan};
use crate::sim::{
    run_multicore, CpuConfig, Metrics, PipelineSim, SampleConfig, SampleReport, SampledSim,
};
use crate::trace::{
    resolve_ingest_threads, BlockSink, BlockTee, Broadcast, CapturedTrace, NullSink,
    PipelinedIngest, Recorder, ReplaySource, ReplayStats, TraceMeta, TraceSummary, TraceWriter,
};
use crate::util::error::Result;
use crate::workloads::{LibraryProfile, RunContext, RunResult, Workload};
use std::path::Path;

/// Global experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Row-count scale factor applied to each workload's default size
    /// (1.0 reproduces the committed EXPERIMENTS.md numbers; crank it up
    /// to approach the paper's 10M-row scale).
    pub scale: f64,
    pub features: usize,
    pub iterations: usize,
    pub seed: u64,
    pub profile: LibraryProfile,
    pub cpu: CpuConfig,
    /// Shrink the cache hierarchy proportionally when the (scaled-down)
    /// dataset would otherwise fit in the LLC. The paper's datasets are
    /// ~200x the LLC; reduced-scale runs keep the *ratio* working-set :
    /// LLC >= 4 by clamping the LLC to dataset/4 (L2 = LLC/32, L1 = L2/8),
    /// which preserves the miss-rate shape (DESIGN.md "Reduced default
    /// scale"). Disable to simulate the full Table V hierarchy.
    pub auto_shrink: bool,
    /// Total threads for file-trace ingest (`--ingest-threads`): `0` =
    /// auto, `1` = synchronous, `N ≥ 2` = one I/O thread + `N-1`
    /// decoders ([`crate::trace::PipelinedIngest`]). Pure execution
    /// policy: pipelined ingest delivers the bit-identical block stream,
    /// so this knob can never change results and is deliberately
    /// **excluded** from ledger fingerprints (asserted by a test).
    pub ingest_threads: usize,
    /// SMARTS-style sampled replay (`--sample <detail>:<period>`):
    /// `Some` runs replay cells through [`crate::sim::SampledSim`] —
    /// periodic detailed windows + exact functional warming — reporting
    /// estimated timeline metrics with a 95% CI instead of simulating
    /// every block in detail. `None` (default) is full simulation.
    /// Unlike `ingest_threads` this **changes results**, so it enters
    /// ledger fingerprints: sampled and full cells never alias.
    pub sample: Option<SampleConfig>,
    /// Fail-fast mode (`--strict`): the first failing grid cell aborts
    /// the whole run instead of being quarantined into
    /// [`DriverReport::failed`](crate::coordinator::DriverReport). Pure
    /// failure *policy* — it cannot change any successful cell's metrics
    /// — so, like `ingest_threads`, it is excluded from ledger
    /// fingerprints.
    pub strict: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            scale: 1.0,
            features: 20,
            iterations: 2,
            seed: 0xDA7A,
            profile: LibraryProfile::Sklearn,
            cpu: CpuConfig::default(),
            auto_shrink: true,
            ingest_threads: 0,
            sample: None,
            strict: false,
        }
    }
}

impl ExperimentConfig {
    /// Default row count per workload, scaled. Sizes are chosen so each
    /// workload's working set is ≥2× the simulated LLC (8 MiB) while the
    /// trace-driven simulation stays minutes-not-hours (DESIGN.md
    /// "Reduced default scale"); per-workload factors bound the costlier
    /// O(n log n)/ensemble workloads.
    pub fn rows_for(&self, w: &dyn Workload) -> usize {
        let base = match w.name() {
            "Lasso" => 60_000,
            "Ridge" | "PCA" | "Linear SVM" => 120_000,
            "SVM-RBF" => 40_000,
            "LDA" => 4_000,
            "KMeans" | "GMM" => 80_000,
            "KNN" | "DBSCAN" => 30_000,
            "t-SNE" => 12_000,
            "Decision Tree" => 24_000,
            "Random Forests" => 10_000,
            "Adaboost" => 10_000,
            _ => 30_000,
        };
        ((base as f64 * self.scale) as usize).max(256)
    }

    /// RunContext for this config.
    pub fn run_ctx(&self) -> RunContext {
        RunContext {
            iterations: self.iterations,
            seed: self.seed,
            profile: self.profile,
            visit_order: None,
        }
    }
}

/// Output of one characterized run.
pub struct Characterization {
    pub metrics: Metrics,
    pub result: RunResult,
}

/// Run `w` end to end, stream its trace through the pipeline simulator
/// with `mutate` applied to the CPU config, and return the metric set.
pub fn characterize_with(
    w: &dyn Workload,
    cfg: &ExperimentConfig,
    sw_prefetch: bool,
    ctx_override: Option<RunContext>,
    dataset_override: Option<&Dataset>,
    mutate: impl FnOnce(&mut CpuConfig),
) -> Characterization {
    let mut cpu = cfg.cpu.clone();
    mutate(&mut cpu);
    let rows = cfg.rows_for(w);
    let owned;
    let ds: &Dataset = match dataset_override {
        Some(d) => d,
        None => {
            owned = w.make_dataset(rows, cfg.features, cfg.seed);
            &owned
        }
    };
    if cfg.auto_shrink {
        shrink_hierarchy(&mut cpu, ds.bytes());
    }
    let ctx = ctx_override.unwrap_or_else(|| cfg.run_ctx());
    let mut sim = PipelineSim::new(cpu);
    let result = {
        let mut rec = Recorder::new(&mut sim, workload_ns(w));
        rec.sw_prefetch_enabled = sw_prefetch;
        rec.profile_overhead = ctx.profile.loop_overhead_uops();
        let r = w.run(ds, &ctx, &mut rec);
        rec.finish();
        r
    };
    Characterization { metrics: sim.metrics(), result }
}

/// Clamp the hierarchy so the working set is >= 4x the LLC (see
/// [`ExperimentConfig::auto_shrink`]).
pub fn shrink_hierarchy(cpu: &mut CpuConfig, working_set_bytes: u64) {
    let target_llc = (working_set_bytes / 4)
        .next_power_of_two()
        .clamp(128 * 1024, cpu.cache.l3_bytes);
    if target_llc < cpu.cache.l3_bytes {
        cpu.cache.l3_bytes = target_llc;
        cpu.cache.l2_bytes = (target_llc / 32).max(16 * 1024);
        cpu.cache.l1_bytes = (cpu.cache.l2_bytes / 8).max(4 * 1024);
    }
}

/// Baseline characterization (Figs. 1–10).
pub fn characterize(w: &dyn Workload, cfg: &ExperimentConfig) -> Characterization {
    characterize_with(w, cfg, false, None, None, |_| {})
}

/// Trace header for a recording of `w` under `cfg`.
fn trace_meta(
    w: &dyn Workload,
    cfg: &ExperimentConfig,
    sw_prefetch: bool,
    ds: &Dataset,
) -> TraceMeta {
    TraceMeta {
        workload: w.name().to_string(),
        profile: cfg.profile,
        sw_prefetch,
        rows: ds.n_samples() as u64,
        features: ds.n_features() as u64,
        iterations: cfg.iterations as u64,
        seed: cfg.seed,
        dataset_bytes: ds.bytes(),
    }
}

/// One workload execution captured as a replayable in-memory trace — the
/// record half of record-once/replay-many. Replaying [`RecordedRun::trace`]
/// into a `PipelineSim` configured like the original run reproduces its
/// `Metrics` bit-for-bit ([`replay_characterize`]).
pub struct RecordedRun {
    pub trace: CapturedTrace,
    /// Algorithm outcome of the recording run. Scenario replays reuse it:
    /// the trace fixes the execution, so CPU-config variations cannot
    /// change the model quality.
    pub result: RunResult,
    pub meta: TraceMeta,
}

/// Execute `w` once under `cfg`, capturing its block stream in memory
/// for later replays instead of simulating it now.
pub fn capture_trace(w: &dyn Workload, cfg: &ExperimentConfig, sw_prefetch: bool) -> RecordedRun {
    let rows = cfg.rows_for(w);
    let ds = w.make_dataset(rows, cfg.features, cfg.seed);
    let ctx = cfg.run_ctx();
    let mut trace = CapturedTrace::default();
    let result = {
        let mut rec = Recorder::new(&mut trace, workload_ns(w));
        rec.sw_prefetch_enabled = sw_prefetch;
        rec.profile_overhead = ctx.profile.loop_overhead_uops();
        let r = w.run(&ds, &ctx, &mut rec);
        rec.finish();
        r
    };
    let meta = trace_meta(w, cfg, sw_prefetch, &ds);
    RecordedRun { trace, result, meta }
}

/// Replay a captured trace through a fresh `PipelineSim` with `mutate`
/// applied to the CPU config — the replay counterpart of
/// [`characterize_with`], sharing its config discipline (`mutate` first,
/// then `auto_shrink` against the recorded dataset footprint) so the
/// `Metrics` are bit-identical to a direct run under the same scenario.
pub fn replay_characterize(
    recorded: &RecordedRun,
    cfg: &ExperimentConfig,
    mutate: impl FnOnce(&mut CpuConfig),
) -> Metrics {
    let mut cpu = cfg.cpu.clone();
    mutate(&mut cpu);
    if cfg.auto_shrink {
        shrink_hierarchy(&mut cpu, recorded.meta.dataset_bytes);
    }
    let mut sim = PipelineSim::new(cpu);
    recorded.trace.replay_into(&mut sim);
    sim.metrics()
}

/// Broadcast counterpart of [`replay_characterize`]: satisfy every
/// scenario in `scenarios` from **one** pass over the captured block
/// stream — a [`Broadcast`] sink fans each block out to one fresh
/// `PipelineSim` per scenario. Each simulator observes the identical
/// stream it would see replayed alone, and each cell's CPU config goes
/// through the exact [`replay_characterize`] discipline (scenario
/// mutation first, then `auto_shrink` against the recorded footprint),
/// so the returned `Metrics` are bit-identical to per-cell replay
/// (`tests/broadcast.rs` gates this), in `scenarios` order.
pub fn replay_characterize_many(
    recorded: &RecordedRun,
    cfg: &ExperimentConfig,
    scenarios: &[Scenario],
) -> Vec<Metrics> {
    let mut sims: Vec<PipelineSim> = scenarios
        .iter()
        .map(|s| {
            let mut cpu = cfg.cpu.clone();
            s.apply_cpu(&mut cpu);
            if cfg.auto_shrink {
                shrink_hierarchy(&mut cpu, recorded.meta.dataset_bytes);
            }
            PipelineSim::new(cpu)
        })
        .collect();
    {
        let sinks: Vec<&mut dyn BlockSink> =
            sims.iter_mut().map(|s| s as &mut dyn BlockSink).collect();
        let mut bc = Broadcast::new(sinks);
        recorded.trace.replay_into(&mut bc);
    }
    sims.iter().map(PipelineSim::metrics).collect()
}

/// Sampled counterpart of [`replay_characterize`]: the identical config
/// discipline (`mutate` first, then `auto_shrink` against the recorded
/// footprint), but the block stream runs through a [`SampledSim`] —
/// detailed windows + functional warming per `sample` — and the result
/// is a [`SampleReport`] whose estimate carries a CPI confidence
/// interval. With a degenerate `sample` (detail ≥ period) the estimate
/// equals [`replay_characterize`] bit-for-bit.
pub fn replay_characterize_sampled(
    recorded: &RecordedRun,
    cfg: &ExperimentConfig,
    sample: SampleConfig,
    mutate: impl FnOnce(&mut CpuConfig),
) -> SampleReport {
    let mut cpu = cfg.cpu.clone();
    mutate(&mut cpu);
    if cfg.auto_shrink {
        shrink_hierarchy(&mut cpu, recorded.meta.dataset_bytes);
    }
    let mut sim = SampledSim::new(PipelineSim::new(cpu), sample);
    recorded.trace.replay_into(&mut sim);
    sim.into_report()
}

/// Sampled counterpart of [`replay_characterize_many`]: one pass over
/// the captured stream fans out to one [`SampledSim`] per scenario via
/// [`Broadcast`]. The window schedule is positional over the shared
/// block stream, so every scenario samples the *same* windows — their
/// estimates stay comparable cell-to-cell.
pub fn replay_characterize_many_sampled(
    recorded: &RecordedRun,
    cfg: &ExperimentConfig,
    scenarios: &[Scenario],
    sample: SampleConfig,
) -> Vec<SampleReport> {
    let mut sims: Vec<SampledSim> = scenarios
        .iter()
        .map(|s| {
            let mut cpu = cfg.cpu.clone();
            s.apply_cpu(&mut cpu);
            if cfg.auto_shrink {
                shrink_hierarchy(&mut cpu, recorded.meta.dataset_bytes);
            }
            SampledSim::new(PipelineSim::new(cpu), sample)
        })
        .collect();
    {
        let sinks: Vec<&mut dyn BlockSink> =
            sims.iter_mut().map(|s| s as &mut dyn BlockSink).collect();
        let mut bc = Broadcast::new(sinks);
        recorded.trace.replay_into(&mut bc);
    }
    sims.into_iter().map(SampledSim::into_report).collect()
}

/// `mlperf record`: run `w` once, streaming its trace to `path` while
/// simultaneously simulating it (one execution yields both the trace
/// artifact and the baseline metric table).
pub fn record_characterize(
    w: &dyn Workload,
    cfg: &ExperimentConfig,
    sw_prefetch: bool,
    path: &Path,
) -> Result<(Characterization, TraceSummary)> {
    let rows = cfg.rows_for(w);
    let ds = w.make_dataset(rows, cfg.features, cfg.seed);
    let mut cpu = cfg.cpu.clone();
    if cfg.auto_shrink {
        shrink_hierarchy(&mut cpu, ds.bytes());
    }
    let ctx = cfg.run_ctx();
    let mut writer = TraceWriter::create(path, &trace_meta(w, cfg, sw_prefetch, &ds))?;
    let mut sim = PipelineSim::new(cpu);
    let result = {
        let mut tee = BlockTee { a: &mut sim, b: &mut writer };
        let mut rec = Recorder::new(&mut tee, workload_ns(w));
        rec.sw_prefetch_enabled = sw_prefetch;
        rec.profile_overhead = ctx.profile.loop_overhead_uops();
        let r = w.run(&ds, &ctx, &mut rec);
        rec.finish();
        r
    };
    let summary = writer.finish()?;
    Ok((Characterization { metrics: sim.metrics(), result }, summary))
}

/// `mlperf replay`: stream a stored trace file through `PipelineSim`
/// with `mutate` applied to the CPU config, never constructing the
/// workload. `auto_shrink` uses the dataset footprint recorded in the
/// trace header, matching the recording run's hierarchy exactly.
///
/// Ingest is staged per `cfg.ingest_threads` (0 = auto): with ≥ 2
/// effective threads, [`PipelinedIngest`] overlaps file I/O and columnar
/// decode with the simulation; with 1, the synchronous [`ReplaySource`]
/// path runs. Both deliver the identical block stream, so the `Metrics`
/// are bit-identical either way (`rust/tests/ingest.rs` asserts it).
pub fn replay_file(
    path: &Path,
    cfg: &ExperimentConfig,
    mutate: impl FnOnce(&mut CpuConfig),
) -> Result<(TraceMeta, Metrics, ReplayStats)> {
    // the two sources share every step but the final pump, so the
    // config discipline (mutate, then auto_shrink against the recorded
    // footprint) cannot drift between the ingest modes
    enum Src {
        Sync(ReplaySource),
        Pipelined(PipelinedIngest),
    }
    let src = if resolve_ingest_threads(cfg.ingest_threads) > 1 {
        Src::Pipelined(PipelinedIngest::open(path, cfg.ingest_threads)?)
    } else {
        Src::Sync(ReplaySource::open(path)?)
    };
    let meta = match &src {
        Src::Sync(s) => s.meta().clone(),
        Src::Pipelined(s) => s.meta().clone(),
    };
    let mut cpu = cfg.cpu.clone();
    mutate(&mut cpu);
    if cfg.auto_shrink {
        shrink_hierarchy(&mut cpu, meta.dataset_bytes);
    }
    let mut sim = PipelineSim::new(cpu);
    let stats = match src {
        Src::Sync(s) => s.replay_into(&mut sim)?,
        Src::Pipelined(s) => s.replay_into(&mut sim)?,
    };
    Ok((meta, sim.metrics(), stats))
}

/// Broadcast counterpart of [`replay_file`]: one pass over the stored
/// trace — one read, one checksum verification, one columnar decode —
/// feeds a fresh `PipelineSim` per scenario through a [`Broadcast`]
/// sink, returning per-scenario `Metrics` in `scenarios` order. The
/// `ReplayStats` count the single shared decode, so `stats.blocks`
/// equals the file's block count no matter how wide the fan-out
/// (`tests/broadcast.rs` asserts it). Ingest staging follows
/// `cfg.ingest_threads` exactly like [`replay_file`].
pub fn replay_file_many(
    path: &Path,
    cfg: &ExperimentConfig,
    scenarios: &[Scenario],
) -> Result<(TraceMeta, Vec<Metrics>, ReplayStats)> {
    enum Src {
        Sync(ReplaySource),
        Pipelined(PipelinedIngest),
    }
    let src = if resolve_ingest_threads(cfg.ingest_threads) > 1 {
        Src::Pipelined(PipelinedIngest::open(path, cfg.ingest_threads)?)
    } else {
        Src::Sync(ReplaySource::open(path)?)
    };
    let meta = match &src {
        Src::Sync(s) => s.meta().clone(),
        Src::Pipelined(s) => s.meta().clone(),
    };
    let mut sims: Vec<PipelineSim> = scenarios
        .iter()
        .map(|s| {
            let mut cpu = cfg.cpu.clone();
            s.apply_cpu(&mut cpu);
            if cfg.auto_shrink {
                shrink_hierarchy(&mut cpu, meta.dataset_bytes);
            }
            PipelineSim::new(cpu)
        })
        .collect();
    let stats = {
        let sinks: Vec<&mut dyn BlockSink> =
            sims.iter_mut().map(|s| s as &mut dyn BlockSink).collect();
        let mut bc = Broadcast::new(sinks);
        match src {
            Src::Sync(s) => s.replay_into(&mut bc)?,
            Src::Pipelined(s) => s.replay_into(&mut bc)?,
        }
    };
    Ok((meta, sims.iter().map(PipelineSim::metrics).collect(), stats))
}

/// Sampled counterpart of [`replay_file`]: stream a stored trace through
/// a [`SampledSim`]. Ingest staging (`cfg.ingest_threads`) is honoured
/// exactly as in full replay — sampling is downstream of delivery, so
/// pipelined and synchronous ingest produce the identical report.
pub fn replay_file_sampled(
    path: &Path,
    cfg: &ExperimentConfig,
    sample: SampleConfig,
    mutate: impl FnOnce(&mut CpuConfig),
) -> Result<(TraceMeta, SampleReport, ReplayStats)> {
    enum Src {
        Sync(ReplaySource),
        Pipelined(PipelinedIngest),
    }
    let src = if resolve_ingest_threads(cfg.ingest_threads) > 1 {
        Src::Pipelined(PipelinedIngest::open(path, cfg.ingest_threads)?)
    } else {
        Src::Sync(ReplaySource::open(path)?)
    };
    let meta = match &src {
        Src::Sync(s) => s.meta().clone(),
        Src::Pipelined(s) => s.meta().clone(),
    };
    let mut cpu = cfg.cpu.clone();
    mutate(&mut cpu);
    if cfg.auto_shrink {
        shrink_hierarchy(&mut cpu, meta.dataset_bytes);
    }
    let mut sim = SampledSim::new(PipelineSim::new(cpu), sample);
    let stats = match src {
        Src::Sync(s) => s.replay_into(&mut sim)?,
        Src::Pipelined(s) => s.replay_into(&mut sim)?,
    };
    Ok((meta, sim.into_report(), stats))
}

fn workload_ns(w: &dyn Workload) -> u32 {
    // stable per-workload namespace for branch sites
    let mut h: u32 = 0;
    for b in w.name().bytes() {
        h = h.wrapping_mul(31).wrapping_add(b as u32);
    }
    (h % 60000) + 1
}

/// Fig. 12: IPC improvement with perfect L2 / perfect LLC.
pub struct PerfectCacheStudy {
    pub base: Metrics,
    pub perfect_l2: Metrics,
    pub perfect_llc: Metrics,
}

pub fn perfect_cache_study(w: &dyn Workload, cfg: &ExperimentConfig) -> PerfectCacheStudy {
    PerfectCacheStudy {
        base: characterize(w, cfg).metrics,
        perfect_l2: characterize_with(w, cfg, false, None, None, |c| c.cache.perfect_l2 = true)
            .metrics,
        perfect_llc: characterize_with(w, cfg, false, None, None, |c| c.cache.perfect_llc = true)
            .metrics,
    }
}

/// Figs. 14–18: software prefetching before/after.
pub struct PrefetchStudy {
    pub base: Metrics,
    pub prefetched: Metrics,
    pub base_quality: f64,
    pub prefetched_quality: f64,
}

pub fn prefetch_study(w: &dyn Workload, cfg: &ExperimentConfig) -> PrefetchStudy {
    let base = characterize(w, cfg);
    let pf = characterize_with(w, cfg, true, None, None, |_| {});
    PrefetchStudy {
        base: base.metrics,
        prefetched: pf.metrics,
        base_quality: base.result.quality,
        prefetched_quality: pf.result.quality,
    }
}

/// Figs. 20–24: one reordering applied to one workload.
pub struct ReorderStudy {
    pub kind: ReorderKind,
    pub baseline: Metrics,
    pub reordered: Metrics,
    /// Cycles spent computing + applying the reordering (Fig. 24's
    /// overhead term; ~0 events when the kind is offline *and* amortized).
    pub overhead_cycles: f64,
    pub baseline_quality: f64,
    pub reordered_quality: f64,
}

impl ReorderStudy {
    /// Fig. 23: speedup ignoring reordering overhead.
    pub fn speedup_no_overhead(&self) -> f64 {
        self.baseline.cycles / self.reordered.cycles
    }

    /// Fig. 24: speedup with the overhead added to the optimized run.
    pub fn speedup_with_overhead(&self) -> f64 {
        self.baseline.cycles / (self.reordered.cycles + self.overhead_cycles)
    }
}

pub fn reorder_study(w: &dyn Workload, kind: ReorderKind, cfg: &ExperimentConfig) -> ReorderStudy {
    let rows = cfg.rows_for(w);
    let ds = w.make_dataset(rows, cfg.features, cfg.seed);
    let ctx = cfg.run_ctx();

    let baseline = characterize_with(w, cfg, false, Some(ctx.clone()), Some(&ds), |_| {});

    // compute the plan, measuring its overhead through its own simulator
    let mut overhead_sim = PipelineSim::new(cfg.cpu.clone());
    let plan: ReorderPlan = {
        let mut rec = Recorder::new(&mut overhead_sim, 61);
        let p = compute_plan(kind, &ds, w, &ctx, &mut rec);
        rec.finish();
        p
    };
    let overhead_cycles = overhead_sim.metrics().cycles;

    let (ds2, ctx2) = plan.apply(&ds, &ctx);
    let reordered = characterize_with(w, cfg, false, Some(ctx2), Some(&ds2), |_| {});

    ReorderStudy {
        kind,
        baseline: baseline.metrics,
        reordered: reordered.metrics,
        overhead_cycles,
        baseline_quality: baseline.result.quality,
        reordered_quality: reordered.result.quality,
    }
}

/// Tables III/IV: run the workload sharded over `n_cores` with shared
/// LLC/bandwidth contention modelling.
pub fn multicore_characterize(
    w: &dyn Workload,
    cfg: &ExperimentConfig,
    n_cores: usize,
) -> Metrics {
    let rows = cfg.rows_for(w) / n_cores;
    let mut cpu = cfg.cpu.clone();
    if cfg.auto_shrink {
        let per_core_bytes = (rows.max(256) * cfg.features * 8) as u64;
        shrink_hierarchy(&mut cpu, per_core_bytes * n_cores as u64);
    }
    run_multicore(&cpu, n_cores, workload_ns(w), |core, rec| {
        let ds = w.make_dataset(rows.max(256), cfg.features, cfg.seed + core as u64);
        let mut ctx = cfg.run_ctx();
        ctx.seed = cfg.seed + 1000 + core as u64;
        rec.profile_overhead = ctx.profile.loop_overhead_uops();
        w.run(&ds, &ctx, rec);
    })
}

/// DRAM-only study (Table VII): run the workload's DRAM-reaching stream
/// through a DRAM model configured by `mutate_dram`, returning its stats.
pub fn dram_study(
    w: &dyn Workload,
    cfg: &ExperimentConfig,
    ideal_row_hits: bool,
) -> crate::sim::DramStats {
    let c = characterize_with(w, cfg, false, None, None, |c| {
        c.dram.ideal_row_hits = ideal_row_hits;
    });
    c.metrics.dram
}

/// Quick smoke run of a workload at tiny scale (used by tests and the
/// quickstart example).
pub fn smoke(w: &dyn Workload, rows: usize) -> Characterization {
    let cfg = ExperimentConfig {
        scale: rows as f64 / 30_000.0,
        iterations: 1,
        ..Default::default()
    };
    characterize(w, &cfg)
}

/// Run a workload without any simulation (algorithm-only; returns the
/// quality metric) — used to verify optimizations do not change results.
pub fn run_untraced(w: &dyn Workload, ds: &Dataset, ctx: &RunContext) -> RunResult {
    let mut sink = NullSink;
    let mut rec = Recorder::new(&mut sink, workload_ns(w));
    let r = w.run(ds, ctx, &mut rec);
    rec.finish();
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    fn tiny() -> ExperimentConfig {
        ExperimentConfig { scale: 0.02, iterations: 1, ..Default::default() }
    }

    #[test]
    fn characterize_produces_sane_metrics() {
        let w = by_name("kmeans").unwrap();
        let c = characterize(w.as_ref(), &tiny());
        assert!(c.metrics.cpi > 0.05 && c.metrics.cpi < 20.0, "cpi {}", c.metrics.cpi);
        assert!(c.metrics.instructions > 10_000);
        assert!(c.result.quality.is_finite());
    }

    #[test]
    fn perfect_llc_improves_ipc() {
        let w = by_name("knn").unwrap();
        let s = perfect_cache_study(w.as_ref(), &tiny());
        assert!(
            s.perfect_llc.ipc >= s.base.ipc * 0.99,
            "perfect LLC should not hurt: {} vs {}",
            s.perfect_llc.ipc,
            s.base.ipc
        );
        assert!(
            s.perfect_l2.ipc >= s.perfect_llc.ipc * 0.95,
            "perfect L2 at least as good as perfect LLC"
        );
    }

    #[test]
    fn prefetch_study_preserves_quality() {
        let w = by_name("knn").unwrap();
        let s = prefetch_study(w.as_ref(), &tiny());
        assert_eq!(s.base_quality, s.prefetched_quality, "prefetching must not change results");
        // at tiny scale the working set fits in L2 so issued prefetches
        // may be filtered as already-resident; the *instructions* must be
        // there regardless
        assert!(s.prefetched.mix.sw_prefetches > 0, "prefetch instructions expected");
        assert_eq!(s.base.mix.sw_prefetches, 0);
    }

    #[test]
    fn reorder_study_preserves_quality_for_data_layouts() {
        // kNN's LOO accuracy is exactly permutation-invariant (exact
        // search over the same point set), so a data-layout reorder must
        // not change it at all
        let w = by_name("knn").unwrap();
        let s = reorder_study(w.as_ref(), ReorderKind::ZOrder, &tiny());
        assert_eq!(s.baseline_quality, s.reordered_quality);
        assert!(s.overhead_cycles > 0.0);
        assert!(s.speedup_with_overhead() <= s.speedup_no_overhead());
    }

    #[test]
    fn multicore_runs_all_cores() {
        let w = by_name("gmm").unwrap();
        let m = multicore_characterize(w.as_ref(), &tiny(), 4);
        assert!(m.instructions > 0);
        assert!(m.cpi > 0.0);
    }

    #[test]
    fn dram_ideal_mode_hits_always() {
        let w = by_name("dbscan").unwrap();
        let st = dram_study(w.as_ref(), &tiny(), true);
        assert!(st.requests > 0);
        assert_eq!(st.row_hit_ratio(), 1.0);
    }

    #[test]
    fn replayed_capture_matches_direct_metrics() {
        let w = by_name("kmeans").unwrap();
        let cfg = tiny();
        let direct = characterize(w.as_ref(), &cfg);
        let recorded = capture_trace(w.as_ref(), &cfg, false);
        assert!(recorded.trace.is_finalized());
        assert_eq!(recorded.result.quality, direct.result.quality);
        let replayed = replay_characterize(&recorded, &cfg, |_| {});
        assert_eq!(replayed, direct.metrics, "replay must be bit-identical");
        // and under a scenario mutation
        let direct_l2 =
            characterize_with(w.as_ref(), &cfg, false, None, None, |c| c.cache.perfect_l2 = true);
        let replayed_l2 = replay_characterize(&recorded, &cfg, |c| c.cache.perfect_l2 = true);
        assert_eq!(replayed_l2, direct_l2.metrics);
    }

    #[test]
    fn sampled_replay_smoke() {
        let w = by_name("kmeans").unwrap();
        let cfg = tiny();
        let recorded = capture_trace(w.as_ref(), &cfg, false);
        let full = replay_characterize(&recorded, &cfg, |_| {});
        let rep = replay_characterize_sampled(
            &recorded,
            &cfg,
            SampleConfig { detail: 2, period: 16 },
            |_| {},
        );
        assert!(!rep.degenerate);
        assert!(rep.blocks_detailed < rep.blocks_total);
        assert!(
            rep.cpi_within_ci(full.cpi),
            "estimate {} ± {} vs truth {}",
            rep.estimate.cpi,
            rep.cpi_ci95,
            full.cpi
        );
        // state-derived metrics are exact, not estimated
        assert_eq!(rep.estimate.mix, full.mix);
        assert_eq!(rep.estimate.llc_miss_ratio, full.llc_miss_ratio);
        // degenerate sampling is full replay bit-for-bit
        let deg = replay_characterize_sampled(
            &recorded,
            &cfg,
            SampleConfig { detail: 4, period: 4 },
            |_| {},
        );
        assert_eq!(deg.estimate, full);
    }

    #[test]
    fn smoke_runs_every_workload() {
        for w in crate::workloads::registry() {
            let c = smoke(w.as_ref(), 600);
            assert!(
                c.metrics.instructions > 1000,
                "{} produced a trivial trace",
                w.name()
            );
        }
    }
}
