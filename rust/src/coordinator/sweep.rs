//! Cache-geometry sweep runner: `mlperf grid --sweep cache`.
//!
//! A conventional geometry sweep replays the trace once per (size ×
//! associativity) cell. The [`StackProfiler`] collapses that to **one
//! trace pass per workload**: each workload streams its demand-line
//! stream through the reuse-distance profiler exactly once, and every
//! geometry's exact-LRU miss count falls out of the per-set-class
//! histograms in closed form (`sim::stack` module docs). This runner
//! adds the grid plumbing: a worker pool over workloads
//! ([`driver::fan_out`]), per-(workload × geometry) content addressing
//! through the experiment ledger ([`sweep_cell_fingerprint`]), and the
//! report the CLI renders as the miss-curve table / JSON artifact.
//!
//! Ledger granularity is per cell, but execution granularity is per
//! workload: the single pass prices *all* geometries at once, so a
//! workload re-runs iff **any** of its swept cells is missing — the
//! still-cached cells are answered from the ledger and only the missing
//! ones are appended.
//!
//! [`StackProfiler`]: crate::sim::StackProfiler
//! [`driver::fan_out`]: super::driver::fan_out
//! [`sweep_cell_fingerprint`]: crate::ledger::sweep_cell_fingerprint

use std::sync::Mutex;

use super::{driver::fan_out, workload_ns, ExperimentConfig};
use crate::ledger::{sweep_cell_fingerprint, Fingerprint, Ledger, LedgerRecord, Provenance};
use crate::sim::{Metrics, StackProfiler, SweepCurve, SweepGeometry};
use crate::trace::{InstructionMix, Recorder};
use crate::util::error::Result;
use crate::util::telemetry::{self, Stage};
use crate::workloads::by_name;

/// One (workload × geometry) point of the sweep grid.
#[derive(Debug, Clone)]
pub struct SweepCell {
    pub workload: String,
    pub geometry: SweepGeometry,
    /// Demand line accesses — identical for every geometry of a workload
    /// (one shared trace pass).
    pub accesses: u64,
    /// Exact-LRU demand misses at this geometry.
    pub misses: u64,
    pub fingerprint: Fingerprint,
    /// Answered from the ledger without executing the workload.
    pub cached: bool,
}

impl SweepCell {
    pub fn miss_ratio(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }
}

/// What [`run_cache_sweep`] hands back.
#[derive(Debug)]
pub struct SweepReport {
    /// Workload-major, geometry order preserved — deterministic
    /// regardless of worker interleaving.
    pub cells: Vec<SweepCell>,
    /// Workloads that actually executed (0 on a fully warmed ledger).
    pub workload_executions: usize,
    /// Cells answered straight from the ledger.
    pub cached_cells: usize,
    pub threads_used: usize,
    pub wall_seconds: f64,
}

/// Resolve the full (workloads × geometries) miss-curve grid, executing
/// each workload at most once (see the module docs). With a ledger,
/// cached cells are served from disk bit-identically (`u64` counts
/// round-trip exactly) and fresh cells are appended under
/// `scenario = "sweep:<geometry>"` provenance.
pub fn run_cache_sweep(
    cfg: &ExperimentConfig,
    workloads: &[String],
    geometries: &[SweepGeometry],
    threads: usize,
    mut ledger: Option<&mut Ledger>,
) -> Result<SweepReport> {
    let t0 = std::time::Instant::now();
    if workloads.is_empty() || geometries.is_empty() {
        return Ok(SweepReport {
            cells: Vec::new(),
            workload_executions: 0,
            cached_cells: 0,
            threads_used: 1,
            wall_seconds: t0.elapsed().as_secs_f64(),
        });
    }

    // per-workload fingerprint row + which cells the ledger already holds
    let fps: Vec<Vec<Fingerprint>> = workloads
        .iter()
        .map(|w| geometries.iter().map(|&g| sweep_cell_fingerprint(cfg, w, g)).collect())
        .collect();
    let cached_rows: Vec<Vec<Option<(u64, u64)>>> = fps
        .iter()
        .map(|row| {
            row.iter()
                .map(|&fp| {
                    ledger.as_deref().and_then(|l| l.get(fp)).map(|rec| {
                        // sweep cells pack (accesses, misses) into the
                        // u64 metric slots — see the append below
                        (rec.metrics.instructions, rec.metrics.mix.loads)
                    })
                })
                .collect()
        })
        .collect();

    // a workload executes iff any of its swept cells is missing
    let need_run: Vec<usize> = (0..workloads.len())
        .filter(|&wi| cached_rows[wi].iter().any(|c| c.is_none()))
        .collect();
    let curves: Vec<Mutex<Option<Vec<SweepCurve>>>> =
        need_run.iter().map(|_| Mutex::new(None)).collect();

    let threads_used = if need_run.is_empty() {
        1
    } else {
        fan_out(need_run.len(), threads, |u| {
            let name = &workloads[need_run[u]];
            // one span per executed workload: the single profiler pass
            // prices every geometry, so there is no per-geometry wall
            let _sp = telemetry::span_labeled(Stage::SweepCell, name);
            let w = by_name(name)
                .unwrap_or_else(|| panic!("sweep: unknown workload {name:?}"));
            let w = w.as_ref();
            let ds = w.make_dataset(cfg.rows_for(w), cfg.features, cfg.seed);
            let ctx = cfg.run_ctx();
            let mut prof = StackProfiler::new(geometries);
            {
                let mut rec = Recorder::new(&mut prof, workload_ns(w));
                rec.sw_prefetch_enabled = false;
                rec.profile_overhead = ctx.profile.loop_overhead_uops();
                w.run(&ds, &ctx, &mut rec);
                rec.finish();
            }
            *curves[u].lock().unwrap() = Some(prof.curves());
        })
    };

    // assemble cells in deterministic order; append fresh results
    let unix_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let wall_nanos = (t0.elapsed().as_nanos() as u64)
        / (need_run.len().max(1) as u64 * geometries.len() as u64);
    let mut cells = Vec::with_capacity(workloads.len() * geometries.len());
    let mut cached_cells = 0;
    for (wi, name) in workloads.iter().enumerate() {
        let fresh: Option<Vec<SweepCurve>> = need_run
            .iter()
            .position(|&r| r == wi)
            .map(|u| curves[u].lock().unwrap().take().expect("sweep worker filled its slot"));
        for (gi, &g) in geometries.iter().enumerate() {
            let fp = fps[wi][gi];
            let (accesses, misses, cached) = match (cached_rows[wi][gi], &fresh) {
                // a cached cell is served from the ledger even when the
                // workload re-ran for a sibling geometry (equal by
                // determinism; the test asserts it)
                (Some((a, m)), _) => (a, m, true),
                (None, Some(cs)) => {
                    let c = cs[gi];
                    debug_assert_eq!(c.geometry, g);
                    (c.accesses, c.misses, false)
                }
                (None, None) => unreachable!("missing cell implies executed workload"),
            };
            if cached {
                cached_cells += 1;
            } else if let Some(l) = ledger.as_deref_mut() {
                // pack the curve point into the u64 metric slots so it
                // round-trips bit-exactly: instructions = accesses,
                // mix.loads = misses (llc_miss_ratio doubles as the
                // human-readable ratio in `mlperf ledger show`)
                let metrics = Metrics {
                    instructions: accesses,
                    mix: InstructionMix { loads: misses, ..Default::default() },
                    llc_miss_ratio: if accesses == 0 {
                        0.0
                    } else {
                        misses as f64 / accesses as f64
                    },
                    ..Default::default()
                };
                let rows = by_name(name).map(|w| cfg.rows_for(w.as_ref()) as u64).unwrap_or(0);
                l.append(LedgerRecord {
                    fingerprint: fp,
                    provenance: Provenance {
                        workload: name.clone(),
                        scenario: format!("sweep:{}", g.label()),
                        profile: format!("{:?}", cfg.profile),
                        rows,
                        features: cfg.features as u64,
                        iterations: cfg.iterations as u64,
                        seed: cfg.seed,
                        dataset_bytes: rows * cfg.features as u64 * 8,
                        wall_nanos,
                        unix_secs,
                    },
                    metrics,
                    quality: None,
                })?;
            }
            cells.push(SweepCell {
                workload: name.clone(),
                geometry: g,
                accesses,
                misses,
                fingerprint: fp,
                cached,
            });
        }
    }

    Ok(SweepReport {
        cells,
        workload_executions: need_run.len(),
        cached_cells,
        threads_used,
        wall_seconds: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tiny() -> ExperimentConfig {
        ExperimentConfig { scale: 0.02, iterations: 1, ..Default::default() }
    }

    fn small_sweep() -> Vec<SweepGeometry> {
        vec![
            SweepGeometry::new(32 * 1024, 4),
            SweepGeometry::new(64 * 1024, 4),
            SweepGeometry::new(64 * 1024, 8),
        ]
    }

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("mlperf-sweep-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn sweep_fills_every_cell_deterministically() {
        let cfg = tiny();
        let wls = vec!["KMeans".to_string(), "KNN".to_string()];
        let a = run_cache_sweep(&cfg, &wls, &small_sweep(), 2, None).unwrap();
        assert_eq!(a.cells.len(), 2 * 3);
        assert_eq!(a.workload_executions, 2);
        assert_eq!(a.cached_cells, 0);
        for chunk in a.cells.chunks(3) {
            // one pass per workload: every geometry shares its accesses
            assert!(chunk[0].accesses > 0);
            assert!(chunk.iter().all(|c| c.accesses == chunk[0].accesses));
            for c in chunk {
                assert!(c.misses <= c.accesses, "{} @ {}", c.workload, c.geometry);
            }
            // 32KiB/4w and 64KiB/8w share a set class (128 sets), so
            // stack inclusion orders them: more ways, fewer misses
            assert!(chunk[2].misses <= chunk[0].misses);
        }
        let b = run_cache_sweep(&cfg, &wls, &small_sweep(), 1, None).unwrap();
        for (x, y) in a.cells.iter().zip(&b.cells) {
            assert_eq!((x.accesses, x.misses), (y.accesses, y.misses), "{}", x.geometry);
        }
    }

    #[test]
    fn ledger_serves_warm_sweep_without_execution() {
        let cfg = tiny();
        let wls = vec!["DBSCAN".to_string()];
        let path = tmpfile("warm_sweep.ledger");
        let mut ledger = Ledger::open(&path).unwrap();
        let cold = run_cache_sweep(&cfg, &wls, &small_sweep(), 1, Some(&mut ledger)).unwrap();
        assert_eq!(cold.workload_executions, 1);
        assert_eq!(cold.cached_cells, 0);
        drop(ledger);

        let mut ledger = Ledger::open(&path).unwrap();
        let warm = run_cache_sweep(&cfg, &wls, &small_sweep(), 1, Some(&mut ledger)).unwrap();
        assert_eq!(warm.workload_executions, 0, "fully warmed sweep executes nothing");
        assert_eq!(warm.cached_cells, warm.cells.len());
        for (c, w) in cold.cells.iter().zip(&warm.cells) {
            assert_eq!((c.accesses, c.misses), (w.accesses, w.misses), "bit-exact round-trip");
            assert!(w.cached);
        }
    }

    #[test]
    fn new_geometry_reruns_but_keeps_cached_cells() {
        let cfg = tiny();
        let wls = vec!["Ridge".to_string()];
        let path = tmpfile("partial_sweep.ledger");
        let mut ledger = Ledger::open(&path).unwrap();
        let two = small_sweep()[..2].to_vec();
        run_cache_sweep(&cfg, &wls, &two, 1, Some(&mut ledger)).unwrap();

        // widening the sweep re-runs the workload (one pass prices all
        // geometries) but the old cells still answer from the ledger
        let mixed = run_cache_sweep(&cfg, &wls, &small_sweep(), 1, Some(&mut ledger)).unwrap();
        assert_eq!(mixed.workload_executions, 1);
        assert_eq!(mixed.cached_cells, 2);
        assert!(mixed.cells[0].cached && mixed.cells[1].cached && !mixed.cells[2].cached);
        // cached and fresh agree: same accesses for every geometry
        assert_eq!(mixed.cells[0].accesses, mixed.cells[2].accesses);
    }
}
