//! Binary dataset I/O.
//!
//! The paper converts generated datasets to binary (`.npy` for
//! scikit-learn, `.bin` for mlpack) "to avoid the overhead incurred due to
//! reading input text files". We implement the same idea with a minimal
//! self-describing container: magic, version, rows, cols, n_classes,
//! little-endian f64 X payload followed by f64 y payload. (Trace files
//! are a separate container — see [`crate::trace::store`]; both share the
//! [`crate::util::binio`] encoding primitives.)

use super::synth::Dataset;
use crate::bail;
use crate::util::binio::{read_u64, write_u64};
use crate::util::error::{Context, Result};
use crate::util::Matrix;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"MLPERF01";

/// Write a dataset to `path` in the binary container format.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    write_u64(&mut f, ds.n_samples() as u64)?;
    write_u64(&mut f, ds.n_features() as u64)?;
    write_u64(&mut f, ds.n_classes as u64)?;
    for v in ds.x.as_slice() {
        f.write_all(&v.to_le_bytes())?;
    }
    for v in &ds.y {
        f.write_all(&v.to_le_bytes())?;
    }
    f.flush()?;
    Ok(())
}

/// Read a dataset previously written by [`save`].
pub fn load(path: &Path) -> Result<Dataset> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad magic (not an mlperf dataset)", path.display());
    }
    let rows = read_u64(&mut f)? as usize;
    let cols = read_u64(&mut f)? as usize;
    let n_classes = read_u64(&mut f)? as usize;
    // Guard absurd headers before allocating.
    let cells = (rows as u128) * (cols as u128);
    if cells > (1u128 << 34) {
        bail!("{}: header implies {} cells — refusing", path.display(), cells);
    }
    let mut xdata = vec![0.0f64; rows * cols];
    read_f64s(&mut f, &mut xdata)?;
    let mut y = vec![0.0f64; rows];
    read_f64s(&mut f, &mut y)?;
    Ok(Dataset { x: Matrix::from_vec(rows, cols, xdata), y, n_classes })
}

fn read_f64s<R: Read>(r: &mut R, out: &mut [f64]) -> Result<()> {
    let mut buf = vec![0u8; out.len() * 8];
    r.read_exact(&mut buf).context("truncated dataset payload")?;
    for (i, chunk) in buf.chunks_exact(8).enumerate() {
        out[i] = f64::from_le_bytes(chunk.try_into().unwrap());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::make_blobs;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("mlperf-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let ds = make_blobs(120, 7, 3, 1.0, 11);
        let p = tmpfile("roundtrip.bin");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.x, ds.x);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.n_classes, 3);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmpfile("badmagic.bin");
        std::fs::write(&p, b"NOTMAGIC________________").unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn rejects_truncated_payload() {
        let ds = make_blobs(50, 4, 2, 1.0, 12);
        let p = tmpfile("trunc.bin");
        save(&ds, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_absurd_header() {
        let p = tmpfile("absurd.bin");
        let mut v = Vec::new();
        v.extend_from_slice(MAGIC);
        v.extend_from_slice(&u64::MAX.to_le_bytes());
        v.extend_from_slice(&u64::MAX.to_le_bytes());
        v.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&p, v).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("refusing"), "{err}");
    }

    #[test]
    fn missing_file_is_contextful_error() {
        let err = load(Path::new("/nonexistent/x.bin")).unwrap_err().to_string();
        assert!(err.contains("open"), "{err}");
    }
}
