//! Dataset substrate: synthetic generators (ports of `sklearn.datasets`)
//! and the binary container format the experiments load from.

pub mod io;
pub mod synth;

pub use synth::{make_blobs, make_classification, make_documents, make_regression, Dataset};
