//! Synthetic dataset generators, ports of the `sklearn.datasets` functions
//! the paper uses ("dummy datasets of size 10 million rows and 20
//! features ... generated using the datasets module in the scikit-learn
//! library").

use crate::util::{Matrix, Pcg64};

/// A generated dataset: row-major features plus per-row targets.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// n_samples x n_features feature matrix.
    pub x: Matrix,
    /// Regression target or class label (as f64) per sample.
    pub y: Vec<f64>,
    /// Number of distinct classes (0 for regression data).
    pub n_classes: usize,
}

impl Dataset {
    pub fn n_samples(&self) -> usize {
        self.x.rows()
    }
    pub fn n_features(&self) -> usize {
        self.x.cols()
    }

    /// Working-set footprint of the feature matrix in bytes.
    pub fn bytes(&self) -> u64 {
        (self.n_samples() * self.n_features() * 8) as u64
    }

    /// Apply a row permutation to both features and targets
    /// (data-layout reordering keeps X/y consistent).
    pub fn permuted(&self, perm: &[usize]) -> Dataset {
        Dataset {
            x: self.x.permute_rows(perm),
            y: perm.iter().map(|&i| self.y[i]).collect(),
            n_classes: self.n_classes,
        }
    }
}

/// `make_blobs`: isotropic Gaussian clusters, the standard input for the
/// clustering / neighbour workloads (KMeans, GMM, DBSCAN, KNN, t-SNE).
pub fn make_blobs(
    n_samples: usize,
    n_features: usize,
    centers: usize,
    cluster_std: f64,
    seed: u64,
) -> Dataset {
    assert!(centers > 0);
    let mut rng = Pcg64::new(seed);
    // Centers uniform in [-10, 10]^d, as sklearn's default box.
    let mut ctr = Matrix::zeros(centers, n_features);
    for c in 0..centers {
        for f in 0..n_features {
            ctr[(c, f)] = rng.uniform(-10.0, 10.0);
        }
    }
    let mut x = Matrix::zeros(n_samples, n_features);
    let mut y = vec![0.0; n_samples];
    for i in 0..n_samples {
        let c = rng.index(centers);
        y[i] = c as f64;
        for f in 0..n_features {
            x[(i, f)] = rng.normal_ms(ctr[(c, f)], cluster_std);
        }
    }
    Dataset { x, y, n_classes: centers }
}

/// `make_classification`-style data: class-dependent Gaussian informative
/// features plus pure-noise features (used by the tree-based workloads;
/// a fraction `flip_y` of labels is flipped to create the label noise that
/// makes boosting rounds non-trivial).
pub fn make_classification(
    n_samples: usize,
    n_features: usize,
    n_informative: usize,
    n_classes: usize,
    flip_y: f64,
    seed: u64,
) -> Dataset {
    assert!(n_informative <= n_features);
    assert!(n_classes >= 2);
    let mut rng = Pcg64::new(seed);
    // One Gaussian center per class over informative dims.
    let mut ctr = Matrix::zeros(n_classes, n_informative);
    for c in 0..n_classes {
        for f in 0..n_informative {
            ctr[(c, f)] = rng.uniform(-4.0, 4.0);
        }
    }
    let mut x = Matrix::zeros(n_samples, n_features);
    let mut y = vec![0.0; n_samples];
    for i in 0..n_samples {
        let c = rng.index(n_classes);
        let label = if rng.next_f64() < flip_y {
            rng.index(n_classes)
        } else {
            c
        };
        y[i] = label as f64;
        for f in 0..n_informative {
            x[(i, f)] = rng.normal_ms(ctr[(c, f)], 1.0);
        }
        for f in n_informative..n_features {
            x[(i, f)] = rng.normal(); // noise features
        }
    }
    Dataset { x, y, n_classes }
}

/// `make_regression`: linear model y = X w + noise over standard-normal X
/// (Lasso/Ridge input). A fraction of true coefficients is zero so that
/// Lasso's sparsity mechanism is exercised.
pub fn make_regression(
    n_samples: usize,
    n_features: usize,
    n_informative: usize,
    noise: f64,
    seed: u64,
) -> (Dataset, Vec<f64>) {
    assert!(n_informative <= n_features);
    let mut rng = Pcg64::new(seed);
    let mut w = vec![0.0; n_features];
    for wi in w.iter_mut().take(n_informative) {
        *wi = rng.uniform(-100.0, 100.0);
    }
    let mut x = Matrix::zeros(n_samples, n_features);
    let mut y = vec![0.0; n_samples];
    for i in 0..n_samples {
        let mut dot = 0.0;
        for f in 0..n_features {
            let v = rng.normal();
            x[(i, f)] = v;
            dot += v * w[f];
        }
        y[i] = dot + rng.normal_ms(0.0, noise);
    }
    (Dataset { x, y, n_classes: 0 }, w)
}

/// Document-term count matrix for LDA: `n_topics` latent topics with
/// Dirichlet word distributions; each "document" row holds word counts.
pub fn make_documents(
    n_docs: usize,
    vocab: usize,
    n_topics: usize,
    words_per_doc: usize,
    seed: u64,
) -> Dataset {
    let mut rng = Pcg64::new(seed);
    // Topic-word distributions.
    let topics: Vec<Vec<f64>> = (0..n_topics).map(|_| rng.dirichlet(0.1, vocab)).collect();
    let mut x = Matrix::zeros(n_docs, vocab);
    let mut y = vec![0.0; n_docs];
    for d in 0..n_docs {
        let theta = rng.dirichlet(0.5, n_topics);
        // record dominant topic as "label" for sanity checks
        y[d] = crate::util::stats::argmax(&theta).unwrap_or(0) as f64;
        for _ in 0..words_per_doc {
            // sample topic, then word
            let t = sample_categorical(&mut rng, &theta);
            let w = sample_categorical(&mut rng, &topics[t]);
            x[(d, w)] += 1.0;
        }
    }
    Dataset { x, y, n_classes: n_topics }
}

fn sample_categorical(rng: &mut Pcg64, p: &[f64]) -> usize {
    let u = rng.next_f64();
    let mut acc = 0.0;
    for (i, &pi) in p.iter().enumerate() {
        acc += pi;
        if u < acc {
            return i;
        }
    }
    p.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn blobs_shapes_and_labels() {
        let d = make_blobs(500, 20, 4, 1.0, 1);
        assert_eq!(d.n_samples(), 500);
        assert_eq!(d.n_features(), 20);
        assert_eq!(d.n_classes, 4);
        assert!(d.y.iter().all(|&l| l >= 0.0 && l < 4.0));
        // every class represented
        for c in 0..4 {
            assert!(d.y.iter().any(|&l| l as usize == c));
        }
    }

    #[test]
    fn blobs_are_clustered() {
        // points of the same blob must on average be far closer than points
        // of different blobs (cluster_std 0.5 vs centers in [-10,10]).
        let d = make_blobs(300, 5, 3, 0.5, 2);
        let mut intra = Vec::new();
        let mut inter = Vec::new();
        for i in 0..100 {
            for j in (i + 1)..100 {
                let dist = stats::sqdist(d.x.row(i), d.x.row(j));
                if d.y[i] == d.y[j] {
                    intra.push(dist);
                } else {
                    inter.push(dist);
                }
            }
        }
        assert!(stats::mean(&intra) * 4.0 < stats::mean(&inter));
    }

    #[test]
    fn blobs_deterministic_per_seed() {
        let a = make_blobs(50, 3, 2, 1.0, 7);
        let b = make_blobs(50, 3, 2, 1.0, 7);
        assert_eq!(a.x, b.x);
        let c = make_blobs(50, 3, 2, 1.0, 8);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn classification_flip_y_adds_noise() {
        let clean = make_classification(2000, 10, 5, 2, 0.0, 3);
        let noisy = make_classification(2000, 10, 5, 2, 0.3, 3);
        assert_eq!(clean.n_classes, 2);
        // both have both labels present
        assert!(noisy.y.iter().any(|&l| l == 0.0));
        assert!(noisy.y.iter().any(|&l| l == 1.0));
    }

    #[test]
    fn regression_recoverable_by_least_squares() {
        let (d, w) = make_regression(2000, 5, 5, 0.1, 4);
        // Solve normal equations X^T X w = X^T y and compare to true w.
        let xt = d.x.transpose();
        let xtx = xt.matmul(&d.x);
        let xty: Vec<f64> = (0..5)
            .map(|f| (0..2000).map(|i| d.x[(i, f)] * d.y[i]).sum())
            .collect();
        let west = crate::util::solve_spd(&xtx, &xty).unwrap();
        for (a, b) in west.iter().zip(w.iter()) {
            assert!((a - b).abs() < 0.05, "est {a} true {b}");
        }
    }

    #[test]
    fn regression_sparse_truth() {
        let (_, w) = make_regression(10, 8, 3, 0.0, 5);
        assert!(w[3..].iter().all(|&x| x == 0.0));
        assert!(w[..3].iter().all(|&x| x != 0.0));
    }

    #[test]
    fn documents_counts_sum() {
        let d = make_documents(20, 50, 3, 100, 6);
        for i in 0..20 {
            let total: f64 = d.x.row(i).iter().sum();
            assert_eq!(total, 100.0);
            assert!(d.x.row(i).iter().all(|&c| c >= 0.0));
        }
    }

    #[test]
    fn permuted_keeps_xy_aligned() {
        let d = make_blobs(10, 2, 2, 1.0, 9);
        let perm: Vec<usize> = (0..10).rev().collect();
        let p = d.permuted(&perm);
        for i in 0..10 {
            assert_eq!(p.x.row(i), d.x.row(9 - i));
            assert_eq!(p.y[i], d.y[9 - i]);
        }
    }

    #[test]
    fn bytes_footprint() {
        let d = make_blobs(100, 20, 2, 1.0, 1);
        assert_eq!(d.bytes(), 100 * 20 * 8);
    }
}
