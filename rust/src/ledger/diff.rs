//! Baseline comparison and regression gating.
//!
//! A grid run flattens to a [`GridResults`] — one [`GridCell`] of tracked
//! metrics per (workload, scenario) — serialized as the canonical results
//! JSON (`BENCH_grid_baseline.json` is exactly this format, committed).
//! [`diff`] compares a current run against a baseline with a relative
//! tolerance band per metric and produces a machine-readable
//! [`DiffReport`]: per-metric deltas, missing cells, and a single
//! pass/fail verdict `mlperf report --gate` turns into an exit code.
//!
//! The simulator is deterministic, so under an unchanged configuration
//! the expected drift is exactly zero — the tolerance band exists to
//! absorb *intentional* small perturbations (e.g. a recalibrated DRAM
//! timing constant) without forcing a baseline refresh for every commit.

use super::fingerprint::Fingerprint;
use crate::analysis::Table;
use crate::coordinator::{ExperimentConfig, JobOutput};
use crate::sim::{Metrics, SampleConfig};
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{anyhow, bail};
use std::path::Path;

/// The metrics the gate tracks, by name — the paper's headline
/// characterization numbers. Quality is tracked separately (it comes
/// from the workload, not the simulator).
pub const TRACKED: &[(&str, fn(&Metrics) -> f64)] = &[
    ("cpi", |m| m.cpi),
    ("ipc", |m| m.ipc),
    ("retiring_pct", |m| m.retiring_pct),
    ("bad_spec_pct", |m| m.bad_spec_pct),
    ("dram_bound_pct", |m| m.dram_bound_pct),
    ("core_bound_pct", |m| m.core_bound_pct),
    ("branch_mispredict_ratio", |m| m.branch_mispredict_ratio),
    ("l2_miss_ratio", |m| m.l2_miss_ratio),
    ("llc_miss_ratio", |m| m.llc_miss_ratio),
    ("dram_row_hit_ratio", |m| m.dram.row_hit_ratio()),
];

/// Default relative tolerance band (1%) — see module docs for why the
/// expected drift is zero.
pub const DEFAULT_TOLERANCE: f64 = 0.01;

/// One grid cell's tracked results.
#[derive(Debug, Clone, PartialEq)]
pub struct GridCell {
    pub workload: String,
    pub scenario: String,
    pub fingerprint: Option<Fingerprint>,
    pub quality: Option<f64>,
    /// Half-width of the 95% CPI confidence interval when the cell was
    /// produced by sampled replay (`--sample`); `None` for exact cells.
    /// Informational — the diff never compares it (it is a property of
    /// the estimator, not of the simulated machine).
    pub cpi_ci95: Option<f64>,
    /// `(metric name, value)` in [`TRACKED`] order.
    pub metrics: Vec<(String, f64)>,
}

/// A whole grid run, flattened for serialization and diffing. The run
/// parameters (scale/profile/seed/iterations/features) ride along so a
/// gate re-run can reproduce the producing configuration exactly —
/// without them a baseline built with non-default flags would always
/// "drift".
#[derive(Debug, Clone, PartialEq)]
pub struct GridResults {
    pub scale: f64,
    pub profile: String,
    pub seed: u64,
    pub iterations: usize,
    pub features: usize,
    /// The one CPU-level knob the grid CLI exposes (`--no-hw-prefetch`);
    /// without it a baseline recorded with prefetchers off could not be
    /// reproduced by the gate.
    pub hw_prefetch: bool,
    /// Sampling parameters when the grid ran under `--sample`; `None`
    /// for a full (exact) run. Rides along so a gate re-run reproduces
    /// the producing mode — comparing a sampled run against a full
    /// baseline is possible but the reader should know it happened.
    pub sample: Option<SampleConfig>,
    pub cells: Vec<GridCell>,
}

const SCHEMA: &str = "mlperf-grid/v1";

impl GridResults {
    /// Flatten driver outputs into the canonical results form.
    pub fn from_outputs(cfg: &ExperimentConfig, outputs: &[JobOutput]) -> GridResults {
        let cells = outputs
            .iter()
            .map(|out| GridCell {
                workload: out.job.workload.clone(),
                scenario: out.job.scenario.to_string(),
                fingerprint: Some(super::fingerprint::cell_fingerprint(cfg, &out.job)),
                quality: out.quality,
                cpi_ci95: out.sample.map(|s| s.cpi_ci95),
                metrics: TRACKED
                    .iter()
                    .map(|(name, get)| ((*name).to_string(), get(&out.metrics)))
                    .collect(),
            })
            .collect();
        GridResults {
            scale: cfg.scale,
            profile: format!("{:?}", cfg.profile),
            seed: cfg.seed,
            iterations: cfg.iterations,
            features: cfg.features,
            hw_prefetch: cfg.cpu.cache.hw_prefetch,
            sample: cfg.sample,
            cells,
        }
    }

    pub fn to_json(&self) -> String {
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut fields = vec![
                    ("workload".to_string(), Json::Str(c.workload.clone())),
                    ("scenario".to_string(), Json::Str(c.scenario.clone())),
                ];
                if let Some(fp) = c.fingerprint {
                    fields.push(("fingerprint".to_string(), Json::Str(fp.to_string())));
                }
                fields.push((
                    "quality".to_string(),
                    c.quality.map(Json::num).unwrap_or(Json::Null),
                ));
                if let Some(ci) = c.cpi_ci95 {
                    fields.push(("cpi_ci95".to_string(), Json::num(ci)));
                }
                fields.push((
                    "metrics".to_string(),
                    Json::Obj(
                        c.metrics
                            .iter()
                            .map(|(k, v)| (k.clone(), Json::num(*v)))
                            .collect(),
                    ),
                ));
                Json::Obj(fields)
            })
            .collect();
        Json::Obj(vec![
            ("schema".to_string(), Json::Str(SCHEMA.into())),
            ("scale".to_string(), Json::num(self.scale)),
            ("profile".to_string(), Json::Str(self.profile.clone())),
            // string, not number: a full-range u64 seed would lose bits
            // through a JSON f64
            ("seed".to_string(), Json::Str(self.seed.to_string())),
            ("iterations".to_string(), Json::num(self.iterations as f64)),
            ("features".to_string(), Json::num(self.features as f64)),
            ("hw_prefetch".to_string(), Json::Bool(self.hw_prefetch)),
            (
                "sample".to_string(),
                self.sample
                    .map(|s| Json::Str(s.to_string()))
                    .unwrap_or(Json::Null),
            ),
            ("cells".to_string(), Json::Arr(cells)),
        ])
        .render()
    }

    pub fn from_json(s: &str) -> Result<GridResults> {
        let v = Json::parse(s).context("parsing grid results JSON")?;
        let schema = v.get("schema").and_then(Json::as_str).unwrap_or("");
        if schema != SCHEMA {
            bail!("unsupported results schema {schema:?} (expected {SCHEMA:?})");
        }
        let scale = v.get("scale").and_then(Json::as_f64).unwrap_or(0.0);
        let profile = v
            .get("profile")
            .and_then(Json::as_str)
            .unwrap_or("Sklearn")
            .to_string();
        // absent run parameters (pre-run-parameter files) fall back to
        // the crate defaults; a *present but malformed* one is an error,
        // never a silent substitution
        let defaults = ExperimentConfig::default();
        let seed = match v.get("seed") {
            None | Some(Json::Null) => defaults.seed,
            // canonical encoding is a string (a full u64 overflows f64),
            // but accept the numeric spelling hand-written files use
            Some(Json::Str(s)) => s
                .parse::<u64>()
                .map_err(|_| anyhow!("results JSON has malformed seed {s:?}"))?,
            Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 && *n < 9e15 => *n as u64,
            Some(other) => bail!("results JSON has malformed seed {:?}", other),
        };
        let iterations = match v.get("iterations") {
            None | Some(Json::Null) => defaults.iterations,
            Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => *n as usize,
            Some(other) => bail!("results JSON has malformed iterations {:?}", other),
        };
        let features = match v.get("features") {
            None | Some(Json::Null) => defaults.features,
            Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => *n as usize,
            Some(other) => bail!("results JSON has malformed features {:?}", other),
        };
        let hw_prefetch = match v.get("hw_prefetch") {
            None | Some(Json::Null) => true,
            Some(Json::Bool(b)) => *b,
            Some(other) => bail!("results JSON has malformed hw_prefetch {:?}", other),
        };
        // absent in pre-sampling files → exact run; present but
        // unparseable is an error like every other run parameter
        let sample = match v.get("sample") {
            None | Some(Json::Null) => None,
            Some(Json::Str(s)) => Some(
                SampleConfig::parse(s)
                    .ok_or_else(|| anyhow!("results JSON has malformed sample {s:?}"))?,
            ),
            Some(other) => bail!("results JSON has malformed sample {:?}", other),
        };
        let mut cells = Vec::new();
        for cell in v
            .get("cells")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("results JSON has no \"cells\" array"))?
        {
            let workload = cell
                .get("workload")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("cell missing \"workload\""))?
                .to_string();
            let scenario = cell
                .get("scenario")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("cell missing \"scenario\""))?
                .to_string();
            let quality = cell.get("quality").and_then(Json::as_f64);
            let cpi_ci95 = cell.get("cpi_ci95").and_then(Json::as_f64);
            let mut metrics = Vec::new();
            if let Some(Json::Obj(fields)) = cell.get("metrics") {
                for (k, v) in fields {
                    let val = v
                        .as_f64()
                        .ok_or_else(|| anyhow!("metric {k:?} is not a number"))?;
                    metrics.push((k.clone(), val));
                }
            }
            cells.push(GridCell {
                workload,
                scenario,
                fingerprint: None, // informational; not needed for diffing
                quality,
                cpi_ci95,
                metrics,
            });
        }
        Ok(GridResults { scale, profile, seed, iterations, features, hw_prefetch, sample, cells })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<GridResults> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&s).with_context(|| path.display().to_string())
    }
}

/// One compared metric.
#[derive(Debug, Clone, PartialEq)]
pub struct DiffRow {
    pub workload: String,
    pub scenario: String,
    pub metric: String,
    pub baseline: f64,
    pub current: f64,
    /// Signed relative delta `(current - baseline) / |baseline|`
    /// (absolute delta when the baseline is ~0).
    pub rel_delta: f64,
    pub within: bool,
}

/// Full comparison outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct DiffReport {
    pub tolerance: f64,
    pub rows: Vec<DiffRow>,
    /// Baseline cells absent from the current run — a vanished cell is a
    /// regression (a workload or scenario silently dropped out).
    pub missing: Vec<(String, String)>,
    /// Current cells the baseline does not know (new workloads/scenarios
    /// — informational, never a failure).
    pub untracked: usize,
}

impl DiffReport {
    /// The gate verdict: every tracked metric within tolerance and no
    /// baseline cell missing.
    pub fn pass(&self) -> bool {
        self.missing.is_empty() && self.rows.iter().all(|r| r.within)
    }

    pub fn drifted(&self) -> usize {
        self.rows.iter().filter(|r| !r.within).count()
    }

    /// Per-metric delta table: drifted rows always shown, in-band rows
    /// summarized (printing hundreds of zero-delta lines buries the
    /// signal).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "baseline_diff",
            &format!(
                "baseline comparison — {} metrics over {} cells, tolerance ±{:.2}%: {}",
                self.rows.len(),
                self.cell_count(),
                self.tolerance * 100.0,
                if self.pass() { "PASS" } else { "FAIL" }
            ),
            &["workload", "scenario", "metric", "baseline", "current", "delta%", "ok"],
        );
        for r in self.rows.iter().filter(|r| !r.within) {
            t.row(row_cells(r));
        }
        // worst in-band drifts give the reader scale even when passing
        let mut within: Vec<&DiffRow> = self.rows.iter().filter(|r| r.within).collect();
        within.sort_by(|a, b| {
            b.rel_delta.abs().partial_cmp(&a.rel_delta.abs()).unwrap_or(std::cmp::Ordering::Equal)
        });
        for r in within.into_iter().take(5) {
            t.row(row_cells(r));
        }
        for (w, s) in &self.missing {
            t.row(vec![
                w.clone(),
                s.clone(),
                "<cell missing>".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "FAIL".into(),
            ]);
        }
        t
    }

    fn cell_count(&self) -> usize {
        let mut cells: Vec<(&str, &str)> = self
            .rows
            .iter()
            .map(|r| (r.workload.as_str(), r.scenario.as_str()))
            .collect();
        cells.sort();
        cells.dedup();
        cells.len()
    }

    /// Machine-readable verdict (written next to the tables so CI and
    /// scripts need no table scraping).
    pub fn verdict_json(&self) -> String {
        Json::Obj(vec![
            ("schema".to_string(), Json::Str("mlperf-gate-verdict/v1".into())),
            ("pass".to_string(), Json::Bool(self.pass())),
            ("tolerance".to_string(), Json::num(self.tolerance)),
            ("compared".to_string(), Json::num(self.rows.len() as f64)),
            ("drifted".to_string(), Json::num(self.drifted() as f64)),
            ("missing".to_string(), Json::num(self.missing.len() as f64)),
            ("untracked".to_string(), Json::num(self.untracked as f64)),
            (
                "failures".to_string(),
                Json::Arr(
                    self.rows
                        .iter()
                        .filter(|r| !r.within)
                        .map(|r| {
                            Json::Obj(vec![
                                ("workload".to_string(), Json::Str(r.workload.clone())),
                                ("scenario".to_string(), Json::Str(r.scenario.clone())),
                                ("metric".to_string(), Json::Str(r.metric.clone())),
                                ("baseline".to_string(), Json::num(r.baseline)),
                                ("current".to_string(), Json::num(r.current)),
                                ("rel_delta".to_string(), Json::num(r.rel_delta)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .render()
    }
}

fn row_cells(r: &DiffRow) -> Vec<String> {
    vec![
        r.workload.clone(),
        r.scenario.clone(),
        r.metric.clone(),
        format!("{:.4}", r.baseline),
        format!("{:.4}", r.current),
        format!("{:+.3}", r.rel_delta * 100.0),
        if r.within { "ok" } else { "FAIL" }.into(),
    ]
}

/// Values this close to zero are compared absolutely — a ratio that goes
/// from 0.0 to 1e-12 is noise, not an infinite relative regression.
const ZERO_EPS: f64 = 1e-9;

/// Compare `current` against `baseline` with relative tolerance `tol`.
/// Metrics present in only one of the two cell versions are skipped
/// (schema evolution must not fail old baselines); quality is compared
/// like any tracked metric when both sides carry it.
pub fn diff(current: &GridResults, baseline: &GridResults, tol: f64) -> DiffReport {
    let find = |w: &str, s: &str| {
        current
            .cells
            .iter()
            .find(|c| c.workload == w && c.scenario == s)
    };
    let mut rows = Vec::new();
    let mut missing = Vec::new();
    for base in &baseline.cells {
        let Some(cur) = find(&base.workload, &base.scenario) else {
            missing.push((base.workload.clone(), base.scenario.clone()));
            continue;
        };
        for (name, bval) in &base.metrics {
            let Some((_, cval)) = cur.metrics.iter().find(|(n, _)| n == name) else {
                continue;
            };
            rows.push(make_row(base, name, *bval, *cval, tol));
        }
        if let (Some(bq), Some(cq)) = (base.quality, cur.quality) {
            rows.push(make_row(base, "quality", bq, cq, tol));
        }
    }
    let untracked = current
        .cells
        .iter()
        .filter(|c| {
            !baseline
                .cells
                .iter()
                .any(|b| b.workload == c.workload && b.scenario == c.scenario)
        })
        .count();
    DiffReport { tolerance: tol, rows, missing, untracked }
}

fn make_row(cell: &GridCell, metric: &str, baseline: f64, current: f64, tol: f64) -> DiffRow {
    let rel_delta = if baseline.abs() < ZERO_EPS {
        current - baseline
    } else {
        (current - baseline) / baseline.abs()
    };
    DiffRow {
        workload: cell.workload.clone(),
        scenario: cell.scenario.clone(),
        metric: metric.to_string(),
        baseline,
        current,
        rel_delta,
        within: rel_delta.abs() <= tol,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_results() -> GridResults {
        GridResults {
            scale: 0.02,
            profile: "Sklearn".into(),
            // > 2^53, to prove the string encoding loses no seed bits
            seed: 0xDEAD_BEEF_DEAD_BEEF,
            iterations: 1,
            features: 20,
            hw_prefetch: false,
            sample: Some(SampleConfig { detail: 2, period: 256 }),
            cells: vec![
                GridCell {
                    workload: "KMeans".into(),
                    scenario: "baseline".into(),
                    fingerprint: Some(Fingerprint { version: 1, hash: 0x1234 }),
                    quality: Some(0.87),
                    cpi_ci95: Some(0.031),
                    metrics: vec![("cpi".into(), 1.25), ("llc_miss_ratio".into(), 0.4)],
                },
                GridCell {
                    workload: "KNN".into(),
                    scenario: "perfect-L2".into(),
                    fingerprint: None,
                    quality: None,
                    cpi_ci95: None,
                    metrics: vec![("cpi".into(), 0.75)],
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_preserves_cells() {
        let r = sample_results();
        let back = GridResults::from_json(&r.to_json()).unwrap();
        assert_eq!(back.scale, r.scale);
        assert_eq!(back.profile, r.profile);
        assert_eq!(back.seed, 0xDEAD_BEEF_DEAD_BEEF, "seed must round-trip bit-exactly");
        assert_eq!(back.iterations, r.iterations);
        assert_eq!(back.features, r.features);
        assert!(!back.hw_prefetch, "the --no-hw-prefetch knob must ride along");
        assert_eq!(back.sample, r.sample, "sampling params must round-trip");
        assert_eq!(back.cells.len(), 2);
        assert_eq!(back.cells[0].workload, "KMeans");
        assert_eq!(back.cells[0].quality, Some(0.87));
        assert_eq!(back.cells[0].cpi_ci95, Some(0.031));
        assert_eq!(back.cells[0].metrics, r.cells[0].metrics);
        assert_eq!(back.cells[1].quality, None);
        assert_eq!(back.cells[1].cpi_ci95, None);
    }

    #[test]
    fn run_parameters_accept_legacy_and_numeric_spellings() {
        // pre-run-parameter files (no seed/iterations/...) get defaults
        let legacy = r#"{"schema":"mlperf-grid/v1","scale":0.02,"profile":"Sklearn","cells":[]}"#;
        let r = GridResults::from_json(legacy).unwrap();
        let d = ExperimentConfig::default();
        assert_eq!(r.seed, d.seed);
        assert_eq!(r.iterations, d.iterations);
        assert_eq!(r.features, d.features);
        assert!(r.hw_prefetch);

        // a hand-written numeric seed is honored, not silently defaulted
        let numeric = r#"{"schema":"mlperf-grid/v1","scale":0.02,"profile":"Sklearn","seed":123,"cells":[]}"#;
        assert_eq!(GridResults::from_json(numeric).unwrap().seed, 123);

        // malformed run parameters are errors, never substitutions
        for bad in [
            r#"{"schema":"mlperf-grid/v1","scale":1,"profile":"Sklearn","seed":1.5,"cells":[]}"#,
            r#"{"schema":"mlperf-grid/v1","scale":1,"profile":"Sklearn","seed":"x","cells":[]}"#,
            r#"{"schema":"mlperf-grid/v1","scale":1,"profile":"Sklearn","iterations":"two","cells":[]}"#,
            r#"{"schema":"mlperf-grid/v1","scale":1,"profile":"Sklearn","hw_prefetch":1,"cells":[]}"#,
            r#"{"schema":"mlperf-grid/v1","scale":1,"profile":"Sklearn","sample":"0:8","cells":[]}"#,
            r#"{"schema":"mlperf-grid/v1","scale":1,"profile":"Sklearn","sample":7,"cells":[]}"#,
        ] {
            assert!(GridResults::from_json(bad).is_err(), "{bad}");
        }

        // absent sample → full run; well-formed sample parses
        assert_eq!(GridResults::from_json(legacy).unwrap().sample, None);
        let sampled = r#"{"schema":"mlperf-grid/v1","scale":1,"profile":"Sklearn","sample":"4:128","cells":[]}"#;
        assert_eq!(
            GridResults::from_json(sampled).unwrap().sample,
            Some(SampleConfig { detail: 4, period: 128 })
        );
    }

    #[test]
    fn identical_results_pass() {
        let r = sample_results();
        let report = diff(&r, &r, 0.0);
        assert!(report.pass());
        assert_eq!(report.drifted(), 0);
        assert!(report.missing.is_empty());
        assert_eq!(report.untracked, 0);
        // 3 metric rows + 1 quality row
        assert_eq!(report.rows.len(), 4);
    }

    #[test]
    fn drift_beyond_tolerance_fails() {
        let base = sample_results();
        let mut cur = base.clone();
        cur.cells[0].metrics[0].1 = 1.25 * 1.05; // +5% CPI
        let report = diff(&cur, &base, 0.01);
        assert!(!report.pass());
        assert_eq!(report.drifted(), 1);
        let bad = report.rows.iter().find(|r| !r.within).unwrap();
        assert_eq!(bad.metric, "cpi");
        assert!((bad.rel_delta - 0.05).abs() < 1e-12);
        // same drift inside a wider band passes
        assert!(diff(&cur, &base, 0.10).pass());
    }

    #[test]
    fn missing_cell_fails_untracked_does_not() {
        let base = sample_results();
        let mut cur = base.clone();
        cur.cells.remove(1);
        cur.cells.push(GridCell {
            workload: "GMM".into(),
            scenario: "baseline".into(),
            fingerprint: None,
            quality: None,
            cpi_ci95: None,
            metrics: vec![("cpi".into(), 2.0)],
        });
        let report = diff(&cur, &base, 0.01);
        assert!(!report.pass());
        assert_eq!(report.missing, vec![("KNN".to_string(), "perfect-L2".to_string())]);
        assert_eq!(report.untracked, 1);

        // untracked alone is not a failure
        let mut grown = base.clone();
        grown.cells.push(cur.cells.last().unwrap().clone());
        assert!(diff(&grown, &base, 0.01).pass());
    }

    #[test]
    fn zero_baseline_compares_absolutely() {
        let mut base = sample_results();
        base.cells[0].metrics[0].1 = 0.0;
        let mut cur = base.clone();
        cur.cells[0].metrics[0].1 = 1e-12;
        assert!(diff(&cur, &base, 0.01).pass(), "1e-12 above a zero baseline is noise");
        cur.cells[0].metrics[0].1 = 0.5;
        assert!(!diff(&cur, &base, 0.01).pass());
    }

    #[test]
    fn verdict_json_parses_and_reports_failures() {
        let base = sample_results();
        let mut cur = base.clone();
        cur.cells[0].metrics[1].1 *= 2.0;
        let report = diff(&cur, &base, 0.01);
        let v = Json::parse(&report.verdict_json()).unwrap();
        assert_eq!(v.get("pass").unwrap().as_bool(), Some(false));
        let failures = v.get("failures").unwrap().as_arr().unwrap();
        assert_eq!(failures.len(), 1);
        assert_eq!(
            failures[0].get("metric").unwrap().as_str(),
            Some("llc_miss_ratio")
        );
    }

    #[test]
    fn diff_table_shows_failures_and_verdict() {
        let base = sample_results();
        let mut cur = base.clone();
        cur.cells[0].metrics[0].1 *= 1.5;
        let report = diff(&cur, &base, 0.01);
        let rendered = report.table().render();
        assert!(rendered.contains("FAIL"));
        assert!(rendered.contains("cpi"));
    }

    #[test]
    fn empty_baseline_passes_trivially() {
        let cur = sample_results();
        let empty = GridResults {
            scale: 0.02,
            profile: "Sklearn".into(),
            seed: 0xDA7A,
            iterations: 1,
            features: 20,
            hw_prefetch: true,
            sample: None,
            cells: vec![],
        };
        let report = diff(&cur, &empty, 0.01);
        assert!(report.pass());
        assert_eq!(report.untracked, 2);
    }
}
