//! Content-addressing of experiment cells.
//!
//! A grid cell is fully determined by (workload identity, dataset
//! parameters, library profile, scenario, post-scenario simulator
//! configuration). This module reduces that tuple to a stable 64-bit
//! fingerprint so the [ledger store](super::store) can answer "has this
//! exact simulation already run?" without re-executing anything.
//!
//! ## Canonicalization
//!
//! Every configuration field is serialized as a named `(field, value)`
//! pair; the pairs are **sorted by field name** before hashing, so the
//! fingerprint is independent of the order fields are added (struct
//! reordering, refactors that regroup the builder calls). Values are
//! length-prefixed and hashed with the same FNV-1a-64 the trace
//! container uses per block ([`fnv1a64`]), keeping the whole on-disk
//! story on one checksum primitive.
//!
//! ## Invalidation rules
//!
//! - Changing any field *value* changes the hash (the property tests
//!   enumerate every `CpuConfig`/`DramConfig` field).
//! - Adding or removing a field changes the hash for every cell — new
//!   simulator knobs invalidate old results, which is the safe default.
//! - [`FINGERPRINT_VERSION`] is carried alongside the hash and must be
//!   bumped when the canonicalization itself changes meaning without
//!   changing bytes (e.g. a field is renamed but keeps its value, or a
//!   value's encoding changes). Lookups only match on (version, hash),
//!   so a bump invalidates the whole ledger cleanly rather than
//!   returning stale cells.
//! - The crate version participates in every hash, so a release that
//!   changes simulator *behavior* (not just configuration surface) must
//!   bump the version in Cargo.toml — that invalidates warm ledgers
//!   built by older binaries.

use crate::coordinator::{ExperimentConfig, Job};
use crate::sim::{AddrMap, CpuConfig};
use crate::util::binio::{fnv1a64, put_uvarint};

/// Bump when the canonicalization changes incompatibly (see module docs).
pub const FINGERPRINT_VERSION: u32 = 1;

/// A versioned 64-bit content address of one experiment cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Fingerprint {
    pub version: u32,
    pub hash: u64,
}

impl std::fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "v{}:{:016x}", self.version, self.hash)
    }
}

/// Accumulates named fields and hashes them order-independently.
#[derive(Debug, Default)]
pub struct FingerprintBuilder {
    fields: Vec<(&'static str, Vec<u8>)>,
}

impl FingerprintBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&mut self, name: &'static str, bytes: Vec<u8>) {
        debug_assert!(
            !self.fields.iter().any(|(n, _)| *n == name),
            "duplicate fingerprint field {name:?}"
        );
        self.fields.push((name, bytes));
    }

    pub fn u64(&mut self, name: &'static str, v: u64) {
        let mut b = Vec::with_capacity(10);
        put_uvarint(&mut b, v);
        self.push(name, b);
    }

    pub fn usize(&mut self, name: &'static str, v: usize) {
        self.u64(name, v as u64);
    }

    pub fn bool(&mut self, name: &'static str, v: bool) {
        self.push(name, vec![u8::from(v)]);
    }

    /// `f64` by exact bit pattern — two configs fingerprint equal only if
    /// the values are bit-identical (0.1 + 0.2 != 0.3 here, by design).
    pub fn f64(&mut self, name: &'static str, v: f64) {
        self.push(name, v.to_bits().to_le_bytes().to_vec());
    }

    pub fn str(&mut self, name: &'static str, v: &str) {
        self.push(name, v.as_bytes().to_vec());
    }

    /// Hash the accumulated fields: sort by name, then FNV-1a-64 over
    /// `len(name) · name · len(value) · value` for each pair, seeded with
    /// the version so `v1` and `v2` never collide by construction.
    pub fn finish(mut self) -> Fingerprint {
        self.fields.sort_by(|a, b| a.0.cmp(b.0));
        let mut buf = Vec::with_capacity(64 + self.fields.len() * 24);
        put_uvarint(&mut buf, u64::from(FINGERPRINT_VERSION));
        for (name, value) in &self.fields {
            put_uvarint(&mut buf, name.len() as u64);
            buf.extend_from_slice(name.as_bytes());
            put_uvarint(&mut buf, value.len() as u64);
            buf.extend_from_slice(value);
        }
        Fingerprint { version: FINGERPRINT_VERSION, hash: fnv1a64(&buf) }
    }
}

fn addr_map_name(m: AddrMap) -> &'static str {
    match m {
        AddrMap::RoBaRaCoCh => "RoBaRaCoCh",
        AddrMap::ChRaBaRoCo => "ChRaBaRoCo",
    }
}

/// Add every `CpuConfig` field (core + cache hierarchy + DRAM) to `b`.
/// New simulator knobs **must** be added here — the `fingerprint_covers_
/// every_config_field` property test enumerates the fields and fails on
/// a knob whose change does not change the fingerprint.
pub fn fingerprint_cpu(b: &mut FingerprintBuilder, cpu: &CpuConfig) {
    b.f64("cpu.width", cpu.width);
    b.f64("cpu.freq_ghz", cpu.freq_ghz);
    b.f64("cpu.mispredict_penalty", cpu.mispredict_penalty);
    b.f64("cpu.rob_uops", cpu.rob_uops);
    b.usize("cpu.mshrs", cpu.mshrs);
    b.f64("cpu.fp_ports", cpu.fp_ports);
    b.f64("cpu.int_ports", cpu.int_ports);
    b.f64("cpu.mem_ports", cpu.mem_ports);

    b.u64("cache.l1_bytes", cpu.cache.l1_bytes);
    b.usize("cache.l1_ways", cpu.cache.l1_ways);
    b.u64("cache.l2_bytes", cpu.cache.l2_bytes);
    b.usize("cache.l2_ways", cpu.cache.l2_ways);
    b.u64("cache.l3_bytes", cpu.cache.l3_bytes);
    b.usize("cache.l3_ways", cpu.cache.l3_ways);
    b.bool("cache.hw_prefetch", cpu.cache.hw_prefetch);
    b.bool("cache.perfect_l2", cpu.cache.perfect_l2);
    b.bool("cache.perfect_llc", cpu.cache.perfect_llc);

    b.u64("dram.channels", cpu.dram.channels);
    b.u64("dram.ranks", cpu.dram.ranks);
    b.u64("dram.banks", cpu.dram.banks);
    b.u64("dram.rows_per_bank", cpu.dram.rows_per_bank);
    b.u64("dram.row_bytes", cpu.dram.row_bytes);
    b.str("dram.addr_map", addr_map_name(cpu.dram.addr_map));
    b.u64("dram.cap", u64::from(cpu.dram.cap));
    b.bool("dram.ideal_row_hits", cpu.dram.ideal_row_hits);
    b.f64("dram.t_rcd", cpu.dram.t_rcd);
    b.f64("dram.t_cl", cpu.dram.t_cl);
    b.f64("dram.t_rp", cpu.dram.t_rp);
    b.f64("dram.t_bl", cpu.dram.t_bl);
    b.f64("dram.t_overhead", cpu.dram.t_overhead);
}

/// Fingerprint one grid cell: the workload + dataset + profile identity
/// (which fix the recorded trace, block checksums and all), the scenario
/// discriminator, and the **post-scenario** simulator configuration
/// ([`Scenario::apply_cpu`](crate::coordinator::Scenario::apply_cpu) is
/// applied before hashing, so a cell cached under `perfect-L2` can never
/// satisfy a `baseline` lookup even if the scenario labels were
/// mangled).
///
/// Pure *execution-policy* knobs — thread counts, ingest parallelism
/// (`ExperimentConfig::ingest_threads`) — are deliberately **not**
/// hashed: they cannot change results (the replay stream is bit-
/// identical at any parallelism), so hashing them would only split the
/// cache. `ingest_threads_is_invisible` locks this in.
pub fn cell_fingerprint(cfg: &ExperimentConfig, job: &Job) -> Fingerprint {
    let mut b = FingerprintBuilder::new();
    // Configuration alone cannot see *simulator behavior* changes, so the
    // crate version participates too: a release that changes what the
    // simulator computes must bump the version in Cargo.toml (or
    // `FINGERPRINT_VERSION`), or a warm ledger would serve stale results
    // produced by the old binary.
    b.str("code.crate_version", env!("CARGO_PKG_VERSION"));
    b.str("cell.workload", &job.workload);
    b.str("cell.scenario", &job.scenario.to_string());
    b.str("cell.profile", &format!("{:?}", cfg.profile));
    b.f64("cell.scale", cfg.scale);
    b.usize("cell.features", cfg.features);
    b.usize("cell.iterations", cfg.iterations);
    b.u64("cell.seed", cfg.seed);
    b.bool("cell.auto_shrink", cfg.auto_shrink);
    // Sampled simulation changes what the cell *contains* (an estimate
    // with estimator error, not the exact Metrics), so the sampling
    // parameters are configuration, not execution policy: a sampled cell
    // must never answer a full-replay lookup or vice versa. Pushing the
    // fields only when sampling is on means every pre-sampling ledger
    // entry keeps its hash — field *presence* already separates the two
    // domains, because adding a field changes the sorted-name digest.
    if let Some(s) = cfg.sample {
        b.u64("sample.detail", s.detail);
        b.u64("sample.period", s.period);
    }
    let mut cpu = cfg.cpu.clone();
    job.scenario.apply_cpu(&mut cpu);
    fingerprint_cpu(&mut b, &cpu);
    b.finish()
}

/// Fingerprint one cache-sweep cell: a (workload, geometry) point of
/// `mlperf grid --sweep cache`. The trace-identity fields (workload,
/// profile, scale/features/iterations/seed — everything that fixes the
/// recorded demand stream) are hashed together with the sweep geometry
/// itself, so changing `--sweep` sizes or associativities invalidates
/// exactly the cells whose geometry changed. A `sweep.kind`
/// discriminator keeps the domain disjoint from [`cell_fingerprint`]
/// even if field sets ever coincide.
///
/// Deliberately **not** hashed: the simulator `CpuConfig`, `auto_shrink`,
/// and hardware-prefetch settings. A miss curve is a property of the
/// demand reference stream and the candidate geometry alone — the stack
/// profiler never consults the configured hierarchy — so hashing the CPU
/// config would split the cache across settings that cannot change the
/// result (the sweep analogue of the `ingest_threads` rule above).
pub fn sweep_cell_fingerprint(
    cfg: &ExperimentConfig,
    workload: &str,
    geometry: crate::sim::SweepGeometry,
) -> Fingerprint {
    let mut b = FingerprintBuilder::new();
    b.str("code.crate_version", env!("CARGO_PKG_VERSION"));
    b.str("sweep.kind", "cache-miss-curve");
    b.str("cell.workload", workload);
    b.str("cell.profile", &format!("{:?}", cfg.profile));
    b.f64("cell.scale", cfg.scale);
    b.usize("cell.features", cfg.features);
    b.usize("cell.iterations", cfg.iterations);
    b.u64("cell.seed", cfg.seed);
    b.u64("sweep.bytes", geometry.bytes);
    b.usize("sweep.ways", geometry.ways);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Scenario;
    use crate::reorder::ReorderKind;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig { scale: 0.02, iterations: 1, ..Default::default() }
    }

    #[test]
    fn identical_cells_fingerprint_equal() {
        // two independently constructed configs — nothing shared
        let a = cell_fingerprint(&cfg(), &Job::new("KMeans", Scenario::Baseline));
        let b = cell_fingerprint(&cfg(), &Job::new("KMeans", Scenario::Baseline));
        assert_eq!(a, b);
        assert_eq!(a.version, FINGERPRINT_VERSION);
    }

    #[test]
    fn field_order_does_not_matter() {
        let mut fwd = FingerprintBuilder::new();
        fwd.u64("alpha", 7);
        fwd.str("beta", "x");
        fwd.bool("gamma", true);
        let mut rev = FingerprintBuilder::new();
        rev.bool("gamma", true);
        rev.str("beta", "x");
        rev.u64("alpha", 7);
        assert_eq!(fwd.finish(), rev.finish());
    }

    #[test]
    fn name_value_split_is_unambiguous() {
        // ("ab", "c") must not collide with ("a", "bc")
        let mut a = FingerprintBuilder::new();
        a.str("ab", "c");
        let mut b = FingerprintBuilder::new();
        b.str("a", "bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn scenario_and_workload_distinguish_cells() {
        let base = cell_fingerprint(&cfg(), &Job::new("KMeans", Scenario::Baseline));
        for job in [
            Job::new("KNN", Scenario::Baseline),
            Job::new("KMeans", Scenario::PerfectL2),
            Job::new("KMeans", Scenario::PerfectLlc),
            Job::new("KMeans", Scenario::SwPrefetch),
            Job::new("KMeans", Scenario::Multicore(4)),
            Job::new("KMeans", Scenario::Multicore(8)),
            Job::new("KMeans", Scenario::Reorder(ReorderKind::Hilbert)),
        ] {
            assert_ne!(base, cell_fingerprint(&cfg(), &job), "{job:?}");
        }
    }

    #[test]
    fn experiment_config_fields_distinguish_cells() {
        let job = Job::new("KMeans", Scenario::Baseline);
        let base = cell_fingerprint(&cfg(), &job);
        let muts: &[(&str, fn(&mut ExperimentConfig))] = &[
            ("scale", |c| c.scale = 0.03),
            ("features", |c| c.features += 1),
            ("iterations", |c| c.iterations += 1),
            ("seed", |c| c.seed ^= 1),
            ("auto_shrink", |c| c.auto_shrink = !c.auto_shrink),
            ("profile", |c| c.profile = crate::workloads::LibraryProfile::Mlpack),
        ];
        for (name, m) in muts {
            let mut c = cfg();
            m(&mut c);
            assert_ne!(base, cell_fingerprint(&c, &job), "mutating {name} did not change fp");
        }
    }

    #[test]
    fn ingest_threads_is_invisible() {
        // ingest parallelism is execution policy, not configuration: any
        // value must land on the same cell (pipelined ingest is
        // bit-identical, so caching per-thread-count would only split
        // the ledger)
        let job = Job::new("KMeans", Scenario::Baseline);
        let base = cell_fingerprint(&cfg(), &job);
        for threads in [0usize, 1, 2, 8, 64] {
            let c = ExperimentConfig { ingest_threads: threads, ..cfg() };
            assert_eq!(base, cell_fingerprint(&c, &job), "ingest_threads={threads}");
        }
    }

    #[test]
    fn sampling_params_enter_the_fingerprint() {
        use crate::sim::SampleConfig;
        let job = Job::new("KMeans", Scenario::Baseline);
        let full = cell_fingerprint(&cfg(), &job);

        // a sampled cell never aliases a full-replay cell, even at the
        // degenerate detail == period setting that reproduces full
        // metrics bit-exactly (the *contract* differs: estimate vs exact)
        let sampled = |detail, period| {
            let c = ExperimentConfig {
                sample: Some(SampleConfig { detail, period }),
                ..cfg()
            };
            cell_fingerprint(&c, &job)
        };
        let base = sampled(2, 256);
        assert_ne!(full, base, "sampled cell aliased a full-replay cell");
        assert_ne!(full, sampled(4, 4), "degenerate sampled cell aliased full");

        // every sampling parameter invalidates independently
        assert_ne!(base, sampled(1, 256), "mutating detail did not change fp");
        assert_ne!(base, sampled(4, 256), "mutating detail did not change fp");
        assert_ne!(base, sampled(2, 128), "mutating period did not change fp");
        assert_ne!(base, sampled(2, 512), "mutating period did not change fp");
        // and the two parameters don't collide with each other
        assert_ne!(sampled(2, 128), sampled(128, 2));

        // deterministic: same params, same cell
        assert_eq!(base, sampled(2, 256));
    }

    #[test]
    fn fingerprint_covers_every_config_field() {
        let job = Job::new("KMeans", Scenario::Baseline);
        let base = cell_fingerprint(&cfg(), &job);
        let muts: &[(&str, fn(&mut CpuConfig))] = &[
            ("width", |c| c.width += 1.0),
            ("freq_ghz", |c| c.freq_ghz += 0.1),
            ("mispredict_penalty", |c| c.mispredict_penalty += 1.0),
            ("rob_uops", |c| c.rob_uops += 1.0),
            ("mshrs", |c| c.mshrs += 1),
            ("fp_ports", |c| c.fp_ports += 1.0),
            ("int_ports", |c| c.int_ports += 1.0),
            ("mem_ports", |c| c.mem_ports += 1.0),
            ("l1_bytes", |c| c.cache.l1_bytes *= 2),
            ("l1_ways", |c| c.cache.l1_ways *= 2),
            ("l2_bytes", |c| c.cache.l2_bytes *= 2),
            ("l2_ways", |c| c.cache.l2_ways *= 2),
            ("l3_bytes", |c| c.cache.l3_bytes *= 2),
            ("l3_ways", |c| c.cache.l3_ways *= 2),
            ("hw_prefetch", |c| c.cache.hw_prefetch = !c.cache.hw_prefetch),
            ("perfect_l2", |c| c.cache.perfect_l2 = !c.cache.perfect_l2),
            ("perfect_llc", |c| c.cache.perfect_llc = !c.cache.perfect_llc),
            ("channels", |c| c.dram.channels *= 2),
            ("ranks", |c| c.dram.ranks *= 2),
            ("banks", |c| c.dram.banks *= 2),
            ("rows_per_bank", |c| c.dram.rows_per_bank *= 2),
            ("row_bytes", |c| c.dram.row_bytes *= 2),
            ("addr_map", |c| c.dram.addr_map = AddrMap::ChRaBaRoCo),
            ("cap", |c| c.dram.cap += 1),
            ("ideal_row_hits", |c| c.dram.ideal_row_hits = !c.dram.ideal_row_hits),
            ("t_rcd", |c| c.dram.t_rcd += 0.01),
            ("t_cl", |c| c.dram.t_cl += 0.01),
            ("t_rp", |c| c.dram.t_rp += 0.01),
            ("t_bl", |c| c.dram.t_bl += 0.01),
            ("t_overhead", |c| c.dram.t_overhead += 0.01),
        ];
        let mut seen = std::collections::BTreeSet::new();
        for (name, m) in muts {
            let mut c = cfg();
            m(&mut c.cpu);
            let fp = cell_fingerprint(&c, &job);
            assert_ne!(base, fp, "mutating {name} did not change the fingerprint");
            assert!(seen.insert(fp.hash), "{name} collided with another single-field mutation");
        }
    }

    #[test]
    fn sweep_fingerprint_covers_geometry_and_trace_identity() {
        use crate::sim::SweepGeometry;
        let g = SweepGeometry::new(256 * 1024, 8);
        let base = sweep_cell_fingerprint(&cfg(), "KMeans", g);
        assert_eq!(base, sweep_cell_fingerprint(&cfg(), "KMeans", g), "deterministic");
        // geometry changes invalidate
        assert_ne!(base, sweep_cell_fingerprint(&cfg(), "KMeans", SweepGeometry::new(512 * 1024, 8)));
        assert_ne!(base, sweep_cell_fingerprint(&cfg(), "KMeans", SweepGeometry::new(256 * 1024, 4)));
        // trace-identity changes invalidate
        assert_ne!(base, sweep_cell_fingerprint(&cfg(), "KNN", g));
        let muts: &[(&str, fn(&mut ExperimentConfig))] = &[
            ("scale", |c| c.scale = 0.03),
            ("features", |c| c.features += 1),
            ("iterations", |c| c.iterations += 1),
            ("seed", |c| c.seed ^= 1),
            ("profile", |c| c.profile = crate::workloads::LibraryProfile::Mlpack),
        ];
        for (name, m) in muts {
            let mut c = cfg();
            m(&mut c);
            assert_ne!(base, sweep_cell_fingerprint(&c, "KMeans", g), "mutating {name}");
        }
    }

    #[test]
    fn sweep_fingerprint_ignores_simulator_config() {
        // miss curves depend only on the demand stream + geometry: the
        // configured hierarchy, auto_shrink, and ingest policy must all
        // land on the same sweep cell
        use crate::sim::SweepGeometry;
        let g = SweepGeometry::new(1024 * 1024, 16);
        let base = sweep_cell_fingerprint(&cfg(), "DBSCAN", g);
        let mut c = cfg();
        c.cpu.cache.l3_bytes *= 2;
        c.cpu.cache.hw_prefetch = false;
        c.auto_shrink = !c.auto_shrink;
        c.ingest_threads = 8;
        assert_eq!(base, sweep_cell_fingerprint(&c, "DBSCAN", g));
    }

    #[test]
    fn sweep_domain_is_disjoint_from_cell_domain() {
        let job = Job::new("KMeans", Scenario::Baseline);
        let cell = cell_fingerprint(&cfg(), &job);
        for g in crate::sim::default_sweep() {
            assert_ne!(cell, sweep_cell_fingerprint(&cfg(), "KMeans", g));
        }
    }

    #[test]
    fn display_is_hex() {
        let fp = Fingerprint { version: 1, hash: 0xDEAD_BEEF };
        assert_eq!(fp.to_string(), "v1:00000000deadbeef");
    }
}
