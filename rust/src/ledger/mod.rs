//! Experiment ledger: simulate-once/query-many.
//!
//! PR 2 made one workload *execution* serve many scenario cells
//! (record-once/replay-many); this module completes the arc by making
//! one *simulation* serve many grid runs. Every (workload × scenario ×
//! configuration) cell is reduced to a content address
//! ([`fingerprint`]), its full result set is persisted in an append-only
//! checksummed store ([`store`]), and whole runs become durable,
//! diffable artifacts with tolerance-banded regression gating
//! ([`diff`]).
//!
//! The driver consults the ledger before scheduling
//! ([`run_jobs_ledgered`](crate::coordinator::run_jobs_ledgered)): a
//! grid whose configuration has not changed re-executes **nothing** —
//! the second `mlperf grid --ledger` run reports 0 executions and
//! renders byte-identical tables from stored bits.

pub mod diff;
pub mod fingerprint;
pub mod store;

pub use diff::{diff, DiffReport, DiffRow, GridCell, GridResults, DEFAULT_TOLERANCE, TRACKED};
pub use fingerprint::{
    cell_fingerprint, fingerprint_cpu, sweep_cell_fingerprint, Fingerprint, FingerprintBuilder,
    FINGERPRINT_VERSION,
};
pub use store::{
    CompactionReport, Ledger, LedgerRecord, LedgerStats, Provenance, LEDGER_VERSION,
};
