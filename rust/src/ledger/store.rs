//! Append-only on-disk experiment ledger.
//!
//! Maps [`Fingerprint`] → full result payload (`Metrics` + provenance),
//! so a grid run can skip every cell whose exact configuration has
//! already been simulated. The file format follows the trace container's
//! discipline (`trace/store.rs`): a magic/version header, then
//! self-delimiting checksummed records —
//!
//! ```text
//! header   "MLLG" · version u32
//! records  repeated: 0xE1 · payload_len u32 · fnv1a64(payload) u64 · payload
//! ```
//!
//! Appends are atomic at record granularity: a crash mid-write leaves a
//! torn tail that [`Ledger::open`] detects (marker, length bound, or
//! checksum mismatch) and truncates, keeping every record before it —
//! an append-only log needs no other repair. Duplicate fingerprints are
//! legal (re-runs append; the in-memory index keeps the latest) and are
//! garbage-collected by [`Ledger::compact`].
//!
//! All `f64` values are stored as raw IEEE-754 bits, so a metric read
//! back from the ledger is bit-identical to the one the simulator
//! produced — cached grid cells render byte-identical tables.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::fingerprint::Fingerprint;
use crate::sim::{BranchStats, DramStats, Metrics, PrefetchStats};
use crate::trace::{retry_backoff, InstructionMix, MAX_IO_RETRIES};
use crate::util::binio::{fnv1a64, get_uvarint, put_uvarint};
use crate::util::error::{Context, Result};
use crate::util::fault;
use crate::util::json::Json;
use crate::util::telemetry::{self, Counter, Stage};
use crate::{anyhow, bail};

const MAGIC: &[u8; 4] = b"MLLG";
/// Bump when the record payload layout changes — an old-version file is
/// rejected at open (results are cheap to regenerate; migration is not
/// worth the code).
pub const LEDGER_VERSION: u32 = 1;
const HEADER_LEN: u64 = 8;
const RECORD_MARKER: u8 = 0xE1;
/// A record is one metric set + provenance strings — a few hundred
/// bytes. Anything above this is a corrupt length field.
const MAX_PAYLOAD: usize = 1 << 20;

/// Where a result came from — everything a human (or the export
/// artifact) needs to interpret a ledger entry without the config that
/// produced it.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    pub workload: String,
    pub scenario: String,
    pub profile: String,
    pub rows: u64,
    pub features: u64,
    pub iterations: u64,
    pub seed: u64,
    /// Modelled dataset footprint (rows × features × 8), bytes.
    pub dataset_bytes: u64,
    /// Wall time attributed to producing this cell, nanoseconds
    /// (amortized over the batch that executed it).
    pub wall_nanos: u64,
    /// Unix timestamp (seconds) when the record was appended.
    pub unix_secs: u64,
}

/// One ledger entry: fingerprint → result + provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct LedgerRecord {
    pub fingerprint: Fingerprint,
    pub provenance: Provenance,
    pub metrics: Metrics,
    pub quality: Option<f64>,
}

/// Summary counters for `mlperf ledger stats`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LedgerStats {
    pub records: usize,
    /// Distinct fingerprints (lookups resolve to the latest record).
    pub unique: usize,
    /// Records shadowed by a newer append with the same fingerprint.
    pub superseded: usize,
    pub file_bytes: u64,
    /// Torn-tail bytes dropped by recovery at open (0 = clean file).
    pub recovered_tail_bytes: u64,
}

/// Outcome of [`Ledger::compact`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompactionReport {
    pub records_before: usize,
    pub records_after: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
}

// ---------------------------------------------------------------------
// payload encoding

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn get_f64(buf: &[u8], pos: &mut usize) -> Result<f64> {
    let Some(chunk) = buf.get(*pos..*pos + 8) else {
        bail!("truncated f64 at byte {}", *pos);
    };
    *pos += 8;
    Ok(f64::from_bits(u64::from_le_bytes(chunk.try_into().unwrap())))
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_uvarint(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn get_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    let len = get_uvarint(buf, pos)? as usize;
    if len > MAX_PAYLOAD {
        bail!("ledger string length {len} is corrupt");
    }
    let Some(chunk) = buf.get(*pos..*pos + len) else {
        bail!("truncated string at byte {}", *pos);
    };
    *pos += len;
    String::from_utf8(chunk.to_vec()).map_err(|_| anyhow!("ledger string is not utf-8"))
}

fn encode_metrics(buf: &mut Vec<u8>, m: &Metrics) {
    put_uvarint(buf, m.instructions);
    for v in [
        m.cycles,
        m.cpi,
        m.ipc,
        m.retiring_pct,
        m.bad_spec_pct,
        m.core_bound_pct,
        m.mem_bound_pct,
        m.dram_bound_pct,
        m.l2_bound_pct,
        m.l3_bound_pct,
        m.branch_mispredict_ratio,
        m.branch_fraction,
        m.cond_branch_fraction,
        m.l1_miss_ratio,
        m.l2_miss_ratio,
        m.llc_miss_ratio,
    ] {
        put_f64(buf, v);
    }
    for v in m.port_dist {
        put_f64(buf, v);
    }
    for v in [
        m.mix.int_ops,
        m.mix.fp_ops,
        m.mix.loads,
        m.mix.stores,
        m.mix.branches,
        m.mix.cond_branches,
        m.mix.sw_prefetches,
        m.mix.bytes_loaded,
        m.mix.bytes_stored,
    ] {
        put_uvarint(buf, v);
    }
    for v in [m.branch.conditional, m.branch.unconditional, m.branch.mispredicts] {
        put_uvarint(buf, v);
    }
    for v in [
        m.dram.requests,
        m.dram.reads,
        m.dram.writes,
        m.dram.prefetch_reads,
        m.dram.row_hits,
        m.dram.row_misses,
        m.dram.row_conflicts,
        m.dram.demand_row_hits,
        m.dram.demand_requests,
    ] {
        put_uvarint(buf, v);
    }
    for v in [
        m.dram.total_latency_ns,
        m.dram.demand_latency_ns,
        m.dram.bus_busy_ns,
        m.dram.last_completion_ns,
        m.dram.first_arrival_ns,
    ] {
        put_f64(buf, v);
    }
    for v in [
        m.prefetch.hw_issued,
        m.prefetch.hw_useful,
        m.prefetch.hw_useless,
        m.prefetch.sw_issued,
        m.prefetch.sw_useful,
        m.prefetch.sw_useless,
    ] {
        put_uvarint(buf, v);
    }
    put_f64(buf, m.sim_time_ns);
}

fn decode_metrics(buf: &[u8], pos: &mut usize) -> Result<Metrics> {
    // struct-literal fields evaluate in written order, which is exactly
    // the encode order above
    Ok(Metrics {
        instructions: get_uvarint(buf, pos)?,
        cycles: get_f64(buf, pos)?,
        cpi: get_f64(buf, pos)?,
        ipc: get_f64(buf, pos)?,
        retiring_pct: get_f64(buf, pos)?,
        bad_spec_pct: get_f64(buf, pos)?,
        core_bound_pct: get_f64(buf, pos)?,
        mem_bound_pct: get_f64(buf, pos)?,
        dram_bound_pct: get_f64(buf, pos)?,
        l2_bound_pct: get_f64(buf, pos)?,
        l3_bound_pct: get_f64(buf, pos)?,
        branch_mispredict_ratio: get_f64(buf, pos)?,
        branch_fraction: get_f64(buf, pos)?,
        cond_branch_fraction: get_f64(buf, pos)?,
        l1_miss_ratio: get_f64(buf, pos)?,
        l2_miss_ratio: get_f64(buf, pos)?,
        llc_miss_ratio: get_f64(buf, pos)?,
        port_dist: [
            get_f64(buf, pos)?,
            get_f64(buf, pos)?,
            get_f64(buf, pos)?,
            get_f64(buf, pos)?,
        ],
        mix: InstructionMix {
            int_ops: get_uvarint(buf, pos)?,
            fp_ops: get_uvarint(buf, pos)?,
            loads: get_uvarint(buf, pos)?,
            stores: get_uvarint(buf, pos)?,
            branches: get_uvarint(buf, pos)?,
            cond_branches: get_uvarint(buf, pos)?,
            sw_prefetches: get_uvarint(buf, pos)?,
            bytes_loaded: get_uvarint(buf, pos)?,
            bytes_stored: get_uvarint(buf, pos)?,
        },
        branch: BranchStats {
            conditional: get_uvarint(buf, pos)?,
            unconditional: get_uvarint(buf, pos)?,
            mispredicts: get_uvarint(buf, pos)?,
        },
        dram: DramStats {
            requests: get_uvarint(buf, pos)?,
            reads: get_uvarint(buf, pos)?,
            writes: get_uvarint(buf, pos)?,
            prefetch_reads: get_uvarint(buf, pos)?,
            row_hits: get_uvarint(buf, pos)?,
            row_misses: get_uvarint(buf, pos)?,
            row_conflicts: get_uvarint(buf, pos)?,
            demand_row_hits: get_uvarint(buf, pos)?,
            demand_requests: get_uvarint(buf, pos)?,
            total_latency_ns: get_f64(buf, pos)?,
            demand_latency_ns: get_f64(buf, pos)?,
            bus_busy_ns: get_f64(buf, pos)?,
            last_completion_ns: get_f64(buf, pos)?,
            first_arrival_ns: get_f64(buf, pos)?,
        },
        prefetch: PrefetchStats {
            hw_issued: get_uvarint(buf, pos)?,
            hw_useful: get_uvarint(buf, pos)?,
            hw_useless: get_uvarint(buf, pos)?,
            sw_issued: get_uvarint(buf, pos)?,
            sw_useful: get_uvarint(buf, pos)?,
            sw_useless: get_uvarint(buf, pos)?,
        },
        sim_time_ns: get_f64(buf, pos)?,
    })
}

/// Encode a record payload (everything after the checksum).
fn encode_record(rec: &LedgerRecord, buf: &mut Vec<u8>) {
    put_uvarint(buf, u64::from(rec.fingerprint.version));
    buf.extend_from_slice(&rec.fingerprint.hash.to_le_bytes());
    let p = &rec.provenance;
    put_str(buf, &p.workload);
    put_str(buf, &p.scenario);
    put_str(buf, &p.profile);
    for v in [
        p.rows,
        p.features,
        p.iterations,
        p.seed,
        p.dataset_bytes,
        p.wall_nanos,
        p.unix_secs,
    ] {
        put_uvarint(buf, v);
    }
    match rec.quality {
        Some(q) => {
            buf.push(1);
            put_f64(buf, q);
        }
        None => buf.push(0),
    }
    encode_metrics(buf, &rec.metrics);
}

fn decode_record(buf: &[u8]) -> Result<LedgerRecord> {
    let mut pos = 0usize;
    let version = get_uvarint(buf, &mut pos)? as u32;
    let Some(chunk) = buf.get(pos..pos + 8) else {
        bail!("truncated fingerprint hash");
    };
    let hash = u64::from_le_bytes(chunk.try_into().unwrap());
    pos += 8;
    let workload = get_str(buf, &mut pos)?;
    let scenario = get_str(buf, &mut pos)?;
    let profile = get_str(buf, &mut pos)?;
    let provenance = Provenance {
        workload,
        scenario,
        profile,
        rows: get_uvarint(buf, &mut pos)?,
        features: get_uvarint(buf, &mut pos)?,
        iterations: get_uvarint(buf, &mut pos)?,
        seed: get_uvarint(buf, &mut pos)?,
        dataset_bytes: get_uvarint(buf, &mut pos)?,
        wall_nanos: get_uvarint(buf, &mut pos)?,
        unix_secs: get_uvarint(buf, &mut pos)?,
    };
    let quality = match buf.get(pos) {
        Some(&0) => {
            pos += 1;
            None
        }
        Some(&1) => {
            pos += 1;
            Some(get_f64(buf, &mut pos)?)
        }
        _ => bail!("invalid quality marker at byte {pos}"),
    };
    let metrics = decode_metrics(buf, &mut pos)?;
    if pos != buf.len() {
        bail!("record has {} trailing bytes", buf.len() - pos);
    }
    Ok(LedgerRecord {
        fingerprint: Fingerprint { version, hash },
        provenance,
        metrics,
        quality,
    })
}

/// Build one framed record (marker · length · checksum · payload) as a
/// contiguous byte buffer — the single definition of the frame layout
/// shared by `append` and `compact`. Materializing the whole frame
/// before any byte reaches the file keeps the torn-write window down to
/// a single `write_all`, which append-time recovery can truncate away.
fn frame_bytes(rec: &LedgerRecord) -> Vec<u8> {
    let mut payload = Vec::with_capacity(512);
    encode_record(rec, &mut payload);
    let mut frame = Vec::with_capacity(13 + payload.len());
    frame.push(RECORD_MARKER);
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    frame.extend_from_slice(&payload);
    frame
}

/// Write one framed record. Returns the framed byte count.
fn write_frame<W: Write>(w: &mut W, rec: &LedgerRecord) -> Result<u64> {
    let frame = frame_bytes(rec);
    w.write_all(&frame)?;
    Ok(frame.len() as u64)
}

// ---------------------------------------------------------------------
// the store

/// The on-disk ledger with its in-memory index. Open once, look up and
/// append freely; every append is flushed to disk before returning.
pub struct Ledger {
    path: PathBuf,
    file: File,
    records: Vec<LedgerRecord>,
    /// fingerprint → index into `records` of the **latest** record.
    index: BTreeMap<Fingerprint, usize>,
    file_bytes: u64,
    recovered_tail_bytes: u64,
    /// When set, every append is `fsync`ed before returning (see
    /// [`Ledger::set_durable`]).
    durable: bool,
}

impl Ledger {
    /// Open (creating if absent) the ledger at `path`. A corrupt or torn
    /// tail is truncated away — every record before the first bad byte
    /// survives; a wrong magic/version is a hard error (not silently
    /// clobbered: the file is not ours to rewrite).
    pub fn open(path: &Path) -> Result<Ledger> {
        let _sp = telemetry::span(Stage::LedgerOpen);
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)
            .with_context(|| format!("opening ledger {}", path.display()))?;
        let len = file.metadata()?.len();
        if len == 0 {
            file.write_all(MAGIC)?;
            file.write_all(&LEDGER_VERSION.to_le_bytes())?;
            return Ok(Ledger {
                path: path.to_path_buf(),
                file,
                records: Vec::new(),
                index: BTreeMap::new(),
                file_bytes: HEADER_LEN,
                recovered_tail_bytes: 0,
                durable: false,
            });
        }

        let mut bytes = Vec::with_capacity(len as usize);
        file.read_to_end(&mut bytes)?;
        if bytes.len() < HEADER_LEN as usize || &bytes[0..4] != MAGIC {
            bail!("{} is not a ledger file (bad magic)", path.display());
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != LEDGER_VERSION {
            bail!(
                "{}: ledger version {version} unsupported (this build reads v{LEDGER_VERSION}); \
                 delete the file to regenerate",
                path.display()
            );
        }

        let mut records = Vec::new();
        let mut good_end = HEADER_LEN as usize;
        let mut pos = good_end;
        // Stop at the first malformed record: in an append-only log
        // everything after a torn write is unreachable garbage.
        while pos < bytes.len() {
            match Self::parse_record_at(&bytes, pos) {
                Some((rec, next)) => {
                    records.push(rec);
                    good_end = next;
                    pos = next;
                }
                None => break,
            }
        }

        let recovered = (bytes.len() - good_end) as u64;
        if recovered > 0 {
            file.set_len(good_end as u64)?;
        }
        file.seek(SeekFrom::Start(good_end as u64))?;

        let mut index = BTreeMap::new();
        for (i, rec) in records.iter().enumerate() {
            index.insert(rec.fingerprint, i); // later records shadow earlier
        }
        Ok(Ledger {
            path: path.to_path_buf(),
            file,
            records,
            index,
            file_bytes: good_end as u64,
            recovered_tail_bytes: recovered,
            durable: false,
        })
    }

    /// Toggle durable appends: when on, [`Ledger::append`] calls
    /// `fsync` (`sync_data`) after the flush, so a completed append
    /// survives power loss, not just process death. Off by default —
    /// results are cheap to regenerate, and per-record fsync costs
    /// milliseconds on spinning media.
    pub fn set_durable(&mut self, durable: bool) {
        self.durable = durable;
    }

    /// Parse one record starting at `pos`; `None` on any corruption
    /// (bad marker, absurd length, truncation, checksum, decode error).
    fn parse_record_at(bytes: &[u8], pos: usize) -> Option<(LedgerRecord, usize)> {
        let header = bytes.get(pos..pos + 13)?;
        if header[0] != RECORD_MARKER {
            return None;
        }
        let payload_len = u32::from_le_bytes(header[1..5].try_into().unwrap()) as usize;
        if payload_len > MAX_PAYLOAD {
            return None;
        }
        let checksum = u64::from_le_bytes(header[5..13].try_into().unwrap());
        let payload = bytes.get(pos + 13..pos + 13 + payload_len)?;
        if fnv1a64(payload) != checksum {
            return None;
        }
        let rec = decode_record(payload).ok()?;
        Some((rec, pos + 13 + payload_len))
    }

    /// Latest record for `fp`, if any.
    pub fn get(&self, fp: Fingerprint) -> Option<&LedgerRecord> {
        self.index.get(&fp).map(|&i| &self.records[i])
    }

    /// Append a record and flush it to disk.
    ///
    /// Transient (EINTR-class) write failures are retried up to
    /// [`MAX_IO_RETRIES`] times with [`retry_backoff`] between attempts,
    /// truncating back to the last record boundary first so a partial
    /// write never survives into the retry. A permanent failure is also
    /// self-healed the same way before the error is returned: the file
    /// and the in-memory index stay consistent — only the one record is
    /// lost. With [`Ledger::set_durable`] the frame is `fsync`ed before
    /// the append is reported complete.
    pub fn append(&mut self, rec: LedgerRecord) -> Result<()> {
        let _sp = telemetry::span(Stage::LedgerAppend);
        let frame = frame_bytes(&rec);

        // fault site `ledger-append-kill`: simulate a crash mid-append —
        // leave a torn half-frame on disk, flushed, and fail *without*
        // healing; the crash-consistency suite asserts that reopening
        // truncates it away.
        if fault::fired(fault::Site::LedgerAppendKill).is_some() {
            self.file.write_all(&frame[..frame.len() / 2])?;
            self.file.flush()?;
            bail!(
                "injected crash mid-append to ledger {} (torn frame left on disk)",
                self.path.display()
            );
        }

        let mut attempt = 0u32;
        loop {
            // fault site `ledger-io`: an EINTR-class transient error,
            // handled by the same retry path a real one would take.
            let res = if fault::fired(fault::Site::LedgerIo).is_some() {
                Err(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    "injected transient ledger I/O error",
                ))
            } else {
                self.file.write_all(&frame).and_then(|()| self.file.flush())
            };
            match res {
                Ok(()) => break,
                Err(e) => {
                    // rewind to the last record boundary so neither a
                    // partial write nor the retry's full frame can leave
                    // the file torn or double-framed
                    let _ = self.file.set_len(self.file_bytes);
                    let _ = self.file.seek(SeekFrom::Start(self.file_bytes));
                    let transient = matches!(
                        e.kind(),
                        std::io::ErrorKind::Interrupted
                            | std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                    );
                    if transient && attempt < MAX_IO_RETRIES {
                        attempt += 1;
                        let backoff = retry_backoff(attempt);
                        telemetry::add(Counter::LedgerRetry, 1);
                        telemetry::add(Counter::BackoffNanos, backoff.as_nanos() as u64);
                        std::thread::sleep(backoff);
                        continue;
                    }
                    return Err(e).with_context(|| {
                        format!("appending to ledger {}", self.path.display())
                    });
                }
            }
        }
        if self.durable {
            self.file
                .sync_data()
                .with_context(|| format!("syncing ledger {}", self.path.display()))?;
        }
        self.file_bytes += frame.len() as u64;
        self.index.insert(rec.fingerprint, self.records.len());
        self.records.push(rec);

        // fault site `grid-kill`: hard process death *after* a completed
        // append — the crash/resume suite uses this to stop a grid run
        // between cells with the ledger in a known-good state. Sync
        // first so the just-appended record deterministically survives.
        if fault::fired(fault::Site::GridKill).is_some() {
            let _ = self.file.sync_data();
            std::process::abort();
        }
        Ok(())
    }

    /// All records in append order (superseded duplicates included).
    pub fn records(&self) -> &[LedgerRecord] {
        &self.records
    }

    pub fn stats(&self) -> LedgerStats {
        LedgerStats {
            records: self.records.len(),
            unique: self.index.len(),
            superseded: self.records.len() - self.index.len(),
            file_bytes: self.file_bytes,
            recovered_tail_bytes: self.recovered_tail_bytes,
        }
    }

    /// Rewrite the file keeping only the latest record per fingerprint
    /// (append order preserved among survivors). Crash-atomic: the
    /// replacement is fully written **and fsynced** to a sibling temp
    /// file before being renamed over the original, and the containing
    /// directory is fsynced after the rename — at every instant the
    /// path names either the complete old file or the complete new one.
    pub fn compact(&mut self) -> Result<CompactionReport> {
        let _sp = telemetry::span(Stage::LedgerCompact);
        let before = self.stats();
        let keep: std::collections::BTreeSet<usize> = self.index.values().copied().collect();
        let survivors: Vec<LedgerRecord> = self
            .records
            .iter()
            .enumerate()
            .filter(|(i, _)| keep.contains(i))
            .map(|(_, r)| r.clone())
            .collect();

        let tmp = self.path.with_extension("mllg.tmp");
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(MAGIC)?;
            f.write_all(&LEDGER_VERSION.to_le_bytes())?;
            for rec in &survivors {
                write_frame(&mut f, rec)?;
            }
            f.flush()?;
            f.sync_all()
                .with_context(|| format!("syncing {}", tmp.display()))?;
        }

        // fault site `ledger-compact-kill`: crash in the window between
        // the temp-file write and the rename — the original ledger must
        // be untouched and the next open must see every record.
        if fault::fired(fault::Site::LedgerCompactKill).is_some() {
            bail!(
                "injected crash between compaction temp-write and rename \
                 (original {} left intact)",
                self.path.display()
            );
        }

        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        // fsync the directory so the rename itself is durable (a power
        // loss after this point cannot resurrect the old file)
        #[cfg(unix)]
        {
            let dir = match self.path.parent() {
                Some(d) if !d.as_os_str().is_empty() => d,
                _ => Path::new("."),
            };
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }

        // reopen the handle on the new file, positioned for appends
        self.file = OpenOptions::new().read(true).write(true).open(&self.path)?;
        self.file_bytes = self.file.seek(SeekFrom::End(0))?;
        self.records = survivors;
        self.index = self
            .records
            .iter()
            .enumerate()
            .map(|(i, r)| (r.fingerprint, i))
            .collect();
        Ok(CompactionReport {
            records_before: before.records,
            records_after: self.records.len(),
            bytes_before: before.file_bytes,
            bytes_after: self.file_bytes,
        })
    }

    /// Machine-readable export of every live (non-superseded) record:
    /// the artifact CI uploads so a perf trajectory can be reconstructed
    /// without the binary file format.
    pub fn export_json(&self) -> String {
        let mut cells = Vec::new();
        for &i in self.index.values() {
            let r = &self.records[i];
            let p = &r.provenance;
            let mut metrics: Vec<(String, Json)> = vec![
                ("instructions".into(), Json::num(r.metrics.instructions as f64)),
                ("cycles".into(), Json::num(r.metrics.cycles)),
            ];
            for (name, get) in super::diff::TRACKED {
                metrics.push(((*name).into(), Json::num(get(&r.metrics))));
            }
            cells.push(Json::Obj(vec![
                ("fingerprint".into(), Json::Str(r.fingerprint.to_string())),
                ("workload".into(), Json::Str(p.workload.clone())),
                ("scenario".into(), Json::Str(p.scenario.clone())),
                ("profile".into(), Json::Str(p.profile.clone())),
                ("rows".into(), Json::num(p.rows as f64)),
                ("features".into(), Json::num(p.features as f64)),
                ("iterations".into(), Json::num(p.iterations as f64)),
                // string, like the grid results JSON: a full-range u64
                // seed would lose bits through a JSON f64
                ("seed".into(), Json::Str(p.seed.to_string())),
                ("dataset_bytes".into(), Json::num(p.dataset_bytes as f64)),
                ("wall_nanos".into(), Json::num(p.wall_nanos as f64)),
                ("unix_secs".into(), Json::num(p.unix_secs as f64)),
                (
                    "quality".into(),
                    r.quality.map(Json::num).unwrap_or(Json::Null),
                ),
                ("metrics".into(), Json::Obj(metrics)),
            ]));
        }
        Json::Obj(vec![
            ("schema".into(), Json::Str("mlperf-ledger-export/v1".into())),
            ("records".into(), Json::num(self.records.len() as f64)),
            ("unique".into(), Json::num(self.index.len() as f64)),
            ("cells".into(), Json::Arr(cells)),
        ])
        .render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("mlperf-ledger-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let _ = std::fs::remove_file(&p);
        p
    }

    fn sample(tag: u64) -> LedgerRecord {
        let m = Metrics {
            instructions: 1000 + tag,
            cycles: 1234.5 + tag as f64,
            cpi: 1.0 + tag as f64 * 0.25,
            port_dist: [0.1, 0.2, 0.3, 0.4],
            mix: InstructionMix { loads: 77 + tag, ..Default::default() },
            dram: DramStats {
                requests: 9 * tag,
                total_latency_ns: 0.125 * tag as f64,
                ..Default::default()
            },
            prefetch: PrefetchStats { hw_issued: tag, ..Default::default() },
            sim_time_ns: 5e6 + tag as f64,
            ..Default::default()
        };
        LedgerRecord {
            fingerprint: Fingerprint { version: 1, hash: 0xABCD_0000 + tag },
            provenance: Provenance {
                workload: format!("W{tag}"),
                scenario: "baseline".into(),
                profile: "Sklearn".into(),
                rows: 600,
                features: 20,
                iterations: 1,
                seed: 0xDA7A,
                dataset_bytes: 600 * 20 * 8,
                wall_nanos: 42,
                unix_secs: 1_700_000_000,
            },
            metrics: m,
            quality: if tag % 2 == 0 { Some(0.5 + tag as f64) } else { None },
        }
    }

    #[test]
    fn record_payload_roundtrips_bit_exact() {
        for tag in [0u64, 1, 7] {
            let rec = sample(tag);
            let mut buf = Vec::new();
            encode_record(&rec, &mut buf);
            let back = decode_record(&buf).unwrap();
            assert_eq!(back, rec);
        }
    }

    #[test]
    fn open_append_reopen() {
        let path = tmpfile("roundtrip.mllg");
        {
            let mut l = Ledger::open(&path).unwrap();
            assert_eq!(l.stats().records, 0);
            l.append(sample(1)).unwrap();
            l.append(sample(2)).unwrap();
        }
        let l = Ledger::open(&path).unwrap();
        assert_eq!(l.stats().records, 2);
        assert_eq!(l.stats().recovered_tail_bytes, 0);
        let rec = l.get(Fingerprint { version: 1, hash: 0xABCD_0001 }).unwrap();
        assert_eq!(rec.provenance.workload, "W1");
        assert_eq!(rec.metrics, sample(1).metrics);
        assert!(l.get(Fingerprint { version: 2, hash: 0xABCD_0001 }).is_none());
    }

    #[test]
    fn duplicate_fingerprint_latest_wins_and_compacts() {
        let path = tmpfile("dups.mllg");
        let mut l = Ledger::open(&path).unwrap();
        let mut a = sample(3);
        l.append(a.clone()).unwrap();
        a.metrics.instructions = 999_999;
        l.append(a.clone()).unwrap();
        assert_eq!(l.stats().records, 2);
        assert_eq!(l.stats().unique, 1);
        assert_eq!(l.get(a.fingerprint).unwrap().metrics.instructions, 999_999);

        let report = l.compact().unwrap();
        assert_eq!(report.records_before, 2);
        assert_eq!(report.records_after, 1);
        assert!(report.bytes_after < report.bytes_before);
        assert_eq!(l.get(a.fingerprint).unwrap().metrics.instructions, 999_999);

        // appends still work after compaction, and survive a reopen
        l.append(sample(4)).unwrap();
        drop(l);
        let l = Ledger::open(&path).unwrap();
        assert_eq!(l.stats().records, 2);
        assert_eq!(l.get(a.fingerprint).unwrap().metrics.instructions, 999_999);
    }

    #[test]
    fn torn_tail_is_truncated_and_appendable() {
        let path = tmpfile("torn.mllg");
        {
            let mut l = Ledger::open(&path).unwrap();
            l.append(sample(1)).unwrap();
            l.append(sample(2)).unwrap();
        }
        // simulate a crash mid-append: chop 5 bytes off the last record
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 5).unwrap();
        drop(f);

        let mut l = Ledger::open(&path).unwrap();
        assert_eq!(l.stats().records, 1, "record before the tear survives");
        assert!(l.stats().recovered_tail_bytes > 0);
        l.append(sample(5)).unwrap();
        drop(l);
        let l = Ledger::open(&path).unwrap();
        assert_eq!(l.stats().records, 2);
        assert_eq!(l.stats().recovered_tail_bytes, 0, "file is clean after recovery");
    }

    #[test]
    fn corrupted_checksum_drops_tail_only() {
        let path = tmpfile("bitrot.mllg");
        {
            let mut l = Ledger::open(&path).unwrap();
            l.append(sample(1)).unwrap();
            l.append(sample(2)).unwrap();
            l.append(sample(3)).unwrap();
        }
        // flip one payload byte inside the second record
        let mut bytes = std::fs::read(&path).unwrap();
        let second_start = {
            // first record starts at 8; walk one frame
            let len1 = u32::from_le_bytes(bytes[9..13].try_into().unwrap()) as usize;
            8 + 13 + len1
        };
        bytes[second_start + 20] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        let l = Ledger::open(&path).unwrap();
        // record 1 intact; records 2 and 3 dropped (append-only recovery
        // cannot trust anything after the first bad frame)
        assert_eq!(l.stats().records, 1);
        assert_eq!(l.get(sample(1).fingerprint).unwrap().provenance.workload, "W1");
        assert!(l.stats().recovered_tail_bytes > 0);
    }

    #[test]
    fn wrong_magic_and_version_are_hard_errors() {
        let path = tmpfile("notaledger.mllg");
        std::fs::write(&path, b"NOPE....garbage").unwrap();
        let err = Ledger::open(&path).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");

        let path2 = tmpfile("futurever.mllg");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path2, &bytes).unwrap();
        let err = Ledger::open(&path2).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");
    }

    #[test]
    fn export_json_is_parseable() {
        let path = tmpfile("export.mllg");
        let mut l = Ledger::open(&path).unwrap();
        l.append(sample(1)).unwrap();
        l.append(sample(2)).unwrap();
        let parsed = Json::parse(&l.export_json()).unwrap();
        assert_eq!(parsed.get("unique").unwrap().as_f64().unwrap(), 2.0);
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells[0].get("metrics").unwrap().get("cpi").is_some());
    }
}
