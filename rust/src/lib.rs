//! # mlperf-repro
//!
//! Reproduction of Kumar & Govindarajan, *Performance Characterization
//! and Optimizations of Traditional ML Applications* (cs.PF 2024).
//!
//! The crate provides, as a library:
//!
//! - [`workloads`] — the paper's 13 traditional-ML workloads (Table I),
//!   instrumented to emit micro-architectural event traces, in two
//!   library profiles (scikit-learn-like and mlpack-like).
//! - [`sim`] — the measurement substrate: cache hierarchy, hardware
//!   prefetchers, DDR4 row-buffer model, gshare branch predictor, and a
//!   top-down pipeline model (substitutes for perf/VTune, Sniper and
//!   Ramulator; see DESIGN.md for the substitution table).
//! - [`reorder`] — the paper's five data-layout / computation reordering
//!   optimizations (Table VIII) with overhead accounting.
//! - [`coordinator`] — the experiment registry mapping every figure and
//!   table of the paper to a runnable experiment, plus the parallel
//!   (workload × scenario) driver (`coordinator::driver`) with its
//!   record-once/replay-many and ledger-gated grid modes.
//! - [`ledger`] — the experiment ledger: content-addressed, append-only
//!   result store (fingerprint → full metric set + provenance) that makes
//!   grids incremental and runs diffable/gateable against committed
//!   baselines.
//! - [`trace`] — the batched columnar event pipeline ([`trace::block`])
//!   connecting instrumented workloads to the simulators, and the
//!   on-disk columnar trace store ([`trace::store`]) that makes one
//!   recorded execution replayable across many simulator configurations.
//! - [`runtime`] — PJRT executor that loads the AOT-compiled JAX/Pallas
//!   numeric kernels (`artifacts/*.hlo.txt`) and runs them from Rust;
//!   stubbed out unless built with `--features pjrt` (needs `xla`
//!   bindings the offline image lacks).
//! - [`obs`] — observability: exporters for the zero-cost-when-off
//!   telemetry spine ([`util::telemetry`]) — Chrome-trace timelines,
//!   the `mlperf-telemetry/v1` summary, host provenance — plus the
//!   live grid progress line.
//! - [`serve`] — grid-as-a-service: the crash-safe `mlperf serve`
//!   daemon answering grid queries over a versioned TCP protocol from
//!   a fingerprint-sharded ledger, with admission control, deadlines,
//!   miss coalescing, and degrade-not-die overload behavior.
//!
//! See `rust/examples/quickstart.rs` for the five-minute tour, DESIGN.md
//! (repo root) for the substitution table and pipeline architecture.

pub mod analysis;
pub mod coordinator;
pub mod data;
pub mod ledger;
pub mod obs;
pub mod runtime;
pub mod reorder;
pub mod serve;
pub mod workloads;
pub mod sim;
pub mod trace;
pub mod util;
