//! `mlperf` — command-line launcher for the characterization /
//! optimization experiments.
//!
//! ```text
//! mlperf list
//! mlperf characterize --workload kmeans [--scale 0.5] [--profile mlpack]
//! mlperf prefetch    --workload knn
//! mlperf reorder     --workload dbscan --method hilbert
//! mlperf multicore   --workload gmm --cores 4
//! mlperf gen-data    --rows 100000 --features 20 --out data.bin
//! mlperf record      --workload kmeans [--out kmeans.mlt] [--sw-prefetch]
//! mlperf replay      --trace kmeans.mlt [--perfect-l2|--perfect-llc|--no-hw-prefetch|--ideal-rows]
//! mlperf runtime     [--artifacts artifacts/]
//! mlperf report      [--scale 0.2]     # every figure/table, slow
//! mlperf grid        [--threads 0] [--direct]
//! ```

use mlperf::analysis::{pct, r2, r3, Table};
use mlperf::sim::Metrics;
use mlperf::util::error::Result;
use mlperf::{anyhow, bail};
use mlperf::coordinator::*;
use mlperf::reorder::ReorderKind;
use mlperf::util::Args;
use mlperf::workloads::{by_name, registry, supported_names, LibraryProfile, Workload};

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig {
        scale: args.get_parsed_or("scale", 1.0),
        iterations: args.get_parsed_or("iterations", 2),
        seed: args.get_parsed_or("seed", 0xDA7Au64),
        ..Default::default()
    };
    cfg.profile = match args.get_or("profile", "sklearn").as_str() {
        "sklearn" => LibraryProfile::Sklearn,
        "mlpack" => LibraryProfile::Mlpack,
        other => bail!("unknown profile {other:?} (sklearn|mlpack)"),
    };
    if args.has("no-hw-prefetch") {
        cfg.cpu.cache.hw_prefetch = false;
    }
    Ok(cfg)
}

fn workload_from(args: &Args) -> Result<Box<dyn Workload>> {
    let name = args
        .get("workload")
        .ok_or_else(|| anyhow!("--workload <name> required (see `mlperf list`)"))?;
    by_name(name).ok_or_else(|| anyhow!("unknown workload {name:?} (see `mlperf list`)"))
}

/// Reject workloads the selected library profile does not implement with
/// an actionable error (instead of silently simulating — or panicking on
/// — an implementation that does not exist in the real library).
fn require_profile_support(w: &dyn Workload, profile: LibraryProfile) -> Result<()> {
    if !profile.implements(w) {
        bail!(
            "{} is not implemented in the {:?} profile (mlpack v3.4 ships no \
             SVM-RBF/LDA/t-SNE); valid workloads for this profile: {}",
            w.name(),
            profile,
            supported_names(profile).join(", ")
        );
    }
    Ok(())
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("list") => cmd_list(),
        Some("characterize") => cmd_characterize(args),
        Some("prefetch") => cmd_prefetch(args),
        Some("reorder") => cmd_reorder(args),
        Some("multicore") => cmd_multicore(args),
        Some("gen-data") => cmd_gen_data(args),
        Some("record") => cmd_record(args),
        Some("replay") => cmd_replay(args),
        Some("runtime") => cmd_runtime(args),
        Some("report") => cmd_report(args),
        Some("grid") => cmd_grid(args),
        Some(other) => bail!("unknown subcommand {other:?}"),
        None => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "mlperf — Performance Characterization of Traditional ML (repro)
subcommands: list, characterize, prefetch, reorder, multicore, gen-data, record, replay, runtime, report, grid
common flags: --workload <name> --scale <f> --iterations <n> --profile sklearn|mlpack --seed <n>
record flags: --out <file.mlt> --sw-prefetch       (execute once, persist the columnar trace)
replay flags: --trace <file.mlt> [--perfect-l2 --perfect-llc --no-hw-prefetch --ideal-rows]
grid flags:   --threads <n> (0 = one per core) --full (all scenario columns) --direct (re-execute per cell)";

fn cmd_list() -> Result<()> {
    let mut t = Table::new("workloads", "Table I — workloads and categories", &[
        "workload", "category", "in mlpack", "comp-reorderable",
    ]);
    for w in registry() {
        t.row(vec![
            w.name().into(),
            w.category().to_string(),
            if w.in_mlpack() { "yes" } else { "no" }.into(),
            if w.supports_visit_order() { "yes" } else { "no" }.into(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// The full single-run metric rows shared by `characterize`, `record`,
/// and `replay`.
fn metric_rows(m: &Metrics) -> Vec<(&'static str, String)> {
    vec![
        ("instructions", format!("{}", m.instructions)),
        ("cycles", format!("{:.0}", m.cycles)),
        ("CPI", r2(m.cpi)),
        ("IPC", r2(m.ipc)),
        ("retiring %", pct(m.retiring_pct)),
        ("bad speculation %", pct(m.bad_spec_pct)),
        ("DRAM bound %", pct(m.dram_bound_pct)),
        ("core bound %", pct(m.core_bound_pct)),
        ("branch fraction", r3(m.branch_fraction)),
        ("cond branch fraction", r3(m.cond_branch_fraction)),
        ("branch mispredict ratio", r3(m.branch_mispredict_ratio)),
        ("L2 miss ratio", r3(m.l2_miss_ratio)),
        ("LLC miss ratio", r3(m.llc_miss_ratio)),
        ("DRAM row-hit ratio", r3(m.dram.row_hit_ratio())),
        ("DRAM avg latency (ns)", r2(m.dram.avg_latency_ns())),
        ("bandwidth utilization %", pct(m.bandwidth_utilization_pct())),
        ("HW prefetch useless frac", r3(m.prefetch.hw_useless_fraction())),
    ]
}

fn cmd_characterize(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let w = workload_from(args)?;
    require_profile_support(w.as_ref(), cfg.profile)?;
    let c = characterize(w.as_ref(), &cfg);
    let mut t = Table::new(
        "characterize",
        &format!("{} ({:?}, rows={})", w.name(), cfg.profile, cfg.rows_for(w.as_ref())),
        &["metric", "value"],
    );
    for (k, v) in metric_rows(&c.metrics) {
        t.row(vec![k.into(), v]);
    }
    t.row(vec!["quality".into(), format!("{:.4}", c.result.quality)]);
    t.row(vec!["model".into(), c.result.detail.clone()]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_record(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let w = workload_from(args)?;
    require_profile_support(w.as_ref(), cfg.profile)?;
    let sw_prefetch = args.has("sw-prefetch");
    let default_out = format!("{}.mlt", w.name().to_lowercase().replace([' ', '-'], "_"));
    let out = args.get_or("out", &default_out);
    let (c, summary) =
        record_characterize(w.as_ref(), &cfg, sw_prefetch, std::path::Path::new(&out))?;
    let mut t = Table::new(
        "record",
        &format!(
            "recorded {} ({:?}, rows={}, sw_prefetch={})",
            w.name(),
            cfg.profile,
            cfg.rows_for(w.as_ref()),
            sw_prefetch
        ),
        &["metric", "value"],
    );
    for (k, v) in metric_rows(&c.metrics) {
        t.row(vec![k.into(), v]);
    }
    t.row(vec!["quality".into(), format!("{:.4}", c.result.quality)]);
    t.row(vec!["trace file".into(), out.clone()]);
    t.row(vec!["trace blocks".into(), format!("{}", summary.blocks)]);
    t.row(vec!["trace events".into(), format!("{}", summary.events)]);
    t.row(vec!["trace bytes".into(), format!("{}", summary.bytes)]);
    t.row(vec![
        "bytes/event".into(),
        format!("{:.2}", summary.bytes as f64 / summary.events.max(1) as f64),
    ]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let path = args.get("trace").ok_or_else(|| {
        anyhow!("--trace <file.mlt> required (create one with `mlperf record`)")
    })?;
    let (meta, m, stats) = replay_file(std::path::Path::new(path), &cfg, |c| {
        if args.has("perfect-l2") {
            c.cache.perfect_l2 = true;
        }
        if args.has("perfect-llc") {
            c.cache.perfect_llc = true;
        }
        if args.has("no-hw-prefetch") {
            c.cache.hw_prefetch = false;
        }
        if args.has("ideal-rows") {
            c.dram.ideal_row_hits = true;
        }
    })?;
    let mut t = Table::new(
        "replay",
        &format!(
            "replayed {} ({:?}, rows={}, sw_prefetch={}, {} events in {} blocks)",
            meta.workload, meta.profile, meta.rows, meta.sw_prefetch, stats.events, stats.blocks
        ),
        &["metric", "value"],
    );
    for (k, v) in metric_rows(&m) {
        t.row(vec![k.into(), v]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_prefetch(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let w = workload_from(args)?;
    require_profile_support(w.as_ref(), cfg.profile)?;
    let s = prefetch_study(w.as_ref(), &cfg);
    let mut t = Table::new(
        "prefetch",
        &format!("software prefetching on {} (Figs. 14-18)", w.name()),
        &["metric", "baseline", "prefetched"],
    );
    t.row(vec!["L2 miss ratio".into(), r3(s.base.l2_miss_ratio), r3(s.prefetched.l2_miss_ratio)]);
    t.row(vec!["DRAM bound %".into(), pct(s.base.dram_bound_pct), pct(s.prefetched.dram_bound_pct)]);
    t.row(vec!["bad spec %".into(), pct(s.base.bad_spec_pct), pct(s.prefetched.bad_spec_pct)]);
    t.row(vec![
        "2+ uops/cycle frac".into(),
        r3(s.base.two_plus_uops_fraction()),
        r3(s.prefetched.two_plus_uops_fraction()),
    ]);
    t.row(vec!["CPI".into(), r2(s.base.cpi), r2(s.prefetched.cpi)]);
    t.row(vec![
        "speedup".into(),
        "1.00".into(),
        r3(s.prefetched.speedup_vs(&s.base)),
    ]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_reorder(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let w = workload_from(args)?;
    require_profile_support(w.as_ref(), cfg.profile)?;
    let method = args.get_or("method", "zorder");
    let kind = parse_kind(&method)?;
    if !kind.applicable_to(w.as_ref()) {
        bail!("{} is not applicable to {}", kind, w.name());
    }
    let s = reorder_study(w.as_ref(), kind, &cfg);
    let mut t = Table::new(
        "reorder",
        &format!("{} on {} (Figs. 20-24)", kind, w.name()),
        &["metric", "baseline", "reordered"],
    );
    t.row(vec![
        "row-buffer hit ratio".into(),
        r3(s.baseline.dram.row_hit_ratio()),
        r3(s.reordered.dram.row_hit_ratio()),
    ]);
    t.row(vec![
        "avg DRAM latency (ns)".into(),
        r2(s.baseline.dram.avg_latency_ns()),
        r2(s.reordered.dram.avg_latency_ns()),
    ]);
    t.row(vec![
        "bad spec %".into(),
        pct(s.baseline.bad_spec_pct),
        pct(s.reordered.bad_spec_pct),
    ]);
    t.row(vec!["CPI".into(), r2(s.baseline.cpi), r2(s.reordered.cpi)]);
    t.row(vec![
        "speedup (no overhead)".into(),
        "1.00".into(),
        r3(s.speedup_no_overhead()),
    ]);
    t.row(vec![
        "speedup (with overhead)".into(),
        "1.00".into(),
        r3(s.speedup_with_overhead()),
    ]);
    println!("{}", t.render());
    Ok(())
}

pub fn parse_kind(s: &str) -> Result<ReorderKind> {
    Ok(match s.to_lowercase().replace(['-', '_'], "").as_str() {
        "firsttouch" | "ft" => ReorderKind::FirstTouch,
        "rcb" => ReorderKind::Rcb,
        "hilbert" => ReorderKind::Hilbert,
        "zorder" | "morton" => ReorderKind::ZOrder,
        "blocking" | "localityblocking" => ReorderKind::LocalityBlocking,
        "zordercomp" | "zorderc" => ReorderKind::ZOrderComp,
        other => bail!("unknown reorder method {other:?}"),
    })
}

fn cmd_multicore(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let w = workload_from(args)?;
    require_profile_support(w.as_ref(), cfg.profile)?;
    let cores: usize = args.get_parsed_or("cores", 4);
    let m = multicore_characterize(w.as_ref(), &cfg, cores);
    let mut t = Table::new(
        "multicore",
        &format!("{} on {} cores (Tables III/IV)", w.name(), cores),
        &["CPI", "retiring %", "bad spec %", "DRAM bound %", "core bound %"],
    );
    t.row(mlperf::analysis::topdown_cells(&m));
    println!("{}", t.render());
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let rows: usize = args.get_parsed_or("rows", 100_000);
    let features: usize = args.get_parsed_or("features", 20);
    let seed: u64 = args.get_parsed_or("seed", 1u64);
    let out = args.get_or("out", "data.bin");
    let ds = mlperf::data::make_blobs(rows, features, 8, 1.0, seed);
    mlperf::data::io::save(&ds, std::path::Path::new(&out))?;
    println!("wrote {rows}x{features} dataset ({} MB) to {out}", ds.bytes() / 1_000_000);
    Ok(())
}

fn cmd_runtime(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(mlperf::runtime::default_artifacts_dir);
    let rt = mlperf::runtime::Runtime::load(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let mut rng = mlperf::util::Pcg64::new(1);
    let x: Vec<f32> = (0..mlperf::runtime::BATCH * mlperf::runtime::FEATURES)
        .map(|_| rng.normal() as f32)
        .collect();
    let c: Vec<f32> = (0..mlperf::runtime::K * mlperf::runtime::FEATURES)
        .map(|_| rng.normal() as f32)
        .collect();
    let (_, inertia) = rt.kmeans_step(&x, &c)?;
    println!("kmeans_step OK (batch inertia {inertia:.1})");
    Ok(())
}

fn cmd_grid(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let threads: usize = args.get_parsed_or("threads", 0usize);
    let direct = args.has("direct");
    let jobs = if args.has("full") { full_grid(&cfg) } else { standard_grid(&cfg) };
    println!(
        "running {} jobs at scale {} in {} mode …",
        jobs.len(),
        cfg.scale,
        if direct { "direct" } else { "record-once/replay-many" }
    );
    let report = if direct {
        run_jobs(&cfg, &jobs, threads)
    } else {
        run_jobs_replayed(&cfg, &jobs, threads)
    };
    let mut t = Table::new(
        "grid",
        &format!(
            "parallel experiment grid ({} jobs, {} workload executions, {} threads, {:.1}s wall)",
            report.outputs.len(),
            report.workload_executions,
            report.threads_used,
            report.wall_seconds
        ),
        &["workload", "scenario", "CPI", "ret%", "bspec%", "dram%", "core%", "quality"],
    );
    for out in &report.outputs {
        let m = &out.metrics;
        t.row(vec![
            out.job.workload.clone(),
            out.job.scenario.to_string(),
            r2(m.cpi),
            pct(m.retiring_pct),
            pct(m.bad_spec_pct),
            pct(m.dram_bound_pct),
            pct(m.core_bound_pct),
            out.quality.map(|q| format!("{q:.4}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.emit();
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    println!("running the full figure/table suite at scale {} …", cfg.scale);
    let mut t = Table::new(
        "fig01_10",
        "single-core characterization (Figs. 1-10)",
        &["workload", "CPI", "ret%", "bspec%", "dram%", "core%", "br-frac", "LLC-miss"],
    );
    for w in registry() {
        if !cfg.profile.implements(w.as_ref()) {
            continue;
        }
        let c = characterize(w.as_ref(), &cfg);
        let m = &c.metrics;
        t.row(vec![
            w.name().into(),
            r2(m.cpi),
            pct(m.retiring_pct),
            pct(m.bad_spec_pct),
            pct(m.dram_bound_pct),
            pct(m.core_bound_pct),
            r3(m.branch_fraction),
            r3(m.llc_miss_ratio),
        ]);
    }
    t.emit();
    Ok(())
}
