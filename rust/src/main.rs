//! `mlperf` — command-line launcher for the characterization /
//! optimization experiments.
//!
//! ```text
//! mlperf list
//! mlperf characterize --workload kmeans [--scale 0.5] [--profile mlpack]
//! mlperf prefetch    --workload knn
//! mlperf reorder     --workload dbscan --method hilbert
//! mlperf multicore   --workload gmm --cores 4
//! mlperf gen-data    --rows 100000 --features 20 --out data.bin
//! mlperf record      --workload kmeans [--out kmeans.mlt] [--sw-prefetch]
//! mlperf replay      --trace kmeans.mlt [--perfect-l2|--perfect-llc|--no-hw-prefetch|--ideal-rows]
//!                    [--ingest-threads 0] [--sample 2:256]
//! mlperf runtime     [--artifacts artifacts/]
//! mlperf report      [--scale 0.2]     # every figure/table, slow
//! mlperf report      --baseline BENCH_grid_baseline.json --gate
//! mlperf report      --baseline BENCH_grid_baseline.json --bless   # refresh/bootstrap the baseline
//! mlperf grid        [--threads 0] [--direct] [--ledger grid.mllg] [--json out.json]
//! mlperf grid        --sweep cache [--workload knn] [--ledger grid.mllg] [--json sweep.json]
//! mlperf ledger      stats|gc|export --ledger grid.mllg [--out export.json]
//! mlperf serve       [--listen 127.0.0.1:0] [--dir results/serve] [--queue-depth 64]
//!                    [--default-deadline 5000] [--shards 4] [--durable]
//! mlperf query       --workload kmeans [--scenario baseline] [--deadline-ms 500]
//!                    [--addr host:port | --dir results/serve] [--op query|stats|compact|ping|shutdown]
//! ```

use mlperf::analysis::{pct, r2, r3, Table};
use mlperf::ledger::{diff, GridResults, Ledger, DEFAULT_TOLERANCE};
use mlperf::obs::progress;
use mlperf::sim::{default_sweep, Metrics, SampleConfig};
use mlperf::util::Json;
use mlperf::util::diag;
use mlperf::util::error::Result;
use mlperf::{anyhow, bail};
use mlperf::coordinator::*;
use mlperf::reorder::ReorderKind;
use mlperf::util::Args;
use mlperf::workloads::{by_name, registry, supported_names, LibraryProfile, Workload};

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig {
        scale: args.get_parsed_or("scale", 1.0),
        iterations: args.get_parsed_or("iterations", 2),
        seed: args.get_parsed_or("seed", 0xDA7Au64),
        ingest_threads: args.get_parsed_or("ingest-threads", 0usize),
        strict: args.has("strict"),
        ..Default::default()
    };
    cfg.profile = match args.get_or("profile", "sklearn").as_str() {
        "sklearn" => LibraryProfile::Sklearn,
        "mlpack" => LibraryProfile::Mlpack,
        other => bail!("unknown profile {other:?} (sklearn|mlpack)"),
    };
    if args.has("no-hw-prefetch") {
        cfg.cpu.cache.hw_prefetch = false;
    }
    if let Some(spec) = args.get("sample") {
        cfg.sample = Some(SampleConfig::parse(spec).ok_or_else(|| {
            anyhow!(
                "malformed --sample {spec:?} (expected <detail>:<period> with both > 0, \
                 e.g. --sample {})",
                SampleConfig::default()
            )
        })?);
    }
    Ok(cfg)
}

fn workload_from(args: &Args) -> Result<Box<dyn Workload>> {
    let name = args
        .get("workload")
        .ok_or_else(|| anyhow!("--workload <name> required (see `mlperf list`)"))?;
    by_name(name).ok_or_else(|| anyhow!("unknown workload {name:?} (see `mlperf list`)"))
}

/// Reject workloads the selected library profile does not implement with
/// an actionable error (instead of silently simulating — or panicking on
/// — an implementation that does not exist in the real library).
fn require_profile_support(w: &dyn Workload, profile: LibraryProfile) -> Result<()> {
    if !profile.implements(w) {
        bail!(
            "{} is not implemented in the {:?} profile (mlpack v3.4 ships no \
             SVM-RBF/LDA/t-SNE); valid workloads for this profile: {}",
            w.name(),
            profile,
            supported_names(profile).join(", ")
        );
    }
    Ok(())
}

/// Parse and arm the deterministic fault-injection plan (`--chaos
/// <spec>`, falling back to `MLPERF_CHAOS`; the flag wins). No flag and
/// no env var means nothing is installed and every injection site stays
/// on its zero-cost fast path.
fn install_chaos(args: &Args) -> Result<()> {
    let spec = match args.get("chaos") {
        Some(s) => Some(s.to_string()),
        None => std::env::var("MLPERF_CHAOS").ok().filter(|s| !s.trim().is_empty()),
    };
    let Some(spec) = spec else { return Ok(()) };
    let plan = mlperf::util::fault::FaultPlan::parse(&spec)?;
    if plan.is_empty() {
        mlperf::util::fault::install(None);
        return Ok(());
    }
    diag::note(format!(
        "chaos: fault injection ARMED ({} rule(s), seed {}) — {plan}",
        plan.rule_count(),
        plan.seed()
    ));
    mlperf::util::fault::install(Some(plan));
    Ok(())
}

/// Install the telemetry collector (`--telemetry [<dir>]`, falling back
/// to `MLPERF_TELEMETRY`; the flag wins, and the bare switch defaults
/// the output directory to `results/`). Nothing installed means every
/// instrumentation site stays on its relaxed-atomic-load fast path —
/// and telemetry never enters experiment configs or fingerprints, so
/// arming it cannot change any result.
fn install_telemetry(args: &Args) {
    let dir = match args.get("telemetry") {
        Some(d) => Some(d.to_string()),
        None if args.has("telemetry") => Some("results".to_string()),
        None => std::env::var("MLPERF_TELEMETRY").ok().filter(|s| !s.trim().is_empty()),
    };
    let Some(dir) = dir else { return };
    mlperf::util::telemetry::install(Some(std::path::PathBuf::from(dir)));
}

fn dispatch(args: &Args) -> Result<()> {
    install_chaos(args)?;
    install_telemetry(args);
    let result = run_command(args);
    // export even when the command failed — a failing run's timeline is
    // exactly the one worth looking at
    match mlperf::obs::export_all() {
        Ok(Some((summary, trace))) => diag::note(format!(
            "telemetry: wrote {} and {}",
            summary.display(),
            trace.display()
        )),
        Ok(None) => {}
        Err(e) => diag::warn(format!("telemetry artifacts not persisted: {e:#}")),
    }
    result
}

fn run_command(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("list") => cmd_list(),
        Some("characterize") => cmd_characterize(args),
        Some("prefetch") => cmd_prefetch(args),
        Some("reorder") => cmd_reorder(args),
        Some("multicore") => cmd_multicore(args),
        Some("gen-data") => cmd_gen_data(args),
        Some("record") => cmd_record(args),
        Some("replay") => cmd_replay(args),
        Some("runtime") => cmd_runtime(args),
        Some("report") => cmd_report(args),
        Some("grid") => cmd_grid(args),
        Some("ledger") => cmd_ledger(args),
        Some("serve") => cmd_serve(args),
        Some("query") => cmd_query(args),
        Some(other) => bail!("unknown subcommand {other:?}"),
        None => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "mlperf — Performance Characterization of Traditional ML (repro)
subcommands: list, characterize, prefetch, reorder, multicore, gen-data, record, replay, runtime, report, grid, ledger, serve, query
common flags: --workload <name> --scale <f> --iterations <n> --profile sklearn|mlpack --seed <n>
record flags: --out <file.mlt> --sw-prefetch       (execute once, persist the columnar trace)
replay flags: --trace <file.mlt> [--perfect-l2 --perfect-llc --no-hw-prefetch --ideal-rows]
              --ingest-threads <n> (0 = auto, 1 = synchronous; staged I/O/decode ingest, bit-identical)
              --sample <detail>:<period> (SMARTS sampled simulation: detailed windows + functional
              warming; CPI estimate with a 95% CI; try --sample 2:256)
grid flags:   --threads <n> (0 = one per core) --full (all scenario columns) --direct (re-execute per cell)
              --ledger <file.mllg> (skip cells already simulated) --json <out.json> (results artifact)
              --assert-cached (fail if anything executed) --baseline <base.json> --gate --tolerance <f>
              --sample <detail>:<period> (sampled replay cells; adds a CPI +-CI column)
              --strict (first failing cell aborts the run; default quarantines it into
              results/failures.json and completes the rest) --durable (fsync every ledger append)
sweep flags:  grid --sweep cache (exact-LRU miss curves for every geometry from ONE trace pass per
              workload) [--workload <name>] [--ledger <file.mllg>] [--json <out.json>] [--assert-cached]
report flags: --baseline <base.json> (re-run its cells and diff) --gate (non-zero exit on drift)
              --tolerance <f> (relative band, default 0.01) --ledger <file.mllg>
              --bless (overwrite <base.json> with the freshly computed results — documented
              refresh flow; an empty/missing baseline is blessed from the standard grid)
              --allow-vacuous (let --gate pass against an empty placeholder baseline; by
              default a vacuous gate exits non-zero so CI cannot certify nothing)
serve flags:  --listen <addr> (default 127.0.0.1:0; bound address is written to <dir>/serve.addr)
              --dir <d> (shards + lock files, default results/serve) --shards <n> (fresh dirs only)
              --queue-depth <n> (admission bound, default 64; beyond it queries are shed with a
              typed 'overloaded' rejection) --default-deadline <ms> (default 5000) --threads <n>
              (miss-batch sim threads) --durable (fsync every shard append); SIGTERM drains:
              stop admitting, finish in-flight, flush shards, exit 0
query flags:  --workload <name> [--scenario <s>] [--deadline-ms <ms>] — one grid cell over TCP,
              bit-identical to `mlperf grid`; --addr <host:port> or --dir <d> (reads serve.addr)
              --op query|stats|compact|ping|shutdown (default query) --timeout <ms> (client side)
chaos flags:  --chaos <spec> (or MLPERF_CHAOS) — deterministic fault injection, e.g.
              --chaos 'seed=7;read-transient@2' or 'frame-bitflip%0.01;decode-panic@1';
              sites: read-transient read-short frame-bitflip torn-tail decode-panic stall
              capture-panic cell-panic ledger-io ledger-append-kill ledger-compact-kill grid-kill
              conn-drop slow-client serve-kill (serve path: drop a connection unanswered, hold an
              admission slot <param> ms, abort after the nth answered query)
telemetry:    --telemetry [<dir>] (or MLPERF_TELEMETRY=<dir>) — scoped spans + counters on every
              stage; writes <dir>/telemetry.json (mlperf-telemetry/v1 summary) and
              <dir>/telemetry_trace.json (Chrome trace-event JSON, load in Perfetto / about:tracing);
              dir defaults to results/. Provably inert: results and fingerprints are unchanged.
              grid also shows a live progress line on a TTY (cells done/cached/failed + ETA)
              and `--json -` streams the results artifact to stdout (tables move to stderr)
ledger usage: mlperf ledger stats|gc|export --ledger <file.mllg> [--out <file.json>]";

fn cmd_list() -> Result<()> {
    let mut t = Table::new("workloads", "Table I — workloads and categories", &[
        "workload", "category", "in mlpack", "comp-reorderable",
    ]);
    for w in registry() {
        t.row(vec![
            w.name().into(),
            w.category().to_string(),
            if w.in_mlpack() { "yes" } else { "no" }.into(),
            if w.supports_visit_order() { "yes" } else { "no" }.into(),
        ]);
    }
    println!("{}", t.render());

    let mut t = Table::new(
        "reorder_methods",
        "Table VIII — reordering methods (`mlperf reorder --method <cli name>`)",
        &["cli name", "paper label", "kind", "phase", "applicable to"],
    );
    let workloads = registry();
    for k in ReorderKind::ALL {
        let applicable = workloads.iter().filter(|w| k.applicable_to(w.as_ref())).count();
        t.row(vec![
            cli_method_name(k).into(),
            k.name().into(),
            if k.is_data_layout() { "data layout" } else { "computation" }.into(),
            if k.is_offline() { "offline" } else { "runtime" }.into(),
            format!("{applicable}/{} workloads", workloads.len()),
        ]);
    }
    println!("{}", t.render());

    let mut t = Table::new(
        "profiles",
        "library profiles (`--profile <name>`)",
        &["profile", "models", "workloads", "missing"],
    );
    for (flag, profile) in [("sklearn", LibraryProfile::Sklearn), ("mlpack", LibraryProfile::Mlpack)]
    {
        let supported = supported_names(profile);
        let missing: Vec<&str> = registry()
            .iter()
            .filter(|w| !profile.implements(w.as_ref()))
            .map(|w| w.name())
            .collect();
        t.row(vec![
            flag.into(),
            format!("{profile:?}-like library behaviour"),
            format!("{}", supported.len()),
            if missing.is_empty() { "-".into() } else { missing.join(", ") },
        ]);
    }
    println!("{}", t.render());

    let mut t = Table::new(
        "grid_scenarios",
        "grid scenario columns (replayable cells share one recording per workload)",
        &["scenario", "grid", "replayable", "models"],
    );
    let rows: [(Scenario, &str, &str); 8] = [
        (Scenario::Baseline, "standard+full", "Figs. 1-10 baseline characterization"),
        (Scenario::SwPrefetch, "full", "Figs. 14-18 software prefetching"),
        (Scenario::PerfectL2, "full", "Fig. 12 perfect (always-hit) L2"),
        (Scenario::PerfectLlc, "full", "Fig. 12 perfect (always-hit) LLC"),
        (Scenario::NoHwPrefetch, "full", "Fig. 13 hardware prefetchers off"),
        (Scenario::DramIdealRows, "full", "Table VII ideal row-buffer DRAM"),
        (Scenario::Multicore(4), "standard+full", "Tables III/IV sharded cores"),
        (
            Scenario::Reorder(ReorderKind::ZOrder),
            "via `mlperf reorder`",
            "Figs. 20-24 reordering optimizations",
        ),
    ];
    for (s, grids, what) in rows {
        t.row(vec![
            s.to_string(),
            grids.into(),
            if s.trace_variant().is_some() { "yes" } else { "no (direct)" }.into(),
            what.into(),
        ]);
    }
    println!("{}", t.render());

    let sweep = default_sweep();
    let mut t = Table::new(
        "sweeps",
        &format!(
            "cache sweep grid — {} geometries per workload, one trace pass (`mlperf grid --sweep cache`)",
            sweep.len()
        ),
        &["capacity", "ways swept", "sets per geometry"],
    );
    let mut i = 0;
    while i < sweep.len() {
        let bytes = sweep[i].bytes;
        let (mut ways, mut sets) = (Vec::new(), Vec::new());
        while i < sweep.len() && sweep[i].bytes == bytes {
            ways.push(sweep[i].ways.to_string());
            sets.push(sweep[i].sets().to_string());
            i += 1;
        }
        const MIB: u64 = 1024 * 1024;
        let cap = if bytes >= MIB && bytes % MIB == 0 {
            format!("{}MiB", bytes / MIB)
        } else {
            format!("{}KiB", bytes / 1024)
        };
        t.row(vec![cap, ways.join(", "), sets.join(", ")]);
    }
    println!("{}", t.render());

    let mut t = Table::new(
        "serve_protocol",
        &format!(
            "serve protocol v{} (`mlperf serve` daemon / `mlperf query --op <op>` client)",
            mlperf::serve::PROTOCOL_VERSION
        ),
        &["op", "what it does"],
    );
    for (op, what) in mlperf::serve::OPS {
        t.row(vec![(*op).into(), (*what).into()]);
    }
    println!("{}", t.render());

    let mut t = Table::new(
        "chaos_sites",
        "deterministic fault-injection sites (`--chaos 'seed=N;<site>@n[=param]'`)",
        &["site", "path"],
    );
    for &(site, name) in mlperf::util::fault::SITES {
        use mlperf::util::fault::Site;
        let path = match site {
            Site::ConnDrop | Site::SlowClient | Site::ServeKill => "serve",
            Site::LedgerIo | Site::LedgerAppendKill | Site::LedgerCompactKill => "ledger",
            Site::GridKill | Site::CapturePanic | Site::CellPanic | Site::Stall => "grid",
            _ => "trace",
        };
        t.row(vec![name.into(), path.into()]);
    }
    println!("{}", t.render());
    Ok(())
}

/// The `--method` spelling [`parse_kind`] accepts for each kind.
fn cli_method_name(k: ReorderKind) -> &'static str {
    match k {
        ReorderKind::FirstTouch => "first-touch",
        ReorderKind::Rcb => "rcb",
        ReorderKind::Hilbert => "hilbert",
        ReorderKind::ZOrder => "zorder",
        ReorderKind::LocalityBlocking => "blocking",
        ReorderKind::ZOrderComp => "zorder-comp",
    }
}

/// The full single-run metric rows shared by `characterize`, `record`,
/// and `replay`.
fn metric_rows(m: &Metrics) -> Vec<(&'static str, String)> {
    vec![
        ("instructions", format!("{}", m.instructions)),
        ("cycles", format!("{:.0}", m.cycles)),
        ("CPI", r2(m.cpi)),
        ("IPC", r2(m.ipc)),
        ("retiring %", pct(m.retiring_pct)),
        ("bad speculation %", pct(m.bad_spec_pct)),
        ("DRAM bound %", pct(m.dram_bound_pct)),
        ("core bound %", pct(m.core_bound_pct)),
        ("branch fraction", r3(m.branch_fraction)),
        ("cond branch fraction", r3(m.cond_branch_fraction)),
        ("branch mispredict ratio", r3(m.branch_mispredict_ratio)),
        ("L2 miss ratio", r3(m.l2_miss_ratio)),
        ("LLC miss ratio", r3(m.llc_miss_ratio)),
        ("DRAM row-hit ratio", r3(m.dram.row_hit_ratio())),
        ("DRAM avg latency (ns)", r2(m.dram.avg_latency_ns())),
        ("bandwidth utilization %", pct(m.bandwidth_utilization_pct())),
        ("HW prefetch useless frac", r3(m.prefetch.hw_useless_fraction())),
    ]
}

fn cmd_characterize(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let w = workload_from(args)?;
    require_profile_support(w.as_ref(), cfg.profile)?;
    let c = characterize(w.as_ref(), &cfg);
    let mut t = Table::new(
        "characterize",
        &format!("{} ({:?}, rows={})", w.name(), cfg.profile, cfg.rows_for(w.as_ref())),
        &["metric", "value"],
    );
    for (k, v) in metric_rows(&c.metrics) {
        t.row(vec![k.into(), v]);
    }
    t.row(vec!["quality".into(), format!("{:.4}", c.result.quality)]);
    t.row(vec!["model".into(), c.result.detail.clone()]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_record(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let w = workload_from(args)?;
    require_profile_support(w.as_ref(), cfg.profile)?;
    let sw_prefetch = args.has("sw-prefetch");
    let default_out = format!("{}.mlt", w.name().to_lowercase().replace([' ', '-'], "_"));
    let out = args.get_or("out", &default_out);
    let (c, summary) =
        record_characterize(w.as_ref(), &cfg, sw_prefetch, std::path::Path::new(&out))?;
    let mut t = Table::new(
        "record",
        &format!(
            "recorded {} ({:?}, rows={}, sw_prefetch={})",
            w.name(),
            cfg.profile,
            cfg.rows_for(w.as_ref()),
            sw_prefetch
        ),
        &["metric", "value"],
    );
    for (k, v) in metric_rows(&c.metrics) {
        t.row(vec![k.into(), v]);
    }
    t.row(vec!["quality".into(), format!("{:.4}", c.result.quality)]);
    t.row(vec!["trace file".into(), out.clone()]);
    t.row(vec!["trace blocks".into(), format!("{}", summary.blocks)]);
    t.row(vec!["trace events".into(), format!("{}", summary.events)]);
    t.row(vec!["trace bytes".into(), format!("{}", summary.bytes)]);
    t.row(vec![
        "bytes/event".into(),
        format!("{:.2}", summary.bytes as f64 / summary.events.max(1) as f64),
    ]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let path = args.get("trace").ok_or_else(|| {
        anyhow!("--trace <file.mlt> required (create one with `mlperf record`)")
    })?;
    let mutate = |c: &mut mlperf::sim::CpuConfig| {
        if args.has("perfect-l2") {
            c.cache.perfect_l2 = true;
        }
        if args.has("perfect-llc") {
            c.cache.perfect_llc = true;
        }
        if args.has("no-hw-prefetch") {
            c.cache.hw_prefetch = false;
        }
        if args.has("ideal-rows") {
            c.dram.ideal_row_hits = true;
        }
    };
    if let Some(sc) = cfg.sample {
        let (meta, report, stats) =
            replay_file_sampled(std::path::Path::new(path), &cfg, sc, mutate)?;
        let mut t = Table::new(
            "replay_sampled",
            &format!(
                "sampled replay {} ({:?}, rows={}, sw_prefetch={}, {} events in {} blocks, sample {})",
                meta.workload, meta.profile, meta.rows, meta.sw_prefetch, stats.events,
                stats.blocks, sc
            ),
            &["metric", "value (estimate)"],
        );
        for (k, v) in metric_rows(&report.estimate) {
            t.row(vec![k.into(), v]);
        }
        t.row(vec!["CPI 95% CI (±)".into(), format!("{:.4}", report.cpi_ci95)]);
        t.row(vec!["detailed windows".into(), format!("{}", report.windows)]);
        t.row(vec![
            "blocks detailed/total".into(),
            format!("{}/{}", report.blocks_detailed, report.blocks_total),
        ]);
        t.row(vec![
            "instr detailed/total".into(),
            format!("{}/{}", report.instructions_detailed, report.instructions),
        ]);
        if report.degenerate {
            t.row(vec!["mode".into(), "degenerate (detail >= period): exact full run".into()]);
        }
        println!("{}", t.render());
        return Ok(());
    }
    let (meta, m, stats) = replay_file(std::path::Path::new(path), &cfg, mutate)?;
    let mut t = Table::new(
        "replay",
        &format!(
            "replayed {} ({:?}, rows={}, sw_prefetch={}, {} events in {} blocks)",
            meta.workload, meta.profile, meta.rows, meta.sw_prefetch, stats.events, stats.blocks
        ),
        &["metric", "value"],
    );
    for (k, v) in metric_rows(&m) {
        t.row(vec![k.into(), v]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_prefetch(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let w = workload_from(args)?;
    require_profile_support(w.as_ref(), cfg.profile)?;
    let s = prefetch_study(w.as_ref(), &cfg);
    let mut t = Table::new(
        "prefetch",
        &format!("software prefetching on {} (Figs. 14-18)", w.name()),
        &["metric", "baseline", "prefetched"],
    );
    t.row(vec!["L2 miss ratio".into(), r3(s.base.l2_miss_ratio), r3(s.prefetched.l2_miss_ratio)]);
    t.row(vec!["DRAM bound %".into(), pct(s.base.dram_bound_pct), pct(s.prefetched.dram_bound_pct)]);
    t.row(vec!["bad spec %".into(), pct(s.base.bad_spec_pct), pct(s.prefetched.bad_spec_pct)]);
    t.row(vec![
        "2+ uops/cycle frac".into(),
        r3(s.base.two_plus_uops_fraction()),
        r3(s.prefetched.two_plus_uops_fraction()),
    ]);
    t.row(vec!["CPI".into(), r2(s.base.cpi), r2(s.prefetched.cpi)]);
    t.row(vec![
        "speedup".into(),
        "1.00".into(),
        r3(s.prefetched.speedup_vs(&s.base)),
    ]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_reorder(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let w = workload_from(args)?;
    require_profile_support(w.as_ref(), cfg.profile)?;
    let method = args.get_or("method", "zorder");
    let kind = parse_kind(&method)?;
    if !kind.applicable_to(w.as_ref()) {
        bail!("{} is not applicable to {}", kind, w.name());
    }
    let s = reorder_study(w.as_ref(), kind, &cfg);
    let mut t = Table::new(
        "reorder",
        &format!("{} on {} (Figs. 20-24)", kind, w.name()),
        &["metric", "baseline", "reordered"],
    );
    t.row(vec![
        "row-buffer hit ratio".into(),
        r3(s.baseline.dram.row_hit_ratio()),
        r3(s.reordered.dram.row_hit_ratio()),
    ]);
    t.row(vec![
        "avg DRAM latency (ns)".into(),
        r2(s.baseline.dram.avg_latency_ns()),
        r2(s.reordered.dram.avg_latency_ns()),
    ]);
    t.row(vec![
        "bad spec %".into(),
        pct(s.baseline.bad_spec_pct),
        pct(s.reordered.bad_spec_pct),
    ]);
    t.row(vec!["CPI".into(), r2(s.baseline.cpi), r2(s.reordered.cpi)]);
    t.row(vec![
        "speedup (no overhead)".into(),
        "1.00".into(),
        r3(s.speedup_no_overhead()),
    ]);
    t.row(vec![
        "speedup (with overhead)".into(),
        "1.00".into(),
        r3(s.speedup_with_overhead()),
    ]);
    println!("{}", t.render());
    Ok(())
}

pub fn parse_kind(s: &str) -> Result<ReorderKind> {
    let norm = s.to_lowercase().replace(['-', '_'], "");
    // the names `mlperf list` advertises are accepted by construction —
    // the two can never drift apart
    if let Some(k) = ReorderKind::ALL
        .into_iter()
        .find(|&k| cli_method_name(k).replace('-', "") == norm)
    {
        return Ok(k);
    }
    Ok(match norm.as_str() {
        "ft" => ReorderKind::FirstTouch,
        "morton" => ReorderKind::ZOrder,
        "localityblocking" => ReorderKind::LocalityBlocking,
        "zorderc" => ReorderKind::ZOrderComp,
        other => bail!("unknown reorder method {other:?} (see `mlperf list`)"),
    })
}

fn cmd_multicore(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let w = workload_from(args)?;
    require_profile_support(w.as_ref(), cfg.profile)?;
    let cores: usize = args.get_parsed_or("cores", 4);
    let m = multicore_characterize(w.as_ref(), &cfg, cores);
    let mut t = Table::new(
        "multicore",
        &format!("{} on {} cores (Tables III/IV)", w.name(), cores),
        &["CPI", "retiring %", "bad spec %", "DRAM bound %", "core bound %"],
    );
    t.row(mlperf::analysis::topdown_cells(&m));
    println!("{}", t.render());
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let rows: usize = args.get_parsed_or("rows", 100_000);
    let features: usize = args.get_parsed_or("features", 20);
    let seed: u64 = args.get_parsed_or("seed", 1u64);
    let out = args.get_or("out", "data.bin");
    let ds = mlperf::data::make_blobs(rows, features, 8, 1.0, seed);
    mlperf::data::io::save(&ds, std::path::Path::new(&out))?;
    println!("wrote {rows}x{features} dataset ({} MB) to {out}", ds.bytes() / 1_000_000);
    Ok(())
}

fn cmd_runtime(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(mlperf::runtime::default_artifacts_dir);
    let rt = mlperf::runtime::Runtime::load(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let mut rng = mlperf::util::Pcg64::new(1);
    let x: Vec<f32> = (0..mlperf::runtime::BATCH * mlperf::runtime::FEATURES)
        .map(|_| rng.normal() as f32)
        .collect();
    let c: Vec<f32> = (0..mlperf::runtime::K * mlperf::runtime::FEATURES)
        .map(|_| rng.normal() as f32)
        .collect();
    let (_, inertia) = rt.kmeans_step(&x, &c)?;
    println!("kmeans_step OK (batch inertia {inertia:.1})");
    Ok(())
}

fn cmd_grid(args: &Args) -> Result<()> {
    // grid work is simulated from in-memory captures (and the sweep
    // streams workloads straight into the profiler) — nothing is decoded
    // from disk, so silently accepting the ingest knob would be a lie
    if args.get("ingest-threads").is_some() {
        diag::warn(
            "--ingest-threads has no effect on `mlperf grid` — grid replay broadcasts \
             in-memory captures and decodes nothing from disk; the knob staged-ingests file \
             traces (`mlperf replay --trace`)",
        );
    }
    if let Some(kind) = args.get("sweep") {
        return cmd_grid_sweep(args, kind);
    }
    let mut cfg = config_from(args)?;
    let threads: usize = args.get_parsed_or("threads", 0usize);
    let direct = args.has("direct");
    if direct && cfg.sample.is_some() {
        diag::warn(
            "--sample has no effect on `mlperf grid --direct` — direct cells re-execute \
             the workload through the full simulator; dropping the sampling request so the \
             results artifact does not claim estimates it did not make",
        );
        cfg.sample = None;
    }
    // `--json -` streams the results artifact to stdout, so every
    // human-facing line (status, tables, progress) moves to stderr and
    // `mlperf grid --json - | python3 -m json.tool` just works
    let json_out = args.get("json");
    let json_to_stdout = json_out == Some("-");
    let ledger_path = args.get("ledger");
    let jobs = if args.has("full") { full_grid(&cfg) } else { standard_grid(&cfg) };
    diag::note(format!(
        "running {} jobs at scale {} in {} mode …",
        jobs.len(),
        cfg.scale,
        match (ledger_path, direct) {
            (Some(_), _) => "ledgered (simulate-once/query-many)",
            (None, true) => "direct",
            (None, false) => "record-once/replay-many",
        }
    ));
    progress::start(jobs.len());
    let report = match ledger_path {
        Some(lp) => {
            if direct {
                diag::warn(
                    "--direct is ignored with --ledger (misses run in replay mode); \
                     drop --ledger to force per-cell re-execution",
                );
            }
            let mut ledger = Ledger::open(std::path::Path::new(lp))?;
            ledger.set_durable(args.has("durable"));
            run_jobs_ledgered(&cfg, &jobs, threads, &mut ledger)?
        }
        None if direct => run_jobs(&cfg, &jobs, threads),
        None => run_jobs_replayed(&cfg, &jobs, threads),
    };
    progress::finish();
    let sampled = cfg.sample.is_some();
    let mut headers = vec!["workload", "scenario", "CPI"];
    if sampled {
        headers.push("+-CI95");
    }
    headers.extend(["ret%", "bspec%", "dram%", "core%", "quality"]);
    let mut t = Table::new(
        "grid",
        &format!(
            "parallel experiment grid ({} jobs, {} workload executions, {} cached, {} threads, {:.1}s wall{})",
            report.outputs.len(),
            report.workload_executions,
            report.cached_cells,
            report.threads_used,
            report.wall_seconds,
            cfg.sample
                .map(|s| format!(", sampled {s}"))
                .unwrap_or_default()
        ),
        &headers,
    );
    for out in &report.outputs {
        let m = &out.metrics;
        let mut cells = vec![
            out.job.workload.clone(),
            out.job.scenario.to_string(),
            r2(m.cpi),
        ];
        if sampled {
            // "-" marks cells the sampler cannot serve (direct scenarios
            // like multicore) or that came exact out of the ledger
            cells.push(
                out.sample
                    .map(|s| format!("{:.3}", s.cpi_ci95))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        cells.extend([
            pct(m.retiring_pct),
            pct(m.bad_spec_pct),
            pct(m.dram_bound_pct),
            pct(m.core_bound_pct),
            out.quality.map(|q| format!("{q:.4}")).unwrap_or_else(|| "-".into()),
        ]);
        t.row(cells);
    }
    if json_to_stdout {
        t.emit_stderr();
    } else {
        t.emit();
    }

    // quarantine report: human-readable lines plus the machine-readable
    // `results/failures.json` artifact (written even when empty, so CI
    // can assert the exact quarantined set of a chaos run)
    for f in &report.failed {
        diag::note(format!(
            "quarantined: {} / {} [{}] {} (fingerprint {})",
            f.job.workload, f.job.scenario, f.kind, f.error, f.fingerprint
        ));
    }
    let failures_path = std::path::Path::new("results").join("failures.json");
    match std::fs::create_dir_all("results")
        .and_then(|()| std::fs::write(&failures_path, failures_json(&report.failed)))
    {
        Ok(()) if report.failed.is_empty() => {}
        Ok(()) => diag::note(format!(
            "wrote {} failed cell(s) to {}",
            report.failed.len(),
            failures_path.display()
        )),
        Err(e) => diag::warn(format!(
            "failures not persisted to {}: {e}",
            failures_path.display()
        )),
    }
    if cfg.strict && !report.failed.is_empty() {
        let f = &report.failed[0];
        bail!(
            "--strict: {} grid cell(s) failed; first: {} / {}: {}",
            report.failed.len(),
            f.job.workload,
            f.job.scenario,
            f.error
        );
    }

    let current = GridResults::from_outputs(&cfg, &report.outputs);
    if let Some(jp) = json_out {
        if json_to_stdout {
            println!("{}", current.to_json());
        } else {
            current.save(std::path::Path::new(jp))?;
            diag::note(format!("wrote grid results JSON to {jp}"));
        }
    }
    if args.has("assert-cached") && report.workload_executions > 0 {
        bail!(
            "--assert-cached: {} workload execution(s) occurred ({} of {} cells cached) — \
             the ledger did not fully cover this grid",
            report.workload_executions,
            report.cached_cells,
            report.outputs.len()
        );
    }
    if let Some(bp) = args.get("baseline") {
        gate_against_baseline(
            &current,
            bp,
            tolerance_from(args),
            args.has("gate"),
            args.has("allow-vacuous"),
        )?;
    }
    Ok(())
}

/// `mlperf grid --sweep cache`: resolve the whole (workloads × cache
/// geometries) miss-curve grid with **one trace pass per workload** —
/// the reuse-distance stack profiler prices every exact-LRU geometry
/// from a single walk of the demand stream, instead of one replay per
/// (size × ways) cell.
fn cmd_grid_sweep(args: &Args, kind: &str) -> Result<()> {
    if kind != "cache" {
        bail!("unknown --sweep kind {kind:?} (supported: cache)");
    }
    let cfg = config_from(args)?;
    let threads: usize = args.get_parsed_or("threads", 0usize);
    let workloads: Vec<String> = match args.get("workload") {
        Some(name) => {
            let w = by_name(name)
                .ok_or_else(|| anyhow!("unknown workload {name:?} (see `mlperf list`)"))?;
            require_profile_support(w.as_ref(), cfg.profile)?;
            vec![w.name().to_string()]
        }
        None => registry()
            .iter()
            .filter(|w| cfg.profile.implements(w.as_ref()))
            .map(|w| w.name().to_string())
            .collect(),
    };
    let geometries = default_sweep();
    let json_out = args.get("json");
    let json_to_stdout = json_out == Some("-");
    diag::note(format!(
        "sweeping {} workload(s) × {} cache geometries (one trace pass per workload) …",
        workloads.len(),
        geometries.len()
    ));
    let mut ledger = match args.get("ledger") {
        Some(lp) => Some(Ledger::open(std::path::Path::new(lp))?),
        None => None,
    };
    let report = run_cache_sweep(&cfg, &workloads, &geometries, threads, ledger.as_mut())?;
    let mut t = Table::new(
        "cache_sweep",
        &format!(
            "exact-LRU miss curves ({} cells, {} workload executions, {} cached, {} threads, {:.1}s wall)",
            report.cells.len(),
            report.workload_executions,
            report.cached_cells,
            report.threads_used,
            report.wall_seconds
        ),
        &["workload", "geometry", "sets", "accesses", "misses", "miss-ratio", "cached"],
    );
    for c in &report.cells {
        t.row(vec![
            c.workload.clone(),
            c.geometry.label(),
            format!("{}", c.geometry.sets()),
            format!("{}", c.accesses),
            format!("{}", c.misses),
            r3(c.miss_ratio()),
            if c.cached { "yes" } else { "no" }.into(),
        ]);
    }
    if json_to_stdout {
        t.emit_stderr();
    } else {
        t.emit();
    }
    if let Some(jp) = json_out {
        if json_to_stdout {
            println!("{}", sweep_json(&cfg, &report));
        } else {
            std::fs::write(jp, sweep_json(&cfg, &report))
                .map_err(|e| anyhow!("writing {jp}: {e}"))?;
            diag::note(format!("wrote cache sweep JSON to {jp}"));
        }
    }
    if args.has("assert-cached") && report.workload_executions > 0 {
        bail!(
            "--assert-cached: {} workload execution(s) occurred ({} of {} sweep cells cached) — \
             the ledger did not fully cover this sweep",
            report.workload_executions,
            report.cached_cells,
            report.cells.len()
        );
    }
    Ok(())
}

/// The `mlperf-cache-sweep/v1` results artifact (`grid --sweep cache
/// --json`): run parameters + one record per (workload × geometry) cell,
/// fingerprints included so artifacts can be joined against ledgers.
fn sweep_json(cfg: &ExperimentConfig, report: &SweepReport) -> String {
    let cells: Vec<Json> = report
        .cells
        .iter()
        .map(|c| {
            Json::Obj(vec![
                ("workload".to_string(), Json::Str(c.workload.clone())),
                ("geometry".to_string(), Json::Str(c.geometry.label())),
                ("bytes".to_string(), Json::num(c.geometry.bytes as f64)),
                ("ways".to_string(), Json::num(c.geometry.ways as f64)),
                ("sets".to_string(), Json::num(c.geometry.sets() as f64)),
                ("accesses".to_string(), Json::num(c.accesses as f64)),
                ("misses".to_string(), Json::num(c.misses as f64)),
                ("miss_ratio".to_string(), Json::num(c.miss_ratio())),
                ("fingerprint".to_string(), Json::Str(c.fingerprint.to_string())),
                ("cached".to_string(), Json::Bool(c.cached)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema".to_string(), Json::Str("mlperf-cache-sweep/v1".to_string())),
        ("scale".to_string(), Json::num(cfg.scale)),
        ("profile".to_string(), Json::Str(format!("{:?}", cfg.profile))),
        ("seed".to_string(), Json::Str(cfg.seed.to_string())),
        ("iterations".to_string(), Json::num(cfg.iterations as f64)),
        ("features".to_string(), Json::num(cfg.features as f64)),
        ("workload_executions".to_string(), Json::num(report.workload_executions as f64)),
        ("cached_cells".to_string(), Json::num(report.cached_cells as f64)),
        ("wall_seconds".to_string(), Json::num(report.wall_seconds)),
        ("cells".to_string(), Json::Arr(cells)),
    ])
    .render()
}

/// The `mlperf-failures/v2` artifact: one record per quarantined grid
/// cell, keyed the same way as the results JSON so the two can be
/// joined (a cell appears in exactly one of them). v2 adds per-failure
/// timing telemetry: `wall_nanos` (time-to-failure) and `backoff_nanos`
/// (retry sleep spent before giving up).
fn failures_json(failed: &[FailedCell]) -> String {
    let cells: Vec<Json> = failed
        .iter()
        .map(|f| {
            Json::Obj(vec![
                ("workload".to_string(), Json::Str(f.job.workload.clone())),
                ("scenario".to_string(), Json::Str(f.job.scenario.to_string())),
                ("fingerprint".to_string(), Json::Str(f.fingerprint.to_string())),
                ("kind".to_string(), Json::Str(f.kind.clone())),
                ("error".to_string(), Json::Str(f.error.clone())),
                ("retries".to_string(), Json::num(f.retries as f64)),
                ("wall_nanos".to_string(), Json::num(f.wall_nanos as f64)),
                ("backoff_nanos".to_string(), Json::num(f.backoff_nanos as f64)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema".to_string(), Json::Str("mlperf-failures/v2".to_string())),
        ("failed".to_string(), Json::num(failed.len() as f64)),
        ("cells".to_string(), Json::Arr(cells)),
    ])
    .render()
}

fn tolerance_from(args: &Args) -> f64 {
    args.get_parsed_or("tolerance", DEFAULT_TOLERANCE)
}

/// Diff `current` against the baseline file, emit the delta table and
/// the machine-readable verdict, and (when `gate`) fail on drift. A
/// gate against an empty placeholder baseline compares nothing — that
/// is an error by default (a passing exit must certify something);
/// `allow_vacuous` downgrades it to the historical warning.
fn gate_against_baseline(
    current: &GridResults,
    baseline_path: &str,
    tolerance: f64,
    gate: bool,
    allow_vacuous: bool,
) -> Result<()> {
    let baseline = GridResults::load(std::path::Path::new(baseline_path))?;
    if baseline.cells.is_empty() {
        println!(
            "baseline {baseline_path} has no cells (bootstrap placeholder) — nothing to diff; \
             regenerate it with `mlperf grid --json {baseline_path}`"
        );
        if gate {
            if allow_vacuous {
                eprintln!(
                    "warning: --gate against the empty baseline is VACUOUS — zero metrics were \
                     compared, so this exit code certifies nothing (--allow-vacuous accepted it); \
                     populate {baseline_path} to arm the gate"
                );
            } else {
                bail!(
                    "--gate against empty baseline {baseline_path} is vacuous: zero metrics were \
                     compared, so a passing exit would certify nothing; populate the baseline \
                     (`mlperf grid --json {baseline_path}`) or pass --allow-vacuous to accept a \
                     no-op gate"
                );
            }
        }
        return Ok(());
    }
    let report = diff(current, &baseline, tolerance);
    report.table().emit();
    let verdict_path = std::path::Path::new("results").join("gate_verdict.json");
    match std::fs::create_dir_all("results")
        .and_then(|()| std::fs::write(&verdict_path, report.verdict_json()))
    {
        Ok(()) => println!("wrote gate verdict to {}", verdict_path.display()),
        Err(e) => eprintln!(
            "warning: gate verdict not persisted to {}: {e}",
            verdict_path.display()
        ),
    }
    if report.pass() {
        println!(
            "gate vs {baseline_path}: PASS ({} metrics compared, tolerance ±{:.2}%)",
            report.rows.len(),
            tolerance * 100.0
        );
        Ok(())
    } else if gate {
        bail!(
            "regression gate vs {baseline_path} FAILED: {} metric(s) drifted beyond ±{:.2}% \
             and {} baseline cell(s) are missing",
            report.drifted(),
            tolerance * 100.0,
            report.missing.len()
        )
    } else {
        println!(
            "gate vs {baseline_path}: FAIL (advisory — pass --gate to turn this into a non-zero exit)"
        );
        Ok(())
    }
}

fn cmd_ledger(args: &Args) -> Result<()> {
    let action = args.positional.first().map(String::as_str).unwrap_or("stats");
    let path = args
        .get("ledger")
        .ok_or_else(|| anyhow!("--ledger <file.mllg> required (see `mlperf grid --ledger`)"))?;
    let mut ledger = Ledger::open(std::path::Path::new(path))?;
    match action {
        "stats" => {
            let s = ledger.stats();
            let mut t = Table::new(
                "ledger_stats",
                &format!("experiment ledger {path}"),
                &["metric", "value"],
            );
            t.row(vec!["records".into(), format!("{}", s.records)]);
            t.row(vec!["unique cells".into(), format!("{}", s.unique)]);
            t.row(vec!["superseded".into(), format!("{}", s.superseded)]);
            t.row(vec!["file bytes".into(), format!("{}", s.file_bytes)]);
            t.row(vec![
                "recovered tail bytes".into(),
                format!("{}", s.recovered_tail_bytes),
            ]);
            println!("{}", t.render());
        }
        "gc" => {
            let r = ledger.compact()?;
            println!(
                "compacted {path}: {} -> {} records, {} -> {} bytes",
                r.records_before, r.records_after, r.bytes_before, r.bytes_after
            );
        }
        "export" => {
            let json = ledger.export_json();
            match args.get("out") {
                Some(out) => {
                    std::fs::write(out, &json)
                        .map_err(|e| anyhow!("writing {out}: {e}"))?;
                    println!(
                        "exported {} cells to {out}",
                        ledger.stats().unique
                    );
                }
                None => println!("{json}"),
            }
        }
        other => bail!("unknown ledger action {other:?} (stats|gc|export)"),
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let mut cfg = config_from(args)?;
    if let Some(bp) = args.get("baseline") {
        return cmd_report_baseline(args, &mut cfg, bp);
    }
    println!("running the full figure/table suite at scale {} …", cfg.scale);
    let mut t = Table::new(
        "fig01_10",
        "single-core characterization (Figs. 1-10)",
        &["workload", "CPI", "ret%", "bspec%", "dram%", "core%", "br-frac", "LLC-miss"],
    );
    for w in registry() {
        if !cfg.profile.implements(w.as_ref()) {
            continue;
        }
        let c = characterize(w.as_ref(), &cfg);
        let m = &c.metrics;
        t.row(vec![
            w.name().into(),
            r2(m.cpi),
            pct(m.retiring_pct),
            pct(m.bad_spec_pct),
            pct(m.dram_bound_pct),
            pct(m.core_bound_pct),
            r3(m.branch_fraction),
            r3(m.llc_miss_ratio),
        ]);
    }
    t.emit();
    Ok(())
}

/// `mlperf report --baseline <file.json> [--gate|--bless]`: re-run
/// exactly the cells the baseline tracks (at the baseline's recorded
/// scale/profile unless overridden) and diff the tracked metrics
/// against it — or, with `--bless`, overwrite the baseline file with
/// the freshly computed results. Blessing an empty or missing baseline
/// bootstraps it from the standard grid (`--full` for every scenario
/// column), which is the documented replacement for committing a
/// placeholder `BENCH_grid_baseline.json` by hand.
fn cmd_report_baseline(args: &Args, cfg: &mut ExperimentConfig, baseline_path: &str) -> Result<()> {
    let bless = args.has("bless");
    let baseline = match GridResults::load(std::path::Path::new(baseline_path)) {
        Ok(b) => Some(b),
        Err(e) if bless => {
            println!("baseline {baseline_path} not loadable ({e:#}) — blessing from scratch");
            None
        }
        Err(e) => return Err(e),
    };
    let is_empty = baseline.as_ref().map(|b| b.cells.is_empty()).unwrap_or(true);
    if is_empty && !bless {
        println!(
            "baseline {baseline_path} has no cells (bootstrap placeholder) — nothing to gate; \
             regenerate it with `mlperf report --baseline {baseline_path} --bless`"
        );
        if args.has("gate") {
            if args.has("allow-vacuous") {
                eprintln!(
                    "warning: --gate against the empty baseline is VACUOUS — no cell was re-run \
                     or compared, so this exit code certifies nothing (--allow-vacuous accepted \
                     it); bless {baseline_path} to arm the gate"
                );
            } else {
                bail!(
                    "--gate against empty baseline {baseline_path} is vacuous: no cell was re-run \
                     or compared, so a passing exit would certify nothing; bless the baseline \
                     (`mlperf report --baseline {baseline_path} --bless`) or pass --allow-vacuous \
                     to accept a no-op gate"
                );
            }
        }
        return Ok(());
    }
    if let Some(baseline) = baseline.as_ref().filter(|b| !b.cells.is_empty()) {
        // default to the baseline's recorded run parameters so the diff
        // compares like with like; explicit flags still win
        if args.get("scale").is_none() && baseline.scale > 0.0 {
            cfg.scale = baseline.scale;
        }
        if args.get("seed").is_none() {
            cfg.seed = baseline.seed;
        }
        if args.get("iterations").is_none() && baseline.iterations > 0 {
            cfg.iterations = baseline.iterations;
        }
        if args.get("features").is_none() && baseline.features > 0 {
            cfg.features = baseline.features;
        }
        if !args.has("no-hw-prefetch") {
            cfg.cpu.cache.hw_prefetch = baseline.hw_prefetch;
        }
        if args.get("sample").is_none() {
            cfg.sample = baseline.sample;
        }
        if args.get("profile").is_none() {
            match baseline.profile.as_str() {
                "Sklearn" => cfg.profile = LibraryProfile::Sklearn,
                "Mlpack" => cfg.profile = LibraryProfile::Mlpack,
                other => bail!("baseline {baseline_path} names unknown profile {other:?}"),
            }
        }
    }
    let jobs = match baseline.as_ref().filter(|b| !b.cells.is_empty()) {
        Some(baseline) => baseline
            .cells
            .iter()
            .map(|c| {
                Scenario::parse(&c.scenario)
                    .map(|s| Job::new(c.workload.clone(), s))
                    .ok_or_else(|| {
                        anyhow!("baseline cell {}/{:?}: unknown scenario", c.workload, c.scenario)
                    })
            })
            .collect::<Result<Vec<Job>>>()?,
        None => {
            if args.has("full") {
                full_grid(cfg)
            } else {
                standard_grid(cfg)
            }
        }
    };
    println!(
        "{} the {} cells at scale {} ({:?}) …",
        if bless { "blessing" } else { "re-running" },
        jobs.len(),
        cfg.scale,
        cfg.profile
    );
    let threads: usize = args.get_parsed_or("threads", 0usize);
    let report = match args.get("ledger") {
        Some(lp) => {
            let mut ledger = Ledger::open(std::path::Path::new(lp))?;
            ledger.set_durable(args.has("durable"));
            run_jobs_ledgered(cfg, &jobs, threads, &mut ledger)?
        }
        None => run_jobs_replayed(cfg, &jobs, threads),
    };
    println!(
        "{} executed, {} cached, {:.1}s wall",
        report.workload_executions, report.cached_cells, report.wall_seconds
    );
    if !report.failed.is_empty() {
        // a gate or bless over a partial grid would silently shrink the
        // baseline — always fail loudly here, strict or not
        let f = &report.failed[0];
        bail!(
            "{} cell(s) failed during the baseline {}; first: {} / {}: {}",
            report.failed.len(),
            if bless { "bless" } else { "re-run" },
            f.job.workload,
            f.job.scenario,
            f.error
        );
    }
    let current = GridResults::from_outputs(cfg, &report.outputs);
    if bless {
        current.save(std::path::Path::new(baseline_path))?;
        println!(
            "blessed {} cells (scale {}, {:?}{}) to {baseline_path} — commit it to arm the gate",
            current.cells.len(),
            current.scale,
            cfg.profile,
            cfg.sample.map(|s| format!(", sampled {s}")).unwrap_or_default()
        );
        return Ok(());
    }
    gate_against_baseline(
        &current,
        baseline_path,
        tolerance_from(args),
        args.has("gate"),
        args.has("allow-vacuous"),
    )
}

/// `mlperf serve`: bring up the grid-as-a-service daemon and block
/// until SIGTERM/SIGINT or a protocol `shutdown` drains it (exit 0).
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let opts = mlperf::serve::ServeOptions {
        listen: args.get_or("listen", "127.0.0.1:0"),
        dir: std::path::PathBuf::from(args.get_or("dir", "results/serve")),
        shards: args.get_parsed_or("shards", mlperf::serve::DEFAULT_SHARDS),
        queue_depth: args.get_parsed_or("queue-depth", 64usize),
        default_deadline_ms: args.get_parsed_or("default-deadline", 5000u64),
        sim_threads: args.get_parsed_or("threads", 0usize),
        durable: args.has("durable"),
        cfg,
    };
    let dir = opts.dir.clone();
    let server = mlperf::serve::Server::bind(opts)?;
    diag::note(format!(
        "serve: listening on {} (protocol v{}, pid {}, addr file {}/serve.addr) — \
         drain with SIGTERM or `mlperf query --dir {} --op shutdown`",
        server.addr(),
        mlperf::serve::PROTOCOL_VERSION,
        std::process::id(),
        dir.display(),
        dir.display(),
    ));
    server.run()
}

/// `mlperf query`: one request against a running serve daemon. Prints
/// the response document; a typed rejection (`overloaded`,
/// `deadline-exceeded`, …) also becomes a non-zero exit so scripts can
/// branch on it.
fn cmd_query(args: &Args) -> Result<()> {
    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => {
            let dir = args.get_or("dir", "results/serve");
            mlperf::serve::discover_addr(std::path::Path::new(&dir))?
        }
    };
    let mut client = mlperf::serve::Client::connect(&addr)?;
    client.set_timeout(Some(std::time::Duration::from_millis(
        args.get_parsed_or("timeout", 30_000u64),
    )))?;
    let op = args.get_or("op", "query");
    let resp = if op == "query" {
        let workload = args.get("workload").ok_or_else(|| {
            anyhow!("--workload <name> required for --op query (see `mlperf list`)")
        })?;
        let scenario = args.get_or("scenario", "baseline");
        let deadline_ms = match args.get("deadline-ms") {
            Some(s) => Some(
                s.parse::<u64>()
                    .map_err(|_| anyhow!("malformed --deadline-ms {s:?} (milliseconds)"))?,
            ),
            None => None,
        };
        client.query(workload, &scenario, deadline_ms)?
    } else {
        client.op(&op)?
    };
    println!("{}", resp.render());
    if resp.get("ok").and_then(Json::as_bool) == Some(false) {
        let kind = resp.get("kind").and_then(Json::as_str).unwrap_or("error");
        let msg = resp.get("error").and_then(Json::as_str).unwrap_or("request failed");
        bail!("{kind}: {msg}");
    }
    Ok(())
}
