//! Chrome trace-event exporter: renders a
//! [`Snapshot`](crate::util::telemetry::Snapshot) as the JSON Array
//! Format understood by Perfetto and `chrome://tracing`.
//!
//! Each telemetry lane becomes one timeline row (`tid`), named via an
//! `"M"` (metadata) `thread_name` event. Every recorded span becomes a
//! balanced `"B"`/`"E"` pair. Correct nesting is *not* reconstructed
//! from timestamps — independent clock reads can tie or jitter by
//! nanoseconds — but from the collector's shared open/close sequence
//! ([`SpanRec::open_seq`](crate::util::telemetry::SpanRec::open_seq)):
//! sorting a lane's B/E events by sequence reproduces the exact stack
//! discipline the RAII guards enforced, so every `E` closes the
//! innermost open `B` by construction. Timestamps are then repaired to
//! be non-decreasing along each lane's event stream (clamping the odd
//! nanosecond of cross-clock jitter), which guarantees non-negative
//! durations. The trace-event format does not require globally sorted
//! events, so lanes are emitted one after another.
//!
//! Timestamps are microseconds (fractional), the unit the trace-event
//! spec mandates.

use crate::util::json::Json;
use crate::util::telemetry::Snapshot;

/// The `pid` all events share: one process, many lanes.
const PID: f64 = 1.0;

fn event(ph: &str, name: &str, cat: &str, ts_ns: u64, tid: u32) -> Json {
    Json::Obj(vec![
        ("name".to_string(), Json::Str(name.to_string())),
        ("cat".to_string(), Json::Str(cat.to_string())),
        ("ph".to_string(), Json::Str(ph.to_string())),
        ("ts".to_string(), Json::num(ts_ns as f64 / 1000.0)),
        ("pid".to_string(), Json::num(PID)),
        ("tid".to_string(), Json::num(tid as f64)),
    ])
}

/// Build the trace-event JSON document (`{"traceEvents": [...]}`).
pub fn chrome_trace(snap: &Snapshot) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(snap.lanes.len() + 2 * snap.spans.len());

    // one metadata event per lane names its timeline row
    for (i, name) in snap.lanes.iter().enumerate() {
        events.push(Json::Obj(vec![
            ("name".to_string(), Json::Str("thread_name".to_string())),
            ("ph".to_string(), Json::Str("M".to_string())),
            ("pid".to_string(), Json::num(PID)),
            ("tid".to_string(), Json::num(i as f64)),
            ("args".to_string(), Json::Obj(vec![("name".to_string(), Json::Str(name.clone()))])),
        ]));
    }

    // per lane: (seq, is_end, span index), sorted by the shared sequence
    let lane_count = snap.lanes.len().max(
        snap.spans.iter().map(|s| s.lane as usize + 1).max().unwrap_or(0),
    );
    let mut per_lane: Vec<Vec<(u64, bool, usize)>> = vec![Vec::new(); lane_count];
    for (i, s) in snap.spans.iter().enumerate() {
        per_lane[s.lane as usize].push((s.open_seq, false, i));
        per_lane[s.lane as usize].push((s.close_seq, true, i));
    }
    for lane_events in &mut per_lane {
        lane_events.sort_unstable_by_key(|&(seq, _, _)| seq);
        let mut last_ts = 0u64;
        for &(_, is_end, i) in lane_events.iter() {
            let s = &snap.spans[i];
            let name = if s.label.is_empty() { s.stage.name() } else { s.label.as_str() };
            let raw_ts =
                if is_end { s.start_ns.saturating_add(s.dur_ns) } else { s.start_ns };
            let ts = raw_ts.max(last_ts);
            last_ts = ts;
            events.push(event(if is_end { "E" } else { "B" }, name, s.stage.name(), ts, s.lane));
        }
    }

    Json::Obj(vec![
        ("traceEvents".to_string(), Json::Arr(events)),
        ("displayTimeUnit".to_string(), Json::Str("ms".to_string())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::telemetry::{SpanRec, Stage};
    use std::path::PathBuf;

    fn snap_with(spans: Vec<SpanRec>, lanes: Vec<String>) -> Snapshot {
        Snapshot {
            wall_nanos: 1_000_000,
            out_dir: PathBuf::from("results"),
            lanes,
            spans,
            counters: Vec::new(),
            stages: Vec::new(),
            cells: Vec::new(),
        }
    }

    fn sp(
        lane: u32,
        stage: Stage,
        start_ns: u64,
        dur_ns: u64,
        open_seq: u64,
        close_seq: u64,
    ) -> SpanRec {
        SpanRec { lane, stage, label: String::new(), start_ns, dur_ns, open_seq, close_seq }
    }

    /// Walk the rendered events and assert per-lane stack discipline:
    /// every E closes the most recent open B on its lane, nothing is
    /// left open, timestamps never run backwards along a lane, and no
    /// duration is negative.
    #[test]
    fn events_form_balanced_nested_stacks() {
        // completion (drop) order with a shared seq counter; includes a
        // zero-width span at the outer span's end timestamp and an
        // inner span whose measured end jitters 2 ns past its parent's
        let spans = vec![
            sp(0, Stage::Decode, 100, 200, 1, 2),   // nested, closed first
            sp(0, Stage::Decode, 400, 602, 3, 4),   // sibling, end jitters past outer
            sp(1, Stage::IoRead, 50, 500, 5, 6),    // other lane overlaps freely
            sp(0, Stage::CellRun, 0, 1000, 0, 7),   // outer
            sp(0, Stage::Consume, 1000, 0, 8, 9),   // zero-width after outer
        ];
        let doc = chrome_trace(&snap_with(spans, vec!["worker".into(), "io".into()]));
        let events = match doc.get("traceEvents") {
            Some(Json::Arr(a)) => a,
            _ => panic!("traceEvents array"),
        };
        let mut stacks: std::collections::BTreeMap<u64, Vec<String>> = Default::default();
        let mut last_ts: std::collections::BTreeMap<u64, f64> = Default::default();
        let mut b = 0;
        let mut e = 0;
        for ev in events {
            let ph = ev.get("ph").and_then(Json::as_str).unwrap();
            if ph == "M" {
                continue;
            }
            let ts = ev.get("ts").and_then(Json::as_f64).unwrap();
            let tid = ev.get("tid").and_then(Json::as_f64).unwrap() as u64;
            let prev = last_ts.entry(tid).or_insert(f64::MIN);
            assert!(ts >= *prev, "lane {tid}: timestamps must be non-decreasing");
            *prev = ts;
            let name = ev.get("name").and_then(Json::as_str).unwrap().to_string();
            let stack = stacks.entry(tid).or_default();
            match ph {
                "B" => {
                    stack.push(name);
                    b += 1;
                }
                "E" => {
                    let open = stack.pop().expect("E with no open B");
                    assert_eq!(open, name, "E must close the innermost B");
                    e += 1;
                }
                other => panic!("unexpected phase {other}"),
            }
        }
        assert_eq!(b, 5);
        assert_eq!(e, 5, "every B has an E");
        assert!(stacks.values().all(Vec::is_empty), "no span left open");
    }

    #[test]
    fn lane_metadata_and_units() {
        let doc =
            chrome_trace(&snap_with(vec![sp(0, Stage::IoRead, 1500, 500, 0, 1)], vec!["io".into()]));
        let rendered = doc.render();
        assert!(rendered.contains("\"thread_name\""));
        assert!(rendered.contains("\"io\""));
        // 1500 ns -> 1.5 µs
        assert!(rendered.contains("\"ts\":1.5"), "{rendered}");
    }
}
