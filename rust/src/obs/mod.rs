//! Observability exporters and live progress for the telemetry spine.
//!
//! [`crate::util::telemetry`] collects; this module renders. Three
//! consumers, all driven from one [`telemetry::Snapshot`]:
//!
//! - [`chrome`] — Chrome trace-event JSON (`telemetry_trace.json`),
//!   loadable in Perfetto or `chrome://tracing`: one lane per thread,
//!   spans nested, balanced B/E pairs.
//! - [`summary`] — the `mlperf-telemetry/v1` summary
//!   (`telemetry.json`): per-stage totals, counters, per-cell rows,
//!   host provenance, and chaos fault-fire counts when armed.
//! - [`progress`] — a TTY-gated live progress line for `grid` plus a
//!   final one-line summary on stderr (always printed), independent of
//!   whether `--telemetry` is set.
//!
//! The shared [`provenance_json`] block (core count, rustc, git rev)
//! is also embedded by every `BENCH_*.json` emitter so blessed numbers
//! are attributable to the machine and toolchain that produced them.

pub mod chrome;
pub mod progress;
pub mod summary;

use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::telemetry;
use std::path::PathBuf;

/// Host/toolchain provenance block: who produced this artifact.
/// `rustc` and `git_rev` come from `build.rs` probes at compile time
/// and degrade to `"unknown"` when the probe tool is unavailable.
pub fn provenance_json() -> Json {
    let cores = std::thread::available_parallelism().map_or(0, |n| n.get());
    Json::Obj(vec![
        ("crate_version".to_string(), Json::Str(env!("CARGO_PKG_VERSION").to_string())),
        ("rustc".to_string(), Json::Str(env!("MLPERF_RUSTC_VERSION").to_string())),
        ("git_rev".to_string(), Json::Str(env!("MLPERF_GIT_REV").to_string())),
        ("cores".to_string(), Json::num(cores as f64)),
        (
            "host".to_string(),
            Json::Str(format!("{}-{}", std::env::consts::OS, std::env::consts::ARCH)),
        ),
    ])
}

/// Snapshot the installed collector and write both artifacts into its
/// output directory: `telemetry.json` (summary) and
/// `telemetry_trace.json` (Chrome trace). Returns the two paths, or
/// `None` when telemetry is off.
pub fn export_all() -> Result<Option<(PathBuf, PathBuf)>> {
    let Some(snap) = telemetry::snapshot() else {
        return Ok(None);
    };
    std::fs::create_dir_all(&snap.out_dir)
        .with_context(|| format!("creating {}", snap.out_dir.display()))?;
    let summary_path = snap.out_dir.join("telemetry.json");
    std::fs::write(&summary_path, summary::summary_json(&snap).render())
        .with_context(|| format!("writing {}", summary_path.display()))?;
    let trace_path = snap.out_dir.join("telemetry_trace.json");
    std::fs::write(&trace_path, chrome::chrome_trace(&snap).render())
        .with_context(|| format!("writing {}", trace_path.display()))?;
    Ok(Some((summary_path, trace_path)))
}
