//! Live grid progress: a `\r`-rewritten status line on stderr while a
//! grid runs on a TTY, and a final one-line summary that is always
//! printed (TTY or not), so even a redirected CI log records how the
//! run went.
//!
//! Progress is independent of `--telemetry`: it is pure presentation,
//! costs one relaxed atomic load per completed cell when inactive, and
//! writes only to **stderr** — stdout stays reserved for
//! machine-readable tables and JSON (see [`crate::util::diag`]).
//!
//! The ETA extrapolates from completed-cell walls: `elapsed / done *
//! remaining`. Cached cells complete in microseconds, so a mostly
//! cached rerun converges to a near-zero ETA immediately — exactly the
//! behaviour a ledgered grid should show.

use std::io::IsTerminal;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

static ACTIVE: AtomicBool = AtomicBool::new(false);
static TTY: AtomicBool = AtomicBool::new(false);
static TOTAL: AtomicU64 = AtomicU64::new(0);
static DONE: AtomicU64 = AtomicU64::new(0);
static CACHED: AtomicU64 = AtomicU64::new(0);
static FAILED: AtomicU64 = AtomicU64::new(0);
static STARTED: Mutex<Option<Instant>> = Mutex::new(None);

/// Begin tracking a grid of `total` cells. Called by the `grid`
/// command only — library callers (benches, tests) never activate
/// progress, so their stderr stays quiet.
pub fn start(total: usize) {
    TOTAL.store(total as u64, Ordering::Relaxed);
    DONE.store(0, Ordering::Relaxed);
    CACHED.store(0, Ordering::Relaxed);
    FAILED.store(0, Ordering::Relaxed);
    *STARTED.lock().unwrap_or_else(|e| e.into_inner()) = Some(Instant::now());
    TTY.store(std::io::stderr().is_terminal(), Ordering::Relaxed);
    ACTIVE.store(true, Ordering::SeqCst);
}

/// Record one completed cell. Inactive path: one relaxed load.
#[inline]
pub fn cell_done(cached: bool, failed: bool) {
    if !ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    cell_done_slow(cached, failed);
}

#[cold]
fn cell_done_slow(cached: bool, failed: bool) {
    let done = DONE.fetch_add(1, Ordering::Relaxed) + 1;
    if cached {
        CACHED.fetch_add(1, Ordering::Relaxed);
    }
    if failed {
        FAILED.fetch_add(1, Ordering::Relaxed);
    }
    if TTY.load(Ordering::Relaxed) {
        redraw(done);
    }
}

fn elapsed_secs() -> f64 {
    STARTED
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .map_or(0.0, |t| t.elapsed().as_secs_f64())
}

fn redraw(done: u64) {
    let total = TOTAL.load(Ordering::Relaxed);
    let cached = CACHED.load(Ordering::Relaxed);
    let failed = FAILED.load(Ordering::Relaxed);
    let elapsed = elapsed_secs();
    let eta = if done > 0 && total > done {
        elapsed / done as f64 * (total - done) as f64
    } else {
        0.0
    };
    // \x1b[K clears to end of line so a shrinking line leaves no tail
    eprint!(
        "\r[grid] {done}/{total} cells \u{b7} {cached} cached \u{b7} {failed} failed \u{b7} ETA {eta:.0}s\x1b[K"
    );
}

/// Stop tracking and print the always-on one-line summary to stderr.
/// A no-op unless [`start`] activated progress.
pub fn finish() {
    if !ACTIVE.swap(false, Ordering::SeqCst) {
        return;
    }
    let done = DONE.load(Ordering::Relaxed);
    let total = TOTAL.load(Ordering::Relaxed);
    let cached = CACHED.load(Ordering::Relaxed);
    let failed = FAILED.load(Ordering::Relaxed);
    let elapsed = elapsed_secs();
    if TTY.load(Ordering::Relaxed) {
        eprint!("\r\x1b[K"); // clear the live line before the summary
    }
    eprintln!(
        "[grid] {done}/{total} cells in {elapsed:.1}s \u{b7} {cached} cached \u{b7} {failed} failed"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Single combined test: the globals are process-wide, so one test
    /// owns the activate/count/finish cycle.
    #[test]
    fn lifecycle_counts() {
        // inactive: a no-op, no counters move
        cell_done(true, false);
        assert_eq!(DONE.load(Ordering::Relaxed), 0);

        start(4);
        cell_done(false, false);
        cell_done(true, false);
        cell_done(false, true);
        assert_eq!(DONE.load(Ordering::Relaxed), 3);
        assert_eq!(CACHED.load(Ordering::Relaxed), 1);
        assert_eq!(FAILED.load(Ordering::Relaxed), 1);
        finish();
        assert!(!ACTIVE.load(Ordering::Relaxed));
        // after finish, counting stops again
        cell_done(false, false);
        assert_eq!(DONE.load(Ordering::Relaxed), 3);
        // double-finish is harmless
        finish();
    }
}
