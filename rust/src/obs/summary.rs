//! `mlperf-telemetry/v1` summary exporter: the machine-readable
//! companion to the Chrome trace, written to `telemetry.json`.
//!
//! One document answers "where did the run's wall clock go, and what
//! happened to each cell" from artifacts alone:
//!
//! - `stages` — per-stage total nanoseconds and span counts (the
//!   [`STAGES`](crate::util::telemetry::STAGES) taxonomy). Totals are
//!   summed across threads, so on an `N`-worker grid they reconcile
//!   with `wall_nanos` scaled by the active thread count.
//! - `counters` — every named counter, including the deterministic
//!   ones (`blocks_decoded`, `ledger_hit`) that `tests/telemetry.rs`
//!   cross-checks against simulator ground truth.
//! - `cells` — per-cell rows: fingerprint, wall, blocks,
//!   cached/run/failed status, retries.
//! - `provenance` — host/toolchain attribution ([`provenance_json`]).
//! - `faults` — chaos fault-injection fire counts per site (empty
//!   object when chaos is unarmed), so a chaos run's telemetry records
//!   what was injected alongside what it cost.

use crate::obs::provenance_json;
use crate::util::fault;
use crate::util::json::Json;
use crate::util::telemetry::Snapshot;

/// Schema identifier of the summary document.
pub const SCHEMA: &str = "mlperf-telemetry/v1";

/// Build the summary document for one snapshot.
pub fn summary_json(snap: &Snapshot) -> Json {
    let stages = snap
        .stages
        .iter()
        .map(|&(name, nanos, count)| {
            Json::Obj(vec![
                ("stage".to_string(), Json::Str(name.to_string())),
                ("total_nanos".to_string(), Json::num(nanos as f64)),
                ("count".to_string(), Json::num(count as f64)),
            ])
        })
        .collect();

    let counters =
        snap.counters.iter().map(|&(n, v)| (n.to_string(), Json::num(v as f64))).collect();

    let cells = snap
        .cells
        .iter()
        .map(|c| {
            Json::Obj(vec![
                ("fingerprint".to_string(), Json::Str(c.fingerprint.clone())),
                ("workload".to_string(), Json::Str(c.workload.clone())),
                ("scenario".to_string(), Json::Str(c.scenario.clone())),
                ("status".to_string(), Json::Str(c.status.clone())),
                ("wall_nanos".to_string(), Json::num(c.wall_nanos as f64)),
                ("blocks".to_string(), Json::num(c.blocks as f64)),
                ("retries".to_string(), Json::num(c.retries as f64)),
            ])
        })
        .collect();

    // chaos integration: record which injected faults actually fired
    let faults: Vec<(String, Json)> = fault::SITES
        .iter()
        .filter_map(|&(site, name)| {
            let fires = fault::fires_at(site);
            (fires > 0).then(|| (name.to_string(), Json::num(fires as f64)))
        })
        .collect();

    Json::Obj(vec![
        ("schema".to_string(), Json::Str(SCHEMA.to_string())),
        ("wall_nanos".to_string(), Json::num(snap.wall_nanos as f64)),
        ("provenance".to_string(), provenance_json()),
        ("stages".to_string(), Json::Arr(stages)),
        ("counters".to_string(), Json::Obj(counters)),
        ("cells".to_string(), Json::Arr(cells)),
        ("faults".to_string(), Json::Obj(faults)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::telemetry::CellRow;
    use std::path::PathBuf;

    #[test]
    fn summary_shape_and_roundtrip() {
        let snap = Snapshot {
            wall_nanos: 123,
            out_dir: PathBuf::from("results"),
            lanes: vec!["main".into()],
            spans: Vec::new(),
            counters: vec![("blocks_decoded", 7)],
            stages: vec![("decode", 55, 7)],
            cells: vec![CellRow {
                fingerprint: "v1:00000000000000aa".into(),
                workload: "KMeans".into(),
                scenario: "baseline".into(),
                status: "run".into(),
                wall_nanos: 99,
                blocks: 7,
                retries: 0,
            }],
        };
        let doc = summary_json(&snap);
        let parsed = Json::parse(&doc.render()).expect("self-parse");
        assert_eq!(parsed.get("schema").and_then(Json::as_str), Some(SCHEMA));
        assert_eq!(parsed.get("wall_nanos").and_then(Json::as_f64), Some(123.0));
        let counters = parsed.get("counters").expect("counters");
        assert_eq!(counters.get("blocks_decoded").and_then(Json::as_f64), Some(7.0));
        let stages = parsed.get("stages").and_then(Json::as_arr).expect("stages");
        assert_eq!(stages[0].get("stage").and_then(Json::as_str), Some("decode"));
        assert_eq!(stages[0].get("total_nanos").and_then(Json::as_f64), Some(55.0));
        let cells = parsed.get("cells").and_then(Json::as_arr).expect("cells");
        assert_eq!(cells[0].get("status").and_then(Json::as_str), Some("run"));
        assert_eq!(cells[0].get("blocks").and_then(Json::as_f64), Some(7.0));
        // provenance is always attributable, even if only as "unknown"
        let prov = parsed.get("provenance").expect("provenance");
        assert!(prov.get("rustc").and_then(Json::as_str).is_some());
        assert!(prov.get("git_rev").and_then(Json::as_str).is_some());
        assert!(prov.get("cores").and_then(Json::as_f64).is_some());
        // chaos unarmed in this test: faults object present and empty
        assert!(matches!(parsed.get("faults"), Some(Json::Obj(v)) if v.is_empty()));
    }
}
