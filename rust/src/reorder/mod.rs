//! Data-layout and computation reordering algorithms (paper Section VI,
//! Table VIII).
//!
//! | Category | Algorithm | Kind | Venue |
//! |---|---|---|---|
//! | First-touch & RCB | First-touch | data layout | runtime (inspector–executor) |
//! | | RCB | data layout | offline |
//! | SFC | Hilbert | data layout | offline |
//! | | Z-order | data layout | offline |
//! | Computation | Locality blocking | visit order | runtime |
//! | | Z-order (index-based) | visit order | runtime |
//!
//! Every algorithm both *computes* its permutation (really — the
//! experiments run on genuinely reordered data) and *traces the cost* of
//! computing and applying it, so Fig. 23 (no overhead) and Fig. 24
//! (overhead included) can both be regenerated.

pub mod rcb;
pub mod sfc;

use crate::data::Dataset;
use crate::trace::{AddressSpace, Recorder};
use crate::workloads::{RunContext, Workload};

/// The six reordering algorithms of Table VIII.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReorderKind {
    FirstTouch,
    Rcb,
    Hilbert,
    ZOrder,
    LocalityBlocking,
    ZOrderComp,
}

impl ReorderKind {
    pub const ALL: [ReorderKind; 6] = [
        ReorderKind::FirstTouch,
        ReorderKind::Rcb,
        ReorderKind::Hilbert,
        ReorderKind::ZOrder,
        ReorderKind::LocalityBlocking,
        ReorderKind::ZOrderComp,
    ];

    /// Paper's figure labels; "(c)" marks computation reordering
    /// (Figs. 20–24 use the same convention).
    pub fn name(&self) -> &'static str {
        match self {
            ReorderKind::FirstTouch => "First-touch",
            ReorderKind::Rcb => "RCB",
            ReorderKind::Hilbert => "Hilbert",
            ReorderKind::ZOrder => "Z-order",
            ReorderKind::LocalityBlocking => "Blocking(c)",
            ReorderKind::ZOrderComp => "Z-order(c)",
        }
    }

    /// Data-layout (rows are physically permuted) vs computation
    /// reordering (visit order changes, layout untouched).
    pub fn is_data_layout(&self) -> bool {
        matches!(
            self,
            ReorderKind::FirstTouch | ReorderKind::Rcb | ReorderKind::Hilbert | ReorderKind::ZOrder
        )
    }

    /// Offline algorithms pre-process the file before training (Table
    /// VIII); runtime ones run inside the library.
    pub fn is_offline(&self) -> bool {
        matches!(self, ReorderKind::Rcb | ReorderKind::Hilbert | ReorderKind::ZOrder)
    }

    /// Computation reordering requires the workload's outer loop to accept
    /// a visit order (tree ensembles don't — Table IX "Not applicable").
    pub fn applicable_to(&self, w: &dyn Workload) -> bool {
        self.is_data_layout() || w.supports_visit_order()
    }
}

impl std::fmt::Display for ReorderKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// A computed reordering: the permutation plus how to apply it.
pub struct ReorderPlan {
    pub kind: ReorderKind,
    pub perm: Vec<usize>,
}

impl ReorderPlan {
    /// Apply to a dataset + run context: data-layout reorderings permute
    /// the rows; computation reorderings set the visit order.
    pub fn apply(&self, ds: &Dataset, ctx: &RunContext) -> (Dataset, RunContext) {
        if self.kind.is_data_layout() {
            (ds.permuted(&self.perm), ctx.clone())
        } else {
            let mut c = ctx.clone();
            c.visit_order = Some(self.perm.clone());
            (ds.clone(), c)
        }
    }
}

// trace-site ids for the reordering machinery itself
const NS_REORDER: u32 = 40;
const SITE_SORT_CMP: u32 = 1;

/// Emit the trace of computing SFC/blocking keys for every row.
fn trace_key_pass(ds: &Dataset, space: &mut AddressSpace, rec: &mut Recorder, ops_per_row: u32) {
    let (n, m) = (ds.n_samples(), ds.n_features());
    let r_x = space.alloc_matrix("reorder.x", n, m);
    let r_keys = space.alloc("reorder.keys", n as u64 * 16);
    for i in 0..n {
        rec.load_row(r_x, i, m);
        rec.compute(ops_per_row, 0);
        rec.store(r_keys.at(i as u64 * 16), 16);
    }
}

/// Emit the trace of sorting n (key, index) pairs: log2(n) streaming
/// merge passes with data-dependent compare branches.
fn trace_sort(n: usize, space: &mut AddressSpace, rec: &mut Recorder) {
    if n < 2 {
        return;
    }
    let r_a = space.alloc("reorder.sort.a", n as u64 * 16);
    let r_b = space.alloc("reorder.sort.b", n as u64 * 16);
    let passes = (n as f64).log2().ceil() as usize;
    // cheap LCG for unpredictable-compare outcomes
    let mut s: u64 = 0x9e3779b97f4a7c15;
    for p in 0..passes {
        let (src, dst) = if p % 2 == 0 { (r_a, r_b) } else { (r_b, r_a) };
        // streaming read + write of the pair arrays, chunked per 4 KiB
        let bytes = n as u64 * 16;
        let mut off = 0;
        while off < bytes {
            let chunk = (bytes - off).min(4096) as u32;
            rec.load(src.at(off), chunk);
            rec.store(dst.at(off), chunk);
            off += chunk as u64;
        }
        rec.compute(2 * n as u32, 0);
        // one data-dependent compare branch per element per pass,
        // sampled at 1:4 with 4x weight folded into compute above
        for _ in 0..n / 4 {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            rec.fcmp_branch(SITE_SORT_CMP, s >> 63 != 0);
        }
        rec.loop_branch(SITE_SORT_CMP + 1, (n / 8).max(1) as u32);
    }
}

/// Emit the trace of applying a row permutation: stream the destination,
/// gather rows from the (random) source positions.
fn trace_permute_apply(ds: &Dataset, space: &mut AddressSpace, rec: &mut Recorder) {
    let (n, m) = (ds.n_samples(), ds.n_features());
    let r_src = space.alloc_matrix("reorder.src", n, m);
    let r_dst = space.alloc_matrix("reorder.dst", n, m);
    let r_perm = space.alloc("reorder.perm", n as u64 * 8);
    // simulate the gather order with a multiplicative hash (the trace
    // shape — random source rows — is what matters for overhead cost)
    let mut h: u64 = 0x2545f4914f6cdd1d;
    for i in 0..n {
        rec.load(r_perm.at(i as u64 * 8), 8);
        h = h.wrapping_mul(0x9e3779b97f4a7c15).rotate_left(17);
        let src_row = (h % n as u64) as usize;
        rec.load_row(r_src, src_row, m);
        rec.store_row(r_dst, i, m);
        rec.compute(2, 0);
    }
}

/// Compute a reordering plan for `kind`, tracing its full overhead
/// (inspection, key computation, sorting, permutation apply) into `rec`.
/// Pass a [`crate::trace::NullSink`]-backed recorder to get Fig. 23's
/// "no overhead cost considered" variant.
pub fn compute_plan(
    kind: ReorderKind,
    ds: &Dataset,
    w: &dyn Workload,
    ctx: &RunContext,
    rec: &mut Recorder,
) -> ReorderPlan {
    assert!(kind.applicable_to(w), "{kind} not applicable to {}", w.name());
    let mut space = AddressSpace::new();
    let m = ds.n_features();
    let bits = sfc::max_bits_for_dims(m);
    let perm = match kind {
        ReorderKind::FirstTouch => {
            // inspector: one first-iteration pass observing touch order
            let order = w.first_touch_order(ds, ctx);
            let r_x = space.alloc_matrix("reorder.inspect", ds.n_samples(), m);
            for i in 0..ds.n_samples() {
                rec.load_row(r_x, i, m);
                rec.compute(3, 0);
            }
            trace_permute_apply(ds, &mut space, rec);
            order
        }
        ReorderKind::Rcb => {
            // log(n/leaf) median-partition passes over one coordinate
            let n = ds.n_samples();
            let levels = ((n as f64 / 32.0).log2().ceil()).max(1.0) as u32;
            trace_key_pass(ds, &mut space, rec, 4 * m as u32);
            for _ in 0..levels {
                let r_v = space.alloc("reorder.rcb", n as u64 * 8);
                let mut s: u64 = 12345;
                for i in 0..n {
                    rec.load_for_branch(r_v.at(i as u64 * 8), 8);
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                    rec.fcmp_branch(SITE_SORT_CMP, s >> 63 != 0);
                }
            }
            trace_permute_apply(ds, &mut space, rec);
            rcb::rcb_order(&ds.x, 32)
        }
        ReorderKind::Hilbert => {
            // Gray-code transform: ~6 ops per coordinate bit
            trace_key_pass(ds, &mut space, rec, 6 * m as u32 * bits);
            trace_sort(ds.n_samples(), &mut space, rec);
            trace_permute_apply(ds, &mut space, rec);
            sfc::sfc_order(&ds.x, bits, true)
        }
        ReorderKind::ZOrder => {
            trace_key_pass(ds, &mut space, rec, 2 * m as u32 * bits);
            trace_sort(ds.n_samples(), &mut space, rec);
            trace_permute_apply(ds, &mut space, rec);
            sfc::sfc_order(&ds.x, bits, false)
        }
        ReorderKind::LocalityBlocking => {
            // page-granular blocking of the visit order: full-precision
            // keys truncated to page-sized buckets
            trace_key_pass(ds, &mut space, rec, 2 * m as u32 * bits);
            trace_sort(ds.n_samples(), &mut space, rec);
            let rows_per_page = (crate::trace::PAGE_SIZE as usize / (m * 8)).max(1);
            let fine = sfc::sfc_order(&ds.x, bits, false);
            // keep original order within each page-sized bucket: group
            // row ids by their curve bucket, preserving id order inside
            let n = ds.n_samples();
            let mut bucket_of = vec![0usize; n];
            for (pos, &row) in fine.iter().enumerate() {
                bucket_of[row] = pos / rows_per_page;
            }
            let mut pairs: Vec<(usize, usize)> =
                (0..n).map(|row| (bucket_of[row], row)).collect();
            pairs.sort();
            pairs.into_iter().map(|(_, row)| row).collect()
        }
        ReorderKind::ZOrderComp => {
            // index-based: cheap low-resolution keys, no data permute
            let cheap_bits = (bits / 2).max(1);
            trace_key_pass(ds, &mut space, rec, 2 * m as u32 * cheap_bits);
            trace_sort(ds.n_samples(), &mut space, rec);
            sfc::sfc_order(&ds.x, cheap_bits, false)
        }
    };
    ReorderPlan { kind, perm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::make_blobs;
    use crate::trace::{InstructionMix, NullSink};
    use crate::workloads::{by_name, RunContext};

    fn plan_for(kind: ReorderKind) -> (ReorderPlan, Dataset) {
        let w = by_name("kmeans").unwrap();
        let ds = make_blobs(300, 5, 3, 1.0, 60);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 40);
        let plan = compute_plan(kind, &ds, w.as_ref(), &RunContext::default(), &mut rec);
        (plan, ds)
    }

    #[test]
    fn all_plans_are_permutations() {
        for kind in ReorderKind::ALL {
            let (plan, _) = plan_for(kind);
            let mut p = plan.perm.clone();
            p.sort_unstable();
            assert_eq!(p, (0..300).collect::<Vec<_>>(), "{kind}");
        }
    }

    #[test]
    fn data_layout_vs_computation_classification() {
        assert!(ReorderKind::FirstTouch.is_data_layout());
        assert!(ReorderKind::Hilbert.is_data_layout());
        assert!(!ReorderKind::ZOrderComp.is_data_layout());
        assert!(!ReorderKind::LocalityBlocking.is_data_layout());
        assert!(ReorderKind::Rcb.is_offline());
        assert!(!ReorderKind::FirstTouch.is_offline());
    }

    #[test]
    fn comp_reorder_not_applicable_to_tree_ensembles() {
        let ada = by_name("adaboost").unwrap();
        assert!(!ReorderKind::ZOrderComp.applicable_to(ada.as_ref()));
        assert!(ReorderKind::Hilbert.applicable_to(ada.as_ref()));
        let km = by_name("kmeans").unwrap();
        assert!(ReorderKind::ZOrderComp.applicable_to(km.as_ref()));
    }

    #[test]
    fn apply_data_layout_permutes_rows() {
        let (plan, ds) = plan_for(ReorderKind::ZOrder);
        let (ds2, ctx2) = plan.apply(&ds, &RunContext::default());
        assert!(ctx2.visit_order.is_none());
        assert_eq!(ds2.x.row(0), ds.x.row(plan.perm[0]));
        assert_eq!(ds2.y[0], ds.y[plan.perm[0]]);
    }

    #[test]
    fn apply_computation_sets_visit_order() {
        let (plan, ds) = plan_for(ReorderKind::ZOrderComp);
        let (ds2, ctx2) = plan.apply(&ds, &RunContext::default());
        assert_eq!(ds2.x.row(0), ds.x.row(0), "layout untouched");
        assert_eq!(ctx2.visit_order.as_deref(), Some(plan.perm.as_slice()));
    }

    #[test]
    fn hilbert_overhead_exceeds_first_touch() {
        let w = by_name("kmeans").unwrap();
        let ds = make_blobs(400, 5, 3, 1.0, 61);
        let cost = |kind| {
            let mut mix = InstructionMix::default();
            {
                let mut rec = Recorder::new(&mut mix, 40);
                compute_plan(kind, &ds, w.as_ref(), &RunContext::default(), &mut rec);
            }
            mix.instructions()
        };
        let ft = cost(ReorderKind::FirstTouch);
        let hb = cost(ReorderKind::Hilbert);
        let zc = cost(ReorderKind::ZOrderComp);
        assert!(hb > ft, "hilbert {hb} !> first-touch {ft}");
        assert!(hb > zc, "hilbert {hb} !> zorder-comp {zc}");
    }

    #[test]
    fn blocking_groups_rows_page_wise() {
        let (plan, _) = plan_for(ReorderKind::LocalityBlocking);
        // within-bucket original ordering is preserved: the permutation
        // must not equal the fine Z-order but must still be block-sorted
        assert_eq!(plan.perm.len(), 300);
    }

    #[test]
    fn first_touch_uses_workload_inspector() {
        let w = by_name("knn").unwrap();
        let ds = make_blobs(200, 4, 2, 1.0, 62);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 40);
        let plan =
            compute_plan(ReorderKind::FirstTouch, &ds, w.as_ref(), &RunContext::default(), &mut rec);
        // kNN's inspector returns the tree leaf order, not identity
        assert_ne!(plan.perm, (0..200).collect::<Vec<_>>());
    }
}
