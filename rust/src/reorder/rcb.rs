//! Recursive Coordinate Bisection reordering [BB87].
//!
//! Recursively split the point set at the median of its widest-spread
//! coordinate; the left-to-right leaf order of the recursion is the new
//! row order. Geometrically close rows end up close in the file — the
//! same idea as the SFC orders but with data-adaptive cuts and cheaper
//! keys (paper Table IX: "small overheads, medium gains").

use crate::util::Matrix;

/// RCB row order: recurse down to `leaf` points per cell.
pub fn rcb_order(x: &Matrix, leaf: usize) -> Vec<usize> {
    let n = x.rows();
    let mut idx: Vec<usize> = (0..n).collect();
    let leaf = leaf.max(1);
    rcb_rec(x, &mut idx, 0, n, leaf);
    idx
}

fn rcb_rec(x: &Matrix, idx: &mut [usize], lo: usize, hi: usize, leaf: usize) {
    if hi - lo <= leaf {
        return;
    }
    let m = x.cols();
    // widest-spread dimension over this cell
    let mut best_dim = 0;
    let mut best_spread = -1.0;
    for d in 0..m {
        let mut mn = f64::INFINITY;
        let mut mx = f64::NEG_INFINITY;
        for &i in idx[lo..hi].iter() {
            let v = x[(i, d)];
            mn = mn.min(v);
            mx = mx.max(v);
        }
        if mx - mn > best_spread {
            best_spread = mx - mn;
            best_dim = d;
        }
    }
    let mid = lo + (hi - lo) / 2;
    idx[lo..hi].select_nth_unstable_by(mid - lo, |&a, &b| {
        x[(a, best_dim)]
            .partial_cmp(&x[(b, best_dim)])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    rcb_rec(x, idx, lo, mid, leaf);
    rcb_rec(x, idx, mid, hi, leaf);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::make_blobs;
    use crate::util::stats::sqdist;

    #[test]
    fn rcb_is_permutation() {
        let ds = make_blobs(500, 5, 4, 1.0, 52);
        let mut ord = rcb_order(&ds.x, 16);
        ord.sort_unstable();
        assert_eq!(ord, (0..500).collect::<Vec<_>>());
    }

    #[test]
    fn rcb_groups_blobs() {
        let ds = make_blobs(600, 4, 3, 0.5, 53);
        let ord = rcb_order(&ds.x, 8);
        let same = ord.windows(2).filter(|w| ds.y[w[0]] == ds.y[w[1]]).count();
        assert!(same as f64 / 599.0 > 0.9, "{same}/599 same-blob neighbours");
    }

    #[test]
    fn rcb_improves_sequential_locality() {
        let ds = make_blobs(400, 3, 2, 1.5, 54);
        let ord = rcb_order(&ds.x, 4);
        let reordered: f64 = ord
            .windows(2)
            .map(|w| sqdist(ds.x.row(w[0]), ds.x.row(w[1])))
            .sum::<f64>();
        let original: f64 = (0..399)
            .map(|i| sqdist(ds.x.row(i), ds.x.row(i + 1)))
            .sum::<f64>();
        assert!(reordered < original, "{reordered} !< {original}");
    }

    #[test]
    fn tiny_inputs_are_safe() {
        let ds = make_blobs(3, 2, 1, 1.0, 55);
        assert_eq!(rcb_order(&ds.x, 16), vec![0, 1, 2]);
        let one = make_blobs(1, 2, 1, 1.0, 56);
        assert_eq!(rcb_order(&one.x, 1), vec![0]);
    }
}
