//! Space-filling-curve keys: Morton (Z-order) and Hilbert [Sag12].
//!
//! Both curves map an M-dimensional quantized point to a 1-D key such
//! that key-adjacent points are space-adjacent. Sorting dataset rows by
//! the key is the paper's SFC data-layout reordering (Table VIII);
//! sorting the *visit order* by it is Z-order computation reordering.
//!
//! The Hilbert index uses Skilling's transpose algorithm ("Programming
//! the Hilbert curve", AIP 2004), which works for any dimensionality.

use crate::util::Matrix;

/// Quantize each feature of each row to `bits` unsigned levels using the
/// per-feature min/max over the dataset.
pub fn quantize(x: &Matrix, bits: u32) -> Vec<Vec<u32>> {
    let (n, m) = (x.rows(), x.cols());
    assert!(bits >= 1 && bits <= 16);
    let mut mins = vec![f64::INFINITY; m];
    let mut maxs = vec![f64::NEG_INFINITY; m];
    for i in 0..n {
        for j in 0..m {
            let v = x[(i, j)];
            mins[j] = mins[j].min(v);
            maxs[j] = maxs[j].max(v);
        }
    }
    let levels = ((1u64 << bits) - 1) as f64;
    (0..n)
        .map(|i| {
            (0..m)
                .map(|j| {
                    let span = maxs[j] - mins[j];
                    if span <= 0.0 {
                        0
                    } else {
                        (((x[(i, j)] - mins[j]) / span) * levels).round() as u32
                    }
                })
                .collect()
        })
        .collect()
}

/// Morton (Z-order) key: bit-interleave the quantized coordinates,
/// most-significant bit first. Key width = bits*m ≤ 128.
pub fn morton_key(coords: &[u32], bits: u32) -> u128 {
    debug_assert!(bits as usize * coords.len() <= 128);
    let mut key: u128 = 0;
    for b in (0..bits).rev() {
        for &c in coords {
            key = (key << 1) | (((c >> b) & 1) as u128);
        }
    }
    key
}

/// Hilbert key via Skilling's transpose algorithm: Gray-code-corrected
/// coordinates, then Morton-interleaved.
pub fn hilbert_key(coords: &[u32], bits: u32) -> u128 {
    let n = coords.len();
    let mut x: Vec<u32> = coords.to_vec();
    if n == 0 {
        return 0;
    }
    // Inverse undo excess work (Skilling's AxestoTranspose)
    let m = 1u32 << (bits - 1);
    let mut q = m;
    while q > 1 {
        let p = q - 1;
        for i in 0..n {
            if x[i] & q != 0 {
                x[0] ^= p; // invert
            } else {
                let t = (x[0] ^ x[i]) & p;
                x[0] ^= t;
                x[i] ^= t;
            }
        }
        q >>= 1;
    }
    // Gray encode
    for i in 1..n {
        x[i] ^= x[i - 1];
    }
    let mut t = 0u32;
    q = m;
    while q > 1 {
        if x[n - 1] & q != 0 {
            t ^= q - 1;
        }
        q >>= 1;
    }
    for xi in x.iter_mut() {
        *xi ^= t;
    }
    morton_key(&x, bits)
}

/// Row order sorted by a SFC key (stable, so equal keys keep dataset
/// order). `hilbert=false` gives the Z-order permutation.
pub fn sfc_order(x: &Matrix, bits: u32, hilbert: bool) -> Vec<usize> {
    let qs = quantize(x, bits);
    let mut keyed: Vec<(u128, usize)> = qs
        .iter()
        .enumerate()
        .map(|(i, c)| {
            let k = if hilbert { hilbert_key(c, bits) } else { morton_key(c, bits) };
            (k, i)
        })
        .collect();
    keyed.sort();
    keyed.into_iter().map(|(_, i)| i).collect()
}

/// Largest per-dimension bit width whose interleaved key fits in 128 bits.
pub fn max_bits_for_dims(m: usize) -> u32 {
    ((128 / m.max(1)) as u32).clamp(1, 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn morton_interleaves() {
        // 2-D, 2 bits: (x=0b10, y=0b01) -> bits x1 y1 x0 y0 = 1 0 0 1
        assert_eq!(morton_key(&[0b10, 0b01], 2), 0b1001);
        assert_eq!(morton_key(&[0, 0], 4), 0);
        assert_eq!(morton_key(&[0b11, 0b11], 2), 0b1111);
    }

    #[test]
    fn hilbert_2d_4x4_is_a_hamiltonian_path() {
        // every consecutive pair of cells along the curve must be
        // neighbours at L1 distance exactly 1 — the defining property
        let bits = 2;
        let mut cells: Vec<(u128, (i32, i32))> = Vec::new();
        for x in 0..4u32 {
            for y in 0..4u32 {
                cells.push((hilbert_key(&[x, y], bits), (x as i32, y as i32)));
            }
        }
        cells.sort();
        // keys must be a permutation of 0..16
        let keys: Vec<u128> = cells.iter().map(|c| c.0).collect();
        assert_eq!(keys, (0..16).collect::<Vec<u128>>());
        for w in cells.windows(2) {
            let (ax, ay) = w[0].1;
            let (bx, by) = w[1].1;
            assert_eq!((ax - bx).abs() + (ay - by).abs(), 1, "{w:?}");
        }
    }

    #[test]
    fn hilbert_3d_keys_are_a_permutation() {
        let bits = 2;
        let mut keys: Vec<u128> = Vec::new();
        for x in 0..4u32 {
            for y in 0..4u32 {
                for z in 0..4u32 {
                    keys.push(hilbert_key(&[x, y, z], bits));
                }
            }
        }
        keys.sort_unstable();
        assert_eq!(keys, (0..64).collect::<Vec<u128>>());
    }

    #[test]
    fn quantize_maps_extremes() {
        let x = Matrix::from_vec(3, 2, vec![0.0, -5.0, 10.0, 5.0, 5.0, 0.0]);
        let q = quantize(&x, 4);
        assert_eq!(q[0][0], 0);
        assert_eq!(q[1][0], 15);
        assert_eq!(q[1][1], 15);
        assert_eq!(q[0][1], 0);
    }

    #[test]
    fn quantize_constant_feature_is_zero() {
        let x = Matrix::from_vec(2, 1, vec![3.3, 3.3]);
        let q = quantize(&x, 8);
        assert_eq!(q[0][0], 0);
        assert_eq!(q[1][0], 0);
    }

    #[test]
    fn sfc_order_is_permutation_and_groups_neighbours() {
        let ds = crate::data::make_blobs(400, 4, 3, 0.5, 50);
        for hilbert in [false, true] {
            let ord = sfc_order(&ds.x, 8, hilbert);
            let mut sorted = ord.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..400).collect::<Vec<_>>());
            // consecutive rows along the curve should usually be same-blob
            let same = ord
                .windows(2)
                .filter(|w| ds.y[w[0]] == ds.y[w[1]])
                .count();
            assert!(
                same as f64 / 399.0 > 0.9,
                "curve (hilbert={hilbert}) mixes blobs: {same}/399"
            );
        }
    }

    #[test]
    fn zorder_locality_beats_random_order() {
        // mean consecutive distance along the curve must be far below a
        // random order's
        let ds = crate::data::make_blobs(300, 3, 1, 2.0, 51);
        let ord = sfc_order(&ds.x, 8, false);
        let curve: f64 = ord
            .windows(2)
            .map(|w| crate::util::stats::sqdist(ds.x.row(w[0]), ds.x.row(w[1])))
            .sum::<f64>()
            / 299.0;
        let random: f64 = (0..299)
            .map(|i| crate::util::stats::sqdist(ds.x.row(i), ds.x.row(i + 1)))
            .sum::<f64>()
            / 299.0;
        assert!(curve * 2.0 < random, "curve {curve} vs random {random}");
    }

    #[test]
    fn max_bits_respects_key_width() {
        assert_eq!(max_bits_for_dims(2), 16);
        assert_eq!(max_bits_for_dims(20), 6);
        assert_eq!(max_bits_for_dims(128), 1);
        assert!(max_bits_for_dims(20) as usize * 20 <= 128);
    }
}
