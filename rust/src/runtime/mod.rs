//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from Rust. Python never runs on this path.
//!
//! The actual PJRT executor lives in [`pjrt`] behind the `pjrt` cargo
//! feature, because it needs an `xla` bindings crate that the offline
//! build image does not provide. The default build substitutes a stub
//! whose `load` fails with an explanatory error: every caller compiles
//! unchanged, the artifact-gated tests skip (they check for artifacts
//! before loading), and the CLI `runtime` subcommand / end-to-end
//! example report the feature-gate error at runtime. DESIGN.md's
//! substitution table records this gating.

use std::path::PathBuf;

/// Fixed AOT batch geometry (must match python/compile/aot.py).
pub const BATCH: usize = 4096;
pub const FEATURES: usize = 20;
pub const K: usize = 8;

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

/// Locate the artifacts directory relative to the crate root (works from
/// `cargo test`, `cargo bench` and installed binaries run in-repo).
pub fn default_artifacts_dir() -> PathBuf {
    let cands = [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    for c in &cands {
        if c.join("kmeans_step.hlo.txt").exists() {
            return c.clone();
        }
    }
    cands[0].clone()
}
