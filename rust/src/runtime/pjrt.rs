//! Real PJRT executor, compiled only with `--features pjrt`.
//!
//! Requires a local `xla` bindings crate (the offline image does not ship
//! one); add it to `Cargo.toml` alongside the feature:
//!
//! ```toml
//! [dependencies]
//! xla = { path = "/opt/xla-rs" }   # or wherever the bindings live
//! ```
//!
//! The interchange format is HLO **text**: jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md).

use super::{BATCH, FEATURES, K};
use crate::util::error::{Context, Error, Result};
use crate::{anyhow, bail};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded PJRT executor for the exported compute graphs.
pub struct Runtime {
    client: xla::PjRtClient,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Runtime {
    /// Load every artifact in `dir` and compile it on the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        let mut rt = Self { client, execs: HashMap::new(), dir: dir.to_path_buf() };
        for name in ["pairwise", "kmeans_step", "gram_xty"] {
            rt.load_one(name)
                .with_context(|| format!("loading artifact {name} from {}", dir.display()))?;
        }
        Ok(rt)
    }

    fn load_one(&mut self, name: &str) -> Result<()> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!(
                "{} missing — run `make artifacts` first (python/compile/aot.py)",
                path.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(wrap)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap)?;
        self.execs.insert(name.to_string(), exe);
        Ok(())
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .execs
            .get(name)
            .ok_or_else(|| anyhow!("executable {name} not loaded"))?;
        let result = exe.execute::<xla::Literal>(inputs).map_err(wrap)?;
        let lit = result[0][0].to_literal_sync().map_err(wrap)?;
        // aot.py lowers with return_tuple=True: always a tuple
        lit.to_tuple().map_err(wrap)
    }

    /// Distance matrix of one batch: x is BATCH*FEATURES, c is K*FEATURES
    /// (both row-major f32). Returns BATCH*K distances.
    pub fn pairwise(&self, x: &[f32], c: &[f32]) -> Result<Vec<f32>> {
        let (lx, lc) = self.batch_inputs(x, c)?;
        let out = self.run("pairwise", &[lx, lc])?;
        out[0].to_vec::<f32>().map_err(wrap)
    }

    /// One Lloyd iteration over a batch: returns (new_centroids K*FEATURES,
    /// batch inertia).
    pub fn kmeans_step(&self, x: &[f32], c: &[f32]) -> Result<(Vec<f32>, f32)> {
        let (lx, lc) = self.batch_inputs(x, c)?;
        let out = self.run("kmeans_step", &[lx, lc])?;
        let new_c = out[0].to_vec::<f32>().map_err(wrap)?;
        let inertia = out[1].to_vec::<f32>().map_err(wrap)?[0];
        Ok((new_c, inertia))
    }

    /// Normal-equation blocks of a batch: returns (XᵀX FEATURES², Xᵀy).
    pub fn gram_xty(&self, x: &[f32], y: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        if x.len() != BATCH * FEATURES || y.len() != BATCH {
            bail!("gram_xty expects {}x{} + {} inputs", BATCH, FEATURES, BATCH);
        }
        let lx = xla::Literal::vec1(x)
            .reshape(&[BATCH as i64, FEATURES as i64])
            .map_err(wrap)?;
        let ly = xla::Literal::vec1(y);
        let out = self.run("gram_xty", &[lx, ly])?;
        Ok((
            out[0].to_vec::<f32>().map_err(wrap)?,
            out[1].to_vec::<f32>().map_err(wrap)?,
        ))
    }

    fn batch_inputs(&self, x: &[f32], c: &[f32]) -> Result<(xla::Literal, xla::Literal)> {
        if x.len() != BATCH * FEATURES {
            bail!("batch must be {}x{} f32, got {} values", BATCH, FEATURES, x.len());
        }
        if c.len() != K * FEATURES {
            bail!("centroids must be {}x{} f32, got {}", K, FEATURES, c.len());
        }
        let lx = xla::Literal::vec1(x)
            .reshape(&[BATCH as i64, FEATURES as i64])
            .map_err(wrap)?;
        let lc = xla::Literal::vec1(c)
            .reshape(&[K as i64, FEATURES as i64])
            .map_err(wrap)?;
        Ok((lx, lc))
    }
}

fn wrap(e: xla::Error) -> Error {
    anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifacts_dir;
    use crate::util::Pcg64;

    fn runtime() -> Option<Runtime> {
        let dir = default_artifacts_dir();
        if !dir.join("kmeans_step.hlo.txt").exists() {
            eprintln!("artifacts missing; skipping runtime test");
            return None;
        }
        Some(Runtime::load(&dir).expect("runtime should load"))
    }

    fn rand_batch(seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let x: Vec<f32> = (0..BATCH * FEATURES).map(|_| rng.normal() as f32).collect();
        let c: Vec<f32> = (0..K * FEATURES).map(|_| rng.normal() as f32).collect();
        (x, c)
    }

    #[test]
    fn pairwise_matches_cpu_reference() {
        let Some(rt) = runtime() else { return };
        let (x, c) = rand_batch(70);
        let d = rt.pairwise(&x, &c).unwrap();
        assert_eq!(d.len(), BATCH * K);
        // check a few entries against a scalar reference
        for &i in &[0usize, 17, 4095] {
            for j in 0..K {
                let mut want = 0.0f32;
                for f in 0..FEATURES {
                    let diff = x[i * FEATURES + f] - c[j * FEATURES + f];
                    want += diff * diff;
                }
                let got = d[i * K + j];
                assert!(
                    (got - want).abs() < 1e-2 * want.abs().max(1.0),
                    "d[{i},{j}] = {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn kmeans_step_reduces_inertia() {
        let Some(rt) = runtime() else { return };
        let (x, c0) = rand_batch(71);
        let (c1, i1) = rt.kmeans_step(&x, &c0).unwrap();
        let (_c2, i2) = rt.kmeans_step(&x, &c1).unwrap();
        assert!(i2 <= i1 * 1.001, "inertia must not increase: {i1} -> {i2}");
        assert_eq!(c1.len(), K * FEATURES);
    }

    #[test]
    fn gram_xty_solves_regression() {
        let Some(rt) = runtime() else { return };
        let mut rng = Pcg64::new(72);
        let w_true: Vec<f64> = (0..FEATURES).map(|_| rng.normal()).collect();
        let x: Vec<f32> = (0..BATCH * FEATURES).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..BATCH)
            .map(|i| {
                (0..FEATURES)
                    .map(|f| x[i * FEATURES + f] as f64 * w_true[f])
                    .sum::<f64>() as f32
            })
            .collect();
        let (g, xty) = rt.gram_xty(&x, &y).unwrap();
        // solve in f64 with the crate's own Cholesky
        let mut a = crate::util::Matrix::zeros(FEATURES, FEATURES);
        for i in 0..FEATURES {
            for j in 0..FEATURES {
                a[(i, j)] = g[i * FEATURES + j] as f64;
            }
            a[(i, i)] += 1e-6;
        }
        let b: Vec<f64> = xty.iter().map(|&v| v as f64).collect();
        let w = crate::util::solve_spd(&a, &b).unwrap();
        for (got, want) in w.iter().zip(&w_true) {
            assert!((got - want).abs() < 1e-2, "{got} vs {want}");
        }
    }

    #[test]
    fn shape_errors_are_reported() {
        let Some(rt) = runtime() else { return };
        let err = rt.pairwise(&[0.0; 10], &[0.0; 10]).unwrap_err().to_string();
        assert!(err.contains("batch must be"), "{err}");
    }
}
