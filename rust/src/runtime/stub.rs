//! Feature-gated stand-in for the PJRT executor: keeps the `runtime` API
//! compiling when the `xla` bindings crate is unavailable (the default
//! offline build). `load` always fails; the methods below are never
//! reachable on this configuration but preserve the call-site types.

use crate::bail;
use crate::util::error::Result;
use std::path::Path;

/// Stub executor. Construction always fails with an explanatory error.
pub struct Runtime {
    _private: (),
}

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: this build has the `pjrt` feature disabled \
     (the offline image ships no `xla` bindings crate). Rebuild with \
     `cargo build --features pjrt` and a local `xla` dependency to run \
     AOT artifacts.";

impl Runtime {
    /// Always fails on a stub build.
    pub fn load(dir: &Path) -> Result<Self> {
        bail!("{UNAVAILABLE} (artifacts dir: {})", dir.display());
    }

    pub fn platform(&self) -> String {
        "unavailable (pjrt feature disabled)".to_string()
    }

    pub fn pairwise(&self, _x: &[f32], _c: &[f32]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}");
    }

    pub fn kmeans_step(&self, _x: &[f32], _c: &[f32]) -> Result<(Vec<f32>, f32)> {
        bail!("{UNAVAILABLE}");
    }

    pub fn gram_xty(&self, _x: &[f32], _y: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        bail!("{UNAVAILABLE}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_reports_feature_gate() {
        let err = Runtime::load(Path::new("artifacts")).err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("pjrt"), "{msg}");
    }
}
