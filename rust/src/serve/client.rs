//! Client side of the serve protocol: connect, frame a request, read
//! the response. Used by the `mlperf query` subcommand, the soak tests,
//! and the load-generator bench — all three speak exactly the wire
//! format in [`crate::serve::protocol`], nothing more.

use std::net::TcpStream;
use std::path::Path;
use std::time::Duration;

use crate::serve::daemon::ADDRFILE;
use crate::serve::protocol;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// One connection to a serve daemon. Requests are strictly
/// call-and-response on this connection; open several clients for
/// concurrency.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    /// Connect to a daemon at `addr` (e.g. `127.0.0.1:7070`).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to serve daemon at {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream })
    }

    /// Bound how long [`Client::call`] waits for a response frame.
    pub fn set_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    /// Send one request document and read the daemon's response frame.
    /// A connection the daemon dropped without answering (chaos
    /// `conn-drop`, or a hard kill) surfaces as a typed error here.
    pub fn call(&mut self, doc: &Json) -> Result<Json> {
        protocol::write_frame(&mut self.stream, doc)?;
        match protocol::read_frame(&mut self.stream)? {
            Some(resp) => Ok(resp),
            None => crate::bail!("serve daemon closed the connection without answering"),
        }
    }

    /// Build and send a `query` request for one grid cell.
    pub fn query(
        &mut self,
        workload: &str,
        scenario: &str,
        deadline_ms: Option<u64>,
    ) -> Result<Json> {
        let mut fields = protocol::message("query");
        fields.push(("workload".to_string(), Json::Str(workload.to_string())));
        fields.push(("scenario".to_string(), Json::Str(scenario.to_string())));
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms".to_string(), Json::num(ms as f64)));
        }
        self.call(&Json::Obj(fields))
    }

    /// Send a bare request (`ping`, `stats`, `compact`, `shutdown`).
    pub fn op(&mut self, op: &str) -> Result<Json> {
        self.call(&Json::Obj(protocol::message(op)))
    }
}

/// Read a daemon's bound address back from its `serve.addr` discovery
/// file (written at bind, removed on drain) — the handshake that lets
/// scripts use `--listen 127.0.0.1:0` without parsing daemon stdout.
pub fn discover_addr(dir: &Path) -> Result<String> {
    let path = dir.join(ADDRFILE);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} (is the daemon running?)", path.display()))?;
    Ok(text.trim().to_string())
}
