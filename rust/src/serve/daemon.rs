//! The `mlperf serve` daemon: grid-as-a-service over the sharded ledger.
//!
//! A long-running process that answers `(workload, scenario)` queries
//! from the [`ShardedLedger`], simulating **only on miss** — and then
//! only once per fingerprint, no matter how many clients ask
//! concurrently. The design goal is *degrade, not die*:
//!
//! - **Admission control** — at most `queue_depth` queries are in
//!   flight; everything beyond is shed immediately with a typed
//!   [`TraceError::overloaded`] rejection instead of queueing
//!   unboundedly until memory or latency collapses.
//! - **Deadlines** — every query carries a `deadline_ms` budget
//!   (defaulting to `--default-deadline`); a query whose budget expires
//!   gets a typed [`TraceError::deadline`] rejection. A coalesced miss
//!   keeps simulating even when a waiter times out: the *leader* always
//!   finishes and appends, so the work is never wasted — the next query
//!   for that fingerprint is a hit.
//! - **Request coalescing** — N concurrent misses on one fingerprint
//!   join a single in-flight [`Flight`]; the batch runner drains every
//!   pending miss into **one** [`run_jobs_replayed`] call, so distinct
//!   scenarios of the same workload share a capture via the driver's
//!   residency-capped fan-out pool.
//! - **Crash safety** — results live in checksummed ledger shards with
//!   torn-tail recovery ([`ShardedLedger`]); a `kill -9` mid-serve
//!   loses at most the record being appended, and a restart answers
//!   every previously served fingerprint with zero re-simulation. A
//!   pidfile (`serve.pid`) refuses double-starts; stale locks from a
//!   crashed daemon are detected and taken over.
//! - **Graceful drain** — SIGTERM/SIGINT (or a protocol `shutdown`
//!   request) stops admission, finishes in-flight connections, removes
//!   the lock files, and exits 0.
//!
//! Chaos sites `conn-drop`, `slow-client`, and `serve-kill`
//! ([`crate::util::fault`]) exercise the recovery paths; serve-stage
//! spans and counters ([`crate::util::telemetry`]) expose queue depth,
//! sheds, deadline hits, and coalescing.

use std::collections::HashMap;
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant, SystemTime};

use crate::coordinator::driver::cell_provenance;
use crate::coordinator::{run_jobs_replayed, ExperimentConfig, Job, Scenario};
use crate::ledger::{cell_fingerprint, Fingerprint, LedgerRecord, TRACKED};
use crate::serve::protocol;
use crate::serve::shard::{ShardedLedger, DEFAULT_SHARDS};
use crate::trace::TraceError;
use crate::util::error::{Context, Result};
use crate::util::fault::{self, Site};
use crate::util::json::Json;
use crate::util::telemetry::{self, Counter, Stage};
use crate::workloads::by_name;

/// Name of the double-start lock file inside the serve directory.
pub const PIDFILE: &str = "serve.pid";

/// Name of the discovery file holding the daemon's bound address
/// (written after bind, removed on drain), so scripts and CI can find a
/// daemon started with `--listen 127.0.0.1:0`.
pub const ADDRFILE: &str = "serve.addr";

/// Everything `mlperf serve` needs to come up.
pub struct ServeOptions {
    /// Bind address; port 0 picks a free port (read it back from
    /// [`Server::addr`] or the `serve.addr` file).
    pub listen: String,
    /// Directory holding the ledger shards and lock files.
    pub dir: PathBuf,
    /// Shard count for a fresh directory (existing shards win; see
    /// [`ShardedLedger::open`]).
    pub shards: usize,
    /// Admission bound: queries in flight beyond this are shed.
    pub queue_depth: usize,
    /// Deadline applied to queries that do not carry `deadline_ms`.
    pub default_deadline_ms: u64,
    /// Threads per miss batch handed to [`run_jobs_replayed`] (0 = auto).
    pub sim_threads: usize,
    /// fsync every shard append.
    pub durable: bool,
    /// Experiment configuration the daemon simulates under; part of
    /// every fingerprint, so one daemon serves exactly one config.
    pub cfg: ExperimentConfig,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            listen: "127.0.0.1:0".to_string(),
            dir: PathBuf::from("results/serve"),
            shards: DEFAULT_SHARDS,
            queue_depth: 64,
            default_deadline_ms: 5000,
            sim_threads: 0,
            durable: false,
            cfg: ExperimentConfig::default(),
        }
    }
}

/// Outcome of one in-flight miss: the appended record, or a
/// `(kind, message)` pair mirroring [`TraceError::kind_str`] tags.
type FlightResult = std::result::Result<LedgerRecord, (String, String)>;

/// One in-flight miss simulation. Concurrent queries for the same
/// fingerprint share a `Flight` and block on its condvar; the batch
/// runner publishes exactly once.
#[derive(Default)]
struct Flight {
    slot: Mutex<Option<FlightResult>>,
    cv: Condvar,
}

impl Flight {
    fn publish(&self, result: FlightResult) {
        let mut slot = lock(&self.slot);
        *slot = Some(result);
        self.cv.notify_all();
    }

    /// Block until the result is published or `deadline` passes
    /// (`None` = deadline expired; the simulation keeps running).
    fn wait_until(&self, deadline: Instant) -> Option<FlightResult> {
        let mut slot = lock(&self.slot);
        loop {
            if let Some(result) = slot.as_ref() {
                return Some(result.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(slot, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            slot = guard;
        }
    }
}

/// Pending misses awaiting the batch runner. `runner_active` makes the
/// first enqueuer the runner; it loops until the queue drains empty
/// (checked under the same lock, so no miss is ever stranded).
#[derive(Default)]
struct MissQueue {
    queued: Vec<(Fingerprint, Job)>,
    runner_active: bool,
}

/// Shared daemon state: config, shards, admission counter, coalescing
/// map, miss queue, and lifetime counters (the counters mirror the
/// telemetry ones but are always on, so `stats` works untraced).
struct ServerState {
    cfg: ExperimentConfig,
    ledger: ShardedLedger,
    dir: PathBuf,
    queue_depth: usize,
    default_deadline_ms: u64,
    sim_threads: usize,
    draining: AtomicBool,
    conns: AtomicUsize,
    admitted: AtomicUsize,
    flights: Mutex<HashMap<Fingerprint, Arc<Flight>>>,
    misses: Mutex<MissQueue>,
    stat_admitted: AtomicU64,
    stat_shed: AtomicU64,
    stat_deadline: AtomicU64,
    stat_hits: AtomicU64,
    stat_misses: AtomicU64,
    stat_coalesced: AtomicU64,
    executions: AtomicU64,
}

impl ServerState {
    fn new(opts: ServeOptions, ledger: ShardedLedger) -> ServerState {
        ServerState {
            cfg: opts.cfg,
            ledger,
            dir: opts.dir,
            queue_depth: opts.queue_depth.max(1),
            default_deadline_ms: opts.default_deadline_ms,
            sim_threads: opts.sim_threads,
            draining: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
            admitted: AtomicUsize::new(0),
            flights: Mutex::new(HashMap::new()),
            misses: Mutex::new(MissQueue::default()),
            stat_admitted: AtomicU64::new(0),
            stat_shed: AtomicU64::new(0),
            stat_deadline: AtomicU64::new(0),
            stat_hits: AtomicU64::new(0),
            stat_misses: AtomicU64::new(0),
            stat_coalesced: AtomicU64::new(0),
            executions: AtomicU64::new(0),
        }
    }

    /// Claim an admission slot, or `None` when the queue is full. The
    /// returned guard releases the slot on drop.
    fn try_admit(&self) -> Option<Admission<'_>> {
        let mut cur = self.admitted.load(Ordering::SeqCst);
        loop {
            if cur >= self.queue_depth {
                return None;
            }
            match self.admitted.compare_exchange(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => {
                    telemetry::maximize(Counter::ServeQueueMax, (cur + 1) as u64);
                    return Some(Admission { state: self });
                }
                Err(actual) => cur = actual,
            }
        }
    }
}

/// RAII admission slot (see [`ServerState::try_admit`]).
struct Admission<'a> {
    state: &'a ServerState,
}

impl Drop for Admission<'_> {
    fn drop(&mut self) {
        self.state.admitted.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Decrements the live-connection count when a handler thread exits —
/// by any path, including a panic — so drain can never hang on a
/// leaked count.
struct ConnGuard(Arc<ServerState>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A bound (but not yet running) serve daemon.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    state: Arc<ServerState>,
    pidfile: PathBuf,
}

impl Server {
    /// Acquire the pidfile lock, open the shards, and bind the listener.
    /// Fails fast — with the lock released — if another daemon holds the
    /// directory or the address is taken.
    pub fn bind(opts: ServeOptions) -> Result<Server> {
        std::fs::create_dir_all(&opts.dir)
            .with_context(|| format!("creating serve directory {}", opts.dir.display()))?;
        let pidfile = acquire_pidfile(&opts.dir)?;
        match Server::bind_locked(opts, pidfile.clone()) {
            Ok(server) => Ok(server),
            Err(e) => {
                let _ = std::fs::remove_file(&pidfile);
                Err(e)
            }
        }
    }

    fn bind_locked(opts: ServeOptions, pidfile: PathBuf) -> Result<Server> {
        let ledger = ShardedLedger::open(&opts.dir, opts.shards)?;
        ledger.set_durable(opts.durable);
        let listener = TcpListener::bind(&opts.listen)
            .with_context(|| format!("binding serve listener on {}", opts.listen))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        std::fs::write(opts.dir.join(ADDRFILE), format!("{addr}\n"))
            .context("writing serve.addr discovery file")?;
        let state = Arc::new(ServerState::new(opts, ledger));
        Ok(Server { listener, addr, state, pidfile })
    }

    /// The bound address (useful with `--listen 127.0.0.1:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept and serve connections until SIGTERM/SIGINT or a protocol
    /// `shutdown` request, then drain: stop admitting, let in-flight
    /// connections finish, remove the lock files, and return `Ok(())`
    /// (the CLI maps that to exit 0).
    pub fn run(self) -> Result<()> {
        install_term_handler();
        let state = self.state;
        loop {
            if term_requested() {
                state.draining.store(true, Ordering::SeqCst);
            }
            if state.draining.load(Ordering::SeqCst) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let _ = stream.set_nodelay(true);
                    // the read timeout doubles as the drain poll tick:
                    // idle connections notice `draining` within ~50ms
                    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
                    state.conns.fetch_add(1, Ordering::SeqCst);
                    let guard = ConnGuard(Arc::clone(&state));
                    std::thread::spawn(move || {
                        telemetry::lane("serve-conn");
                        let _sp = telemetry::span(Stage::ServeConn);
                        handle_conn(&guard.0, stream);
                        drop(guard);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    let _ = std::fs::remove_file(state.dir.join(ADDRFILE));
                    let _ = std::fs::remove_file(&self.pidfile);
                    return Err(crate::anyhow!("serve accept failed: {e}"));
                }
            }
        }
        while state.conns.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        let _ = std::fs::remove_file(state.dir.join(ADDRFILE));
        let _ = std::fs::remove_file(&self.pidfile);
        Ok(())
    }
}

/// Per-connection loop: read a frame, answer it, repeat until the peer
/// closes, the daemon drains, or a protocol error desyncs the stream.
fn handle_conn(state: &ServerState, mut stream: TcpStream) {
    loop {
        let req = match read_request(state, &mut stream) {
            Ok(Some(doc)) => doc,
            Ok(None) | Err(_) => return,
        };
        // chaos: drop the connection after reading, before answering —
        // the client sees EOF, the daemon stays healthy
        if fault::fired(Site::ConnDrop).is_some() {
            return;
        }
        let op = req.get("op").and_then(Json::as_str).unwrap_or("").to_string();
        let resp = dispatch(state, &op, &req);
        if protocol::write_frame(&mut stream, &resp).is_err() {
            return;
        }
        // chaos: hard-kill after fully answering the nth query; the
        // restart must serve every already-appended fingerprint warm
        if op == "query" && fault::fired(Site::ServeKill).is_some() {
            std::process::abort();
        }
    }
}

/// Read one request frame, tolerating read-timeout ticks so an idle
/// connection notices a drain. `Ok(None)` = peer closed or draining.
fn read_request(state: &ServerState, stream: &mut TcpStream) -> Result<Option<Json>> {
    let mut marker = [0u8; 1];
    loop {
        match stream.read(&mut marker) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.draining.load(Ordering::SeqCst) || term_requested() {
                    return Ok(None);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    if marker[0] != protocol::FRAME_MARKER {
        crate::bail!("protocol desync: got 0x{:02X} where a frame marker belonged", marker[0]);
    }
    protocol::read_frame_body(stream).map(Some)
}

fn dispatch(state: &ServerState, op: &str, req: &Json) -> Json {
    match op {
        "ping" => ok_response("ping", Vec::new()),
        "stats" => stats_response(state),
        "compact" => compact_response(state),
        "shutdown" => {
            state.draining.store(true, Ordering::SeqCst);
            ok_response("shutdown", vec![("draining".to_string(), Json::Bool(true))])
        }
        "query" => handle_query(state, req),
        other => error_response(
            other,
            "format",
            &format!("unknown op {other:?} (see `mlperf list` for the protocol)"),
        ),
    }
}

/// The query path: admit → deadline-check → ledger hit → coalesced
/// miss. Rejections are typed (`overloaded` / `deadline-exceeded`),
/// mirroring [`TraceError::kind_str`] on the wire.
fn handle_query(state: &ServerState, req: &Json) -> Json {
    let started = Instant::now();
    let deadline_ms = req
        .get("deadline_ms")
        .and_then(Json::as_f64)
        .map(|v| v.max(0.0) as u64)
        .unwrap_or(state.default_deadline_ms);
    let deadline = started + Duration::from_millis(deadline_ms);

    if state.draining.load(Ordering::SeqCst) {
        return shed_response(state, "daemon is draining; no new queries admitted");
    }
    let Some(_slot) = state.try_admit() else {
        return shed_response(
            state,
            &format!("admission queue full ({} queries in flight)", state.queue_depth),
        );
    };
    state.stat_admitted.fetch_add(1, Ordering::SeqCst);
    telemetry::add(Counter::ServeAdmitted, 1);
    let _sp = telemetry::span(Stage::ServeRequest);

    // chaos: a client that trickles its request in, holding its
    // admission slot while doing nothing useful
    if let Some(ms) = fault::fired(Site::SlowClient) {
        std::thread::sleep(Duration::from_millis(ms));
    }

    let Some(workload) = req.get("workload").and_then(Json::as_str) else {
        return error_response("query", "format", "query is missing its \"workload\" field");
    };
    let Some(wl) = by_name(workload) else {
        return error_response(
            "query",
            "format",
            &format!("unknown workload {workload:?} (see `mlperf list`)"),
        );
    };
    let scenario_str = req.get("scenario").and_then(Json::as_str).unwrap_or("baseline");
    let Some(scenario) = Scenario::parse(scenario_str) else {
        return error_response(
            "query",
            "format",
            &format!("unknown scenario {scenario_str:?} (see `mlperf list`)"),
        );
    };
    let job = Job::new(wl.name(), scenario);
    if Instant::now() >= deadline {
        return deadline_response(state, &job, deadline_ms);
    }

    let fp = cell_fingerprint(&state.cfg, &job);
    if let Some(rec) = state.ledger.get(&fp) {
        state.stat_hits.fetch_add(1, Ordering::SeqCst);
        telemetry::add(Counter::ServeHit, 1);
        return record_response(&rec, true, false);
    }

    // miss: join the in-flight simulation for this fingerprint, or open
    // one and enqueue the job for the batch runner
    let (flight, coalesced) = {
        let mut flights = lock(&state.flights);
        if let Some(f) = flights.get(&fp) {
            (Arc::clone(f), true)
        } else if let Some(rec) = state.ledger.get(&fp) {
            // a batch runner appends before removing its flight, so a
            // fingerprint absent from both maps really is a fresh miss;
            // this re-check under the flights lock closes the race where
            // the runner finished between our two lookups (without it,
            // that window would open a second flight and re-simulate)
            state.stat_hits.fetch_add(1, Ordering::SeqCst);
            telemetry::add(Counter::ServeHit, 1);
            return record_response(&rec, true, false);
        } else {
            let f = Arc::new(Flight::default());
            flights.insert(fp, Arc::clone(&f));
            (f, false)
        }
    };
    let run_now = if coalesced {
        state.stat_coalesced.fetch_add(1, Ordering::SeqCst);
        telemetry::add(Counter::ServeCoalesced, 1);
        false
    } else {
        state.stat_misses.fetch_add(1, Ordering::SeqCst);
        telemetry::add(Counter::ServeMiss, 1);
        let mut q = lock(&state.misses);
        q.queued.push((fp, job.clone()));
        if q.runner_active {
            false
        } else {
            q.runner_active = true;
            true
        }
    };
    if run_now {
        run_misses(state);
    }
    match flight.wait_until(deadline) {
        Some(Ok(rec)) => record_response(&rec, false, coalesced),
        Some(Err((kind, msg))) => error_response("query", &kind, &msg),
        // the runner keeps simulating and will append the result; only
        // this waiter's response times out
        None => deadline_response(state, &job, deadline_ms),
    }
}

/// Drain the miss queue in batches: each pass hands **every** pending
/// miss to one [`run_jobs_replayed`] call, so concurrent misses —
/// including distinct scenarios of one workload — share captures via
/// the driver's residency-capped pool. Loops until the queue is empty
/// (checked under the queue lock, so no enqueuer is stranded).
fn run_misses(state: &ServerState) {
    loop {
        let batch = {
            let mut q = lock(&state.misses);
            if q.queued.is_empty() {
                q.runner_active = false;
                return;
            }
            std::mem::take(&mut q.queued)
        };
        let _sp = telemetry::span_labeled(Stage::ServeSim, &format!("{} cell(s)", batch.len()));
        let jobs: Vec<Job> = batch.iter().map(|(_, job)| job.clone()).collect();
        let report = run_jobs_replayed(&state.cfg, &jobs, state.sim_threads);
        state.executions.fetch_add(report.workload_executions as u64, Ordering::SeqCst);
        let wall_nanos = (report.wall_seconds * 1e9) as u64 / batch.len().max(1) as u64;
        let unix_secs = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let mut failed: HashMap<usize, (String, String)> =
            report.failed.into_iter().map(|f| (f.index, (f.kind, f.error))).collect();
        let mut outputs = report.outputs.into_iter();
        for (i, (fp, _)) in batch.iter().enumerate() {
            let result: FlightResult = if let Some((kind, msg)) = failed.remove(&i) {
                Err((kind, msg))
            } else {
                match outputs.next() {
                    Some(out) => {
                        let rec = LedgerRecord {
                            fingerprint: *fp,
                            provenance: cell_provenance(&state.cfg, &out.job, wall_nanos, unix_secs),
                            metrics: out.metrics,
                            quality: out.quality,
                        };
                        // append BEFORE removing the flight, so a racing
                        // query either hits the ledger or finds the flight
                        match state.ledger.append(rec.clone()) {
                            Ok(()) => Ok(rec),
                            Err(e) => Err(("io".to_string(), format!("ledger append failed: {e}"))),
                        }
                    }
                    None => Err((
                        "panic".to_string(),
                        "driver returned no output for a non-failed cell".to_string(),
                    )),
                }
            };
            let flight = lock(&state.flights).remove(fp);
            if let Some(f) = flight {
                f.publish(result);
            }
        }
    }
}

fn shed_response(state: &ServerState, why: &str) -> Json {
    state.stat_shed.fetch_add(1, Ordering::SeqCst);
    telemetry::add(Counter::ServeShed, 1);
    let err = TraceError::overloaded(why);
    error_response("query", err.kind_str(), &err.to_string())
}

fn deadline_response(state: &ServerState, job: &Job, deadline_ms: u64) -> Json {
    state.stat_deadline.fetch_add(1, Ordering::SeqCst);
    telemetry::add(Counter::ServeDeadline, 1);
    let err = TraceError::deadline(format!(
        "deadline of {deadline_ms}ms expired before {} × {} could be answered",
        job.workload, job.scenario
    ));
    error_response("query", err.kind_str(), &err.to_string())
}

/// A successful query response: provenance identity plus every
/// [`TRACKED`] metric, rendered with the crate's shortest-roundtrip
/// float writer — bit-identical to what `mlperf grid` would report.
fn record_response(rec: &LedgerRecord, cached: bool, coalesced: bool) -> Json {
    let metrics: Vec<(String, Json)> = TRACKED
        .iter()
        .map(|(name, get)| ((*name).to_string(), Json::num(get(&rec.metrics))))
        .collect();
    let mut fields = protocol::message("query");
    fields.push(("ok".to_string(), Json::Bool(true)));
    fields.push(("cached".to_string(), Json::Bool(cached)));
    fields.push(("coalesced".to_string(), Json::Bool(coalesced)));
    fields.push(("workload".to_string(), Json::Str(rec.provenance.workload.clone())));
    fields.push(("scenario".to_string(), Json::Str(rec.provenance.scenario.clone())));
    fields.push(("fingerprint".to_string(), Json::Str(rec.fingerprint.to_string())));
    fields.push(("quality".to_string(), rec.quality.map_or(Json::Null, Json::num)));
    fields.push(("metrics".to_string(), Json::Obj(metrics)));
    Json::Obj(fields)
}

fn ok_response(op: &str, extra: Vec<(String, Json)>) -> Json {
    let mut fields = protocol::message(op);
    fields.push(("ok".to_string(), Json::Bool(true)));
    fields.extend(extra);
    Json::Obj(fields)
}

fn error_response(op: &str, kind: &str, msg: &str) -> Json {
    let mut fields = protocol::message(op);
    fields.push(("ok".to_string(), Json::Bool(false)));
    fields.push(("kind".to_string(), Json::Str(kind.to_string())));
    fields.push(("error".to_string(), Json::Str(msg.to_string())));
    Json::Obj(fields)
}

fn stats_response(state: &ServerState) -> Json {
    let shards: Vec<Json> = state
        .ledger
        .stats()
        .iter()
        .map(|s| {
            Json::Obj(vec![
                ("records".to_string(), Json::num(s.records as f64)),
                ("unique".to_string(), Json::num(s.unique as f64)),
                ("superseded".to_string(), Json::num(s.superseded as f64)),
                ("file_bytes".to_string(), Json::num(s.file_bytes as f64)),
                ("recovered_tail_bytes".to_string(), Json::num(s.recovered_tail_bytes as f64)),
            ])
        })
        .collect();
    let c = |a: &AtomicU64| Json::num(a.load(Ordering::SeqCst) as f64);
    ok_response(
        "stats",
        vec![
            ("draining".to_string(), Json::Bool(state.draining.load(Ordering::SeqCst))),
            ("queue_depth".to_string(), Json::num(state.admitted.load(Ordering::SeqCst) as f64)),
            ("queue_cap".to_string(), Json::num(state.queue_depth as f64)),
            ("default_deadline_ms".to_string(), Json::num(state.default_deadline_ms as f64)),
            ("admitted".to_string(), c(&state.stat_admitted)),
            ("shed".to_string(), c(&state.stat_shed)),
            ("deadline_misses".to_string(), c(&state.stat_deadline)),
            ("hits".to_string(), c(&state.stat_hits)),
            ("misses".to_string(), c(&state.stat_misses)),
            ("coalesced".to_string(), c(&state.stat_coalesced)),
            ("workload_executions".to_string(), c(&state.executions)),
            ("unique_cells".to_string(), Json::num(state.ledger.total_unique() as f64)),
            ("total_records".to_string(), Json::num(state.ledger.total_records() as f64)),
            ("shards".to_string(), Json::Arr(shards)),
        ],
    )
}

fn compact_response(state: &ServerState) -> Json {
    match state.ledger.compact_all() {
        Ok(reports) => {
            let arr: Vec<Json> = reports
                .iter()
                .map(|r| {
                    Json::Obj(vec![
                        ("records_before".to_string(), Json::num(r.records_before as f64)),
                        ("records_after".to_string(), Json::num(r.records_after as f64)),
                        ("bytes_before".to_string(), Json::num(r.bytes_before as f64)),
                        ("bytes_after".to_string(), Json::num(r.bytes_after as f64)),
                    ])
                })
                .collect();
            ok_response("compact", vec![("shards".to_string(), Json::Arr(arr))])
        }
        Err(e) => error_response("compact", "io", &e.to_string()),
    }
}

/// Create `serve.pid` exclusively. An existing file whose recorded pid
/// is still alive refuses the start; a stale lock (crashed daemon) is
/// removed and taken over.
fn acquire_pidfile(dir: &Path) -> Result<PathBuf> {
    let path = dir.join(PIDFILE);
    loop {
        match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
            Ok(mut f) => {
                use std::io::Write as _;
                writeln!(f, "{}", std::process::id())?;
                return Ok(path);
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                let holder = std::fs::read_to_string(&path)
                    .unwrap_or_default()
                    .trim()
                    .parse::<u32>()
                    .ok();
                if let Some(pid) = holder {
                    if pid_alive(pid) {
                        crate::bail!(
                            "serve daemon already running (pid {pid} holds {})",
                            path.display()
                        );
                    }
                }
                // unreadable or dead holder: a crashed daemon left the
                // lock behind — take it over
                std::fs::remove_file(&path)
                    .with_context(|| format!("removing stale pidfile {}", path.display()))?;
            }
            Err(e) => {
                return Err(crate::anyhow!("creating pidfile {}: {e}", path.display()));
            }
        }
    }
}

#[cfg(target_os = "linux")]
fn pid_alive(pid: u32) -> bool {
    Path::new(&format!("/proc/{pid}")).exists()
}

#[cfg(not(target_os = "linux"))]
fn pid_alive(_pid: u32) -> bool {
    // no cheap liveness probe: be conservative and never steal the lock
    true
}

static TERM: AtomicBool = AtomicBool::new(false);

fn term_requested() -> bool {
    TERM.load(Ordering::SeqCst)
}

/// Route SIGTERM/SIGINT to a flag the accept loop polls (the listener
/// is non-blocking, so no syscall restarts to worry about). The handler
/// body is a single atomic store — async-signal-safe by construction.
#[cfg(unix)]
fn install_term_handler() {
    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    // SIGTERM = 15, SIGINT = 2 on every unix this crate targets
    unsafe {
        signal(15, on_term);
        signal(2, on_term);
    }
}

#[cfg(not(unix))]
fn install_term_handler() {}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("mlperf-serve-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn test_state(dir: &Path, queue_depth: usize) -> ServerState {
        let ledger = ShardedLedger::open(dir, 2).unwrap();
        let opts = ServeOptions {
            dir: dir.to_path_buf(),
            queue_depth,
            ..ServeOptions::default()
        };
        ServerState::new(opts, ledger)
    }

    #[test]
    fn admission_is_bounded_and_slots_release_on_drop() {
        let dir = tmpdir("admit");
        let state = test_state(&dir, 2);
        let a = state.try_admit().expect("slot 1");
        let _b = state.try_admit().expect("slot 2");
        assert!(state.try_admit().is_none(), "third query must be shed");
        drop(a);
        assert!(state.try_admit().is_some(), "released slot must be reusable");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pidfile_blocks_double_start_and_recovers_stale_locks() {
        let dir = tmpdir("pidfile");
        let lock = acquire_pidfile(&dir).expect("first acquire");
        let err = acquire_pidfile(&dir).unwrap_err().to_string();
        assert!(err.contains("already running"), "{err}");
        std::fs::remove_file(&lock).unwrap();

        // a lock held by a long-dead pid is stale: takeover succeeds
        std::fs::write(dir.join(PIDFILE), "4000000000\n").unwrap();
        let lock = acquire_pidfile(&dir).expect("stale lock takeover");
        let holder: u32 =
            std::fs::read_to_string(&lock).unwrap().trim().parse().unwrap();
        assert_eq!(holder, std::process::id());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn flight_waiters_see_published_results_and_deadlines_expire() {
        let flight = Arc::new(Flight::default());
        // an already-expired deadline returns None without blocking
        assert!(flight.wait_until(Instant::now()).is_none());

        let waiter = {
            let flight = Arc::clone(&flight);
            std::thread::spawn(move || {
                flight.wait_until(Instant::now() + Duration::from_secs(30))
            })
        };
        flight.publish(Err(("io".to_string(), "boom".to_string())));
        let got = waiter.join().unwrap().expect("published before deadline");
        assert_eq!(got.unwrap_err().0, "io");
    }

    #[test]
    fn typed_rejections_carry_trace_error_tags() {
        let dir = tmpdir("reject");
        let state = test_state(&dir, 1);
        let shed = shed_response(&state, "queue full");
        assert_eq!(shed.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(shed.get("kind").and_then(Json::as_str), Some("overloaded"));
        let job = Job::new("KMeans", Scenario::Baseline);
        let dl = deadline_response(&state, &job, 0);
        assert_eq!(dl.get("kind").and_then(Json::as_str), Some("deadline-exceeded"));
        assert_eq!(state.stat_shed.load(Ordering::SeqCst), 1);
        assert_eq!(state.stat_deadline.load(Ordering::SeqCst), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
