//! Grid-as-a-service: the `mlperf serve` daemon, its wire protocol,
//! and the sharded ledger it serves from.
//!
//! `mlperf grid` re-derives its world on every invocation; `serve`
//! keeps the world resident and answers `(workload, scenario)` queries
//! over TCP, simulating only on ledger miss and never twice for one
//! fingerprint. The layer decomposes as:
//!
//! - [`protocol`] — length-prefixed, checksummed, versioned JSON frames
//!   (marker `0xE5`, mirroring the ledger's on-disk discipline).
//! - [`shard`] — the [`ShardedLedger`]: N independently locked,
//!   independently crash-recoverable `.mllg` shards keyed by
//!   fingerprint hash.
//! - [`daemon`] — admission control, per-query deadlines, miss
//!   coalescing onto the replay fan-out pool, SIGTERM drain, pidfile.
//! - [`client`] — the `mlperf query` side: connect, frame, parse.
//!
//! Overload and faults degrade service (typed `overloaded` /
//! `deadline-exceeded` rejections, dropped connections) instead of
//! killing it; a `kill -9` costs at most one in-flight append, and a
//! restart serves every prior query warm from the shards.

pub mod client;
pub mod daemon;
pub mod protocol;
pub mod shard;

pub use client::{discover_addr, Client};
pub use daemon::{ServeOptions, Server, ADDRFILE, PIDFILE};
pub use protocol::{FRAME_MARKER, MAX_FRAME, OPS, PROTOCOL_VERSION};
pub use shard::{ShardedLedger, DEFAULT_SHARDS};
