//! Wire protocol for the serve daemon: length-prefixed, checksummed
//! JSON frames over a byte stream.
//!
//! Every message — request or response, either direction — is one
//! frame:
//!
//! ```text
//! 0xE5 · payload_len u32 LE · fnv1a64(payload) u64 LE · payload
//! ```
//!
//! built from the same primitives as the crate's on-disk containers
//! ([`crate::util::binio`]); the payload is a single JSON document
//! ([`crate::util::json`]) whose top-level object always carries a
//! `"v"` field equal to [`PROTOCOL_VERSION`]. [`read_frame`] verifies
//! marker, bound, checksum, and version before handing the document to
//! the caller, so a corrupt or cross-version peer surfaces as one typed
//! error instead of undefined downstream parsing.

use std::io::{Read, Write};

use crate::bail;
use crate::util::binio::{fnv1a64, read_u32, read_u64};
use crate::util::error::Result;
use crate::util::json::Json;

/// Version tag every frame payload carries; bump on any incompatible
/// change to the frame format or the request/response vocabulary.
pub const PROTOCOL_VERSION: u32 = 1;

/// Leading marker byte of every frame (mirrors the ledger's `0xE1`
/// record marker discipline: a desynced stream fails fast).
pub const FRAME_MARKER: u8 = 0xE5;

/// Upper bound on a frame payload — a query or response is a few KiB;
/// anything near this bound is a desynced or malicious peer.
pub const MAX_FRAME: usize = 1 << 20;

/// The request operations the daemon understands, paired with a short
/// description (rendered by `mlperf list`).
pub const OPS: &[(&str, &str)] = &[
    ("query", "answer one (workload, scenario) cell from the sharded ledger, simulating on miss"),
    ("stats", "daemon counters, shard stats, and the serving configuration"),
    ("compact", "compact every ledger shard in parallel"),
    ("ping", "liveness probe"),
    ("shutdown", "stop admitting, drain in-flight work, exit 0"),
];

/// Build a request/response skeleton: the version field plus `op`.
pub fn message(op: &str) -> Vec<(String, Json)> {
    vec![
        ("v".to_string(), Json::Num(f64::from(PROTOCOL_VERSION))),
        ("op".to_string(), Json::Str(op.to_string())),
    ]
}

/// Serialize `doc` as one frame onto `w` (single `write_all`, then
/// flush, so a frame is never interleaved with another writer's bytes).
pub fn write_frame<W: Write>(w: &mut W, doc: &Json) -> Result<()> {
    let payload = doc.render().into_bytes();
    if payload.len() > MAX_FRAME {
        bail!("protocol frame too large ({} bytes > {MAX_FRAME})", payload.len());
    }
    let mut frame = Vec::with_capacity(13 + payload.len());
    frame.push(FRAME_MARKER);
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    frame.extend_from_slice(&payload);
    w.write_all(&frame)?;
    w.flush()?;
    Ok(())
}

/// Read one frame from `r`. Returns `Ok(None)` on a clean end of
/// stream (the peer closed between frames); any partial frame, bad
/// marker, oversized length, checksum mismatch, or version mismatch is
/// an error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Json>> {
    // distinguish clean EOF (no marker byte at all) from a torn frame
    let mut marker = [0u8; 1];
    loop {
        match r.read(&mut marker) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    if marker[0] != FRAME_MARKER {
        bail!("protocol desync: expected frame marker 0x{FRAME_MARKER:02X}, got 0x{:02X}", marker[0]);
    }
    read_frame_body(r).map(Some)
}

/// Read the remainder of a frame once the caller has already consumed
/// (and verified) the marker byte. The daemon's connection loop reads
/// the marker itself — with a read timeout, so idle connections can
/// notice a drain — and hands the stream here.
pub fn read_frame_body<R: Read>(r: &mut R) -> Result<Json> {
    let len = read_u32(r)? as usize;
    if len > MAX_FRAME {
        bail!("protocol frame length {len} exceeds the {MAX_FRAME}-byte bound");
    }
    let sum = read_u64(r)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    if fnv1a64(&payload) != sum {
        bail!("protocol frame checksum mismatch ({len}-byte payload)");
    }
    let text = std::str::from_utf8(&payload)
        .map_err(|_| crate::anyhow!("protocol frame payload is not UTF-8"))?;
    let doc = Json::parse(text)
        .map_err(|e| crate::anyhow!("protocol frame payload is not valid JSON: {e}"))?;
    match doc.get("v").and_then(Json::as_f64) {
        Some(v) if v == f64::from(PROTOCOL_VERSION) => Ok(doc),
        Some(v) => bail!(
            "protocol version mismatch: peer speaks v{v}, this build speaks v{PROTOCOL_VERSION}"
        ),
        None => bail!("protocol frame is missing its \"v\" version field"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(doc: &Json) -> Json {
        let mut buf = Vec::new();
        write_frame(&mut buf, doc).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        read_frame(&mut cur).unwrap().expect("one frame present")
    }

    #[test]
    fn frames_roundtrip_bit_exactly() {
        let mut fields = message("query");
        fields.push(("workload".into(), Json::Str("KMeans".into())));
        fields.push(("cpi".into(), Json::Num(1.0 / 3.0)));
        let doc = Json::Obj(fields);
        let back = roundtrip(&doc);
        assert_eq!(back, doc);
        let cpi = back.get("cpi").unwrap().as_f64().unwrap();
        assert_eq!(cpi.to_bits(), (1.0f64 / 3.0).to_bits(), "f64 must survive the wire exactly");
    }

    #[test]
    fn clean_eof_is_none_torn_frame_is_error() {
        let mut empty = std::io::Cursor::new(Vec::new());
        assert!(read_frame(&mut empty).unwrap().is_none());

        let mut buf = Vec::new();
        write_frame(&mut buf, &Json::Obj(message("ping"))).unwrap();
        buf.truncate(buf.len() - 3);
        let mut cur = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cur).is_err(), "torn frame must not read as EOF");
    }

    #[test]
    fn corruption_and_desync_are_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Json::Obj(message("ping"))).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x40;
        let mut cur = std::io::Cursor::new(buf.clone());
        let err = read_frame(&mut cur).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");

        buf[0] = 0x00;
        let mut cur = std::io::Cursor::new(buf);
        let err = read_frame(&mut cur).unwrap_err().to_string();
        assert!(err.contains("desync"), "{err}");
    }

    #[test]
    fn version_mismatch_is_a_typed_refusal() {
        let doc = Json::Obj(vec![
            ("v".to_string(), Json::Num(99.0)),
            ("op".to_string(), Json::Str("ping".to_string())),
        ]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &doc).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        let err = read_frame(&mut cur).unwrap_err().to_string();
        assert!(err.contains("version mismatch"), "{err}");
        assert!(err.contains("v99"), "{err}");

        let unversioned = Json::Obj(vec![("op".to_string(), Json::Str("ping".to_string()))]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &unversioned).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        let err = read_frame(&mut cur).unwrap_err().to_string();
        assert!(err.contains("version field"), "{err}");
    }

    #[test]
    fn oversized_frames_are_refused_on_both_sides() {
        let big = Json::Str("x".repeat(MAX_FRAME + 1));
        let mut buf = Vec::new();
        assert!(write_frame(&mut buf, &big).is_err());

        // hand-build a header claiming an absurd length
        let mut forged = vec![FRAME_MARKER];
        forged.extend_from_slice(&(u32::MAX).to_le_bytes());
        forged.extend_from_slice(&0u64.to_le_bytes());
        let mut cur = std::io::Cursor::new(forged);
        let err = read_frame(&mut cur).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }
}
