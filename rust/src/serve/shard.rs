//! Fingerprint-sharded ledger directory for the serve daemon.
//!
//! One monolithic `.mllg` file serializes every append behind a single
//! lock and makes compaction a stop-the-world rewrite. The daemon
//! instead keeps `N` independent [`Ledger`] shards in one directory
//! (`shard-00.mllg` … `shard-NN.mllg`), routing each record by
//! `fingerprint.hash % N`:
//!
//! - **Concurrency** — appends to different shards proceed in parallel
//!   (one mutex per shard, not per store).
//! - **Crash safety for free** — every shard is a full PR 4/8 ledger:
//!   checksummed frames, torn-tail truncation on open, temp+fsync+rename
//!   compaction. A kill mid-append tears at most one shard's tail; every
//!   other shard recovers untouched.
//! - **Parallel compaction** — shards compact independently, one thread
//!   per shard.
//!
//! The shard count is fixed at directory creation: on reopen the files
//! on disk win over the requested count (a restart with a different
//! `--shards` flag must not orphan records by re-routing fingerprints).

use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

use crate::ledger::{CompactionReport, Fingerprint, Ledger, LedgerRecord, LedgerStats};
use crate::util::error::{Context, Result};

/// Default shard count for a fresh serve directory: enough to keep a
/// handful of concurrent appenders out of each other's way without
/// scattering a small grid across dozens of files.
pub const DEFAULT_SHARDS: usize = 4;

/// A directory of independently locked, independently recoverable
/// ledger shards.
pub struct ShardedLedger {
    dir: PathBuf,
    shards: Vec<Mutex<Ledger>>,
}

impl ShardedLedger {
    /// Open (or create) the shard directory. `requested` is honored only
    /// when the directory holds no shards yet; existing shard files fix
    /// the count permanently (see the module docs).
    pub fn open(dir: &Path, requested: usize) -> Result<ShardedLedger> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating serve ledger directory {}", dir.display()))?;
        let existing = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .filter(|e| {
                let name = e.file_name();
                let name = name.to_string_lossy();
                name.starts_with("shard-") && name.ends_with(".mllg")
            })
            .count();
        let n = if existing > 0 { existing } else { requested.max(1) };
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let path = dir.join(format!("shard-{i:02}.mllg"));
            let ledger = Ledger::open(&path)
                .with_context(|| format!("opening ledger shard {}", path.display()))?;
            shards.push(Mutex::new(ledger));
        }
        Ok(ShardedLedger { dir: dir.to_path_buf(), shards })
    }

    /// The directory holding the shards.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of shards (fixed for the directory's lifetime).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// fsync every shard append when `durable` is set.
    pub fn set_durable(&self, durable: bool) {
        for shard in &self.shards {
            lock(shard).set_durable(durable);
        }
    }

    fn shard_of(&self, fp: &Fingerprint) -> usize {
        (fp.hash % self.shards.len() as u64) as usize
    }

    /// Latest record for `fp`, if any shard holds one.
    pub fn get(&self, fp: &Fingerprint) -> Option<LedgerRecord> {
        lock(&self.shards[self.shard_of(fp)]).get(fp).cloned()
    }

    /// Append `rec` to its fingerprint's shard.
    pub fn append(&self, rec: LedgerRecord) -> Result<()> {
        lock(&self.shards[self.shard_of(&rec.fingerprint)]).append(rec)
    }

    /// Per-shard stats, in shard order.
    pub fn stats(&self) -> Vec<LedgerStats> {
        self.shards.iter().map(|s| lock(s).stats()).collect()
    }

    /// Unique fingerprints across all shards (shards never overlap, so
    /// the per-shard uniques simply add up).
    pub fn total_unique(&self) -> usize {
        self.stats().iter().map(|s| s.unique).sum()
    }

    /// Total records (including superseded duplicates) across shards.
    pub fn total_records(&self) -> usize {
        self.stats().iter().map(|s| s.records).sum()
    }

    /// Compact every shard, one thread per shard. Each compaction is
    /// individually crash-atomic (temp + fsync + rename), so a kill mid
    /// way leaves every shard either compacted or byte-intact.
    pub fn compact_all(&self) -> Result<Vec<CompactionReport>> {
        let results: Vec<Result<CompactionReport>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter()
                .map(|shard| scope.spawn(move || lock(shard).compact()))
                .collect();
            handles.into_iter().map(|h| h.join().expect("compaction thread panicked")).collect()
        });
        let mut reports = Vec::with_capacity(results.len());
        for (i, r) in results.into_iter().enumerate() {
            reports.push(r.with_context(|| format!("compacting shard {i:02}"))?);
        }
        Ok(reports)
    }
}

fn lock(m: &Mutex<Ledger>) -> MutexGuard<'_, Ledger> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ledger::Provenance;
    use crate::sim::Metrics;

    fn record(tag: u64) -> LedgerRecord {
        let metrics = Metrics {
            cpi: 1.0 + tag as f64 * 0.25,
            instructions: tag * 1000,
            ..Metrics::default()
        };
        LedgerRecord {
            fingerprint: Fingerprint { version: 1, hash: tag.wrapping_mul(0x9E37_79B9_7F4A_7C15) },
            provenance: Provenance {
                workload: format!("W{tag}"),
                scenario: "baseline".into(),
                profile: "Sklearn".into(),
                rows: 64,
                features: 4,
                iterations: 1,
                seed: tag,
                dataset_bytes: 2048,
                wall_nanos: 10,
                unix_secs: 0,
            },
            metrics,
            quality: Some(tag as f64),
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mlperf-shard-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn records_route_by_fingerprint_and_read_back_bit_exactly() {
        let dir = tmpdir("route");
        let store = ShardedLedger::open(&dir, 4).unwrap();
        assert_eq!(store.shard_count(), 4);
        let records: Vec<LedgerRecord> = (0..16).map(record).collect();
        for r in &records {
            store.append(r.clone()).unwrap();
        }
        assert_eq!(store.total_unique(), 16);
        // every shard holds exactly the fingerprints that hash to it
        for r in &records {
            let got = store.get(&r.fingerprint).expect("record present");
            assert_eq!(got.fingerprint, r.fingerprint);
            assert_eq!(got.metrics.cpi.to_bits(), r.metrics.cpi.to_bits());
            assert_eq!(got.quality, r.quality);
        }
        // 16 mixed hashes should touch more than one shard
        let populated = store.stats().iter().filter(|s| s.records > 0).count();
        assert!(populated > 1, "all records landed in one shard");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_preserves_shard_count_over_requested() {
        let dir = tmpdir("reopen");
        {
            let store = ShardedLedger::open(&dir, 3).unwrap();
            for i in 0..8 {
                store.append(record(i)).unwrap();
            }
        }
        // a restart asking for a different count must keep the 3 on disk
        let store = ShardedLedger::open(&dir, 8).unwrap();
        assert_eq!(store.shard_count(), 3, "files on disk fix the shard count");
        assert_eq!(store.total_unique(), 8, "every record survives the reopen");
        for i in 0..8 {
            assert!(store.get(&record(i).fingerprint).is_some());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parallel_compaction_drops_superseded_records_in_every_shard() {
        let dir = tmpdir("compact");
        let store = ShardedLedger::open(&dir, 2).unwrap();
        for i in 0..6 {
            store.append(record(i)).unwrap();
            store.append(record(i)).unwrap(); // superseding duplicate
        }
        assert_eq!(store.total_records(), 12);
        assert_eq!(store.total_unique(), 6);
        let reports = store.compact_all().unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports.iter().map(|r| r.records_before).sum::<usize>(), 12);
        assert_eq!(reports.iter().map(|r| r.records_after).sum::<usize>(), 6);
        // compacted shards still answer every fingerprint
        for i in 0..6 {
            assert!(store.get(&record(i).fingerprint).is_some(), "record {i} lost");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_shard_tail_recovers_without_touching_peers() {
        let dir = tmpdir("torn");
        let (damaged_path, survivors) = {
            let store = ShardedLedger::open(&dir, 2).unwrap();
            let records: Vec<LedgerRecord> = (0..8).map(record).collect();
            for r in &records {
                store.append(r.clone()).unwrap();
            }
            let idx = store.shard_of(&records[0].fingerprint);
            (dir.join(format!("shard-{idx:02}.mllg")), records)
        };
        // tear the tail of one shard
        let bytes = std::fs::read(&damaged_path).unwrap();
        std::fs::write(&damaged_path, &bytes[..bytes.len() - 5]).unwrap();

        let store = ShardedLedger::open(&dir, 2).unwrap();
        let stats = store.stats();
        assert_eq!(stats.iter().filter(|s| s.recovered_tail_bytes > 0).count(), 1);
        // exactly one record (the torn tail) is gone; the rest answer
        let answered =
            survivors.iter().filter(|r| store.get(&r.fingerprint).is_some()).count();
        assert_eq!(answered, survivors.len() - 1, "only the torn record may be lost");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
