//! Branch predictor model: gshare two-level adaptive predictor.
//!
//! The paper attributes the tree-based workloads' large bad-speculation
//! bound to data-dependent conditional branches that defeat the branch
//! predictor (Figs. 3–6). A gshare predictor reproduces exactly that
//! behaviour: loop branches and structured control are near-perfect, while
//! branches on effectively-random data (tree split comparisons, distance
//! threshold tests on shuffled samples) converge to ~50% mispredicts.

/// gshare predictor: global history register XOR branch site indexes a
/// table of 2-bit saturating counters.
pub struct Gshare {
    history: u64,
    history_bits: u32,
    counters: Vec<u8>,
}

/// Statistics over predicted branches.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct BranchStats {
    pub conditional: u64,
    pub unconditional: u64,
    pub mispredicts: u64,
}

impl BranchStats {
    /// Misprediction ratio over conditional branches (Fig. 4).
    pub fn mispredict_ratio(&self) -> f64 {
        if self.conditional == 0 {
            0.0
        } else {
            self.mispredicts as f64 / self.conditional as f64
        }
    }
}

impl Gshare {
    /// Predictor with a `2^table_bits`-entry pattern history table.
    pub fn new(table_bits: u32, history_bits: u32) -> Self {
        assert!(table_bits <= 24 && history_bits <= 32);
        Self {
            history: 0,
            history_bits,
            // weakly not-taken initial state
            counters: vec![1u8; 1usize << table_bits],
        }
    }

    /// Default configuration: 64K-entry PHT, 14-bit history — in the class
    /// of the mid-2010s cores the simulator models.
    pub fn default_config() -> Self {
        Self::new(16, 14)
    }

    #[inline]
    fn index(&self, site: u32) -> usize {
        let mask = self.counters.len() - 1;
        ((site as u64 ^ (self.history & ((1 << self.history_bits) - 1))) as usize) & mask
    }

    /// Predict and update for a conditional branch at `site` with actual
    /// outcome `taken`; returns whether the prediction was correct.
    pub fn predict_update(&mut self, site: u32, taken: bool) -> bool {
        let idx = self.index(site);
        let pred = self.counters[idx] >= 2;
        // 2-bit saturating counter update
        if taken {
            if self.counters[idx] < 3 {
                self.counters[idx] += 1;
            }
        } else if self.counters[idx] > 0 {
            self.counters[idx] -= 1;
        }
        self.history = (self.history << 1) | taken as u64;
        pred == taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn run(seq: impl Iterator<Item = (u32, bool)>) -> BranchStats {
        let mut g = Gshare::default_config();
        let mut st = BranchStats::default();
        for (site, taken) in seq {
            st.conditional += 1;
            if !g.predict_update(site, taken) {
                st.mispredicts += 1;
            }
        }
        st
    }

    #[test]
    fn always_taken_converges() {
        let st = run((0..10_000).map(|_| (42u32, true)));
        assert!(st.mispredict_ratio() < 0.01, "{}", st.mispredict_ratio());
    }

    #[test]
    fn loop_exit_pattern_well_predicted() {
        // 99 taken then 1 not-taken, repeated: classic loop branch.
        let seq = (0..50_000).map(|i| (7u32, i % 100 != 99));
        let st = run(seq);
        assert!(st.mispredict_ratio() < 0.05, "{}", st.mispredict_ratio());
    }

    #[test]
    fn alternating_pattern_learned_via_history() {
        let seq = (0..20_000).map(|i| (9u32, i % 2 == 0));
        let st = run(seq);
        assert!(st.mispredict_ratio() < 0.02, "{}", st.mispredict_ratio());
    }

    #[test]
    fn random_branches_near_half() {
        let mut rng = Pcg64::new(1);
        let outcomes: Vec<(u32, bool)> =
            (0..100_000).map(|_| (13u32, rng.next_f64() < 0.5)).collect();
        let st = run(outcomes.into_iter());
        let r = st.mispredict_ratio();
        assert!((0.4..0.6).contains(&r), "expected ~0.5, got {r}");
    }

    #[test]
    fn biased_random_better_than_half() {
        // 90% taken random branch: predictor should mispredict ~<=20%.
        let mut rng = Pcg64::new(2);
        let outcomes: Vec<(u32, bool)> =
            (0..100_000).map(|_| (5u32, rng.next_f64() < 0.9)).collect();
        let st = run(outcomes.into_iter());
        let r = st.mispredict_ratio();
        assert!(r < 0.25, "got {r}");
        assert!(r > 0.02, "suspiciously perfect on random data: {r}");
    }

    #[test]
    fn distinct_sites_do_not_destructively_alias_much() {
        // two sites with opposite fixed outcomes must both be learnable
        let seq = (0..20_000).flat_map(|_| [(1u32, true), (2u32, false)]);
        let st = run(seq);
        assert!(st.mispredict_ratio() < 0.05, "{}", st.mispredict_ratio());
    }
}
