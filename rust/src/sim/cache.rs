//! Set-associative, write-back, write-allocate cache hierarchy with LRU
//! replacement — the Sniper-equivalent substrate for the paper's cache
//! studies (Table V configuration, Fig. 12 perfect-cache experiments,
//! Figs. 13–15 prefetching experiments).

use super::prefetch::{AdjacentLinePrefetcher, PrefetchStats, StreamPrefetcher};
use crate::trace::{line_of, LINE_SIZE};

/// Which level served a demand access.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    L1,
    L2,
    L3,
    Dram,
}

impl Level {
    /// Load-to-use latency in CPU cycles (typical client-core values).
    pub fn latency_cycles(self) -> f64 {
        match self {
            Level::L1 => 4.0,
            Level::L2 => 14.0,
            Level::L3 => 42.0,
            Level::Dram => 220.0,
        }
    }
}

// Per-line metadata bits.
const VALID: u8 = 1;
const DIRTY: u8 = 2;
/// Filled by hardware prefetch, not yet demanded.
const HW_PF: u8 = 4;
/// Filled by software prefetch, not yet demanded.
const SW_PF: u8 = 8;

/// Per-cache counters.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct CacheStats {
    /// Demand accesses (loads + stores), excluding prefetch fills.
    pub accesses: u64,
    /// Demand misses.
    pub misses: u64,
    /// Lines written back dirty on eviction.
    pub writebacks: u64,
}

impl CacheStats {
    /// Demand miss ratio.
    pub fn miss_ratio(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }
}

/// One set-associative cache level.
pub struct Cache {
    sets: usize,
    ways: usize,
    tags: Vec<u64>,
    meta: Vec<u8>,
    lru: Vec<u64>,
    stamp: u64,
    /// Perfect mode: every demand access hits (Fig. 12 idealization).
    pub perfect: bool,
    pub stats: CacheStats,
}

/// Result of an eviction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Evicted {
    pub line: u64,
    pub dirty: bool,
    /// Evicted while still carrying an untouched HW/SW prefetch bit.
    pub untouched_hw_pf: bool,
    pub untouched_sw_pf: bool,
}

impl Cache {
    /// Cache of `size_bytes` with `ways`-way associativity, 64-byte lines.
    pub fn new(size_bytes: u64, ways: usize) -> Self {
        let lines = (size_bytes / LINE_SIZE) as usize;
        assert!(lines % ways == 0, "size/ways mismatch");
        let sets = lines / ways;
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        Self {
            sets,
            ways,
            tags: vec![0; lines],
            meta: vec![0; lines],
            lru: vec![0; lines],
            stamp: 0,
            perfect: false,
            stats: CacheStats::default(),
        }
    }

    #[inline]
    fn set_of(&self, line: u64) -> usize {
        (line as usize) & (self.sets - 1)
    }

    #[inline]
    fn slot_range(&self, set: usize) -> std::ops::Range<usize> {
        set * self.ways..(set + 1) * self.ways
    }

    /// Probe for a line on behalf of a demand access. On hit, updates LRU,
    /// clears prefetch bits (the prefetch proved useful) and returns which
    /// prefetch kind (if any) had filled it.
    /// Returns `(hit, was_hw_pf, was_sw_pf)`.
    pub fn demand_probe(&mut self, line: u64, store: bool) -> (bool, bool, bool) {
        self.stats.accesses += 1;
        self.stamp += 1;
        if self.perfect {
            return (true, false, false);
        }
        let set = self.set_of(line);
        for i in self.slot_range(set) {
            if self.meta[i] & VALID != 0 && self.tags[i] == line {
                self.lru[i] = self.stamp;
                let was_hw = self.meta[i] & HW_PF != 0;
                let was_sw = self.meta[i] & SW_PF != 0;
                self.meta[i] &= !(HW_PF | SW_PF);
                if store {
                    self.meta[i] |= DIRTY;
                }
                return (true, was_hw, was_sw);
            }
        }
        self.stats.misses += 1;
        (false, false, false)
    }

    /// Probe without demand-access accounting (used by prefetch filtering:
    /// don't re-fetch a line that's already resident). Does not touch LRU.
    pub fn contains(&self, line: u64) -> bool {
        if self.perfect {
            return true;
        }
        let set = self.set_of(line);
        self.slot_range(set)
            .any(|i| self.meta[i] & VALID != 0 && self.tags[i] == line)
    }

    /// Insert a line (demand fill or prefetch fill), evicting LRU if
    /// needed. `pf` bits mark prefetch fills for usefulness accounting.
    pub fn fill(&mut self, line: u64, store: bool, hw_pf: bool, sw_pf: bool) -> Option<Evicted> {
        if self.perfect {
            return None;
        }
        self.stamp += 1;
        let set = self.set_of(line);
        // single pass: find an existing copy (a demand fill can race a
        // prefetch) while simultaneously tracking the victim slot
        // (§Perf: fill was 30% of simulator time when it scanned twice)
        let mut victim = set * self.ways;
        let mut best = u64::MAX;
        for i in self.slot_range(set) {
            if self.meta[i] & VALID == 0 {
                if best != 0 {
                    victim = i;
                    best = 0;
                }
                continue;
            }
            if self.tags[i] == line {
                self.lru[i] = self.stamp;
                if store {
                    self.meta[i] |= DIRTY;
                }
                return None;
            }
            if self.lru[i] < best {
                best = self.lru[i];
                victim = i;
            }
        }
        let evicted = if self.meta[victim] & VALID != 0 {
            let dirty = self.meta[victim] & DIRTY != 0;
            if dirty {
                self.stats.writebacks += 1;
            }
            Some(Evicted {
                line: self.tags[victim],
                dirty,
                untouched_hw_pf: self.meta[victim] & HW_PF != 0,
                untouched_sw_pf: self.meta[victim] & SW_PF != 0,
            })
        } else {
            None
        };
        self.tags[victim] = line;
        self.lru[victim] = self.stamp;
        self.meta[victim] = VALID
            | if store { DIRTY } else { 0 }
            | if hw_pf { HW_PF } else { 0 }
            | if sw_pf { SW_PF } else { 0 };
        evicted
    }

    /// Invalidate a line if present (back-invalidation for inclusivity).
    pub fn invalidate(&mut self, line: u64) {
        let set = self.set_of(line);
        for i in self.slot_range(set) {
            if self.meta[i] & VALID != 0 && self.tags[i] == line {
                self.meta[i] = 0;
            }
        }
    }
}

/// Configuration of the three-level hierarchy (defaults = paper Table V).
#[derive(Debug, Clone, Copy)]
pub struct HierarchyConfig {
    pub l1_bytes: u64,
    pub l1_ways: usize,
    pub l2_bytes: u64,
    pub l2_ways: usize,
    pub l3_bytes: u64,
    pub l3_ways: usize,
    /// Hardware prefetchers enabled (paper: on by default).
    pub hw_prefetch: bool,
    /// Idealizations for Fig. 12.
    pub perfect_l2: bool,
    pub perfect_llc: bool,
}

impl Default for HierarchyConfig {
    fn default() -> Self {
        Self {
            l1_bytes: 32 * 1024,
            l1_ways: 8,
            l2_bytes: 256 * 1024,
            l2_ways: 8,
            l3_bytes: 8 * 1024 * 1024,
            l3_ways: 16,
            hw_prefetch: true,
            perfect_l2: false,
            perfect_llc: false,
        }
    }
}

/// A DRAM-bound request produced by the hierarchy (demand miss fill,
/// prefetch fill, or dirty writeback).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DramRequest {
    pub line_addr: u64,
    pub is_write: bool,
    pub is_prefetch: bool,
}

/// Three-level inclusive hierarchy with integrated prefetchers.
pub struct Hierarchy {
    pub l1: Cache,
    pub l2: Cache,
    pub l3: Cache,
    streamer: StreamPrefetcher,
    hw_prefetch: bool,
    pf_scratch: Vec<u64>,
    pub pf_stats: PrefetchStats,
}

impl Hierarchy {
    pub fn new(cfg: &HierarchyConfig) -> Self {
        let mut l2 = Cache::new(cfg.l2_bytes, cfg.l2_ways);
        l2.perfect = cfg.perfect_l2;
        let mut l3 = Cache::new(cfg.l3_bytes, cfg.l3_ways);
        l3.perfect = cfg.perfect_llc;
        Self {
            l1: Cache::new(cfg.l1_bytes, cfg.l1_ways),
            l2,
            l3,
            streamer: StreamPrefetcher::default_config(),
            hw_prefetch: cfg.hw_prefetch,
            pf_scratch: Vec::with_capacity(8),
            pf_stats: PrefetchStats::default(),
        }
    }

    /// Process a demand access of `size` bytes at `addr`. Each touched
    /// cache line is looked up through the hierarchy; DRAM-reaching
    /// traffic is appended to `dram`. Returns the *slowest* level that
    /// served any of the lines (that is what a dependent consumer waits
    /// for) and the number of lines that reached DRAM.
    pub fn access(
        &mut self,
        addr: u64,
        size: u32,
        store: bool,
        dram: &mut Vec<DramRequest>,
    ) -> (Level, u32) {
        let first = line_of(addr);
        let last = line_of(addr + size.max(1) as u64 - 1);
        let mut worst = Level::L1;
        let mut dram_lines = 0;
        for line in first..=last {
            let lvl = self.access_line(line, store, dram);
            if lvl > worst {
                worst = lvl;
            }
            if lvl == Level::Dram {
                dram_lines += 1;
            }
        }
        (worst, dram_lines)
    }

    fn access_line(&mut self, line: u64, store: bool, dram: &mut Vec<DramRequest>) -> Level {
        // L1
        let (hit1, _, _) = self.l1.demand_probe(line, store);
        if hit1 {
            return Level::L1;
        }
        // L2
        let (hit2, was_hw, was_sw) = self.l2.demand_probe(line, store);
        if was_hw {
            self.pf_stats.hw_useful += 1;
        }
        if was_sw {
            self.pf_stats.sw_useful += 1;
        }
        if hit2 {
            self.fill_l1(line, store, dram);
            self.train_streamer(line, dram);
            return Level::L2;
        }
        // L3
        let (hit3, was_hw3, was_sw3) = self.l3.demand_probe(line, store);
        if was_hw3 {
            self.pf_stats.hw_useful += 1;
        }
        if was_sw3 {
            self.pf_stats.sw_useful += 1;
        }
        let served = if hit3 {
            Level::L3
        } else {
            dram.push(DramRequest { line_addr: line * LINE_SIZE, is_write: false, is_prefetch: false });
            Level::Dram
        };
        // Fill path (inclusive): L3 (if missed), L2, L1.
        if !hit3 {
            self.fill_l3(line, dram);
        }
        self.fill_l2(line, store, false, false, dram);
        self.fill_l1(line, store, dram);
        // Prefetchers train on L2 misses.
        if self.hw_prefetch {
            // adjacent-line
            let buddy = line_of(AdjacentLinePrefetcher::buddy(line * LINE_SIZE));
            self.issue_hw_prefetch(buddy, dram);
            self.train_streamer(line, dram);
        }
        served
    }

    fn train_streamer(&mut self, line: u64, dram: &mut Vec<DramRequest>) {
        if !self.hw_prefetch {
            return;
        }
        self.pf_scratch.clear();
        let mut scratch = std::mem::take(&mut self.pf_scratch);
        self.streamer.observe(line * LINE_SIZE, &mut scratch);
        for i in 0..scratch.len() {
            self.issue_hw_prefetch(line_of(scratch[i]), dram);
        }
        scratch.clear();
        self.pf_scratch = scratch;
    }

    fn issue_hw_prefetch(&mut self, line: u64, dram: &mut Vec<DramRequest>) {
        if self.l2.contains(line) || self.l1.contains(line) {
            return; // already resident — filtered, not "issued"
        }
        self.pf_stats.hw_issued += 1;
        // data comes from L3 or DRAM
        if !self.l3.contains(line) {
            dram.push(DramRequest { line_addr: line * LINE_SIZE, is_write: false, is_prefetch: true });
            self.fill_l3(line, dram);
        }
        self.fill_l2(line, false, true, false, dram);
    }

    /// Software prefetch into L2 (the paper targets L2; Section V-C).
    pub fn sw_prefetch(&mut self, addr: u64, dram: &mut Vec<DramRequest>) {
        let line = line_of(addr);
        if self.l1.contains(line) || self.l2.contains(line) {
            return;
        }
        self.pf_stats.sw_issued += 1;
        if !self.l3.contains(line) {
            dram.push(DramRequest { line_addr: line * LINE_SIZE, is_write: false, is_prefetch: true });
            self.fill_l3(line, dram);
        }
        self.fill_l2(line, false, false, true, dram);
    }

    fn fill_l1(&mut self, line: u64, store: bool, dram: &mut Vec<DramRequest>) {
        if let Some(ev) = self.l1.fill(line, store, false, false) {
            if ev.dirty {
                // write back into L2
                self.l2.fill(ev.line, true, false, false).map(|e2| self.handle_l2_evict(e2, dram));
            }
        }
    }

    fn fill_l2(&mut self, line: u64, store: bool, hw: bool, sw: bool, dram: &mut Vec<DramRequest>) {
        if let Some(ev) = self.l2.fill(line, store, hw, sw) {
            self.handle_l2_evict(ev, dram);
        }
    }

    fn handle_l2_evict(&mut self, ev: Evicted, dram: &mut Vec<DramRequest>) {
        if ev.untouched_hw_pf {
            self.pf_stats.hw_useless += 1;
        }
        if ev.untouched_sw_pf {
            self.pf_stats.sw_useless += 1;
        }
        if ev.dirty {
            // write back into L3 (already inclusive, so it's present)
            self.l3.fill(ev.line, true, false, false).map(|e3| {
                if e3.dirty {
                    dram.push(DramRequest {
                        line_addr: e3.line * LINE_SIZE,
                        is_write: true,
                        is_prefetch: false,
                    });
                }
                self.back_invalidate(e3.line);
            });
        }
    }

    fn fill_l3(&mut self, line: u64, dram: &mut Vec<DramRequest>) {
        if let Some(ev) = self.l3.fill(line, false, false, false) {
            if ev.dirty {
                dram.push(DramRequest {
                    line_addr: ev.line * LINE_SIZE,
                    is_write: true,
                    is_prefetch: false,
                });
            }
            // inclusive hierarchy: evicting from L3 invalidates below
            self.back_invalidate(ev.line);
        }
    }

    fn back_invalidate(&mut self, line: u64) {
        self.l1.invalidate(line);
        self.l2.invalidate(line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_hierarchy() -> Hierarchy {
        Hierarchy::new(&HierarchyConfig {
            l1_bytes: 1024,
            l1_ways: 2,
            l2_bytes: 4096,
            l2_ways: 4,
            l3_bytes: 16384,
            l3_ways: 4,
            hw_prefetch: false,
            perfect_l2: false,
            perfect_llc: false,
        })
    }

    #[test]
    fn first_touch_misses_everywhere_then_hits_l1() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        let (lvl, n) = h.access(0x10000, 8, false, &mut dram);
        assert_eq!(lvl, Level::Dram);
        assert_eq!(n, 1);
        assert_eq!(dram.len(), 1);
        let (lvl2, _) = h.access(0x10000, 8, false, &mut dram);
        assert_eq!(lvl2, Level::L1);
        assert_eq!(dram.len(), 1, "no extra dram traffic on a hit");
    }

    #[test]
    fn multi_line_access_touches_each_line() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        // 160-byte row starting at a line boundary spans 3 lines
        let (lvl, n) = h.access(0x20000, 160, false, &mut dram);
        assert_eq!(lvl, Level::Dram);
        assert_eq!(n, 3);
        assert_eq!(h.l1.stats.accesses, 3);
    }

    #[test]
    fn lru_eviction_in_l1_still_hits_l2() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        // L1 = 1KB/2-way/64B = 8 sets; fill one set (2 ways) then a third
        // conflicting line evicts the first.
        let set_stride = 8 * 64; // lines mapping to same set
        for k in 0..3u64 {
            h.access(0x40000 + k * set_stride, 8, false, &mut dram);
        }
        // line 0 evicted from L1, but resident in L2
        let (lvl, _) = h.access(0x40000, 8, false, &mut dram);
        assert_eq!(lvl, Level::L2);
    }

    #[test]
    fn perfect_llc_never_reaches_dram() {
        let mut cfg = HierarchyConfig { hw_prefetch: false, ..Default::default() };
        cfg.perfect_llc = true;
        let mut h = Hierarchy::new(&cfg);
        let mut dram = Vec::new();
        let mut rng = crate::util::Pcg64::new(4);
        for _ in 0..10_000 {
            let addr = rng.below(1 << 30);
            let (lvl, _) = h.access(addr, 8, false, &mut dram);
            assert!(lvl <= Level::L3);
        }
        assert!(dram.is_empty());
    }

    #[test]
    fn perfect_l2_hits_at_l2() {
        let cfg = HierarchyConfig {
            hw_prefetch: false,
            perfect_l2: true,
            ..Default::default()
        };
        let mut h = Hierarchy::new(&cfg);
        let mut dram = Vec::new();
        let (lvl, _) = h.access(0x123456, 8, false, &mut dram);
        assert_eq!(lvl, Level::L2);
    }

    #[test]
    fn dirty_eviction_writes_back() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        // store to many distinct lines to force L3 evictions of dirty data
        for k in 0..2000u64 {
            h.access(k * 64, 8, true, &mut dram);
        }
        assert!(
            dram.iter().any(|r| r.is_write),
            "expected dirty writebacks to DRAM"
        );
    }

    #[test]
    fn sw_prefetch_turns_miss_into_l2_hit() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        h.sw_prefetch(0x80000, &mut dram);
        assert_eq!(h.pf_stats.sw_issued, 1);
        let (lvl, _) = h.access(0x80000, 8, false, &mut dram);
        assert_eq!(lvl, Level::L2);
        assert_eq!(h.pf_stats.sw_useful, 1);
    }

    #[test]
    fn sw_prefetch_of_resident_line_is_filtered() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        h.access(0x90000, 8, false, &mut dram);
        h.sw_prefetch(0x90000, &mut dram);
        assert_eq!(h.pf_stats.sw_issued, 0);
    }

    #[test]
    fn hw_prefetch_useful_on_streaming() {
        let cfg = HierarchyConfig::default();
        let mut h = Hierarchy::new(&cfg);
        let mut dram = Vec::new();
        for line in 0..4000u64 {
            h.access(line * 64, 8, false, &mut dram);
        }
        assert!(h.pf_stats.hw_issued > 100);
        let f = h.pf_stats.hw_useless_fraction();
        assert!(f < 0.2, "streaming should make prefetches useful: {f}");
        // and the L2 miss ratio should be well below 1.0
        assert!(h.l2.stats.miss_ratio() < 0.7);
    }

    #[test]
    fn hw_prefetch_useless_on_random() {
        let cfg = HierarchyConfig::default();
        let mut h = Hierarchy::new(&cfg);
        let mut dram = Vec::new();
        let mut rng = crate::util::Pcg64::new(5);
        for _ in 0..200_000 {
            // random 8-byte reads over 1 GiB
            let addr = rng.below(1 << 30) & !7;
            h.access(addr, 8, false, &mut dram);
        }
        let f = h.pf_stats.hw_useless_fraction();
        assert!(f > 0.3, "random stream should waste prefetches: {f}");
    }

    #[test]
    fn inclusive_l3_eviction_invalidates_l1() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        h.access(0x0, 8, false, &mut dram);
        // thrash L3 (16KB/4-way/64B = 64 sets): fill set 0's ways
        for k in 1..=4u64 {
            h.access(k * 64 * 64 * 4, 8, false, &mut dram); // wait: map to set 0 of l3
        }
        // construct lines that alias L3 set of 0x0: set = line % 64
        let mut victims = 0;
        for k in 1..=8u64 {
            let addr = k * 64 * 64; // line multiple of 64 -> set 0
            h.access(addr, 8, false, &mut dram);
            victims += 1;
        }
        assert!(victims > 4);
        // 0x0 must have been back-invalidated from L1 at some point;
        // accessing it again must not be an L1 hit-after-L3-eviction bug.
        let before_misses = h.l1.stats.misses;
        h.access(0x0, 8, false, &mut dram);
        assert!(h.l1.stats.misses > before_misses, "stale L1 line survived L3 eviction");
    }

    #[test]
    fn cache_stats_miss_ratio() {
        let mut c = Cache::new(1024, 2);
        assert_eq!(c.stats.miss_ratio(), 0.0);
        c.demand_probe(1, false);
        c.fill(1, false, false, false);
        c.demand_probe(1, false);
        assert_eq!(c.stats.accesses, 2);
        assert_eq!(c.stats.misses, 1);
        assert_eq!(c.stats.miss_ratio(), 0.5);
    }
}
