//! Set-associative, write-back, write-allocate cache hierarchy with LRU
//! replacement — the Sniper-equivalent substrate for the paper's cache
//! studies (Table V configuration, Fig. 12 perfect-cache experiments,
//! Figs. 13–15 prefetching experiments).
//!
//! # Hot-path layout
//!
//! Every replayed event funnels through [`Hierarchy::access`], so the
//! probe applies the paper's own data-locality medicine to itself
//! (DESIGN.md "Simulator hot path"):
//!
//! - **Packed set layout** — each way is one `u64` word packing
//!   `tag << 4 | meta` (valid/dirty/prefetch bits in the low nibble), laid
//!   out set-major so a whole ≤8-way set occupies a single 64-byte cache
//!   line. A probe is one mask-and-compare per way instead of the seed's
//!   three parallel-`Vec` loads (`tags`/`meta`/`lru`).
//! - **Compact per-set ages** — LRU uses a `u32` age per way driven by a
//!   per-set tick counter instead of a global `u64` stamp; only relative
//!   order within a set matters, so victim choice is bit-identical to the
//!   seed (renormalized in place on the ~4-billionth touch of a set).
//! - **MRU way filter** — a per-set last-touched-way hint resolves the
//!   dominant repeated-hit case with a single compare, never entering the
//!   set scan. The hint is self-validating (the packed word is checked
//!   before use), so evictions and back-invalidations need no filter
//!   maintenance.
//!
//! The seed probe path survives verbatim as
//! [`RefCache`](super::reference::RefCache); `tests/hotpath_parity.rs`
//! proves the two produce bit-identical `Metrics` on randomized traces.

use super::prefetch::{AdjacentLinePrefetcher, PrefetchStats, StreamPrefetcher};
use crate::trace::{line_of, EventBlock, EventKind, LINE_SIZE};

/// Which level served a demand access.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    L1,
    L2,
    L3,
    Dram,
}

impl Level {
    /// Load-to-use latency in CPU cycles (typical client-core values).
    pub fn latency_cycles(self) -> f64 {
        match self {
            Level::L1 => 4.0,
            Level::L2 => 14.0,
            Level::L3 => 42.0,
            Level::Dram => 220.0,
        }
    }
}

// Per-line metadata bits (the low nibble of a packed set word).
const VALID: u64 = 1;
const DIRTY: u64 = 2;
/// Filled by hardware prefetch, not yet demanded.
const HW_PF: u64 = 4;
/// Filled by software prefetch, not yet demanded.
const SW_PF: u64 = 8;
/// Meta bits per packed word; the tag occupies the remaining 60.
const META_BITS: u32 = 4;
/// Mask keeping tag + VALID: one compare decides "valid and resident"
/// (DIRTY and the prefetch bits are don't-cares for a probe).
const TAG_VALID_MASK: u64 = !(DIRTY | HW_PF | SW_PF);

/// Per-cache counters.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct CacheStats {
    /// Demand accesses (loads + stores), excluding prefetch fills.
    pub accesses: u64,
    /// Demand misses.
    pub misses: u64,
    /// Lines written back dirty on eviction.
    pub writebacks: u64,
}

impl CacheStats {
    /// Demand miss ratio.
    pub fn miss_ratio(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }
}

/// Result of an eviction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Evicted {
    pub line: u64,
    pub dirty: bool,
    /// Evicted while still carrying an untouched HW/SW prefetch bit.
    pub untouched_hw_pf: bool,
    pub untouched_sw_pf: bool,
}

/// Interface one set-associative level exposes to the generic
/// [`Hierarchy`]. Two implementations exist: the packed hot-path
/// [`Cache`] (the default) and the seed-layout
/// [`RefCache`](super::reference::RefCache) retained as the bit-parity
/// reference and performance baseline.
pub trait CacheModel {
    /// Cache of `size_bytes` with `ways`-way associativity, 64-byte lines.
    fn new(size_bytes: u64, ways: usize) -> Self;

    /// Enable/disable perfect mode (every demand access hits; Fig. 12).
    fn set_perfect(&mut self, on: bool);

    /// Whether perfect mode is enabled.
    fn is_perfect(&self) -> bool;

    /// Demand counters.
    fn stats(&self) -> &CacheStats;

    /// Probe for a line on behalf of a demand access. On hit, updates
    /// LRU, clears prefetch bits (the prefetch proved useful) and returns
    /// which prefetch kind (if any) had filled it.
    /// Returns `(hit, was_hw_pf, was_sw_pf)`.
    fn demand_probe(&mut self, line: u64, store: bool) -> (bool, bool, bool);

    /// [`CacheModel::demand_probe`] under the caller's guarantee that the
    /// cache is not perfect — the hierarchy hoists that check out of the
    /// per-line path.
    #[inline]
    fn demand_probe_real(&mut self, line: u64, store: bool) -> (bool, bool, bool) {
        self.demand_probe(line, store)
    }

    /// Probe without demand-access accounting (used by prefetch
    /// filtering: don't re-fetch a resident line). Does not touch LRU.
    fn contains(&self, line: u64) -> bool;

    /// Insert a line (demand fill or prefetch fill), evicting LRU if
    /// needed. `pf` bits mark prefetch fills for usefulness accounting.
    fn fill(&mut self, line: u64, store: bool, hw_pf: bool, sw_pf: bool) -> Option<Evicted>;

    /// Invalidate a line if present (back-invalidation for inclusivity).
    fn invalidate(&mut self, line: u64);
}

/// One set-associative cache level in the packed hot-path layout (see the
/// module docs for the word format).
pub struct Cache {
    sets: usize,
    ways: usize,
    /// `log2(sets)` — the set-index bits dropped from each stored tag.
    set_shift: u32,
    /// Packed per-set layout: `ways` consecutive words per set, each
    /// `(line >> set_shift) << META_BITS | meta`. Word 0 means invalid.
    words: Vec<u64>,
    /// Per-way age; compared only within a set (LRU victim = smallest).
    ages: Vec<u32>,
    /// Per-set age tick, bumped once per LRU touch of the set.
    ticks: Vec<u32>,
    /// MRU way filter: last-touched way per set.
    mru: Vec<u32>,
    /// Perfect mode: every demand access hits (Fig. 12 idealization).
    perfect: bool,
    pub stats: CacheStats,
}

impl Cache {
    /// Cache of `size_bytes` with `ways`-way associativity, 64-byte lines.
    pub fn new(size_bytes: u64, ways: usize) -> Self {
        let lines = (size_bytes / LINE_SIZE) as usize;
        assert!(lines % ways == 0, "size/ways mismatch");
        let sets = lines / ways;
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        Self {
            sets,
            ways,
            set_shift: sets.trailing_zeros(),
            words: vec![0; lines],
            ages: vec![0; lines],
            ticks: vec![0; sets],
            mru: vec![0; sets],
            perfect: false,
            stats: CacheStats::default(),
        }
    }

    #[inline]
    fn set_of(&self, line: u64) -> usize {
        (line as usize) & (self.sets - 1)
    }

    /// Packed word a valid, resident `line` must match (modulo the
    /// DIRTY/prefetch don't-care bits).
    #[inline]
    fn probe_key(&self, line: u64) -> u64 {
        ((line >> self.set_shift) << META_BITS) | VALID
    }

    /// Line number stored in a packed word of `set`.
    #[inline]
    fn stored_line(&self, word: u64, set: usize) -> u64 {
        ((word >> META_BITS) << self.set_shift) | set as u64
    }

    /// Next LRU age for `set` (strictly increasing per set, so relative
    /// order matches the seed's global-stamp scheme exactly).
    #[inline]
    fn next_age(&mut self, set: usize) -> u32 {
        if self.ticks[set] == u32::MAX {
            self.renorm_ages(set);
        }
        self.ticks[set] += 1;
        self.ticks[set]
    }

    /// Compress a set's ages to `1..=ways` preserving relative order.
    /// Runs once every ~4 billion LRU touches of one set, so the probe
    /// can keep `u32` ages without ever reordering victims.
    #[cold]
    fn renorm_ages(&mut self, set: usize) {
        let base = set * self.ways;
        let mut order: Vec<usize> = (0..self.ways).collect();
        order.sort_by_key(|&w| self.ages[base + w]);
        for (rank, &w) in order.iter().enumerate() {
            // invalid ways get renumbered too — harmless, their ages are
            // never compared
            self.ages[base + w] = rank as u32 + 1;
        }
        self.ticks[set] = self.ways as u32;
    }

    /// See [`CacheModel::demand_probe`].
    pub fn demand_probe(&mut self, line: u64, store: bool) -> (bool, bool, bool) {
        self.stats.accesses += 1;
        if self.perfect {
            return (true, false, false);
        }
        self.probe_resident(line, store)
    }

    /// Probe body shared by [`Cache::demand_probe`] and the hoisted
    /// [`CacheModel::demand_probe_real`] entry.
    #[inline]
    fn probe_resident(&mut self, line: u64, store: bool) -> (bool, bool, bool) {
        let set = self.set_of(line);
        let key = self.probe_key(line);
        let base = set * self.ways;
        // MRU way filter: the dominant repeated-hit case is one compare.
        let hint = base + self.mru[set] as usize;
        if self.words[hint] & TAG_VALID_MASK == key {
            return self.probe_hit(set, hint, store);
        }
        for i in base..base + self.ways {
            if self.words[i] & TAG_VALID_MASK == key {
                self.mru[set] = (i - base) as u32;
                return self.probe_hit(set, i, store);
            }
        }
        self.stats.misses += 1;
        (false, false, false)
    }

    #[inline]
    fn probe_hit(&mut self, set: usize, slot: usize, store: bool) -> (bool, bool, bool) {
        let w = self.words[slot];
        let was_hw = w & HW_PF != 0;
        let was_sw = w & SW_PF != 0;
        self.words[slot] = (w & !(HW_PF | SW_PF)) | if store { DIRTY } else { 0 };
        self.ages[slot] = self.next_age(set);
        (true, was_hw, was_sw)
    }

    /// See [`CacheModel::contains`].
    pub fn contains(&self, line: u64) -> bool {
        if self.perfect {
            return true;
        }
        let set = self.set_of(line);
        let key = self.probe_key(line);
        let base = set * self.ways;
        self.words[base..base + self.ways].iter().any(|&w| w & TAG_VALID_MASK == key)
    }

    /// See [`CacheModel::fill`].
    pub fn fill(&mut self, line: u64, store: bool, hw_pf: bool, sw_pf: bool) -> Option<Evicted> {
        if self.perfect {
            return None;
        }
        let set = self.set_of(line);
        let key = self.probe_key(line);
        let base = set * self.ways;
        // single pass: find an existing copy (a demand fill can race a
        // prefetch) while simultaneously tracking the victim slot
        // (§Perf: fill was 30% of simulator time when it scanned twice)
        let mut victim = base;
        let mut best = u64::MAX;
        for i in base..base + self.ways {
            let w = self.words[i];
            if w & VALID == 0 {
                if best != 0 {
                    victim = i;
                    best = 0;
                }
                continue;
            }
            if w & TAG_VALID_MASK == key {
                self.ages[i] = self.next_age(set);
                if store {
                    self.words[i] |= DIRTY;
                }
                self.mru[set] = (i - base) as u32;
                return None;
            }
            if (self.ages[i] as u64) < best {
                best = self.ages[i] as u64;
                victim = i;
            }
        }
        let vw = self.words[victim];
        let evicted = if vw & VALID != 0 {
            let dirty = vw & DIRTY != 0;
            if dirty {
                self.stats.writebacks += 1;
            }
            Some(Evicted {
                line: self.stored_line(vw, set),
                dirty,
                untouched_hw_pf: vw & HW_PF != 0,
                untouched_sw_pf: vw & SW_PF != 0,
            })
        } else {
            None
        };
        self.words[victim] = key
            | if store { DIRTY } else { 0 }
            | if hw_pf { HW_PF } else { 0 }
            | if sw_pf { SW_PF } else { 0 };
        self.ages[victim] = self.next_age(set);
        self.mru[set] = (victim - base) as u32;
        evicted
    }

    /// See [`CacheModel::invalidate`].
    pub fn invalidate(&mut self, line: u64) {
        let set = self.set_of(line);
        let key = self.probe_key(line);
        let base = set * self.ways;
        for i in base..base + self.ways {
            if self.words[i] & TAG_VALID_MASK == key {
                self.words[i] = 0;
                // a line is resident at most once per set
                break;
            }
        }
    }
}

impl CacheModel for Cache {
    fn new(size_bytes: u64, ways: usize) -> Self {
        Cache::new(size_bytes, ways)
    }

    fn set_perfect(&mut self, on: bool) {
        self.perfect = on;
    }

    fn is_perfect(&self) -> bool {
        self.perfect
    }

    fn stats(&self) -> &CacheStats {
        &self.stats
    }

    #[inline]
    fn demand_probe(&mut self, line: u64, store: bool) -> (bool, bool, bool) {
        Cache::demand_probe(self, line, store)
    }

    #[inline]
    fn demand_probe_real(&mut self, line: u64, store: bool) -> (bool, bool, bool) {
        self.stats.accesses += 1;
        self.probe_resident(line, store)
    }

    #[inline]
    fn contains(&self, line: u64) -> bool {
        Cache::contains(self, line)
    }

    #[inline]
    fn fill(&mut self, line: u64, store: bool, hw_pf: bool, sw_pf: bool) -> Option<Evicted> {
        Cache::fill(self, line, store, hw_pf, sw_pf)
    }

    fn invalidate(&mut self, line: u64) {
        Cache::invalidate(self, line)
    }
}

/// Configuration of the three-level hierarchy (defaults = paper Table V).
#[derive(Debug, Clone, Copy)]
pub struct HierarchyConfig {
    pub l1_bytes: u64,
    pub l1_ways: usize,
    pub l2_bytes: u64,
    pub l2_ways: usize,
    pub l3_bytes: u64,
    pub l3_ways: usize,
    /// Hardware prefetchers enabled (paper: on by default).
    pub hw_prefetch: bool,
    /// Idealizations for Fig. 12.
    pub perfect_l2: bool,
    pub perfect_llc: bool,
}

impl Default for HierarchyConfig {
    fn default() -> Self {
        Self {
            l1_bytes: 32 * 1024,
            l1_ways: 8,
            l2_bytes: 256 * 1024,
            l2_ways: 8,
            l3_bytes: 8 * 1024 * 1024,
            l3_ways: 16,
            hw_prefetch: true,
            perfect_l2: false,
            perfect_llc: false,
        }
    }
}

/// A DRAM-bound request produced by the hierarchy (demand miss fill,
/// prefetch fill, or dirty writeback).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DramRequest {
    pub line_addr: u64,
    pub is_write: bool,
    pub is_prefetch: bool,
}

/// Tally of a cache-only block replay ([`Hierarchy::access_block`]).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct BlockAccess {
    /// Demand accesses (loads + stores) replayed.
    pub accesses: u64,
    /// Lines that reached DRAM on the demand path.
    pub dram_lines: u64,
}

/// Three-level inclusive hierarchy with integrated prefetchers, generic
/// over the per-level [`CacheModel`] (packed [`Cache`] by default).
pub struct Hierarchy<C: CacheModel = Cache> {
    pub l1: C,
    pub l2: C,
    pub l3: C,
    streamer: StreamPrefetcher,
    hw_prefetch: bool,
    pf_scratch: Vec<u64>,
    pub pf_stats: PrefetchStats,
}

impl Hierarchy<Cache> {
    /// Hierarchy over the packed hot-path cache model.
    pub fn new(cfg: &HierarchyConfig) -> Self {
        Self::with_model(cfg)
    }
}

impl<C: CacheModel> Hierarchy<C> {
    /// Hierarchy over an explicit cache model (the parity tests
    /// instantiate the seed-layout reference; production code uses
    /// [`Hierarchy::new`]).
    pub fn with_model(cfg: &HierarchyConfig) -> Self {
        let mut l2 = C::new(cfg.l2_bytes, cfg.l2_ways);
        l2.set_perfect(cfg.perfect_l2);
        let mut l3 = C::new(cfg.l3_bytes, cfg.l3_ways);
        l3.set_perfect(cfg.perfect_llc);
        Self {
            l1: C::new(cfg.l1_bytes, cfg.l1_ways),
            l2,
            l3,
            streamer: StreamPrefetcher::default_config(),
            hw_prefetch: cfg.hw_prefetch,
            pf_scratch: Vec::with_capacity(8),
            pf_stats: PrefetchStats::default(),
        }
    }

    /// No level idealized? Checked once per access (three inlined field
    /// reads — it cannot go stale if a level's perfect mode is toggled
    /// after construction), hoisting the per-line perfect checks.
    #[inline]
    fn all_real(&self) -> bool {
        !(self.l1.is_perfect() || self.l2.is_perfect() || self.l3.is_perfect())
    }

    /// Process a demand access of `size` bytes at `addr`. Each touched
    /// cache line is looked up through the hierarchy; DRAM-reaching
    /// traffic is appended to `dram`. Returns the *slowest* level that
    /// served any of the lines (that is what a dependent consumer waits
    /// for) and the number of lines that reached DRAM.
    pub fn access(
        &mut self,
        addr: u64,
        size: u32,
        store: bool,
        dram: &mut Vec<DramRequest>,
    ) -> (Level, u32) {
        let first = line_of(addr);
        let last = line_of(addr + size.max(1) as u64 - 1);
        self.access_span(first, last, store, dram)
    }

    /// [`Hierarchy::access`] for an already-computed line span — the
    /// block lane precomputes spans lane-wise before walking a block, so
    /// the per-event path never recomputes line numbers.
    pub fn access_span(
        &mut self,
        first: u64,
        last: u64,
        store: bool,
        dram: &mut Vec<DramRequest>,
    ) -> (Level, u32) {
        if self.all_real() {
            self.access_span_g::<true>(first, last, store, dram)
        } else {
            self.access_span_g::<false>(first, last, store, dram)
        }
    }

    fn access_span_g<const REAL: bool>(
        &mut self,
        first: u64,
        last: u64,
        store: bool,
        dram: &mut Vec<DramRequest>,
    ) -> (Level, u32) {
        if first == last {
            // dominant single-line case: no span-loop state
            let lvl = self.access_line_g::<REAL>(first, store, dram);
            return (lvl, (lvl == Level::Dram) as u32);
        }
        let mut worst = Level::L1;
        let mut dram_lines = 0;
        for line in first..=last {
            let lvl = self.access_line_g::<REAL>(line, store, dram);
            if lvl > worst {
                worst = lvl;
            }
            if lvl == Level::Dram {
                dram_lines += 1;
            }
        }
        (worst, dram_lines)
    }

    /// Cache-only batch entry: replay a block's memory lanes (loads,
    /// stores, software prefetches) through the hierarchy in emission
    /// order, skipping the non-memory lanes entirely. For locality
    /// studies that want cache/prefetch statistics without the timeline
    /// model.
    pub fn access_block(&mut self, block: &EventBlock, dram: &mut Vec<DramRequest>) -> BlockAccess {
        let mut out = BlockAccess::default();
        let (mut li, mut sti, mut pi) = (0, 0, 0);
        for &kind in block.kinds() {
            match kind {
                EventKind::Load => {
                    let (first, last) = block.loads[li].line_span();
                    li += 1;
                    out.accesses += 1;
                    out.dram_lines += self.access_span(first, last, false, dram).1 as u64;
                }
                EventKind::Store => {
                    let (first, last) = block.stores[sti].line_span();
                    sti += 1;
                    out.accesses += 1;
                    out.dram_lines += self.access_span(first, last, true, dram).1 as u64;
                }
                EventKind::SwPrefetch => {
                    let addr = block.prefetches[pi];
                    pi += 1;
                    self.sw_prefetch(addr, dram);
                }
                _ => {}
            }
        }
        out
    }

    /// One line through L1→L2→L3→DRAM. `REAL` asserts no level is
    /// perfect (established once per span), letting the probes drop
    /// their per-call perfect checks.
    fn access_line_g<const REAL: bool>(
        &mut self,
        line: u64,
        store: bool,
        dram: &mut Vec<DramRequest>,
    ) -> Level {
        // L1 — the hot exit: most lines resolve here
        let (hit1, _, _) = if REAL {
            self.l1.demand_probe_real(line, store)
        } else {
            self.l1.demand_probe(line, store)
        };
        if hit1 {
            return Level::L1;
        }
        // L2
        let (hit2, was_hw, was_sw) = if REAL {
            self.l2.demand_probe_real(line, store)
        } else {
            self.l2.demand_probe(line, store)
        };
        if was_hw {
            self.pf_stats.hw_useful += 1;
        }
        if was_sw {
            self.pf_stats.sw_useful += 1;
        }
        if hit2 {
            self.fill_l1(line, store, dram);
            self.train_streamer(line, dram);
            return Level::L2;
        }
        // L3
        let (hit3, was_hw3, was_sw3) = if REAL {
            self.l3.demand_probe_real(line, store)
        } else {
            self.l3.demand_probe(line, store)
        };
        if was_hw3 {
            self.pf_stats.hw_useful += 1;
        }
        if was_sw3 {
            self.pf_stats.sw_useful += 1;
        }
        let served = if hit3 {
            Level::L3
        } else {
            dram.push(DramRequest {
                line_addr: line * LINE_SIZE,
                is_write: false,
                is_prefetch: false,
            });
            Level::Dram
        };
        // Fill path (inclusive): L3 (if missed), L2, L1.
        if !hit3 {
            self.fill_l3(line, dram);
        }
        self.fill_l2(line, store, false, false, dram);
        self.fill_l1(line, store, dram);
        // Prefetchers train on L2 misses.
        if self.hw_prefetch {
            // adjacent-line
            let buddy = line_of(AdjacentLinePrefetcher::buddy(line * LINE_SIZE));
            self.issue_hw_prefetch(buddy, dram);
            self.train_streamer(line, dram);
        }
        served
    }

    fn train_streamer(&mut self, line: u64, dram: &mut Vec<DramRequest>) {
        if !self.hw_prefetch {
            return;
        }
        // detach the (always-cleared) scratch list so candidates can be
        // issued while the streamer state is no longer borrowed
        let mut scratch = std::mem::take(&mut self.pf_scratch);
        self.streamer.observe(line * LINE_SIZE, &mut scratch);
        for &cand in &scratch {
            self.issue_hw_prefetch(line_of(cand), dram);
        }
        scratch.clear();
        self.pf_scratch = scratch;
    }

    fn issue_hw_prefetch(&mut self, line: u64, dram: &mut Vec<DramRequest>) {
        if self.l2.contains(line) || self.l1.contains(line) {
            return; // already resident — filtered, not "issued"
        }
        self.pf_stats.hw_issued += 1;
        // data comes from L3 or DRAM
        if !self.l3.contains(line) {
            dram.push(DramRequest {
                line_addr: line * LINE_SIZE,
                is_write: false,
                is_prefetch: true,
            });
            self.fill_l3(line, dram);
        }
        self.fill_l2(line, false, true, false, dram);
    }

    /// Software prefetch into L2 (the paper targets L2; Section V-C).
    pub fn sw_prefetch(&mut self, addr: u64, dram: &mut Vec<DramRequest>) {
        let line = line_of(addr);
        if self.l1.contains(line) || self.l2.contains(line) {
            return;
        }
        self.pf_stats.sw_issued += 1;
        if !self.l3.contains(line) {
            dram.push(DramRequest {
                line_addr: line * LINE_SIZE,
                is_write: false,
                is_prefetch: true,
            });
            self.fill_l3(line, dram);
        }
        self.fill_l2(line, false, false, true, dram);
    }

    fn fill_l1(&mut self, line: u64, store: bool, dram: &mut Vec<DramRequest>) {
        if let Some(ev) = self.l1.fill(line, store, false, false) {
            if ev.dirty {
                // write back into L2
                if let Some(e2) = self.l2.fill(ev.line, true, false, false) {
                    self.handle_l2_evict(e2, dram);
                }
            }
        }
    }

    fn fill_l2(&mut self, line: u64, store: bool, hw: bool, sw: bool, dram: &mut Vec<DramRequest>) {
        if let Some(ev) = self.l2.fill(line, store, hw, sw) {
            self.handle_l2_evict(ev, dram);
        }
    }

    fn handle_l2_evict(&mut self, ev: Evicted, dram: &mut Vec<DramRequest>) {
        if ev.untouched_hw_pf {
            self.pf_stats.hw_useless += 1;
        }
        if ev.untouched_sw_pf {
            self.pf_stats.sw_useless += 1;
        }
        if ev.dirty {
            // write back into L3 (already inclusive, so it's present)
            if let Some(e3) = self.l3.fill(ev.line, true, false, false) {
                if e3.dirty {
                    dram.push(DramRequest {
                        line_addr: e3.line * LINE_SIZE,
                        is_write: true,
                        is_prefetch: false,
                    });
                }
                self.back_invalidate(e3.line);
            }
        }
    }

    fn fill_l3(&mut self, line: u64, dram: &mut Vec<DramRequest>) {
        if let Some(ev) = self.l3.fill(line, false, false, false) {
            if ev.dirty {
                dram.push(DramRequest {
                    line_addr: ev.line * LINE_SIZE,
                    is_write: true,
                    is_prefetch: false,
                });
            }
            // inclusive hierarchy: evicting from L3 invalidates below
            self.back_invalidate(ev.line);
        }
    }

    fn back_invalidate(&mut self, line: u64) {
        self.l1.invalidate(line);
        self.l2.invalidate(line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_hierarchy() -> Hierarchy {
        Hierarchy::new(&HierarchyConfig {
            l1_bytes: 1024,
            l1_ways: 2,
            l2_bytes: 4096,
            l2_ways: 4,
            l3_bytes: 16384,
            l3_ways: 4,
            hw_prefetch: false,
            perfect_l2: false,
            perfect_llc: false,
        })
    }

    #[test]
    fn first_touch_misses_everywhere_then_hits_l1() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        let (lvl, n) = h.access(0x10000, 8, false, &mut dram);
        assert_eq!(lvl, Level::Dram);
        assert_eq!(n, 1);
        assert_eq!(dram.len(), 1);
        let (lvl2, _) = h.access(0x10000, 8, false, &mut dram);
        assert_eq!(lvl2, Level::L1);
        assert_eq!(dram.len(), 1, "no extra dram traffic on a hit");
    }

    #[test]
    fn multi_line_access_touches_each_line() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        // 160-byte row starting at a line boundary spans 3 lines
        let (lvl, n) = h.access(0x20000, 160, false, &mut dram);
        assert_eq!(lvl, Level::Dram);
        assert_eq!(n, 3);
        assert_eq!(h.l1.stats.accesses, 3);
    }

    #[test]
    fn lru_eviction_in_l1_still_hits_l2() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        // L1 = 1KB/2-way/64B = 8 sets; fill one set (2 ways) then a third
        // conflicting line evicts the first.
        let set_stride = 8 * 64; // lines mapping to same set
        for k in 0..3u64 {
            h.access(0x40000 + k * set_stride, 8, false, &mut dram);
        }
        // line 0 evicted from L1, but resident in L2
        let (lvl, _) = h.access(0x40000, 8, false, &mut dram);
        assert_eq!(lvl, Level::L2);
    }

    #[test]
    fn perfect_llc_never_reaches_dram() {
        let mut cfg = HierarchyConfig { hw_prefetch: false, ..Default::default() };
        cfg.perfect_llc = true;
        let mut h = Hierarchy::new(&cfg);
        let mut dram = Vec::new();
        let mut rng = crate::util::Pcg64::new(4);
        for _ in 0..10_000 {
            let addr = rng.below(1 << 30);
            let (lvl, _) = h.access(addr, 8, false, &mut dram);
            assert!(lvl <= Level::L3);
        }
        assert!(dram.is_empty());
    }

    #[test]
    fn perfect_l2_hits_at_l2() {
        let cfg = HierarchyConfig {
            hw_prefetch: false,
            perfect_l2: true,
            ..Default::default()
        };
        let mut h = Hierarchy::new(&cfg);
        let mut dram = Vec::new();
        let (lvl, _) = h.access(0x123456, 8, false, &mut dram);
        assert_eq!(lvl, Level::L2);
    }

    #[test]
    fn dirty_eviction_writes_back() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        // store to many distinct lines to force L3 evictions of dirty data
        for k in 0..2000u64 {
            h.access(k * 64, 8, true, &mut dram);
        }
        assert!(
            dram.iter().any(|r| r.is_write),
            "expected dirty writebacks to DRAM"
        );
    }

    #[test]
    fn sw_prefetch_turns_miss_into_l2_hit() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        h.sw_prefetch(0x80000, &mut dram);
        assert_eq!(h.pf_stats.sw_issued, 1);
        let (lvl, _) = h.access(0x80000, 8, false, &mut dram);
        assert_eq!(lvl, Level::L2);
        assert_eq!(h.pf_stats.sw_useful, 1);
    }

    #[test]
    fn sw_prefetch_of_resident_line_is_filtered() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        h.access(0x90000, 8, false, &mut dram);
        h.sw_prefetch(0x90000, &mut dram);
        assert_eq!(h.pf_stats.sw_issued, 0);
    }

    #[test]
    fn hw_prefetch_useful_on_streaming() {
        let cfg = HierarchyConfig::default();
        let mut h = Hierarchy::new(&cfg);
        let mut dram = Vec::new();
        for line in 0..4000u64 {
            h.access(line * 64, 8, false, &mut dram);
        }
        assert!(h.pf_stats.hw_issued > 100);
        let f = h.pf_stats.hw_useless_fraction();
        assert!(f < 0.2, "streaming should make prefetches useful: {f}");
        // and the L2 miss ratio should be well below 1.0
        assert!(h.l2.stats.miss_ratio() < 0.7);
    }

    #[test]
    fn hw_prefetch_useless_on_random() {
        let cfg = HierarchyConfig::default();
        let mut h = Hierarchy::new(&cfg);
        let mut dram = Vec::new();
        let mut rng = crate::util::Pcg64::new(5);
        for _ in 0..200_000 {
            // random 8-byte reads over 1 GiB
            let addr = rng.below(1 << 30) & !7;
            h.access(addr, 8, false, &mut dram);
        }
        let f = h.pf_stats.hw_useless_fraction();
        assert!(f > 0.3, "random stream should waste prefetches: {f}");
    }

    #[test]
    fn inclusive_l3_eviction_invalidates_l1() {
        let mut h = small_hierarchy();
        let mut dram = Vec::new();
        h.access(0x0, 8, false, &mut dram);
        // thrash L3 (16KB/4-way/64B = 64 sets): fill set 0's ways
        for k in 1..=4u64 {
            h.access(k * 64 * 64 * 4, 8, false, &mut dram); // wait: map to set 0 of l3
        }
        // construct lines that alias L3 set of 0x0: set = line % 64
        let mut victims = 0;
        for k in 1..=8u64 {
            let addr = k * 64 * 64; // line multiple of 64 -> set 0
            h.access(addr, 8, false, &mut dram);
            victims += 1;
        }
        assert!(victims > 4);
        // 0x0 must have been back-invalidated from L1 at some point;
        // accessing it again must not be an L1 hit-after-L3-eviction bug.
        let before_misses = h.l1.stats.misses;
        h.access(0x0, 8, false, &mut dram);
        assert!(h.l1.stats.misses > before_misses, "stale L1 line survived L3 eviction");
    }

    #[test]
    fn cache_stats_miss_ratio() {
        let mut c = Cache::new(1024, 2);
        assert_eq!(c.stats.miss_ratio(), 0.0);
        c.demand_probe(1, false);
        c.fill(1, false, false, false);
        c.demand_probe(1, false);
        assert_eq!(c.stats.accesses, 2);
        assert_eq!(c.stats.misses, 1);
        assert_eq!(c.stats.miss_ratio(), 0.5);
    }

    #[test]
    fn packed_word_roundtrips_high_lines() {
        // tags from the top of the address space survive packing
        let mut c = Cache::new(1024, 2);
        let line = line_of(u64::MAX); // 58-bit line number
        assert!(c.fill(line, true, false, false).is_none());
        assert!(c.contains(line));
        let (hit, _, _) = c.demand_probe(line, false);
        assert!(hit);
        // evicting it reports the exact line back
        let set_lines = 8; // 1KB/2-way/64B
        let a = line - set_lines;
        let b = line - 2 * set_lines;
        c.fill(a, false, false, false);
        let ev = c.fill(b, false, false, false).expect("eviction");
        assert_eq!(ev.line, line, "LRU victim is the first-filled line");
        assert!(ev.dirty);
    }

    #[test]
    fn mru_filter_survives_invalidate_and_eviction() {
        let mut c = Cache::new(1024, 2);
        c.fill(3, false, false, false);
        c.demand_probe(3, false); // hint now points at line 3's way
        c.invalidate(3);
        let (hit, _, _) = c.demand_probe(3, false);
        assert!(!hit, "stale MRU hint must not fake a hit");
        // refill the slot with a conflicting line; the hint self-validates
        let alias = 3 + 8; // same set (8 sets)
        c.fill(alias, false, false, false);
        let (hit_alias, _, _) = c.demand_probe(alias, false);
        assert!(hit_alias);
        let (hit3, _, _) = c.demand_probe(3, false);
        assert!(!hit3);
    }

    #[test]
    fn age_renormalization_preserves_lru_order() {
        let mut c = Cache::new(1024, 2);
        // occupy one set with lines 0 and 8; line 0 is older
        c.fill(0, false, false, false);
        c.fill(8, false, false, false);
        // force a renorm of set 0 by exhausting its tick counter
        let set0 = 0usize;
        c.ticks[set0] = u32::MAX;
        c.demand_probe(8, false); // triggers renorm, then touches 8
        // a new conflicting fill must evict line 0 (still the LRU)
        let ev = c.fill(16, false, false, false).expect("eviction");
        assert_eq!(ev.line, 0);
    }
}
