//! Top-down pipeline-slot model.
//!
//! Substitutes for VTune/perf top-down analysis on real silicon: consumes
//! a workload's event trace and produces the metrics the paper reports —
//! CPI, retiring ratio, bad-speculation bound, DRAM/cache bound, core
//! bound, port-utilization distribution (Figs. 1–10, Tables III/IV).
//!
//! The model is an *interval* model in the spirit of Sniper [CHE11]: the
//! core issues `width` uops per cycle until a miss event opens an
//! interval. Long-latency loads overlap within a ROB/MSHR window
//! (memory-level parallelism); mispredicted branches flush the pipeline,
//! and branches fed by in-flight loads resolve only when the load returns
//! — reproducing the paper's observation that prefetching also shrinks
//! the bad-speculation bound (Figs. 16/22).

use super::branch::{BranchStats, Gshare};
use super::cache::{Cache, CacheModel, DramRequest, Hierarchy, HierarchyConfig, Level};
use super::dram::{Dram, DramConfig, DramStats};
use super::prefetch::PrefetchStats;
use crate::trace::{
    line_span, BlockSink, Event, EventBlock, EventKind, InstructionMix, LoadRec, Sink, StoreRec,
};

/// Core configuration (defaults model the paper's "aggressive 5-way
/// superscalar" client core at 2.9 GHz).
#[derive(Debug, Clone)]
pub struct CpuConfig {
    pub width: f64,
    pub freq_ghz: f64,
    /// Pipeline-refill penalty of a mispredicted branch, cycles.
    pub mispredict_penalty: f64,
    pub rob_uops: f64,
    pub mshrs: usize,
    pub fp_ports: f64,
    pub int_ports: f64,
    pub mem_ports: f64,
    pub cache: HierarchyConfig,
    pub dram: DramConfig,
}

impl Default for CpuConfig {
    fn default() -> Self {
        Self {
            width: 5.0,
            freq_ghz: 2.9,
            mispredict_penalty: 15.0,
            rob_uops: 256.0,
            mshrs: 10,
            fp_ports: 2.0,
            int_ports: 4.0,
            mem_ports: 2.0,
            cache: HierarchyConfig::default(),
            dram: DramConfig::default(),
        }
    }
}

/// Outstanding long-latency load.
#[derive(Debug, Clone, Copy)]
struct Outstanding {
    completion_cycle: f64,
    issue_uop: f64,
    level: Level,
}

/// Read-only snapshot of the timeline accumulators — the quantities the
/// sampled-simulation estimator ([`super::sample`]) extrapolates from
/// detailed windows. Everything else the simulator tracks (instruction
/// mix, branch counters, cache/prefetch statistics) is timing-independent
/// and therefore *exact* under functional warming.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TimelineSnapshot {
    pub uops: f64,
    pub cycle: f64,
    pub bad_spec_cycles: f64,
    pub l2_stall: f64,
    pub l3_stall: f64,
    pub dram_stall: f64,
    pub instructions: u64,
}

/// Full metric set for one characterized run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    pub instructions: u64,
    pub cycles: f64,
    pub cpi: f64,
    pub ipc: f64,
    /// Top-down pipeline-slot fractions, percent.
    pub retiring_pct: f64,
    pub bad_spec_pct: f64,
    pub core_bound_pct: f64,
    pub mem_bound_pct: f64,
    pub dram_bound_pct: f64,
    pub l2_bound_pct: f64,
    pub l3_bound_pct: f64,
    /// Branch behaviour (Figs. 3–6).
    pub branch_mispredict_ratio: f64,
    pub branch_fraction: f64,
    pub cond_branch_fraction: f64,
    /// Cache behaviour (Figs. 8, 14).
    pub l1_miss_ratio: f64,
    pub l2_miss_ratio: f64,
    pub llc_miss_ratio: f64,
    /// Port-utilization distribution: fraction of cycles executing
    /// 0 / 1 / 2 / 3+ uops (Figs. 10, 17).
    pub port_dist: [f64; 4],
    pub mix: InstructionMix,
    pub branch: BranchStats,
    pub dram: DramStats,
    pub prefetch: PrefetchStats,
    /// Simulated wall time of the region, ns.
    pub sim_time_ns: f64,
}

impl Metrics {
    /// Fraction of cycles executing 2+ uops (Fig. 17's headline number).
    pub fn two_plus_uops_fraction(&self) -> f64 {
        self.port_dist[2] + self.port_dist[3]
    }

    /// Speedup of `self` relative to `baseline` (>1 means faster).
    pub fn speedup_vs(&self, baseline: &Metrics) -> f64 {
        if self.cycles == 0.0 {
            return 1.0;
        }
        baseline.cycles / self.cycles
    }

    /// DRAM bandwidth utilization percent (Fig. 9).
    pub fn bandwidth_utilization_pct(&self) -> f64 {
        self.dram.bandwidth_utilization() * 100.0
    }
}

/// The trace-driven pipeline simulator. Implements [`Sink`]; feed it a
/// workload trace, call `finish()`, then read [`PipelineSim::metrics`].
///
/// Generic over the cache model so the parity tests and the throughput
/// bench can drive the seed-layout
/// [`RefCache`](super::reference::RefCache) through the identical
/// timeline; production code uses the default packed [`Cache`].
pub struct PipelineSim<C: CacheModel = Cache> {
    cfg: CpuConfig,
    pub hierarchy: Hierarchy<C>,
    pub dram: Dram,
    predictor: Gshare,
    mix: InstructionMix,
    branch_stats: BranchStats,
    // timeline state
    uops: f64,
    cycle: f64,
    outstanding: Vec<Outstanding>,
    dram_scratch: Vec<DramRequest>,
    // block lane scratch: per-lane touched-line spans, precomputed
    // lane-wise before the tag walk (§Perf: block-vectorized access path)
    load_spans: Vec<(u64, u64)>,
    store_spans: Vec<(u64, u64)>,
    // stall accumulators (cycles)
    bad_spec_cycles: f64,
    l2_stall: f64,
    l3_stall: f64,
    dram_stall: f64,
    // last load that feeds a branch: its completion cycle
    feeding_load_completion: f64,
    feeding_load_level: Level,
    finished: bool,
}

impl PipelineSim<Cache> {
    /// Simulator over the packed hot-path cache model.
    pub fn new(cfg: CpuConfig) -> Self {
        Self::with_cache_model(cfg)
    }
}

impl<C: CacheModel> PipelineSim<C> {
    /// Simulator over an explicit cache model (see [`PipelineSim::new`]).
    pub fn with_cache_model(cfg: CpuConfig) -> Self {
        Self {
            hierarchy: Hierarchy::with_model(&cfg.cache),
            dram: Dram::new(cfg.dram.clone()),
            predictor: Gshare::default_config(),
            mix: InstructionMix::default(),
            branch_stats: BranchStats::default(),
            uops: 0.0,
            cycle: 0.0,
            outstanding: Vec::with_capacity(cfg.mshrs + 1),
            dram_scratch: Vec::with_capacity(16),
            load_spans: Vec::new(),
            store_spans: Vec::new(),
            bad_spec_cycles: 0.0,
            l2_stall: 0.0,
            l3_stall: 0.0,
            dram_stall: 0.0,
            feeding_load_completion: 0.0,
            feeding_load_level: Level::L1,
            cfg,
            finished: false,
        }
    }

    #[inline]
    fn issue(&mut self, n: f64) {
        self.uops += n;
        self.cycle += n / self.cfg.width;
    }

    /// Retire outstanding loads whose completion has passed; enforce the
    /// ROB and MSHR limits, attributing stall cycles to the blocking
    /// load's serving level.
    fn drain_window(&mut self, need_mshr: bool) {
        // §Perf: called once per event — skip all bookkeeping when no
        // loads are in flight (the common cache-resident case)
        if self.outstanding.is_empty() {
            return;
        }
        self.outstanding.retain(|o| o.completion_cycle > self.cycle);
        let rob_limit = |o: &Outstanding, uops: f64, rob: f64| uops - o.issue_uop > rob;
        loop {
            // find oldest outstanding
            let oldest = self
                .outstanding
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.completion_cycle.partial_cmp(&b.1.completion_cycle).unwrap())
                .map(|(i, o)| (i, *o));
            let Some((idx, o)) = oldest else { return };
            let mshr_block = need_mshr && self.outstanding.len() >= self.cfg.mshrs;
            let rob_block = rob_limit(&o, self.uops, self.cfg.rob_uops);
            if !mshr_block && !rob_block {
                return;
            }
            // stall until the oldest load completes
            let stall = (o.completion_cycle - self.cycle).max(0.0);
            match o.level {
                Level::L2 => self.l2_stall += stall,
                Level::L3 => self.l3_stall += stall,
                Level::Dram => self.dram_stall += stall,
                Level::L1 => {}
            }
            self.cycle += stall;
            self.outstanding.swap_remove(idx);
            self.outstanding.retain(|q| q.completion_cycle > self.cycle);
        }
    }

    /// Route DRAM-reaching cache traffic through the DRAM timing model,
    /// returning the latency (cycles) of the *demand* request if present.
    fn run_dram_traffic(&mut self) -> Option<f64> {
        // §Perf: hoists the dominant no-DRAM-traffic case (cache-resident
        // accesses, filtered prefetches) past the drain/take machinery
        if self.dram_scratch.is_empty() {
            return None;
        }
        let mut demand_cycles = None;
        let now_ns = self.cycle / self.cfg.freq_ghz;
        // take ownership to satisfy the borrow checker
        let mut reqs = std::mem::take(&mut self.dram_scratch);
        for r in reqs.drain(..) {
            let lat_ns = self.dram.request(now_ns, r.line_addr, r.is_write, r.is_prefetch);
            if !r.is_prefetch && !r.is_write {
                demand_cycles = Some(lat_ns * self.cfg.freq_ghz);
            }
        }
        self.dram_scratch = reqs;
        demand_cycles
    }

    /// Demand access over a precomputed `first..=last` touched-line span
    /// (the block lane computes spans lane-wise; the per-event [`Sink`]
    /// path computes them inline — both land here).
    fn memory_access_span(&mut self, first: u64, last: u64, store: bool, feeds_branch: bool) {
        // one mem uop per touched line (vectorized row reads decompose
        // into per-line accesses in hardware too)
        self.issue((last - first + 1) as f64);
        let (level, _) = self
            .hierarchy
            .access_span(first, last, store, &mut self.dram_scratch);
        let dram_lat = self.run_dram_traffic();
        if store {
            // stores retire through the store buffer; no consumer stalls
            return;
        }
        let latency = match level {
            Level::Dram => dram_lat.unwrap_or(Level::Dram.latency_cycles()),
            l => l.latency_cycles(),
        };
        if level != Level::L1 {
            self.drain_window(true);
            let completion = self.cycle + latency;
            self.outstanding.push(Outstanding {
                completion_cycle: completion,
                issue_uop: self.uops,
                level,
            });
            if feeds_branch {
                self.feeding_load_completion = completion;
                self.feeding_load_level = level;
            }
        } else if feeds_branch {
            self.feeding_load_completion = self.cycle + Level::L1.latency_cycles();
            self.feeding_load_level = Level::L1;
        }
        // ROB pressure from earlier loads
        self.drain_window(false);
    }

    fn branch_event(&mut self, site: u32, taken: bool, conditional: bool) {
        self.issue(1.0);
        if !conditional {
            self.branch_stats.unconditional += 1;
            return;
        }
        self.branch_stats.conditional += 1;
        let correct = self.predictor.predict_update(site, taken);
        if !correct {
            self.branch_stats.mispredicts += 1;
            // The flush cannot happen before the branch *resolves*; if the
            // branch consumed an in-flight load, resolution waits for it.
            // Only part of that wait is wrong-path waste: the load was
            // issued ahead of the branch and overlaps older useful work,
            // so charge a capped, overlap-discounted share (the remainder
            // is already accounted as memory stall by the load itself).
            let resolve_at = self.feeding_load_completion.max(self.cycle);
            let wait = (resolve_at - self.cycle).min(80.0) * 0.35;
            let penalty = wait + self.cfg.mispredict_penalty;
            self.bad_spec_cycles += penalty;
            self.cycle += penalty;
        }
        // consumed
        self.feeding_load_completion = 0.0;
    }

    /// Produce the metric set. Idempotent after `finish()`.
    pub fn metrics(&self) -> Metrics {
        assert!(self.finished, "call finish() before metrics()");
        let base_cycles = self.uops / self.cfg.width;
        // port-pressure core-bound component
        let fp_cycles = self.mix.fp_ops as f64 / self.cfg.fp_ports;
        let int_cycles = self.mix.int_ops as f64 / self.cfg.int_ports;
        let mem_uops = (self.mix.loads + self.mix.stores) as f64;
        let mem_cycles = mem_uops / self.cfg.mem_ports;
        let port_limit = fp_cycles.max(int_cycles).max(mem_cycles);
        let core_bound = (port_limit - base_cycles).max(0.0);
        let total = self.cycle + core_bound;

        let mem_stall = self.l2_stall + self.l3_stall + self.dram_stall;
        let instructions = self.mix.instructions();
        let pct = |x: f64| 100.0 * x / total.max(1e-9);

        // Port-utilization distribution: stall cycles execute 0 uops;
        // core-bound cycles trickle 1 uop; the remaining busy cycles
        // split 2 vs 3+ by how far average busy-IPC exceeds 2.
        let stall = (self.bad_spec_cycles + mem_stall).min(total);
        let busy = (total - stall - core_bound).max(0.0);
        let busy_ipc = if busy > 0.0 { self.uops / busy } else { 0.0 };
        let (p2, p3) = if busy_ipc >= 3.0 {
            (0.25, 0.75)
        } else if busy_ipc >= 2.0 {
            let t = busy_ipc - 2.0;
            (1.0 - t * 0.75, t * 0.75)
        } else {
            (busy_ipc / 2.0, 0.0)
        };
        let port_dist = [
            stall / total,
            core_bound / total + busy / total * (1.0 - p2 - p3).max(0.0),
            busy / total * p2,
            busy / total * p3,
        ];

        Metrics {
            instructions,
            cycles: total,
            cpi: total / instructions.max(1) as f64,
            ipc: instructions as f64 / total.max(1e-9),
            retiring_pct: pct(base_cycles),
            bad_spec_pct: pct(self.bad_spec_cycles),
            core_bound_pct: pct(core_bound),
            mem_bound_pct: pct(mem_stall),
            dram_bound_pct: pct(self.dram_stall),
            l2_bound_pct: pct(self.l2_stall),
            l3_bound_pct: pct(self.l3_stall),
            branch_mispredict_ratio: self.branch_stats.mispredict_ratio(),
            branch_fraction: self.mix.branch_fraction(),
            cond_branch_fraction: self.mix.conditional_branch_fraction(),
            l1_miss_ratio: self.hierarchy.l1.stats().miss_ratio(),
            l2_miss_ratio: self.hierarchy.l2.stats().miss_ratio(),
            llc_miss_ratio: self.hierarchy.l3.stats().miss_ratio(),
            port_dist,
            mix: self.mix.clone(),
            branch: self.branch_stats,
            dram: self.dram.stats.clone(),
            prefetch: self.hierarchy.pf_stats,
            sim_time_ns: total / self.cfg.freq_ghz,
        }
    }

    /// Current timeline accumulators (sampling-window bookkeeping).
    pub fn timeline(&self) -> TimelineSnapshot {
        TimelineSnapshot {
            uops: self.uops,
            cycle: self.cycle,
            bad_spec_cycles: self.bad_spec_cycles,
            l2_stall: self.l2_stall,
            l3_stall: self.l3_stall,
            dram_stall: self.dram_stall,
            instructions: self.mix.instructions(),
        }
    }

    /// The core configuration this simulator runs under.
    pub fn config(&self) -> &CpuConfig {
        &self.cfg
    }

    /// The (exact, lane-accumulated) instruction mix so far.
    pub fn mix(&self) -> &InstructionMix {
        &self.mix
    }

    /// Branch counters so far (exact under functional warming too).
    pub fn branch_stats(&self) -> BranchStats {
        self.branch_stats
    }

    /// Functional warming: replay a block's *state* effects without the
    /// timeline model. Cache tag arrays (all three levels, via the same
    /// `demand_probe`/`fill` path as detailed simulation, hardware
    /// prefetchers included), branch-predictor state, the instruction
    /// mix, branch counters, and the uop count evolve exactly as under
    /// [`BlockSink::consume`] — none of them consult the timeline —
    /// while cycles, stalls, the ROB/MSHR window, and the DRAM model are
    /// skipped entirely.
    ///
    /// `cycles_per_uop` advances the clock at an estimated rate so the
    /// DRAM model's notion of time keeps moving across warmed gaps
    /// (request arrival spacing in the next detailed window depends on
    /// it; state correctness does not).
    pub fn warm_block(&mut self, block: &EventBlock, cycles_per_uop: f64) {
        self.mix.add_block(block);
        // order-insensitive lanes reduce lane-wise: only the memory lanes
        // (cache state) and the branch lane (gshare history) are
        // order-sensitive, and each only relative to its own kind
        let mut uops = 0u64;
        for &(int_ops, fp_ops) in &block.compute {
            uops += (int_ops + fp_ops) as u64;
        }
        for &ops in &block.serial {
            uops += ops as u64;
        }
        for b in &block.branches {
            uops += 1;
            if b.conditional {
                self.branch_stats.conditional += 1;
                if !self.predictor.predict_update(b.site, b.taken) {
                    self.branch_stats.mispredicts += 1;
                }
            } else {
                self.branch_stats.unconditional += 1;
            }
        }
        for &(_site, count) in &block.loop_branches {
            uops += count as u64;
            self.branch_stats.conditional += count as u64;
            if count as u64 > 14 {
                self.branch_stats.mispredicts += 1;
            }
        }
        uops += block.prefetches.len() as u64;
        // loads/stores/prefetches must interleave exactly as emitted
        // (cache state is order-sensitive across the three memory kinds):
        // walk the tag lane dispatching memory ops only
        let (mut li, mut sti, mut pi) = (0, 0, 0);
        for &kind in block.kinds() {
            match kind {
                EventKind::Load => {
                    let (first, last) = block.loads[li].line_span();
                    li += 1;
                    uops += last - first + 1;
                    self.hierarchy.access_span(first, last, false, &mut self.dram_scratch);
                }
                EventKind::Store => {
                    let (first, last) = block.stores[sti].line_span();
                    sti += 1;
                    uops += last - first + 1;
                    self.hierarchy.access_span(first, last, true, &mut self.dram_scratch);
                }
                EventKind::SwPrefetch => {
                    let addr = block.prefetches[pi];
                    pi += 1;
                    self.hierarchy.sw_prefetch(addr, &mut self.dram_scratch);
                }
                _ => {}
            }
        }
        // warmed traffic bypasses the DRAM timing model by design
        self.dram_scratch.clear();
        self.uops += uops as f64;
        self.cycle += uops as f64 * cycles_per_uop;
    }

    /// Close a detailed sampling window: complete every in-flight load
    /// without charging stall cycles — the exact policy [`Sink::finish`]
    /// applies to the end-of-trace tail — and drop any pending
    /// load→branch feeding edge so no timeline dependency crosses the
    /// warmed gap that follows.
    pub fn close_sample_window(&mut self) {
        self.outstanding.clear();
        self.feeding_load_completion = 0.0;
        self.feeding_load_level = Level::L1;
    }
}

// Per-event timeline handlers, shared verbatim by the legacy per-event
// [`Sink`] path and the batched [`BlockSink`] path so the two produce
// bit-identical metrics (the parity tests assert this).
impl<C: CacheModel> PipelineSim<C> {
    #[inline]
    fn on_compute(&mut self, int_ops: u32, fp_ops: u32) {
        self.issue((int_ops + fp_ops) as f64);
        self.drain_window(false);
    }

    #[inline]
    fn on_serial(&mut self, ops: u32) {
        // dependency chain: 1 uop issued, ALU latency exposed
        self.uops += ops as f64;
        self.cycle += ops as f64 * 1.5;
        self.drain_window(false);
    }

    #[inline]
    fn on_loop_branch(&mut self, count: u32) {
        // count-1 taken back-edges + 1 fall-through. A gshare
        // predictor learns the exit only when the whole trip fits
        // in its history register; longer trips mispredict the
        // exit once per loop instance.
        self.issue(count as f64);
        self.branch_stats.conditional += count as u64;
        if count as u64 > 14 {
            self.branch_stats.mispredicts += 1;
            self.bad_spec_cycles += self.cfg.mispredict_penalty;
            self.cycle += self.cfg.mispredict_penalty;
        }
    }

    #[inline]
    fn on_sw_prefetch(&mut self, addr: u64) {
        // a prefetch instruction occupies one issue slot but never
        // blocks retirement
        self.issue(1.0);
        self.hierarchy.sw_prefetch(addr, &mut self.dram_scratch);
        self.run_dram_traffic();
    }
}

impl<C: CacheModel> Sink for PipelineSim<C> {
    fn event(&mut self, ev: Event) {
        self.mix.event(ev);
        match ev {
            Event::Compute { int_ops, fp_ops } => self.on_compute(int_ops, fp_ops),
            Event::Serial { ops } => self.on_serial(ops),
            Event::Load { addr, size, feeds_branch } => {
                let (first, last) = line_span(addr, size);
                self.memory_access_span(first, last, false, feeds_branch);
            }
            Event::Store { addr, size } => {
                let (first, last) = line_span(addr, size);
                self.memory_access_span(first, last, true, false);
            }
            Event::Branch { site, taken, conditional } => {
                self.branch_event(site, taken, conditional);
            }
            Event::LoopBranch { count, .. } => self.on_loop_branch(count),
            Event::SwPrefetch { addr } => self.on_sw_prefetch(addr),
        }
    }

    fn finish(&mut self) {
        // drain every outstanding load
        let remaining: Vec<Outstanding> = self.outstanding.drain(..).collect();
        for o in remaining {
            let stall = (o.completion_cycle - self.cycle).max(0.0);
            // tail stalls attributed the same way
            match o.level {
                Level::L2 => self.l2_stall += stall * 0.0, // tail overlap: free
                Level::L3 => self.l3_stall += stall * 0.0,
                Level::Dram => self.dram_stall += stall * 0.0,
                Level::L1 => {}
            }
        }
        self.finished = true;
    }
}

impl<C: CacheModel> BlockSink for PipelineSim<C> {
    /// Consume a whole columnar block: the instruction mix is accumulated
    /// lane-wise (no per-event dispatch), touched-line spans for both
    /// memory lanes are precomputed in two branch-free lane sweeps, then
    /// the timeline model walks the discriminant lane with per-lane
    /// cursors — monomorphized, with every payload lane contiguous in
    /// cache.
    fn consume(&mut self, block: &EventBlock) {
        self.mix.add_block(block);
        self.load_spans.clear();
        self.load_spans.extend(block.loads.iter().map(LoadRec::line_span));
        self.store_spans.clear();
        self.store_spans.extend(block.stores.iter().map(StoreRec::line_span));
        let (mut ci, mut si, mut li, mut sti, mut bi, mut lbi, mut pi) = (0, 0, 0, 0, 0, 0, 0);
        for &kind in block.kinds() {
            match kind {
                EventKind::Compute => {
                    let (int_ops, fp_ops) = block.compute[ci];
                    ci += 1;
                    self.on_compute(int_ops, fp_ops);
                }
                EventKind::Serial => {
                    let ops = block.serial[si];
                    si += 1;
                    self.on_serial(ops);
                }
                EventKind::Load => {
                    let feeds_branch = block.loads[li].feeds_branch;
                    let (first, last) = self.load_spans[li];
                    li += 1;
                    self.memory_access_span(first, last, false, feeds_branch);
                }
                EventKind::Store => {
                    let (first, last) = self.store_spans[sti];
                    sti += 1;
                    self.memory_access_span(first, last, true, false);
                }
                EventKind::Branch => {
                    let br = block.branches[bi];
                    bi += 1;
                    self.branch_event(br.site, br.taken, br.conditional);
                }
                EventKind::LoopBranch => {
                    let (_site, count) = block.loop_branches[lbi];
                    lbi += 1;
                    self.on_loop_branch(count);
                }
                EventKind::SwPrefetch => {
                    let addr = block.prefetches[pi];
                    pi += 1;
                    self.on_sw_prefetch(addr);
                }
            }
        }
    }

    fn finalize(&mut self) {
        <Self as Sink>::finish(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Recorder;

    fn sim() -> PipelineSim {
        PipelineSim::new(CpuConfig::default())
    }

    /// Pure compute: retiring should dominate, CPI near 1/width.
    #[test]
    fn compute_only_is_retiring_bound() {
        let mut s = sim();
        // balanced int/fp mix that stays inside port limits at width 5
        for _ in 0..10_000 {
            s.event(Event::Compute { int_ops: 2, fp_ops: 1 });
        }
        s.finish();
        let m = s.metrics();
        assert!(m.retiring_pct > 90.0, "retiring {}", m.retiring_pct);
        assert!(m.cpi < 0.3, "cpi {}", m.cpi);
        assert!(m.bad_spec_pct < 1.0);
    }

    /// FP-saturating compute: core bound appears.
    #[test]
    fn fp_pressure_is_core_bound() {
        let mut s = sim();
        for _ in 0..10_000 {
            s.event(Event::Compute { int_ops: 0, fp_ops: 5 });
        }
        s.finish();
        let m = s.metrics();
        assert!(m.core_bound_pct > 20.0, "core {}", m.core_bound_pct);
    }

    /// Random far-apart loads: DRAM bound dominates, CPI high.
    #[test]
    fn pointer_chase_is_dram_bound() {
        let mut s = sim();
        let mut rng = crate::util::Pcg64::new(9);
        for _ in 0..30_000 {
            let addr = rng.below(1 << 31) & !7;
            s.event(Event::Load { addr, size: 8, feeds_branch: false });
            s.event(Event::Compute { int_ops: 2, fp_ops: 1 });
        }
        s.finish();
        let m = s.metrics();
        assert!(m.dram_bound_pct > 20.0, "dram {}", m.dram_bound_pct);
        assert!(m.cpi > 0.5, "cpi {}", m.cpi);
        assert!(m.llc_miss_ratio > 0.5, "llc {}", m.llc_miss_ratio);
    }

    /// Sequential streaming: HW prefetcher keeps DRAM-bound modest and CPI low.
    #[test]
    fn streaming_benefits_from_hw_prefetch() {
        let mut on = sim();
        let mut cfg_off = CpuConfig::default();
        cfg_off.cache.hw_prefetch = false;
        let mut off = PipelineSim::new(cfg_off);
        for k in 0..50_000u64 {
            let ev = Event::Load { addr: k * 8, size: 8, feeds_branch: false };
            on.event(ev);
            on.event(Event::Compute { int_ops: 1, fp_ops: 2 });
            off.event(ev);
            off.event(Event::Compute { int_ops: 1, fp_ops: 2 });
        }
        on.finish();
        off.finish();
        let m_on = on.metrics();
        let m_off = off.metrics();
        assert!(
            m_on.cycles < m_off.cycles,
            "prefetcher must help streaming: {} vs {}",
            m_on.cycles,
            m_off.cycles
        );
    }

    /// Random branches inflate bad speculation; biased ones do not.
    #[test]
    fn random_branches_bad_spec() {
        let mut s = sim();
        let mut rng = crate::util::Pcg64::new(10);
        for _ in 0..20_000 {
            s.event(Event::Compute { int_ops: 3, fp_ops: 0 });
            s.event(Event::Branch { site: 5, taken: rng.next_f64() < 0.5, conditional: true });
        }
        s.finish();
        let m = s.metrics();
        assert!(m.bad_spec_pct > 15.0, "bad spec {}", m.bad_spec_pct);
        assert!(m.branch_mispredict_ratio > 0.35);
    }

    /// A branch fed by a DRAM-missing load costs more than one fed from L1,
    /// and software prefetching that load reduces bad-spec — the Fig. 16/22
    /// mechanism.
    #[test]
    fn load_fed_branches_resolve_faster_with_prefetch() {
        let mut rng = crate::util::Pcg64::new(11);
        let addrs: Vec<u64> = (0..20_000).map(|_| rng.below(1 << 31) & !63).collect();
        let outcomes: Vec<bool> = (0..20_000).map(|_| rng.next_f64() < 0.5).collect();

        let run = |prefetch: bool| {
            let mut s = sim();
            for i in 0..addrs.len() {
                if prefetch && i + 8 < addrs.len() {
                    s.event(Event::SwPrefetch { addr: addrs[i + 8] });
                }
                s.event(Event::Load { addr: addrs[i], size: 8, feeds_branch: true });
                s.event(Event::Branch { site: 3, taken: outcomes[i], conditional: true });
                s.event(Event::Compute { int_ops: 4, fp_ops: 2 });
            }
            s.finish();
            s.metrics()
        };
        let base = run(false);
        let pf = run(true);
        // absolute wrong-path cycles shrink (branches resolve from L2
        // instead of DRAM); the *fraction* can move either way because
        // the total also shrinks
        let base_bs = base.bad_spec_pct / 100.0 * base.cycles;
        let pf_bs = pf.bad_spec_pct / 100.0 * pf.cycles;
        assert!(
            pf_bs < base_bs,
            "prefetch should shrink bad-spec cycles: {base_bs:.0} -> {pf_bs:.0}"
        );
        assert!(pf.cycles < base.cycles, "and run faster overall");
    }

    #[test]
    fn topdown_fractions_sum_below_100() {
        let mut s = sim();
        let mut rng = crate::util::Pcg64::new(12);
        for _ in 0..5000 {
            s.event(Event::Load { addr: rng.below(1 << 28), size: 8, feeds_branch: false });
            s.event(Event::Branch { site: 1, taken: rng.next_f64() < 0.3, conditional: true });
            s.event(Event::Compute { int_ops: 2, fp_ops: 1 });
        }
        s.finish();
        let m = s.metrics();
        let sum = m.retiring_pct + m.bad_spec_pct + m.core_bound_pct + m.mem_bound_pct;
        assert!(sum <= 101.0, "top-down sum {sum}");
        assert!(sum >= 60.0, "unaccounted slots: {sum}");
        let pd_sum: f64 = m.port_dist.iter().sum();
        assert!((pd_sum - 1.0).abs() < 1e-6, "port dist sums to {pd_sum}");
    }

    #[test]
    fn recorder_integration_smoke() {
        let mut s = sim();
        {
            let mut r = Recorder::new(&mut s, 1);
            for i in 0..1000usize {
                r.load(i as u64 * 8, 8);
                r.compute(1, 2);
                r.cmp_branch(1, i % 7 == 0);
            }
            r.finish();
        }
        let m = s.metrics();
        assert_eq!(m.mix.loads, 1000);
        assert!(m.cycles > 0.0);
        assert!(m.cpi > 0.0);
    }

    #[test]
    #[should_panic(expected = "finish")]
    fn metrics_before_finish_panics() {
        let s = sim();
        let _ = s.metrics();
    }

    /// The per-event Sink path and the columnar BlockSink path must agree
    /// bit-for-bit on every metric for an arbitrary mixed stream.
    #[test]
    fn block_and_event_paths_produce_identical_metrics() {
        let mut rng = crate::util::Pcg64::new(77);
        let events: Vec<Event> = (0..30_000)
            .map(|_| match rng.below(7) {
                0 => Event::Compute { int_ops: rng.below(6) as u32, fp_ops: rng.below(6) as u32 },
                1 => Event::Serial { ops: 1 + rng.below(4) as u32 },
                2 => Event::Load {
                    addr: rng.below(1 << 30),
                    size: 1 + rng.below(256) as u32,
                    feeds_branch: rng.next_f64() < 0.2,
                },
                3 => Event::Store { addr: rng.below(1 << 30), size: 8 },
                4 => Event::Branch {
                    site: rng.below(64) as u32,
                    taken: rng.next_f64() < 0.5,
                    conditional: rng.next_f64() < 0.9,
                },
                5 => Event::LoopBranch {
                    site: rng.below(32) as u32,
                    count: 1 + rng.below(30) as u32,
                },
                _ => Event::SwPrefetch { addr: rng.below(1 << 30) },
            })
            .collect();

        let mut per_event = sim();
        for &ev in &events {
            per_event.event(ev);
        }
        Sink::finish(&mut per_event);

        let mut batched = sim();
        let mut block = EventBlock::with_capacity();
        for &ev in &events {
            block.push_event(ev);
            if block.is_full() {
                batched.consume(&block);
                block.clear();
            }
        }
        if !block.is_empty() {
            batched.consume(&block);
        }
        BlockSink::finalize(&mut batched);

        assert_eq!(per_event.metrics(), batched.metrics());
    }

    /// Functional warming must evolve every timing-independent quantity
    /// — instruction mix, branch counters (gshare state included), uop
    /// count, and all cache/prefetch statistics — exactly as detailed
    /// simulation does: warm the first half of a stream, simulate the
    /// second half detailed, and compare against a fully detailed run.
    #[test]
    fn warm_block_evolves_state_exactly() {
        let mut rng = crate::util::Pcg64::new(2024);
        let mut blocks: Vec<EventBlock> = Vec::new();
        let mut block = EventBlock::with_capacity();
        for _ in 0..40_000 {
            let ev = match rng.below(7) {
                0 => Event::Compute { int_ops: rng.below(6) as u32, fp_ops: rng.below(6) as u32 },
                1 => Event::Serial { ops: 1 + rng.below(4) as u32 },
                2 => Event::Load {
                    addr: rng.below(1 << 26),
                    size: 1 + rng.below(256) as u32,
                    feeds_branch: rng.next_f64() < 0.2,
                },
                3 => Event::Store { addr: rng.below(1 << 26), size: 8 },
                4 => Event::Branch {
                    site: rng.below(64) as u32,
                    taken: rng.next_f64() < 0.5,
                    conditional: rng.next_f64() < 0.9,
                },
                5 => Event::LoopBranch { site: rng.below(32) as u32, count: 1 + rng.below(30) as u32 },
                _ => Event::SwPrefetch { addr: rng.below(1 << 26) },
            };
            block.push_event(ev);
            if block.is_full() {
                blocks.push(std::mem::replace(&mut block, EventBlock::with_capacity()));
            }
        }
        if !block.is_empty() {
            blocks.push(block);
        }

        let mut full = sim();
        for b in &blocks {
            full.consume(b);
        }
        BlockSink::finalize(&mut full);

        let mut sampled = sim();
        let half = blocks.len() / 2;
        for b in &blocks[..half] {
            sampled.warm_block(b, 0.4);
        }
        for b in &blocks[half..] {
            sampled.consume(b);
        }
        BlockSink::finalize(&mut sampled);

        assert_eq!(full.mix, sampled.mix, "instruction mix diverged under warming");
        assert_eq!(full.branch_stats, sampled.branch_stats, "branch state diverged");
        assert_eq!(full.timeline().uops, sampled.timeline().uops, "uop count diverged");
        assert_eq!(full.hierarchy.l1.stats(), sampled.hierarchy.l1.stats());
        assert_eq!(full.hierarchy.l2.stats(), sampled.hierarchy.l2.stats());
        assert_eq!(full.hierarchy.l3.stats(), sampled.hierarchy.l3.stats());
        assert_eq!(full.hierarchy.pf_stats, sampled.hierarchy.pf_stats);
    }
}
