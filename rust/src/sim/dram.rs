//! DDR4 DRAM timing model — the Ramulator substitute for the paper's
//! row-buffer studies (Table VI configuration; Table VII and Figs. 20–21
//! experiments).
//!
//! Modelled: per-bank row buffers (open-page policy), activate/precharge/
//! CAS timing, data-bus serialization, two address-mapping schemes
//! (RoBaRaCoCh and ChRaBaRoCo), row hit/miss/conflict classification, and
//! an ideal-row-hit mode for the Table VII upper-bound column.
//!
//! Scheduling: requests are serviced in arrival order with per-bank timing
//! (an in-order approximation of FR-FCFS-Cap — with a single in-order core
//! stream the reorder window of FR-FCFS is rarely exercised, and the CAP
//! fairness rule only binds under multi-stream interference; the knob is
//! retained in the config and honoured by capping consecutive same-row
//! service bursts). DESIGN.md documents this substitution.

/// DRAM address mapping scheme (paper Section VI-A evaluates both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AddrMap {
    /// Row–Bank–Rank–Column–Channel (paper's reported scheme): column bits
    /// low → streaming accesses stay in an open row; adjacent rows map to
    /// different banks.
    RoBaRaCoCh,
    /// Channel–Rank–Bank–Row–Column: row bits below bank bits → crossing a
    /// row boundary stays in the same bank (precharge on stream).
    ChRaBaRoCo,
}

/// DDR4 configuration (defaults = paper Table VI).
#[derive(Debug, Clone)]
pub struct DramConfig {
    pub channels: u64,
    pub ranks: u64,
    pub banks: u64,
    pub rows_per_bank: u64,
    /// Row-buffer (DRAM page) size in bytes per bank.
    pub row_bytes: u64,
    pub addr_map: AddrMap,
    /// FR-FCFS-Cap: max consecutive same-row bursts before forcing a turn.
    pub cap: u32,
    /// Treat every access as a row hit (Table VII "Ideal Hit-Ratio").
    pub ideal_row_hits: bool,
    // --- timing (ns); defaults model DDR4-2400 CL17 ---
    pub t_rcd: f64,
    pub t_cl: f64,
    pub t_rp: f64,
    pub t_bl: f64,
    /// Constant controller + on-chip interconnect overhead added to every
    /// request's latency (calibrated so absolute latencies land in the
    /// paper's reported 68–94 ns band).
    pub t_overhead: f64,
}

impl Default for DramConfig {
    fn default() -> Self {
        Self {
            channels: 1,
            ranks: 1,
            banks: 16,
            rows_per_bank: 32 * 1024,
            row_bytes: 8 * 1024,
            addr_map: AddrMap::RoBaRaCoCh,
            cap: 4,
            ideal_row_hits: false,
            t_rcd: 14.16,
            t_cl: 14.16,
            t_rp: 14.16,
            t_bl: 3.33,
            t_overhead: 48.0,
        }
    }
}

/// Row-buffer outcome of one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowOutcome {
    /// Open row matches: CAS only.
    Hit,
    /// Bank idle (no open row): activate + CAS.
    Miss,
    /// Different row open: precharge + activate + CAS.
    Conflict,
}

/// Aggregate DRAM statistics.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct DramStats {
    pub requests: u64,
    pub reads: u64,
    pub writes: u64,
    pub prefetch_reads: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    /// Row hits among demand (non-prefetch, non-write) reads only — what
    /// the paper's `perf mem`-derived Ramulator traces measure.
    pub demand_row_hits: u64,
    pub total_latency_ns: f64,
    pub demand_requests: u64,
    pub demand_latency_ns: f64,
    pub bus_busy_ns: f64,
    pub last_completion_ns: f64,
    pub first_arrival_ns: f64,
}

impl DramStats {
    /// Row-buffer hit ratio of **demand reads** (Table VII col 2,
    /// Fig. 20). The paper's Ramulator study replays `perf mem` traces,
    /// which contain only demand misses; prefetcher fill traffic would
    /// otherwise mask the irregular-access behaviour under study.
    pub fn row_hit_ratio(&self) -> f64 {
        if self.demand_requests == 0 {
            0.0
        } else {
            self.demand_row_hits as f64 / self.demand_requests as f64
        }
    }

    /// Hit ratio over all traffic (incl. prefetch + writeback).
    pub fn row_hit_ratio_all(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.row_hits as f64 / self.requests as f64
        }
    }

    /// Average access latency over all requests, ns (Table VII col 3,
    /// Fig. 21).
    pub fn avg_latency_ns(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_latency_ns / self.requests as f64
        }
    }

    /// Average latency of demand (non-prefetch) reads, ns.
    pub fn avg_demand_latency_ns(&self) -> f64 {
        if self.demand_requests == 0 {
            0.0
        } else {
            self.demand_latency_ns / self.demand_requests as f64
        }
    }

    /// Data-bus utilization over the span of the trace (Fig. 9).
    pub fn bandwidth_utilization(&self) -> f64 {
        let span = self.last_completion_ns - self.first_arrival_ns;
        if span <= 0.0 {
            0.0
        } else {
            (self.bus_busy_ns / span).min(1.0)
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Bank {
    open_row: Option<u64>,
    busy_until: f64,
    consecutive_hits: u32,
}

/// One field of the precomputed address decomposition:
/// `value = (line >> shift) & mask`.
#[derive(Debug, Clone, Copy)]
struct Field {
    shift: u32,
    mask: u64,
}

impl Field {
    #[inline]
    fn extract(self, line: u64) -> u64 {
        // checked_shr so a degenerate geometry whose fields sum past 64
        // bits extracts 0, exactly as the sequential reference (which
        // shifted in < 64-bit steps) would
        line.checked_shr(self.shift).unwrap_or(0) & self.mask
    }
}

/// Precomputed shift/mask decomposition of a line address for one
/// `(AddrMap, DramConfig)` pair. The seed implementation walked a chain
/// of `take()` calls — each one a shift + mask serially dependent on the
/// previous — per request; here every field extracts independently from
/// the original line address (instruction-level parallel, branch-free),
/// which a parity test locks against the sequential reference.
///
/// `row` is special-cased: under RoBaRaCoCh the row takes **all**
/// remaining high bits modulo `rows_per_bank` (which therefore need not
/// be a power of two), so its mask stays `u64::MAX` and [`Dram::map`]
/// applies the modulo; under ChRaBaRoCo it is a plain masked field (the
/// constructor rejects a non-power-of-two `rows_per_bank` for that map).
#[derive(Debug, Clone, Copy)]
struct AddrFields {
    channel: Field,
    rank: Field,
    bank: Field,
    row: Field,
}

/// Low `bits` set. Well-defined for the full `0..=64` range (the seed's
/// `(1u64 << bits) - 1` overflowed in debug builds at `bits == 64`).
#[inline]
fn low_mask(bits: u32) -> u64 {
    if bits == 0 {
        0
    } else {
        u64::MAX >> (64 - bits)
    }
}

/// `log2` of a config field that must be an exact power of two — a hard
/// error instead of the seed's `debug_assert` (which vanished in release
/// builds and let a bad config silently mis-map every address).
fn checked_ilog2(x: u64, what: &str) -> crate::util::error::Result<u32> {
    if !x.is_power_of_two() {
        crate::bail!("dram config: {what} = {x} must be a power of two");
    }
    Ok(x.trailing_zeros())
}

/// The DRAM device + controller model.
pub struct Dram {
    cfg: DramConfig,
    banks: Vec<Bank>,
    bus_free_at: f64,
    pub stats: DramStats,
    fields: AddrFields,
}

/// Decomposed DRAM coordinates of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DramCoord {
    pub channel: u64,
    pub rank: u64,
    pub bank: u64,
    pub row: u64,
}

impl Dram {
    /// Build the model, validating the geometry: channels, ranks, banks,
    /// and columns-per-row must be powers of two (and `rows_per_bank`
    /// too under ChRaBaRoCo, where the row is a masked bit field).
    pub fn try_new(cfg: DramConfig) -> crate::util::error::Result<Self> {
        let nbanks = (cfg.channels * cfg.ranks * cfg.banks) as usize;
        if cfg.row_bytes < crate::trace::LINE_SIZE {
            crate::bail!(
                "dram config: row_bytes = {} is smaller than a {}-byte cache line",
                cfg.row_bytes,
                crate::trace::LINE_SIZE
            );
        }
        if cfg.rows_per_bank == 0 {
            crate::bail!("dram config: rows_per_bank must be nonzero");
        }
        let col_bits = checked_ilog2(cfg.row_bytes / crate::trace::LINE_SIZE, "columns per row")?;
        let bank_bits = checked_ilog2(cfg.banks, "banks")?;
        let rank_bits = checked_ilog2(cfg.ranks, "ranks")?;
        let chan_bits = checked_ilog2(cfg.channels, "channels")?;
        let fields = match cfg.addr_map {
            // LSB→MSB: channel, column, rank, bank, row
            AddrMap::RoBaRaCoCh => {
                let rank_shift = chan_bits + col_bits;
                let bank_shift = rank_shift + rank_bits;
                AddrFields {
                    channel: Field { shift: 0, mask: low_mask(chan_bits) },
                    rank: Field { shift: rank_shift, mask: low_mask(rank_bits) },
                    bank: Field { shift: bank_shift, mask: low_mask(bank_bits) },
                    // all remaining high bits, reduced mod rows_per_bank
                    // in map() (need not be a power of two)
                    row: Field { shift: bank_shift + bank_bits, mask: u64::MAX },
                }
            }
            // LSB→MSB: column, row, bank, rank, channel
            AddrMap::ChRaBaRoCo => {
                let row_bits =
                    checked_ilog2(cfg.rows_per_bank, "rows_per_bank (ChRaBaRoCo)")?;
                let bank_shift = col_bits + row_bits;
                let rank_shift = bank_shift + bank_bits;
                AddrFields {
                    channel: Field {
                        shift: rank_shift + rank_bits,
                        mask: low_mask(chan_bits),
                    },
                    rank: Field { shift: rank_shift, mask: low_mask(rank_bits) },
                    bank: Field { shift: bank_shift, mask: low_mask(bank_bits) },
                    row: Field { shift: col_bits, mask: low_mask(row_bits) },
                }
            }
        };
        Ok(Self {
            banks: vec![
                Bank { open_row: None, busy_until: 0.0, consecutive_hits: 0 };
                nbanks
            ],
            bus_free_at: 0.0,
            stats: DramStats::default(),
            cfg,
            fields,
        })
    }

    /// Infallible constructor for the well-formed configs the simulator
    /// stack builds internally; panics with the validation message on a
    /// malformed geometry (see [`Dram::try_new`] for the `Result` form).
    pub fn new(cfg: DramConfig) -> Self {
        Self::try_new(cfg).unwrap_or_else(|e| panic!("{e:#}"))
    }

    /// Map a byte address to DRAM coordinates under the configured
    /// scheme: four independent shift-and-mask extracts precomputed per
    /// config (plus one modulo for the RoBaRaCoCh row), instead of the
    /// serially dependent `take()` chain the seed walked per request.
    pub fn map(&self, addr: u64) -> DramCoord {
        // operate at cache-line granularity
        let line = addr / crate::trace::LINE_SIZE;
        let f = &self.fields;
        let row = match self.cfg.addr_map {
            AddrMap::RoBaRaCoCh => f.row.extract(line) % self.cfg.rows_per_bank,
            AddrMap::ChRaBaRoCo => f.row.extract(line),
        };
        DramCoord {
            channel: f.channel.extract(line),
            rank: f.rank.extract(line),
            bank: f.bank.extract(line),
            row,
        }
    }

    #[inline]
    fn bank_index(&self, c: &DramCoord) -> usize {
        ((c.channel * self.cfg.ranks + c.rank) * self.cfg.banks + c.bank) as usize
    }

    /// Service one request arriving at `arrival_ns`. Returns the request's
    /// total latency in ns (queueing + row op + transfer + overhead).
    pub fn request(&mut self, arrival_ns: f64, addr: u64, is_write: bool, is_prefetch: bool) -> f64 {
        let coord = self.map(addr);
        let bi = self.bank_index(&coord);

        if self.stats.requests == 0 {
            self.stats.first_arrival_ns = arrival_ns;
        }
        self.stats.requests += 1;
        if is_write {
            self.stats.writes += 1;
        } else if is_prefetch {
            self.stats.prefetch_reads += 1;
        } else {
            self.stats.reads += 1;
        }

        let bank = &mut self.banks[bi];
        let outcome = if self.cfg.ideal_row_hits {
            RowOutcome::Hit
        } else {
            match bank.open_row {
                Some(r) if r == coord.row => RowOutcome::Hit,
                Some(_) => RowOutcome::Conflict,
                None => RowOutcome::Miss,
            }
        };

        // FR-FCFS-Cap: after `cap` consecutive same-row hits the scheduler
        // forces a round-robin turn; under our in-order stream this shows
        // up as a one-burst bus delay.
        let cap_penalty = if outcome == RowOutcome::Hit {
            bank.consecutive_hits += 1;
            if bank.consecutive_hits > self.cfg.cap {
                bank.consecutive_hits = 0;
                self.cfg.t_bl
            } else {
                0.0
            }
        } else {
            bank.consecutive_hits = 0;
            0.0
        };

        let demand = !is_write && !is_prefetch;
        let op_ns = match outcome {
            RowOutcome::Hit => {
                self.stats.row_hits += 1;
                if demand {
                    self.stats.demand_row_hits += 1;
                }
                self.cfg.t_cl
            }
            RowOutcome::Miss => {
                self.stats.row_misses += 1;
                self.cfg.t_rcd + self.cfg.t_cl
            }
            RowOutcome::Conflict => {
                self.stats.row_conflicts += 1;
                self.cfg.t_rp + self.cfg.t_rcd + self.cfg.t_cl
            }
        };
        if !self.cfg.ideal_row_hits {
            bank.open_row = Some(coord.row);
        }

        // bank availability then data-bus slot
        let start = arrival_ns.max(bank.busy_until) + cap_penalty;
        let cas_done = start + op_ns;
        let xfer_start = cas_done.max(self.bus_free_at);
        let done = xfer_start + self.cfg.t_bl;
        bank.busy_until = cas_done;
        self.bus_free_at = done;

        let latency = done - arrival_ns + self.cfg.t_overhead;
        self.stats.total_latency_ns += latency;
        if !is_prefetch && !is_write {
            self.stats.demand_requests += 1;
            self.stats.demand_latency_ns += latency;
        }
        self.stats.bus_busy_ns += self.cfg.t_bl;
        self.stats.last_completion_ns = self.stats.last_completion_ns.max(done);
        latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The seed's sequential field extractor, kept verbatim as the
    /// parity reference for the precomputed mapper (with the latent
    /// `1 << 64` overflow replaced by [`low_mask`], which is what the
    /// seed computed for every reachable `bits`).
    fn take(a: &mut u64, bits: u32) -> u64 {
        let v = *a & low_mask(bits);
        *a >>= bits;
        v
    }

    /// Seed mapper logic, field by field, for parity locking.
    fn reference_map(cfg: &DramConfig, addr: u64) -> DramCoord {
        let ilog2 = |x: u64| {
            assert!(x.is_power_of_two(), "{x} must be a power of two");
            x.trailing_zeros()
        };
        let mut a = addr / crate::trace::LINE_SIZE;
        let col_bits = ilog2(cfg.row_bytes / crate::trace::LINE_SIZE);
        match cfg.addr_map {
            AddrMap::RoBaRaCoCh => {
                let channel = take(&mut a, ilog2(cfg.channels));
                let _col = take(&mut a, col_bits);
                let rank = take(&mut a, ilog2(cfg.ranks));
                let bank = take(&mut a, ilog2(cfg.banks));
                let row = a % cfg.rows_per_bank;
                DramCoord { channel, rank, bank, row }
            }
            AddrMap::ChRaBaRoCo => {
                let _col = take(&mut a, col_bits);
                let row = take(&mut a, ilog2(cfg.rows_per_bank));
                let bank = take(&mut a, ilog2(cfg.banks));
                let rank = take(&mut a, ilog2(cfg.ranks));
                let channel = take(&mut a, ilog2(cfg.channels));
                DramCoord { channel, rank, bank, row }
            }
        }
    }

    #[test]
    fn precomputed_map_matches_sequential_reference() {
        let configs = [
            DramConfig::default(),
            DramConfig { addr_map: AddrMap::ChRaBaRoCo, ..Default::default() },
            DramConfig { channels: 2, ranks: 2, banks: 8, ..Default::default() },
            DramConfig {
                channels: 4,
                ranks: 2,
                banks: 8,
                row_bytes: 2 * 1024,
                rows_per_bank: 64 * 1024,
                addr_map: AddrMap::ChRaBaRoCo,
                ..Default::default()
            },
            DramConfig { row_bytes: 64, rows_per_bank: 1, ..Default::default() },
        ];
        let mut rng = crate::util::Pcg64::new(0xD12A);
        for cfg in &configs {
            let d = Dram::new(cfg.clone());
            for _ in 0..20_000 {
                let addr = rng.below(1 << 40);
                assert_eq!(
                    d.map(addr),
                    reference_map(cfg, addr),
                    "mapper diverged for addr {addr:#x} under {cfg:?}"
                );
            }
            // boundary addresses
            for addr in [0, 63, 64, u64::MAX, u64::MAX - 63, 1 << 33] {
                assert_eq!(d.map(addr), reference_map(cfg, addr), "{addr:#x} under {cfg:?}");
            }
        }
    }

    #[test]
    fn non_power_of_two_geometry_is_an_error_not_a_silent_mismap() {
        let bad = DramConfig { banks: 12, ..Default::default() };
        let err = Dram::try_new(bad).unwrap_err().to_string();
        assert!(err.contains("power of two"), "{err}");

        let bad = DramConfig {
            rows_per_bank: 3000,
            addr_map: AddrMap::ChRaBaRoCo,
            ..Default::default()
        };
        let err = Dram::try_new(bad).unwrap_err().to_string();
        assert!(err.contains("rows_per_bank"), "{err}");

        // ...but a non-power-of-two rows_per_bank is fine under
        // RoBaRaCoCh, where the row is a modulo, not a bit field
        let ok = DramConfig { rows_per_bank: 3000, ..Default::default() };
        let d = Dram::try_new(ok.clone()).unwrap();
        assert_eq!(d.map(1 << 38), reference_map(&ok, 1 << 38));

        assert!(Dram::try_new(DramConfig { rows_per_bank: 0, ..Default::default() }).is_err());
        assert!(Dram::try_new(DramConfig { row_bytes: 32, ..Default::default() }).is_err());
    }

    #[test]
    fn low_mask_is_total_over_bit_widths() {
        assert_eq!(low_mask(0), 0);
        assert_eq!(low_mask(1), 1);
        assert_eq!(low_mask(7), 0x7F);
        assert_eq!(low_mask(64), u64::MAX);
    }

    fn dram() -> Dram {
        Dram::new(DramConfig::default())
    }

    #[test]
    fn sequential_same_row_hits_after_first() {
        let mut d = dram();
        let mut t = 0.0;
        // 64 consecutive lines: same row under RoBaRaCoCh (col bits low)
        for i in 0..64u64 {
            d.request(t, i * 64, false, false);
            t += 100.0;
        }
        assert_eq!(d.stats.row_misses, 1);
        assert_eq!(d.stats.row_hits, 63);
        assert!(d.stats.row_hit_ratio() > 0.98);
    }

    #[test]
    fn row_crossing_switches_banks_under_robaracoch() {
        let d = dram();
        let c0 = d.map(0);
        let c1 = d.map(8 * 1024); // next row-sized chunk
        assert_ne!(c0.bank, c1.bank, "RoBaRaCoCh spreads rows over banks");
    }

    #[test]
    fn row_crossing_same_bank_under_chrabaroco() {
        let d = Dram::new(DramConfig { addr_map: AddrMap::ChRaBaRoCo, ..Default::default() });
        let c0 = d.map(0);
        let c1 = d.map(8 * 1024);
        assert_eq!(c0.bank, c1.bank, "ChRaBaRoCo keeps adjacent rows in one bank");
        assert_ne!(c0.row, c1.row);
    }

    #[test]
    fn random_rows_mostly_conflict() {
        let mut d = dram();
        let mut rng = crate::util::Pcg64::new(6);
        let mut t = 0.0;
        for _ in 0..50_000 {
            let addr = rng.below(1 << 33) & !63;
            d.request(t, addr, false, false);
            t += 60.0;
        }
        let hr = d.stats.row_hit_ratio();
        assert!(hr < 0.15, "random stream must thrash rows: {hr}");
        let avg = d.stats.avg_latency_ns();
        assert!(avg > 80.0, "conflict-heavy latency should exceed hit latency: {avg}");
    }

    #[test]
    fn ideal_mode_all_hits_and_lower_latency() {
        let mut rng = crate::util::Pcg64::new(7);
        let addrs: Vec<u64> = (0..20_000).map(|_| rng.below(1 << 33) & !63).collect();
        let mut real = dram();
        let mut ideal = Dram::new(DramConfig { ideal_row_hits: true, ..Default::default() });
        let mut t = 0.0;
        for &a in &addrs {
            real.request(t, a, false, false);
            ideal.request(t, a, false, false);
            t += 70.0;
        }
        assert_eq!(ideal.stats.row_hit_ratio(), 1.0);
        assert!(ideal.stats.avg_latency_ns() < real.stats.avg_latency_ns());
        // the paper's ideal latencies sit in the ~65-75ns band
        let il = ideal.stats.avg_latency_ns();
        assert!((55.0..85.0).contains(&il), "ideal latency {il}");
    }

    #[test]
    fn bandwidth_utilization_scales_with_intensity() {
        // dense arrivals → high utilization; sparse → low
        let mut dense = dram();
        let mut sparse = dram();
        for i in 0..10_000u64 {
            dense.request(i as f64 * 4.0, i * 64, false, false);
            sparse.request(i as f64 * 400.0, i * 64, false, false);
        }
        assert!(dense.stats.bandwidth_utilization() > 0.5);
        assert!(sparse.stats.bandwidth_utilization() < 0.05);
    }

    #[test]
    fn queueing_adds_latency_under_bursts() {
        let mut d = dram();
        // all requests arrive at t=0 to different banks → bus serializes
        let mut lats = Vec::new();
        for i in 0..16u64 {
            lats.push(d.request(0.0, i * 8 * 1024, false, false));
        }
        assert!(lats[15] > lats[0], "later requests should queue on the bus");
    }

    #[test]
    fn stats_demand_vs_prefetch_partition() {
        let mut d = dram();
        d.request(0.0, 0, false, false);
        d.request(10.0, 64 * 1024, false, true);
        d.request(20.0, 128 * 1024, true, false);
        assert_eq!(d.stats.reads, 1);
        assert_eq!(d.stats.prefetch_reads, 1);
        assert_eq!(d.stats.writes, 1);
        assert_eq!(d.stats.demand_requests, 1);
    }

    #[test]
    fn map_is_total_and_in_range() {
        let d = dram();
        let mut rng = crate::util::Pcg64::new(8);
        for _ in 0..10_000 {
            let c = d.map(rng.below(1 << 35));
            assert!(c.bank < 16);
            assert!(c.row < 32 * 1024);
            assert_eq!(c.channel, 0);
            assert_eq!(c.rank, 0);
        }
    }

    #[test]
    fn empty_stats_are_zero() {
        let d = dram();
        assert_eq!(d.stats.row_hit_ratio(), 0.0);
        assert_eq!(d.stats.avg_latency_ns(), 0.0);
        assert_eq!(d.stats.bandwidth_utilization(), 0.0);
    }
}
