//! Micro-architectural simulators: the substitutes for the paper's
//! measurement substrate (perf/VTune on an i7-10700, Sniper, Ramulator).
//!
//! - [`branch`] — gshare branch predictor (Figs. 3–4).
//! - [`cache`] — 3-level set-associative hierarchy + perfect modes
//!   (Figs. 8, 12, 14).
//! - [`prefetch`] — hardware stream/adjacent-line prefetchers and the
//!   useless-prefetch accounting (Fig. 13); software prefetch plumbing.
//! - [`dram`] — DDR4 row-buffer/bank timing model, FR-FCFS-Cap
//!   approximation, address-mapping schemes (Table VII, Figs. 20–21).
//! - [`cpu`] — interval-style top-down pipeline model producing the
//!   paper's metric set (Figs. 1–10).
//! - [`multicore`] — shared-LLC/-bandwidth composition (Tables III/IV).
//! - [`reference`] — the seed cache layout, frozen as the bit-parity
//!   reference and performance baseline of the packed hot path.
//! - [`stack`] — single-pass reuse-distance (Mattson stack) profiler:
//!   exact-LRU miss curves for a whole sizes × ways sweep from one trace
//!   walk (`mlperf grid --sweep cache`).
//! - [`sample`] — SMARTS-style sampled simulation: periodic detailed
//!   windows + exact functional warming, CPI confidence intervals from
//!   inter-window variance (`--sample <detail>:<period>`).

pub mod branch;
pub mod cache;
pub mod cpu;
pub mod dram;
pub mod multicore;
pub mod prefetch;
pub mod reference;
pub mod sample;
pub mod stack;

pub use branch::{BranchStats, Gshare};
pub use cache::{
    BlockAccess, Cache, CacheModel, CacheStats, DramRequest, Hierarchy, HierarchyConfig, Level,
};
pub use cpu::{CpuConfig, Metrics, PipelineSim, TimelineSnapshot};
pub use dram::{AddrMap, Dram, DramConfig, DramStats, RowOutcome};
pub use multicore::{aggregate, percore_config, run_multicore, run_multicore_with_model};
pub use prefetch::{AdjacentLinePrefetcher, PrefetchStats, StreamPrefetcher};
pub use reference::{RefCache, RefHierarchy, RefPipelineSim};
pub use sample::{SampleConfig, SampleReport, SampledSim};
pub use stack::{default_sweep, demand_lines, StackProfiler, SweepCurve, SweepGeometry};
