//! Multi-core composition (Tables III and IV).
//!
//! The paper's multicore runs (`n_jobs = 4/8`) shard the work across
//! cores that share the LLC and the memory system. We model each core
//! with its own pipeline/L1/L2/branch state and account for the two
//! first-order shared-resource effects:
//!
//! 1. **LLC capacity sharing** — each core sees `L3/N` of effective
//!    capacity (capacity partitioning is the standard first-order model
//!    for homogeneous co-runners).
//! 2. **Memory bandwidth/queueing sharing** — each core sees a data bus
//!    whose effective burst occupancy is `N ×` longer (N co-runners
//!    interleave on one channel), which both raises queueing latency and
//!    caps per-core bandwidth.
//!
//! This reproduces the paper's Tables III/IV conclusion: the single-core
//! bottleneck structure (high CPI, bad-spec for tree workloads, large
//! DRAM bound) persists at 4 and 8 cores. DESIGN.md documents the
//! substitution (the paper used real hardware).

use super::cache::{Cache, CacheModel};
use super::cpu::{CpuConfig, Metrics, PipelineSim};
use crate::trace::Recorder;
use crate::util::stats;

/// Derive the per-core effective configuration for an `n`-core run.
pub fn percore_config(base: &CpuConfig, n_cores: usize) -> CpuConfig {
    assert!(n_cores >= 1);
    let mut cfg = base.clone();
    let n = n_cores as u64;
    // shared LLC: equal capacity partition, same associativity
    cfg.cache.l3_bytes = (base.cache.l3_bytes / n).max(cfg.cache.l2_bytes * 2);
    // shared channel: burst slots interleave N ways
    cfg.dram.t_bl = base.dram.t_bl * n_cores as f64;
    cfg
}

/// Aggregate per-core metrics into the per-workload row the paper's
/// tables report (arithmetic mean of ratios across homogeneous cores;
/// instruction/cycle totals summed).
pub fn aggregate(per_core: &[Metrics]) -> Metrics {
    assert!(!per_core.is_empty());
    let mut out = per_core[0].clone();
    let n = per_core.len() as f64;
    let m = |f: fn(&Metrics) -> f64| stats::mean(&per_core.iter().map(f).collect::<Vec<_>>());
    out.instructions = per_core.iter().map(|c| c.instructions).sum();
    out.cycles = per_core.iter().map(|c| c.cycles).fold(0.0, f64::max);
    out.cpi = m(|c| c.cpi);
    out.ipc = m(|c| c.ipc);
    out.retiring_pct = m(|c| c.retiring_pct);
    out.bad_spec_pct = m(|c| c.bad_spec_pct);
    out.core_bound_pct = m(|c| c.core_bound_pct);
    out.mem_bound_pct = m(|c| c.mem_bound_pct);
    out.dram_bound_pct = m(|c| c.dram_bound_pct);
    out.l2_bound_pct = m(|c| c.l2_bound_pct);
    out.l3_bound_pct = m(|c| c.l3_bound_pct);
    out.branch_mispredict_ratio = m(|c| c.branch_mispredict_ratio);
    out.branch_fraction = m(|c| c.branch_fraction);
    out.cond_branch_fraction = m(|c| c.cond_branch_fraction);
    out.l1_miss_ratio = m(|c| c.l1_miss_ratio);
    out.l2_miss_ratio = m(|c| c.l2_miss_ratio);
    out.llc_miss_ratio = m(|c| c.llc_miss_ratio);
    for i in 0..4 {
        out.port_dist[i] =
            per_core.iter().map(|c| c.port_dist[i]).sum::<f64>() / n;
    }
    out.sim_time_ns = per_core.iter().map(|c| c.sim_time_ns).fold(0.0, f64::max);
    out
}

/// Run an `n_cores`-way simulation: `run_core(core_id, rec)` drives core
/// `core_id`'s shard of the workload through a block-pipeline [`Recorder`]
/// into that core's private pipeline simulator. `ns` is the branch-site
/// namespace handed to each per-core recorder.
pub fn run_multicore<F>(base: &CpuConfig, n_cores: usize, ns: u32, run_core: F) -> Metrics
where
    F: FnMut(usize, &mut Recorder),
{
    run_multicore_with_model::<Cache, F>(base, n_cores, ns, run_core)
}

/// [`run_multicore`] over an explicit per-core cache model (the hot-path
/// parity tests drive the seed-layout reference through the identical
/// sharding/aggregation).
pub fn run_multicore_with_model<C: CacheModel, F>(
    base: &CpuConfig,
    n_cores: usize,
    ns: u32,
    mut run_core: F,
) -> Metrics
where
    F: FnMut(usize, &mut Recorder),
{
    let cfg = percore_config(base, n_cores);
    let mut per_core = Vec::with_capacity(n_cores);
    for core in 0..n_cores {
        let mut sim = PipelineSim::<C>::with_cache_model(cfg.clone());
        {
            let mut rec = Recorder::new(&mut sim, ns);
            run_core(core, &mut rec);
            rec.finish();
        }
        per_core.push(sim.metrics());
    }
    aggregate(&per_core)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percore_config_partitions_llc_and_bus() {
        let base = CpuConfig::default();
        let c4 = percore_config(&base, 4);
        assert_eq!(c4.cache.l3_bytes, base.cache.l3_bytes / 4);
        assert!((c4.dram.t_bl - base.dram.t_bl * 4.0).abs() < 1e-12);
        let c1 = percore_config(&base, 1);
        assert_eq!(c1.cache.l3_bytes, base.cache.l3_bytes);
    }

    #[test]
    fn llc_partition_never_below_l2() {
        let base = CpuConfig::default();
        let c = percore_config(&base, 64);
        assert!(c.cache.l3_bytes >= 2 * c.cache.l2_bytes);
    }

    #[test]
    fn aggregate_means_ratios_sums_instructions() {
        let mut a = Metrics::default();
        a.cpi = 1.0;
        a.instructions = 100;
        a.cycles = 100.0;
        let mut b = Metrics::default();
        b.cpi = 2.0;
        b.instructions = 300;
        b.cycles = 600.0;
        let g = aggregate(&[a, b]);
        assert_eq!(g.cpi, 1.5);
        assert_eq!(g.instructions, 400);
        assert_eq!(g.cycles, 600.0, "wall time = slowest core");
    }

    #[test]
    fn contention_raises_dram_pressure() {
        // same per-core random-access shard on 1 vs 8 cores
        let mut rng = crate::util::Pcg64::new(13);
        let addrs: Vec<u64> = (0..20_000).map(|_| rng.below(1 << 31) & !63).collect();
        let drive = |_c: usize, rec: &mut Recorder| {
            for &a in &addrs {
                rec.load(a, 8);
                rec.compute(2, 1);
            }
        };
        let base = CpuConfig::default();
        let m1 = run_multicore(&base, 1, 1, drive);
        let m8 = run_multicore(&base, 8, 1, drive);
        assert!(
            m8.cpi >= m1.cpi * 0.9,
            "8-core contention should not make cores faster: {} vs {}",
            m8.cpi,
            m1.cpi
        );
        // headline property the paper reports: DRAM remains a bottleneck
        assert!(m8.dram_bound_pct > 10.0);
    }
}
