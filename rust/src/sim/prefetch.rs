//! Hardware prefetcher models.
//!
//! Two components mirror the mid-range Intel parts the paper measures on:
//! an **adjacent-line prefetcher** (on an L2 demand miss, also fetch the
//! buddy next line) and a **stream prefetcher** (per-4KiB-page stride
//! detector that, once confident, runs `degree` lines ahead). Together
//! they reproduce the paper's Fig. 13 observation: ~40%+ of issued
//! hardware prefetches are useless for irregular `A[B[i]]` access streams,
//! while streaming matrix workloads prefetch near-perfectly.

use crate::trace::{line_of, page_of, LINE_SIZE};

/// One tracked stream (a 4KiB page with an established direction).
#[derive(Clone, Copy, Debug, Default)]
struct StreamEntry {
    page: u64,
    last_line: u64,
    dir: i64,
    confidence: u8,
    stamp: u64,
    valid: bool,
}

/// Stride/stream prefetcher with a small fully-associative stream table.
pub struct StreamPrefetcher {
    entries: Vec<StreamEntry>,
    stamp: u64,
    /// Lines to run ahead once a stream is confirmed.
    pub degree: u64,
    /// Confidence threshold before issuing.
    pub threshold: u8,
}

impl StreamPrefetcher {
    pub fn new(table_size: usize, degree: u64) -> Self {
        Self {
            entries: vec![StreamEntry::default(); table_size],
            stamp: 0,
            degree,
            threshold: 2,
        }
    }

    /// Default: 32 streams, degree 4 (typical L2 streamer settings).
    pub fn default_config() -> Self {
        Self::new(32, 4)
    }

    /// Observe a demand access at `addr`; push prefetch candidate line
    /// addresses into `out`.
    pub fn observe(&mut self, addr: u64, out: &mut Vec<u64>) {
        self.stamp += 1;
        let line = line_of(addr);
        let page = page_of(addr);
        // Find an entry for this page.
        let found = self.entries.iter().position(|e| e.valid && e.page == page);
        match found {
            Some(i) => {
                // update in place (§Perf: the tracked-stream case runs on
                // every L2 miss — no copy-out/copy-back of the entry)
                let stamp = self.stamp;
                let e = &mut self.entries[i];
                let delta = line as i64 - e.last_line as i64;
                if delta == 0 {
                    return; // same line, nothing to learn
                }
                if (delta > 0) == (e.dir > 0) && delta.abs() <= 2 {
                    e.confidence = e.confidence.saturating_add(1);
                } else {
                    e.dir = if delta > 0 { 1 } else { -1 };
                    e.confidence = 0;
                }
                e.last_line = line;
                e.stamp = stamp;
                if e.confidence >= self.threshold {
                    for k in 1..=self.degree {
                        let target = line as i64 + e.dir * k as i64;
                        if target >= 0 && page_of(target as u64 * LINE_SIZE) == page {
                            out.push(target as u64 * LINE_SIZE);
                        }
                    }
                }
            }
            None => {
                // Allocate, evicting the LRU entry.
                let victim = self
                    .entries
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, e)| if e.valid { e.stamp } else { 0 })
                    .map(|(i, _)| i)
                    .unwrap();
                self.entries[victim] = StreamEntry {
                    page,
                    last_line: line,
                    dir: 1,
                    confidence: 0,
                    stamp: self.stamp,
                    valid: true,
                };
            }
        }
    }
}

/// Adjacent-line ("buddy") prefetcher: on an L2 demand miss, fetch the
/// other line of the 128-byte aligned pair.
pub struct AdjacentLinePrefetcher;

impl AdjacentLinePrefetcher {
    /// Buddy line address for a missing line.
    #[inline]
    pub fn buddy(addr: u64) -> u64 {
        let line = line_of(addr);
        let buddy_line = line ^ 1;
        buddy_line * LINE_SIZE
    }
}

/// Aggregate prefetch statistics (hardware and software separately).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct PrefetchStats {
    pub hw_issued: u64,
    pub hw_useful: u64,
    pub hw_useless: u64,
    pub sw_issued: u64,
    pub sw_useful: u64,
    pub sw_useless: u64,
}

impl PrefetchStats {
    /// Fraction of hardware prefetches that were evicted untouched
    /// (Fig. 13). Uses resolved prefetches (useful+useless) as denominator;
    /// in-flight-at-end-of-trace prefetches are not counted either way.
    pub fn hw_useless_fraction(&self) -> f64 {
        let resolved = self.hw_useful + self.hw_useless;
        if resolved == 0 {
            0.0
        } else {
            self.hw_useless as f64 / resolved as f64
        }
    }

    /// Same for software prefetches.
    pub fn sw_useless_fraction(&self) -> f64 {
        let resolved = self.sw_useful + self.sw_useless;
        if resolved == 0 {
            0.0
        } else {
            self.sw_useless as f64 / resolved as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_triggers_prefetch() {
        let mut p = StreamPrefetcher::default_config();
        let mut out = Vec::new();
        // touch lines 0..6 of one page
        for i in 0..6u64 {
            p.observe(i * LINE_SIZE, &mut out);
        }
        assert!(!out.is_empty(), "stream not detected");
        // prefetches run ahead of the access that triggered them (the
        // first trigger can fire as early as line 2) and reach past the
        // end of the touched range
        assert!(out.iter().all(|&a| a > 2 * LINE_SIZE));
        assert!(out.iter().any(|&a| a > 5 * LINE_SIZE));
    }

    #[test]
    fn descending_stream_detected() {
        let mut p = StreamPrefetcher::default_config();
        let mut out = Vec::new();
        for i in (10..40u64).rev() {
            p.observe(i * LINE_SIZE, &mut out);
        }
        assert!(!out.is_empty());
        assert!(out.iter().any(|&a| line_of(a) < 10 + 5));
    }

    #[test]
    fn random_pages_do_not_trigger() {
        let mut p = StreamPrefetcher::default_config();
        let mut out = Vec::new();
        let mut rng = crate::util::Pcg64::new(3);
        for _ in 0..1000 {
            let page = rng.below(1 << 20);
            p.observe(page * 4096 + (rng.below(64)) * 64, &mut out);
        }
        // a few accidental repeats may train a stream, but the vast
        // majority of random accesses must not issue prefetches
        assert!(out.len() < 100, "issued {} prefetches on random", out.len());
    }

    #[test]
    fn prefetches_stay_within_page() {
        let mut p = StreamPrefetcher::default_config();
        let mut out = Vec::new();
        // walk the last lines of a page
        for i in 58..64u64 {
            p.observe(3 * 4096 + i * LINE_SIZE, &mut out);
        }
        for &a in &out {
            assert_eq!(page_of(a), 3, "prefetch crossed page: {a:#x}");
        }
    }

    #[test]
    fn buddy_pairs() {
        assert_eq!(AdjacentLinePrefetcher::buddy(0), 64);
        assert_eq!(AdjacentLinePrefetcher::buddy(64), 0);
        assert_eq!(AdjacentLinePrefetcher::buddy(129), 192);
    }

    #[test]
    fn useless_fraction_math() {
        let st = PrefetchStats { hw_issued: 10, hw_useful: 3, hw_useless: 6, ..Default::default() };
        assert!((st.hw_useless_fraction() - 6.0 / 9.0).abs() < 1e-12);
        assert_eq!(PrefetchStats::default().hw_useless_fraction(), 0.0);
    }
}
