//! Seed-layout reference cache, retained verbatim for parity testing and
//! as the performance baseline of the packed hot path.
//!
//! [`RefCache`] is the cache level exactly as the seed shipped it: three
//! parallel `Vec`s (`tags`/`meta`/`lru`), 8-byte global LRU stamps, a
//! branchy per-way scan, and a full-set `invalidate` sweep. It implements
//! [`CacheModel`], so [`RefHierarchy`]/[`RefPipelineSim`] drive the
//! *identical* hierarchy and timeline code over the old probe path —
//! `tests/hotpath_parity.rs` asserts bit-identical `CacheStats`,
//! `PrefetchStats`, and full `Metrics` against the packed
//! [`Cache`](super::cache::Cache), and `benches/pipeline_throughput.rs`
//! measures the layout speedup against it. Do not "fix" or optimize this
//! module: its value is being frozen seed behavior.

use super::cache::{CacheModel, CacheStats, Evicted, Hierarchy};
use super::cpu::PipelineSim;
use crate::trace::LINE_SIZE;

// Per-line metadata bits (seed encoding).
const VALID: u8 = 1;
const DIRTY: u8 = 2;
const HW_PF: u8 = 4;
const SW_PF: u8 = 8;

/// One set-associative cache level in the seed's scattered layout.
pub struct RefCache {
    sets: usize,
    ways: usize,
    tags: Vec<u64>,
    meta: Vec<u8>,
    lru: Vec<u64>,
    stamp: u64,
    /// Perfect mode: every demand access hits (Fig. 12 idealization).
    pub perfect: bool,
    pub stats: CacheStats,
}

impl RefCache {
    fn set_of(&self, line: u64) -> usize {
        (line as usize) & (self.sets - 1)
    }

    fn slot_range(&self, set: usize) -> std::ops::Range<usize> {
        set * self.ways..(set + 1) * self.ways
    }
}

impl CacheModel for RefCache {
    fn new(size_bytes: u64, ways: usize) -> Self {
        let lines = (size_bytes / LINE_SIZE) as usize;
        assert!(lines % ways == 0, "size/ways mismatch");
        let sets = lines / ways;
        assert!(sets.is_power_of_two(), "sets must be a power of two");
        Self {
            sets,
            ways,
            tags: vec![0; lines],
            meta: vec![0; lines],
            lru: vec![0; lines],
            stamp: 0,
            perfect: false,
            stats: CacheStats::default(),
        }
    }

    fn set_perfect(&mut self, on: bool) {
        self.perfect = on;
    }

    fn is_perfect(&self) -> bool {
        self.perfect
    }

    fn stats(&self) -> &CacheStats {
        &self.stats
    }

    fn demand_probe(&mut self, line: u64, store: bool) -> (bool, bool, bool) {
        self.stats.accesses += 1;
        self.stamp += 1;
        if self.perfect {
            return (true, false, false);
        }
        let set = self.set_of(line);
        for i in self.slot_range(set) {
            if self.meta[i] & VALID != 0 && self.tags[i] == line {
                self.lru[i] = self.stamp;
                let was_hw = self.meta[i] & HW_PF != 0;
                let was_sw = self.meta[i] & SW_PF != 0;
                self.meta[i] &= !(HW_PF | SW_PF);
                if store {
                    self.meta[i] |= DIRTY;
                }
                return (true, was_hw, was_sw);
            }
        }
        self.stats.misses += 1;
        (false, false, false)
    }

    fn contains(&self, line: u64) -> bool {
        if self.perfect {
            return true;
        }
        let set = self.set_of(line);
        self.slot_range(set)
            .any(|i| self.meta[i] & VALID != 0 && self.tags[i] == line)
    }

    fn fill(&mut self, line: u64, store: bool, hw_pf: bool, sw_pf: bool) -> Option<Evicted> {
        if self.perfect {
            return None;
        }
        self.stamp += 1;
        let set = self.set_of(line);
        // single pass: existing copy + victim tracking, as in the seed
        let mut victim = set * self.ways;
        let mut best = u64::MAX;
        for i in self.slot_range(set) {
            if self.meta[i] & VALID == 0 {
                if best != 0 {
                    victim = i;
                    best = 0;
                }
                continue;
            }
            if self.tags[i] == line {
                self.lru[i] = self.stamp;
                if store {
                    self.meta[i] |= DIRTY;
                }
                return None;
            }
            if self.lru[i] < best {
                best = self.lru[i];
                victim = i;
            }
        }
        let evicted = if self.meta[victim] & VALID != 0 {
            let dirty = self.meta[victim] & DIRTY != 0;
            if dirty {
                self.stats.writebacks += 1;
            }
            Some(Evicted {
                line: self.tags[victim],
                dirty,
                untouched_hw_pf: self.meta[victim] & HW_PF != 0,
                untouched_sw_pf: self.meta[victim] & SW_PF != 0,
            })
        } else {
            None
        };
        self.tags[victim] = line;
        self.lru[victim] = self.stamp;
        self.meta[victim] = VALID
            | if store { DIRTY } else { 0 }
            | if hw_pf { HW_PF } else { 0 }
            | if sw_pf { SW_PF } else { 0 };
        evicted
    }

    fn invalidate(&mut self, line: u64) {
        // seed behavior: scan every way even after the (unique) match
        let set = self.set_of(line);
        for i in self.slot_range(set) {
            if self.meta[i] & VALID != 0 && self.tags[i] == line {
                self.meta[i] = 0;
            }
        }
    }
}

/// Hierarchy over the seed cache layout.
pub type RefHierarchy = Hierarchy<RefCache>;

/// Full pipeline simulator over the seed cache layout — same timeline
/// code as the default [`PipelineSim`], differing only in the probe path.
pub type RefPipelineSim = PipelineSim<RefCache>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ref_cache_basic_hit_miss() {
        let mut c = RefCache::new(1024, 2);
        let (hit, _, _) = c.demand_probe(1, false);
        assert!(!hit);
        c.fill(1, false, false, false);
        let (hit2, _, _) = c.demand_probe(1, false);
        assert!(hit2);
        assert_eq!(c.stats.accesses, 2);
        assert_eq!(c.stats.misses, 1);
    }
}
