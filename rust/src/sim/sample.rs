//! SMARTS-style sampled simulation: periodic detailed windows + functional
//! warming, with confidence intervals from inter-window variance.
//!
//! Full replay of billions of trace events is the floor on grid latency.
//! [`SampledSim`] wraps a [`PipelineSim`] and schedules its input block
//! stream into two regimes (Wunderlich et al., *SMARTS: Accelerating
//! Microarchitecture Simulation via Rigorous Statistical Sampling*,
//! ISCA 2003):
//!
//! - **Detailed windows** — `detail` consecutive [`EventBlock`]s out of
//!   every `period` run through the full timeline model
//!   ([`BlockSink::consume`]): ROB/MSHR window, stall attribution, branch
//!   flush costs, and the DDR4 row-buffer model.
//! - **Functional warming** — the remaining `period − detail` blocks run
//!   through [`PipelineSim::warm_block`]: cache tag arrays (all levels,
//!   hardware prefetchers included), branch-predictor state, instruction
//!   mix, and the uop count evolve *exactly* as under detailed
//!   simulation — none of those consult the timeline — while cycles,
//!   stalls and DRAM timing are skipped.
//!
//! Because warming is exact, every *state-derived* metric in the produced
//! [`Metrics`] — cache miss ratios, prefetch stats, branch mispredict
//! ratio, instruction mix — equals the full run bit-for-bit (the
//! `warm_block_evolves_state_exactly` test in [`super::cpu`] locks this).
//! Only *timeline* quantities (cycles, stall decomposition, DRAM request
//! timing) are estimated, by scaling the detailed-window sums with
//! `S = total_instructions / detailed_instructions`; their uncertainty is
//! reported as a 95% confidence interval on CPI derived from the
//! inter-window variance of per-window CPI (Student-t, n−1 df), widened
//! by a relative floor that absorbs window-boundary cold-start bias.
//!
//! The degenerate configuration `detail >= period` disables sampling
//! entirely: every block is consumed detailed and the report's estimate
//! is the full-run [`PipelineSim::metrics`] bit-exactly with a zero-width
//! interval (the CLI's `--sample N:N` escape hatch, also the anchor for
//! the `tests/sampling.rs` degenerate-case gate).

use super::cache::{Cache, CacheModel};
use super::cpu::{Metrics, PipelineSim, TimelineSnapshot};
use super::dram::DramStats;
use crate::trace::{BlockSink, EventBlock};
use crate::util::stats::{sample_stddev, t95};
use crate::util::telemetry;
use std::fmt;

/// Sampling schedule: out of every `period` event blocks, the first
/// `detail` are simulated in detail and the rest are functionally warmed.
///
/// Granularity is the [`EventBlock`] (4096 events), so the default
/// `2:256` means detailed windows of ~8k events every ~1M events — a
/// 0.78% detailed fraction, which puts the wall-clock floor at the cost
/// of the warming path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SampleConfig {
    /// Detailed blocks per period (window length).
    pub detail: u64,
    /// Schedule period in blocks.
    pub period: u64,
}

impl SampleConfig {
    pub const DEFAULT_DETAIL: u64 = 2;
    pub const DEFAULT_PERIOD: u64 = 256;

    /// Parse `"<detail>:<period>"` (both nonzero). Returns `None` on any
    /// malformed input so the CLI can report the expected shape.
    pub fn parse(s: &str) -> Option<Self> {
        let (d, p) = s.split_once(':')?;
        let detail: u64 = d.trim().parse().ok()?;
        let period: u64 = p.trim().parse().ok()?;
        if detail == 0 || period == 0 {
            return None;
        }
        Some(Self { detail, period })
    }

    /// `detail >= period`: every block is detailed, sampling is a
    /// pass-through and the estimate is exact.
    pub fn is_degenerate(&self) -> bool {
        self.detail >= self.period
    }

    /// Fraction of blocks simulated in detail (1.0 when degenerate).
    pub fn detailed_fraction(&self) -> f64 {
        (self.detail as f64 / self.period as f64).min(1.0)
    }
}

impl Default for SampleConfig {
    fn default() -> Self {
        Self { detail: Self::DEFAULT_DETAIL, period: Self::DEFAULT_PERIOD }
    }
}

impl fmt::Display for SampleConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.detail, self.period)
    }
}

/// One closed detailed window's timeline contribution.
#[derive(Debug, Clone, Copy)]
struct WindowStat {
    instructions: u64,
    cycles: f64,
}

/// Result of a sampled run: the estimated metric set plus the sampling
/// diagnostics needed to judge it.
#[derive(Debug, Clone)]
pub struct SampleReport {
    pub sample: SampleConfig,
    /// Closed detailed windows that contributed to the estimate.
    pub windows: usize,
    pub blocks_total: u64,
    pub blocks_detailed: u64,
    /// Exact instruction count of the whole stream (warming counts too).
    pub instructions: u64,
    /// Instructions retired inside detailed windows.
    pub instructions_detailed: u64,
    /// The estimated metric set. State-derived metrics (miss ratios,
    /// prefetch, branch ratios, mix) are **exact**; timeline metrics
    /// (cycles, CPI, stall percentages, DRAM stats) are extrapolated.
    pub estimate: Metrics,
    /// 95% half-width on `estimate.cpi` from inter-window variance.
    /// Zero when degenerate. Because the core-bound add-on is computed
    /// exactly from the full mix, the timeline half-width carries over
    /// to the final CPI unchanged.
    pub cpi_ci95: f64,
    /// `detail >= period`: `estimate` is the full-run metrics bit-exactly.
    pub degenerate: bool,
}

impl SampleReport {
    /// Does `truth` (a full-run CPI) fall inside the reported interval?
    pub fn cpi_within_ci(&self, truth: f64) -> bool {
        (truth - self.estimate.cpi).abs() <= self.cpi_ci95
    }
}

/// Relative CI floor: the interval never narrows below ±5% of the
/// estimate (±50% with a single window). Inter-window variance measures
/// sampling noise but not the small systematic biases of windowing —
/// MSHR/ROB state is discarded at window close ([`PipelineSim::
/// close_sample_window`]) so each window starts cold, and warmed gaps
/// advance the DRAM clock at an estimated rate — and the floor keeps the
/// reported interval honest about them.
const REL_CI_FLOOR: f64 = 0.05;
const SINGLE_WINDOW_REL_CI: f64 = 0.5;

/// A [`BlockSink`] that samples its input stream: detailed windows via
/// the wrapped [`PipelineSim`], functional warming in between. Drop-in
/// wherever a simulator sinks blocks (`ReplaySource`, `PipelinedIngest`,
/// `Broadcast` fan-out) — the scheduling is purely positional, so the
/// delivery mechanism is irrelevant as long as blocks arrive in order.
pub struct SampledSim<C: CacheModel = Cache> {
    sim: PipelineSim<C>,
    cfg: SampleConfig,
    blocks_total: u64,
    blocks_detailed: u64,
    /// Timeline snapshot at the open window's start, if inside one.
    window_open: Option<TimelineSnapshot>,
    windows: Vec<WindowStat>,
    /// Cycles-per-uop rate for the warm clock, refreshed from each
    /// closed window. Block 0 is always detailed, so the placeholder is
    /// replaced before the first warmed block on any nonempty stream.
    warm_rate: f64,
    report: Option<SampleReport>,
    /// Telemetry span covering the open detailed window (inactive when
    /// telemetry is off — purely observational, never touches state).
    window_span: telemetry::Span,
}

impl SampledSim<Cache> {
    /// Sampled simulator over the packed hot-path cache model.
    pub fn new(sim: PipelineSim<Cache>, cfg: SampleConfig) -> Self {
        Self::with_model(sim, cfg)
    }
}

impl<C: CacheModel> SampledSim<C> {
    /// Sampled simulator over an explicit cache model.
    pub fn with_model(sim: PipelineSim<C>, cfg: SampleConfig) -> Self {
        Self {
            sim,
            cfg,
            blocks_total: 0,
            blocks_detailed: 0,
            window_open: None,
            windows: Vec::new(),
            warm_rate: 0.3,
            report: None,
            window_span: telemetry::Span::inactive(),
        }
    }

    /// The wrapped simulator (tests compare its state to a full run).
    pub fn inner(&self) -> &PipelineSim<C> {
        &self.sim
    }

    /// The report; available after `finalize()`.
    pub fn try_report(&self) -> Option<&SampleReport> {
        self.report.as_ref()
    }

    /// The report; panics before `finalize()`.
    pub fn report(&self) -> &SampleReport {
        self.try_report().expect("finalize() the sampled stream before report()")
    }

    /// Consume the simulator, yielding the report. Panics before
    /// `finalize()`.
    pub fn into_report(self) -> SampleReport {
        self.report.expect("finalize() the sampled stream before into_report()")
    }

    fn close_window(&mut self) {
        // dropping the span records the window's wall time
        self.window_span = telemetry::Span::inactive();
        let open = self.window_open.take().expect("no open window to close");
        let now = self.sim.timeline();
        let instructions = now.instructions - open.instructions;
        let cycles = now.cycle - open.cycle;
        if instructions > 0 {
            let uops = (now.uops - open.uops).max(1.0);
            self.warm_rate = (cycles / uops).max(0.0);
            self.windows.push(WindowStat { instructions, cycles });
        }
        self.sim.close_sample_window();
    }

    /// Scale the DRAM model's counters to the whole stream. Counts and
    /// time *sums* scale by `S`; the arrival/completion timestamps stay —
    /// the warm clock keeps simulated time advancing across gaps, so the
    /// activity span already covers the run and bandwidth utilization
    /// (busy ns over span) comes out right once `bus_busy_ns` is scaled.
    fn scale_dram(d: &DramStats, s: f64) -> DramStats {
        let c = |x: u64| (x as f64 * s).round() as u64;
        DramStats {
            requests: c(d.requests),
            reads: c(d.reads),
            writes: c(d.writes),
            prefetch_reads: c(d.prefetch_reads),
            row_hits: c(d.row_hits),
            row_misses: c(d.row_misses),
            row_conflicts: c(d.row_conflicts),
            demand_row_hits: c(d.demand_row_hits),
            total_latency_ns: d.total_latency_ns * s,
            demand_requests: c(d.demand_requests),
            demand_latency_ns: d.demand_latency_ns * s,
            bus_busy_ns: d.bus_busy_ns * s,
            last_completion_ns: d.last_completion_ns,
            first_arrival_ns: d.first_arrival_ns,
        }
    }

    /// Mirror of [`PipelineSim::metrics`] with the timeline components
    /// replaced by their scaled estimates. Everything fed from the mix,
    /// branch counters, cache stats, or the uop count is computed from
    /// the *exact* full-stream values.
    fn estimated_metrics(&self, s: f64, det_cycles: f64) -> Metrics {
        let cfg = self.sim.config();
        let tl = self.sim.timeline();
        let mix = self.sim.mix();
        let branch = self.sim.branch_stats();

        // timeline estimates: stalls only accrue inside detailed windows,
        // so the accumulators are already pure detailed sums
        let cycle_hat = det_cycles * s;
        let bad_spec = tl.bad_spec_cycles * s;
        let l2_stall = tl.l2_stall * s;
        let l3_stall = tl.l3_stall * s;
        let dram_stall = tl.dram_stall * s;

        // exact components (uop count and mix are exact under warming)
        let base_cycles = tl.uops / cfg.width;
        let fp_cycles = mix.fp_ops as f64 / cfg.fp_ports;
        let int_cycles = mix.int_ops as f64 / cfg.int_ports;
        let mem_cycles = (mix.loads + mix.stores) as f64 / cfg.mem_ports;
        let port_limit = fp_cycles.max(int_cycles).max(mem_cycles);
        let core_bound = (port_limit - base_cycles).max(0.0);
        let total = cycle_hat + core_bound;

        let mem_stall = l2_stall + l3_stall + dram_stall;
        let instructions = tl.instructions;
        let pct = |x: f64| 100.0 * x / total.max(1e-9);

        let stall = (bad_spec + mem_stall).min(total);
        let busy = (total - stall - core_bound).max(0.0);
        let busy_ipc = if busy > 0.0 { tl.uops / busy } else { 0.0 };
        let (p2, p3) = if busy_ipc >= 3.0 {
            (0.25, 0.75)
        } else if busy_ipc >= 2.0 {
            let t = busy_ipc - 2.0;
            (1.0 - t * 0.75, t * 0.75)
        } else {
            (busy_ipc / 2.0, 0.0)
        };
        let port_dist = [
            stall / total.max(1e-9),
            core_bound / total.max(1e-9) + busy / total.max(1e-9) * (1.0 - p2 - p3).max(0.0),
            busy / total.max(1e-9) * p2,
            busy / total.max(1e-9) * p3,
        ];

        Metrics {
            instructions,
            cycles: total,
            cpi: total / instructions.max(1) as f64,
            ipc: instructions as f64 / total.max(1e-9),
            retiring_pct: pct(base_cycles),
            bad_spec_pct: pct(bad_spec),
            core_bound_pct: pct(core_bound),
            mem_bound_pct: pct(mem_stall),
            dram_bound_pct: pct(dram_stall),
            l2_bound_pct: pct(l2_stall),
            l3_bound_pct: pct(l3_stall),
            branch_mispredict_ratio: branch.mispredict_ratio(),
            branch_fraction: mix.branch_fraction(),
            cond_branch_fraction: mix.conditional_branch_fraction(),
            l1_miss_ratio: self.sim.hierarchy.l1.stats().miss_ratio(),
            l2_miss_ratio: self.sim.hierarchy.l2.stats().miss_ratio(),
            llc_miss_ratio: self.sim.hierarchy.l3.stats().miss_ratio(),
            port_dist,
            mix: mix.clone(),
            branch,
            dram: Self::scale_dram(&self.sim.dram.stats, s),
            prefetch: self.sim.hierarchy.pf_stats,
            sim_time_ns: total / cfg.freq_ghz,
        }
    }

    fn build_report(&self) -> SampleReport {
        if self.cfg.is_degenerate() {
            let m = self.sim.metrics();
            return SampleReport {
                sample: self.cfg,
                windows: 0,
                blocks_total: self.blocks_total,
                blocks_detailed: self.blocks_detailed,
                instructions: m.instructions,
                instructions_detailed: m.instructions,
                estimate: m,
                cpi_ci95: 0.0,
                degenerate: true,
            };
        }
        let tl = self.sim.timeline();
        let det_instr: u64 = self.windows.iter().map(|w| w.instructions).sum();
        let det_cycles: f64 = self.windows.iter().map(|w| w.cycles).sum();
        let s = if det_instr > 0 { tl.instructions as f64 / det_instr as f64 } else { 1.0 };
        let estimate = self.estimated_metrics(s, det_cycles);

        // CI on CPI from per-window CPI variance (ratio estimator noise):
        // Student-t half-width over n windows, widened by the relative
        // floor that absorbs windowing bias (see REL_CI_FLOOR).
        let cpis: Vec<f64> =
            self.windows.iter().map(|w| w.cycles / w.instructions as f64).collect();
        let n = cpis.len();
        let cpi_ci95 = match n {
            0 => 0.0,
            1 => SINGLE_WINDOW_REL_CI * estimate.cpi,
            _ => {
                let hw = t95(n - 1) * sample_stddev(&cpis) / (n as f64).sqrt();
                hw.max(REL_CI_FLOOR * estimate.cpi)
            }
        };

        SampleReport {
            sample: self.cfg,
            windows: n,
            blocks_total: self.blocks_total,
            blocks_detailed: self.blocks_detailed,
            instructions: tl.instructions,
            instructions_detailed: det_instr,
            estimate,
            cpi_ci95,
            degenerate: false,
        }
    }
}

impl<C: CacheModel> BlockSink for SampledSim<C> {
    fn consume(&mut self, block: &EventBlock) {
        let pos = self.blocks_total % self.cfg.period;
        self.blocks_total += 1;
        if self.cfg.is_degenerate() {
            // pure pass-through: no window bookkeeping may touch the
            // simulator (close_sample_window would drop in-flight loads
            // and change the timeline vs an unwrapped run)
            self.blocks_detailed += 1;
            self.sim.consume(block);
            return;
        }
        if pos < self.cfg.detail {
            if self.window_open.is_none() {
                self.window_open = Some(self.sim.timeline());
                self.window_span = telemetry::span(telemetry::Stage::Window);
            }
            self.sim.consume(block);
            self.blocks_detailed += 1;
            if pos + 1 == self.cfg.detail {
                self.close_window();
            }
        } else {
            self.sim.warm_block(block, self.warm_rate);
        }
    }

    fn finalize(&mut self) {
        // stream may end mid-window
        if self.window_open.is_some() {
            self.close_window();
        }
        self.sim.finalize();
        self.report = Some(self.build_report());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cpu::CpuConfig;
    use crate::trace::Event;

    fn random_blocks(n_events: usize, seed: u64) -> Vec<EventBlock> {
        let mut rng = crate::util::Pcg64::new(seed);
        let mut blocks = Vec::new();
        let mut block = EventBlock::with_capacity();
        for _ in 0..n_events {
            let ev = match rng.below(7) {
                0 => Event::Compute { int_ops: rng.below(6) as u32, fp_ops: rng.below(6) as u32 },
                1 => Event::Serial { ops: 1 + rng.below(4) as u32 },
                2 => Event::Load {
                    addr: rng.below(1 << 27),
                    size: 1 + rng.below(128) as u32,
                    feeds_branch: rng.next_f64() < 0.2,
                },
                3 => Event::Store { addr: rng.below(1 << 27), size: 8 },
                4 => Event::Branch {
                    site: rng.below(64) as u32,
                    taken: rng.next_f64() < 0.5,
                    conditional: rng.next_f64() < 0.9,
                },
                5 => Event::LoopBranch { site: rng.below(32) as u32, count: 1 + rng.below(30) as u32 },
                _ => Event::SwPrefetch { addr: rng.below(1 << 27) },
            };
            block.push_event(ev);
            if block.is_full() {
                blocks.push(std::mem::replace(&mut block, EventBlock::with_capacity()));
            }
        }
        if !block.is_empty() {
            blocks.push(block);
        }
        blocks
    }

    fn run_full(blocks: &[EventBlock]) -> Metrics {
        let mut sim = PipelineSim::new(CpuConfig::default());
        for b in blocks {
            sim.consume(b);
        }
        BlockSink::finalize(&mut sim);
        sim.metrics()
    }

    fn run_sampled(blocks: &[EventBlock], cfg: SampleConfig) -> SampleReport {
        let mut s = SampledSim::new(PipelineSim::new(CpuConfig::default()), cfg);
        for b in blocks {
            s.consume(b);
        }
        BlockSink::finalize(&mut s);
        s.into_report()
    }

    #[test]
    fn parse_and_display_roundtrip() {
        let c = SampleConfig::parse("3:97").unwrap();
        assert_eq!(c, SampleConfig { detail: 3, period: 97 });
        assert_eq!(c.to_string(), "3:97");
        assert_eq!(SampleConfig::parse(" 2 : 256 "), Some(SampleConfig::default()));
        for bad in ["", "3", ":", "0:5", "5:0", "a:b", "1:2:3", "-1:4"] {
            assert!(SampleConfig::parse(bad).is_none(), "{bad:?} must not parse");
        }
        assert!(!SampleConfig::default().is_degenerate());
        assert!(SampleConfig { detail: 8, period: 8 }.is_degenerate());
        assert!(SampleConfig { detail: 9, period: 8 }.is_degenerate());
        assert!((SampleConfig::default().detailed_fraction() - 2.0 / 256.0).abs() < 1e-12);
    }

    /// `detail == period` must reproduce the unwrapped simulator
    /// bit-for-bit — the whole Metrics struct, not just headline numbers.
    #[test]
    fn degenerate_config_is_bit_exact() {
        let blocks = random_blocks(60_000, 41);
        let full = run_full(&blocks);
        for cfg in [SampleConfig { detail: 4, period: 4 }, SampleConfig { detail: 7, period: 3 }] {
            let rep = run_sampled(&blocks, cfg);
            assert!(rep.degenerate);
            assert_eq!(rep.cpi_ci95, 0.0);
            assert_eq!(rep.estimate, full, "degenerate {cfg} must be the full run");
            assert_eq!(rep.blocks_detailed, rep.blocks_total);
            assert_eq!(rep.instructions_detailed, rep.instructions);
        }
    }

    /// The headline sampling contract: state-derived metrics exact, CPI
    /// inside its own reported interval.
    #[test]
    fn sampled_estimate_is_exact_where_promised_and_close_elsewhere() {
        let blocks = random_blocks(300_000, 7);
        let full = run_full(&blocks);
        let rep = run_sampled(&blocks, SampleConfig { detail: 2, period: 16 });

        assert!(!rep.degenerate);
        assert!(rep.windows >= 4, "expected several windows, got {}", rep.windows);
        assert!(rep.blocks_detailed < rep.blocks_total);
        let e = &rep.estimate;

        // exact under warming: everything not fed by the timeline
        assert_eq!(e.instructions, full.instructions);
        assert_eq!(e.mix, full.mix);
        assert_eq!(e.branch, full.branch);
        assert_eq!(e.prefetch, full.prefetch);
        assert_eq!(e.l1_miss_ratio, full.l1_miss_ratio);
        assert_eq!(e.l2_miss_ratio, full.l2_miss_ratio);
        assert_eq!(e.llc_miss_ratio, full.llc_miss_ratio);
        assert_eq!(e.branch_mispredict_ratio, full.branch_mispredict_ratio);

        // estimated: CPI inside the interval the report itself claims
        assert!(rep.cpi_ci95 > 0.0);
        assert!(
            rep.cpi_within_ci(full.cpi),
            "cpi {} ± {} must cover truth {}",
            e.cpi,
            rep.cpi_ci95,
            full.cpi
        );
        // and the interval is not absurdly wide on a homogeneous stream
        assert!(rep.cpi_ci95 < 0.5 * full.cpi, "ci {} vs cpi {}", rep.cpi_ci95, full.cpi);
    }

    /// DRAM counter scaling preserves the ratios the paper reports.
    #[test]
    fn scaled_dram_ratios_track_full_run() {
        let blocks = random_blocks(300_000, 7);
        let full = run_full(&blocks);
        let rep = run_sampled(&blocks, SampleConfig { detail: 2, period: 16 });
        let (e, f) = (&rep.estimate.dram, &full.dram);
        assert!(f.requests > 0, "stream must generate DRAM traffic");
        // demand-read row-hit ratio: the sampled windows see a subset of
        // the same access pattern, so the ratio lands near the full run
        assert!(
            (e.row_hit_ratio() - f.row_hit_ratio()).abs() < 0.15,
            "row hit ratio {} vs {}",
            e.row_hit_ratio(),
            f.row_hit_ratio()
        );
        // scaled request count lands within the CI-floor band
        let ratio = e.requests as f64 / f.requests as f64;
        assert!((0.5..2.0).contains(&ratio), "request scaling off: {ratio}");
    }

    #[test]
    fn report_before_finalize_is_none() {
        let s = SampledSim::new(PipelineSim::new(CpuConfig::default()), SampleConfig::default());
        assert!(s.try_report().is_none());
    }

    /// A stream shorter than one full period still produces a report
    /// (single window, wide interval).
    #[test]
    fn short_stream_single_window() {
        let blocks = random_blocks(6_000, 13); // 2 blocks
        let full = run_full(&blocks);
        let rep = run_sampled(&blocks, SampleConfig { detail: 2, period: 1024 });
        assert_eq!(rep.windows, 1);
        // the whole stream was detailed, so the estimate is the full
        // timeline (S == 1) up to the close_sample_window tail policy,
        // which matches finish() exactly: bit-equal CPI
        assert_eq!(rep.estimate.cpi, full.cpi);
        assert!((rep.cpi_ci95 - SINGLE_WINDOW_REL_CI * rep.estimate.cpi).abs() < 1e-12);
    }
}
