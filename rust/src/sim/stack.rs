//! Single-pass reuse-distance (Mattson stack) profiler: exact-LRU miss
//! counts for **every** swept cache geometry from one walk of the trace.
//!
//! The classical observation (Mattson et al. 1970): under true LRU, an
//! access to line `L` hits a `W`-way set-associative cache iff the number
//! of *distinct* same-set lines touched since the previous access to `L`
//! — its per-set reuse distance `d` — satisfies `d < W`. LRU stacks are
//! inclusive across associativities, so one per-set distance histogram
//! answers the hit/miss question for every way count at once:
//!
//! ```text
//! misses(S sets, W ways) = accesses − Σ_{d < W} hist_S[d]
//! ```
//!
//! (cold accesses and distances beyond the deepest tracked way always
//! miss and therefore never enter the histogram). Geometries sharing a
//! set count `S = bytes / (64 · ways)` share one histogram, so a sizes ×
//! ways sweep costs one distance structure per distinct *set-index
//! class*, not one simulation per geometry.
//!
//! # Hot path
//!
//! The distance query is order-statistics based (Bennett–Kruskal), not a
//! linear stack scan: each set keeps a Fenwick tree over access-sequence
//! slots in which the most-recent slot of every tracked line carries a
//! mark. The reuse distance of an access is then the count of marks
//! *after* the line's previous slot — two `O(log cap)` tree operations —
//! instead of an `O(depth)` move-to-front walk. Slots are recycled by an
//! amortized-`O(1)` compaction when the slot clock reaches capacity.
//!
//! Tracking is bounded by the deepest way count the sweep asks about:
//! once a set tracks `max_ways` lines, the coldest tracked line (found by
//! Fenwick descent, also `O(log cap)`) is dropped — a line deeper than
//! every swept associativity misses everywhere, so nothing is lost.
//!
//! # Parity domain
//!
//! The profiler models a *standalone* demand-only exact-LRU cache — the
//! same replacement the packed [`Cache`](super::Cache) implements for
//! `demand_probe`/`fill` — and walks the block's demand lanes (loads and
//! stores, in recorded order, expanded to touched lines exactly like
//! [`Hierarchy::access_block`](super::Hierarchy::access_block) does).
//! Hierarchy-level effects (inclusive back-invalidation, prefetch fills)
//! are outside the model, which is precisely why `tests/stack_parity.rs`
//! can gate the predicted miss counts **bit-exactly** against a real
//! [`Cache`](super::Cache) driven by the same line stream.

use crate::trace::{BlockSink, EventBlock, EventKind, LINE_SIZE};
use std::collections::HashMap;

/// One swept cache geometry: capacity in bytes and associativity, with
/// the crate-wide 64-byte lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SweepGeometry {
    pub bytes: u64,
    pub ways: usize,
}

impl SweepGeometry {
    pub fn new(bytes: u64, ways: usize) -> Self {
        Self { bytes, ways }
    }

    /// Number of sets: `bytes / (64 · ways)`.
    pub fn sets(&self) -> u64 {
        self.bytes / (LINE_SIZE * self.ways as u64)
    }

    /// Human label, e.g. `64KiB/8w`.
    pub fn label(&self) -> String {
        format!("{}/{}w", fmt_bytes(self.bytes), self.ways)
    }
}

impl std::fmt::Display for SweepGeometry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

fn fmt_bytes(b: u64) -> String {
    const MIB: u64 = 1024 * 1024;
    if b >= MIB && b % MIB == 0 {
        format!("{}MiB", b / MIB)
    } else {
        format!("{}KiB", b / 1024)
    }
}

/// The standard `mlperf grid --sweep cache` geometry grid: 16 KiB …
/// 8 MiB × {2, 4, 8, 16} ways — 40 geometries spanning the paper's L1
/// through LLC capacities, every one an exact-LRU configuration the
/// profiler resolves from a single trace pass.
pub fn default_sweep() -> Vec<SweepGeometry> {
    let mut out = Vec::new();
    let mut bytes = 16 * 1024u64;
    while bytes <= 8 * 1024 * 1024 {
        for ways in [2usize, 4, 8, 16] {
            out.push(SweepGeometry::new(bytes, ways));
        }
        bytes *= 2;
    }
    out
}

/// One geometry's resolved point on the miss curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepCurve {
    pub geometry: SweepGeometry,
    /// Demand line accesses (shared by every geometry — one trace pass).
    pub accesses: u64,
    /// Exact-LRU demand misses for this geometry.
    pub misses: u64,
}

impl SweepCurve {
    pub fn miss_ratio(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }
}

/// Fenwick (binary indexed) tree over `cap` slots, 1-based internally.
/// Marks are 0/1 per slot; `prefix` and `first_marked` are `O(log cap)`.
#[derive(Debug)]
struct Fenwick {
    tree: Vec<u32>,
}

impl Fenwick {
    fn new(cap: usize) -> Self {
        Self { tree: vec![0; cap + 1] }
    }

    fn cap(&self) -> usize {
        self.tree.len() - 1
    }

    /// Add `delta` (±1) at 1-based index `i`.
    fn add(&mut self, mut i: usize, delta: u32) {
        while i < self.tree.len() {
            self.tree[i] = self.tree[i].wrapping_add(delta);
            i += i & i.wrapping_neg();
        }
    }

    /// Sum of marks at 1-based indices `1..=i`.
    fn prefix(&self, mut i: usize) -> u32 {
        let mut s = 0u32;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }

    /// Smallest 1-based index carrying a mark (standard top-down binary
    /// descent for the first index with prefix ≥ 1). Caller guarantees at
    /// least one mark exists.
    fn first_marked(&self) -> usize {
        let mut idx = 0usize;
        let mut remaining = 1u32;
        let mut step = self.cap().next_power_of_two();
        while step > 0 {
            let next = idx + step;
            if next < self.tree.len() && self.tree[next] < remaining {
                idx = next;
                remaining -= self.tree[next];
            }
            step >>= 1;
        }
        idx + 1
    }

    fn clear(&mut self) {
        self.tree.iter_mut().for_each(|v| *v = 0);
    }
}

/// One cache set's bounded recency structure: marks over access-sequence
/// slots plus the line ↔ slot maps the queries need.
#[derive(Debug)]
struct SetStack {
    bit: Fenwick,
    /// Line occupying each 0-based slot (meaningful only where marked).
    slot_line: Vec<u64>,
    /// line → 0-based slot of its most recent access.
    pos: HashMap<u64, u32>,
    /// Next 0-based slot to assign; compaction rewinds it.
    clock: u32,
}

impl SetStack {
    fn new(slot_cap: usize) -> Self {
        Self {
            bit: Fenwick::new(slot_cap),
            slot_line: vec![0; slot_cap],
            pos: HashMap::new(),
            clock: 0,
        }
    }

    /// Record an access to `line`, tracking at most `depth_cap` lines.
    /// Returns the per-set reuse distance, or `None` for an access that
    /// misses every swept geometry (cold, or deeper than `depth_cap`).
    fn access(&mut self, line: u64, depth_cap: u32) -> Option<u32> {
        let live = self.pos.len() as u32;
        let dist = match self.pos.get(&line).copied() {
            Some(p) => {
                // distance = tracked lines touched after p = marks at
                // slots strictly greater than p
                let d = live - self.bit.prefix(p as usize + 1);
                self.bit.add(p as usize + 1, 1u32.wrapping_neg());
                Some(d)
            }
            None => {
                if live >= depth_cap {
                    // drop the coldest tracked line: at depth ≥ depth_cap
                    // it misses every swept associativity anyway
                    let oldest = self.bit.first_marked();
                    self.bit.add(oldest, 1u32.wrapping_neg());
                    let evicted = self.slot_line[oldest - 1];
                    self.pos.remove(&evicted);
                }
                None
            }
        };
        self.place(line);
        dist
    }

    /// Put `line` at the freshest slot, compacting first if the slot
    /// clock hit capacity.
    fn place(&mut self, line: u64) {
        if self.clock as usize == self.bit.cap() {
            self.compact();
        }
        let p = self.clock;
        self.bit.add(p as usize + 1, 1);
        self.slot_line[p as usize] = line;
        self.pos.insert(line, p);
        self.clock += 1;
    }

    /// Reassign the tracked lines to slots `0..live` preserving recency
    /// order. Tracked depth is bounded well below the slot capacity, so
    /// every compaction buys ≥ 3× depth_cap cheap accesses — amortized
    /// `O(1)` per access.
    fn compact(&mut self) {
        let mut entries: Vec<(u32, u64)> =
            self.pos.iter().map(|(&line, &p)| (p, line)).collect();
        entries.sort_unstable();
        self.bit.clear();
        for (new_p, &(_, line)) in entries.iter().enumerate() {
            self.bit.add(new_p + 1, 1);
            self.slot_line[new_p] = line;
            self.pos.insert(line, new_p as u32);
        }
        self.clock = entries.len() as u32;
    }
}

/// All geometries sharing one set count: one histogram, `sets` stacks.
#[derive(Debug)]
struct SetClass {
    sets: u64,
    /// Deepest way count any geometry of this class asks about.
    depth_cap: u32,
    /// `hist[d]` = accesses whose per-set reuse distance was exactly `d`
    /// (`d < depth_cap`; deeper/cold accesses are misses everywhere and
    /// are counted only through the access total).
    hist: Vec<u64>,
    stacks: Vec<SetStack>,
}

impl SetClass {
    fn new(sets: u64, depth_cap: u32) -> Self {
        // 4× headroom over the tracked depth keeps compactions rare;
        // floor of 64 slots keeps tiny depth caps out of thrash territory
        let slot_cap = (depth_cap as usize * 4).max(64);
        Self {
            sets,
            depth_cap,
            hist: vec![0; depth_cap as usize],
            stacks: (0..sets).map(|_| SetStack::new(slot_cap)).collect(),
        }
    }

    #[inline]
    fn access(&mut self, line: u64) {
        let s = (line & (self.sets - 1)) as usize;
        if let Some(d) = self.stacks[s].access(line, self.depth_cap) {
            self.hist[d as usize] += 1;
        }
    }
}

/// The single-pass sweep profiler. Construct with every geometry the
/// sweep will ask about, stream the trace in (it is a [`BlockSink`]),
/// then read exact-LRU miss counts per geometry in closed form.
#[derive(Debug)]
pub struct StackProfiler {
    geometries: Vec<SweepGeometry>,
    classes: Vec<SetClass>,
    accesses: u64,
}

impl StackProfiler {
    /// Panics on a geometry the exact-LRU model cannot represent (zero
    /// ways, capacity not divisible into whole sets, or a set count that
    /// is not a power of two — the same constraints
    /// [`Cache::new`](super::Cache::new) asserts).
    pub fn new(geometries: &[SweepGeometry]) -> Self {
        assert!(!geometries.is_empty(), "sweep needs at least one geometry");
        let mut by_sets: Vec<(u64, u32)> = Vec::new();
        for g in geometries {
            assert!(g.ways > 0, "geometry {g:?} has zero ways");
            assert!(
                g.bytes % (LINE_SIZE * g.ways as u64) == 0,
                "geometry {g:?}: size/ways mismatch"
            );
            let sets = g.sets();
            assert!(
                sets > 0 && sets.is_power_of_two(),
                "geometry {g:?}: sets must be a power of two"
            );
            match by_sets.iter_mut().find(|(s, _)| *s == sets) {
                Some((_, cap)) => *cap = (*cap).max(g.ways as u32),
                None => by_sets.push((sets, g.ways as u32)),
            }
        }
        by_sets.sort_unstable();
        Self {
            geometries: geometries.to_vec(),
            classes: by_sets.iter().map(|&(s, cap)| SetClass::new(s, cap)).collect(),
            accesses: 0,
        }
    }

    /// The geometries this profiler was built for.
    pub fn geometries(&self) -> &[SweepGeometry] {
        &self.geometries
    }

    /// Number of distinct set-index classes (one distance structure each).
    pub fn classes(&self) -> usize {
        self.classes.len()
    }

    /// Record one demand line access against every set-index class.
    #[inline]
    pub fn access_line(&mut self, line: u64) {
        self.accesses += 1;
        for class in &mut self.classes {
            class.access(line);
        }
    }

    /// Total demand line accesses profiled.
    pub fn accesses(&self) -> u64 {
        self.accesses
    }

    /// Closed-form exact-LRU demand miss count for `g`:
    /// `accesses − Σ_{d < ways} hist[d]` over `g`'s set-index class.
    /// Panics if `g`'s class was not registered at construction.
    pub fn misses_for(&self, g: SweepGeometry) -> u64 {
        let sets = g.sets();
        let class = self
            .classes
            .iter()
            .find(|c| c.sets == sets)
            .unwrap_or_else(|| panic!("geometry {g} was not in the swept set"));
        assert!(
            g.ways as u32 <= class.depth_cap,
            "geometry {g} is deeper than the tracked depth"
        );
        let hits: u64 = class.hist[..g.ways].iter().sum();
        self.accesses - hits
    }

    /// The full miss curve, one point per constructed geometry.
    pub fn curves(&self) -> Vec<SweepCurve> {
        self.geometries
            .iter()
            .map(|&g| SweepCurve {
                geometry: g,
                accesses: self.accesses,
                misses: self.misses_for(g),
            })
            .collect()
    }
}

/// Append the demand line stream of `block` to `out` — loads and stores
/// in recorded order, each expanded to its touched lines, exactly the
/// walk [`Hierarchy::access_block`](super::Hierarchy::access_block)
/// performs for demand traffic (prefetches excluded: the profiler models
/// a demand-only cache). `StackProfiler::consume` and the parity tests
/// share this definition so the two streams cannot drift.
pub fn demand_lines(block: &EventBlock, out: &mut Vec<u64>) {
    let (mut li, mut sti) = (0usize, 0usize);
    for &kind in block.kinds() {
        match kind {
            EventKind::Load => {
                let (first, last) = block.loads[li].line_span();
                li += 1;
                out.extend(first..=last);
            }
            EventKind::Store => {
                let (first, last) = block.stores[sti].line_span();
                sti += 1;
                out.extend(first..=last);
            }
            _ => {}
        }
    }
}

impl BlockSink for StackProfiler {
    fn consume(&mut self, block: &EventBlock) {
        let (mut li, mut sti) = (0usize, 0usize);
        for &kind in block.kinds() {
            match kind {
                EventKind::Load => {
                    let (first, last) = block.loads[li].line_span();
                    li += 1;
                    for line in first..=last {
                        self.access_line(line);
                    }
                }
                EventKind::Store => {
                    let (first, last) = block.stores[sti].line_span();
                    sti += 1;
                    for line in first..=last {
                        self.access_line(line);
                    }
                }
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Cache;
    use crate::util::Pcg64;

    /// Infinite-stack LRU reference: hit iff the line's depth among
    /// distinct same-set lines is < ways. O(n²) — test-only oracle.
    fn naive_misses(lines: &[u64], sets: u64, ways: usize) -> u64 {
        let mut stacks: Vec<Vec<u64>> = vec![Vec::new(); sets as usize];
        let mut misses = 0u64;
        for &l in lines {
            let st = &mut stacks[(l & (sets - 1)) as usize];
            match st.iter().rposition(|&x| x == l) {
                Some(i) => {
                    let depth = st.len() - 1 - i;
                    if depth >= ways {
                        misses += 1;
                    }
                    st.remove(i);
                    st.push(l);
                }
                None => {
                    misses += 1;
                    st.push(l);
                }
            }
        }
        misses
    }

    fn packed_cache_misses(lines: &[u64], g: SweepGeometry) -> (u64, u64) {
        let mut c = Cache::new(g.bytes, g.ways);
        for &l in lines {
            let (hit, _, _) = c.demand_probe(l, false);
            if !hit {
                c.fill(l, false, false, false);
            }
        }
        (c.stats.accesses, c.stats.misses)
    }

    #[test]
    fn hand_checked_single_set() {
        // sets=1 geometries: bytes = 64 * ways
        let gs = [SweepGeometry::new(64, 1), SweepGeometry::new(128, 2), SweepGeometry::new(256, 4)];
        let mut p = StackProfiler::new(&gs);
        for l in [10u64, 11, 10, 12, 11, 10] {
            p.access_line(l);
        }
        // distances: 10 cold, 11 cold, 10 d=1, 12 cold, 11 d=1, 10 d=2
        assert_eq!(p.accesses(), 6);
        assert_eq!(p.misses_for(gs[0]), 6, "direct-mapped-equivalent: every distance ≥ 1 misses");
        assert_eq!(p.misses_for(gs[1]), 4, "2-way: the two d=1 accesses hit");
        assert_eq!(p.misses_for(gs[2]), 3, "4-way: d=1,1,2 all hit");
        assert_eq!(p.classes(), 1, "all three geometries share sets=1");
    }

    #[test]
    fn eviction_and_compaction_match_naive_reference() {
        // depth cap 2 with a working set far beyond it, plus enough
        // accesses to force slot compaction many times over
        let g = SweepGeometry::new(256, 2); // sets=2, ways=2
        let mut p = StackProfiler::new(&[g]);
        let mut rng = Pcg64::new(7);
        let lines: Vec<u64> = (0..5000).map(|_| rng.next_u64() % 37).collect();
        for &l in &lines {
            p.access_line(l);
        }
        assert_eq!(p.misses_for(g), naive_misses(&lines, 2, 2));
    }

    #[test]
    fn random_stream_parity_with_packed_cache() {
        let gs = [
            SweepGeometry::new(4 * 1024, 1),
            SweepGeometry::new(8 * 1024, 2),
            SweepGeometry::new(16 * 1024, 4),
            SweepGeometry::new(64 * 1024, 8),
            SweepGeometry::new(128 * 1024, 16),
        ];
        let mut p = StackProfiler::new(&gs);
        let mut rng = Pcg64::new(0xDA7A);
        // skewed stream: hot region with occasional cold sweeps
        let lines: Vec<u64> = (0..30_000)
            .map(|i| {
                if i % 7 == 0 {
                    rng.next_u64() % 100_000
                } else {
                    rng.next_u64() % 600
                }
            })
            .collect();
        for &l in &lines {
            p.access_line(l);
        }
        for g in gs {
            let (acc, misses) = packed_cache_misses(&lines, g);
            assert_eq!(acc, p.accesses());
            assert_eq!(misses, p.misses_for(g), "geometry {g}");
        }
    }

    #[test]
    fn curves_cover_every_geometry_and_are_monotone_in_ways() {
        let gs = default_sweep();
        assert!(gs.len() >= 32, "sweep must span ≥ 32 geometries");
        let mut p = StackProfiler::new(&gs);
        let mut rng = Pcg64::new(3);
        for _ in 0..20_000 {
            p.access_line(rng.next_u64() % 50_000);
        }
        let curves = p.curves();
        assert_eq!(curves.len(), gs.len());
        // more ways at equal sets can only hit more (stack inclusion)
        for a in &curves {
            for b in &curves {
                if a.geometry.sets() == b.geometry.sets() && a.geometry.ways < b.geometry.ways {
                    assert!(a.misses >= b.misses, "{} vs {}", a.geometry, b.geometry);
                }
            }
        }
    }

    #[test]
    fn consume_matches_demand_lines_walk() {
        use crate::trace::EventBlock;
        let mut block = EventBlock::with_capacity();
        block.push_compute(1, 2);
        block.push_load(1000, 8, false);
        block.push_store(64 * 50, 160); // spans 3 lines
        block.push_serial(1);
        block.push_load(64 * 51 + 60, 8, true); // straddles 2 lines
        block.push_prefetch(4096); // excluded from the demand walk
        let mut want = Vec::new();
        demand_lines(&block, &mut want);
        assert_eq!(want, vec![15, 50, 51, 52, 51, 52]);

        let g = SweepGeometry::new(128, 2);
        let mut via_consume = StackProfiler::new(&[g]);
        via_consume.consume(&block);
        let mut via_lines = StackProfiler::new(&[g]);
        for &l in &want {
            via_lines.access_line(l);
        }
        assert_eq!(via_consume.accesses(), via_lines.accesses());
        assert_eq!(via_consume.misses_for(g), via_lines.misses_for(g));
    }

    #[test]
    fn labels_render_sizes() {
        assert_eq!(SweepGeometry::new(16 * 1024, 2).label(), "16KiB/2w");
        assert_eq!(SweepGeometry::new(8 * 1024 * 1024, 16).label(), "8MiB/16w");
    }

    #[test]
    #[should_panic(expected = "sets must be a power of two")]
    fn invalid_geometry_is_rejected() {
        // 192 KiB / 2 ways → 1536 sets: not a power of two
        let _ = StackProfiler::new(&[SweepGeometry::new(192 * 1024, 2)]);
    }
}
