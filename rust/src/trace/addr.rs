//! Virtual address space modelling.
//!
//! Workloads don't trace the *host* addresses of their Rust vectors (those
//! would be polluted by allocator layout and by the tracing machinery
//! itself). Instead each logical array is allocated a region in a modelled
//! virtual address space, and element accesses are translated to modelled
//! addresses. This is what makes layout reordering experiments clean: a
//! data-layout reorder changes the row→address map and nothing else.

/// 4 KiB OS pages, matching the paper's locality-blocking discussion
/// (row-buffer locality is exploited *within* an OS page because
/// virtual→physical mapping beyond a page is unknown to userspace).
pub const PAGE_SIZE: u64 = 4096;
/// 64-byte cache lines (Table V).
pub const LINE_SIZE: u64 = 64;

/// A contiguous allocation in the modelled address space.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Region {
    base: u64,
    bytes: u64,
}

impl Region {
    /// Base address of the region.
    #[inline]
    pub fn base(&self) -> u64 {
        self.base
    }

    /// Size in bytes.
    #[inline]
    pub fn len(&self) -> u64 {
        self.bytes
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.bytes == 0
    }

    /// Address of byte offset `off` (debug-checked against the bound).
    #[inline]
    pub fn at(&self, off: u64) -> u64 {
        debug_assert!(off < self.bytes.max(1), "offset {off} out of region");
        self.base + off
    }

    /// Address of element `idx` of an array of `elem` -byte elements.
    #[inline]
    pub fn elem(&self, idx: usize, elem: u64) -> u64 {
        self.at(idx as u64 * elem)
    }

    /// Address of f64 element `idx`.
    #[inline]
    pub fn f64(&self, idx: usize) -> u64 {
        self.elem(idx, 8)
    }
}

/// Bump allocator over the modelled virtual address space. Regions are
/// page-aligned so that distinct arrays never share an OS page or DRAM row
/// by accident (matching how large `malloc`/numpy allocations behave).
#[derive(Debug)]
pub struct AddressSpace {
    next: u64,
    allocations: Vec<(String, Region)>,
}

impl Default for AddressSpace {
    fn default() -> Self {
        Self::new()
    }
}

impl AddressSpace {
    /// Fresh address space; base offset keeps address 0 unused.
    pub fn new() -> Self {
        Self { next: PAGE_SIZE, allocations: Vec::new() }
    }

    /// Allocate `bytes` bytes, page-aligned. `name` is kept for reports.
    pub fn alloc(&mut self, name: &str, bytes: u64) -> Region {
        let base = self.next;
        let region = Region { base, bytes };
        let padded = bytes.max(1).div_ceil(PAGE_SIZE) * PAGE_SIZE;
        self.next += padded;
        self.allocations.push((name.to_string(), region));
        region
    }

    /// Allocate an array of `n` f64 elements.
    pub fn alloc_f64(&mut self, name: &str, n: usize) -> Region {
        self.alloc(name, n as u64 * 8)
    }

    /// Allocate an `rows x cols` f64 matrix (row-major, rows padded to no
    /// particular boundary — same as numpy / Armadillo dense storage).
    pub fn alloc_matrix(&mut self, name: &str, rows: usize, cols: usize) -> Region {
        self.alloc(name, rows as u64 * cols as u64 * 8)
    }

    /// Total modelled bytes allocated (the working-set size; DESIGN.md's
    /// scale-stability argument checks this is ≥ several × LLC).
    pub fn allocated_bytes(&self) -> u64 {
        self.allocations.iter().map(|(_, r)| r.bytes).sum()
    }

    /// Named allocations, in allocation order.
    pub fn allocations(&self) -> &[(String, Region)] {
        &self.allocations
    }
}

/// Cache-line index of an address.
#[inline]
pub fn line_of(addr: u64) -> u64 {
    addr / LINE_SIZE
}

/// OS-page index of an address.
#[inline]
pub fn page_of(addr: u64) -> u64 {
    addr / PAGE_SIZE
}

/// `(first, last)` cache-line indices touched by a `size`-byte access at
/// `addr` (zero-size accesses touch their first line, matching the
/// simulator's `size.max(1)` convention).
#[inline]
pub fn line_span(addr: u64, size: u32) -> (u64, u64) {
    (line_of(addr), line_of(addr + size.max(1) as u64 - 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_are_page_aligned_and_disjoint() {
        let mut a = AddressSpace::new();
        let r1 = a.alloc("x", 100);
        let r2 = a.alloc("y", PAGE_SIZE + 1);
        let r3 = a.alloc("z", 1);
        assert_eq!(r1.base() % PAGE_SIZE, 0);
        assert_eq!(r2.base() % PAGE_SIZE, 0);
        assert!(r1.base() + PAGE_SIZE <= r2.base());
        assert!(r2.base() + 2 * PAGE_SIZE <= r3.base());
        assert_ne!(r1.base(), 0, "address 0 must stay unused");
    }

    #[test]
    fn elem_addressing() {
        let mut a = AddressSpace::new();
        let r = a.alloc_f64("v", 10);
        assert_eq!(r.f64(0), r.base());
        assert_eq!(r.f64(3), r.base() + 24);
    }

    #[test]
    fn matrix_row_addressing() {
        let mut a = AddressSpace::new();
        let m = a.alloc_matrix("m", 100, 20);
        // row 5, col 2 => (5*20+2)*8
        assert_eq!(m.f64(5 * 20 + 2), m.base() + (5 * 20 + 2) as u64 * 8);
        assert_eq!(m.len(), 100 * 20 * 8);
    }

    #[test]
    fn working_set_accounting() {
        let mut a = AddressSpace::new();
        a.alloc("x", 1000);
        a.alloc("y", 24);
        assert_eq!(a.allocated_bytes(), 1024);
        assert_eq!(a.allocations().len(), 2);
    }

    #[test]
    fn line_and_page_helpers() {
        assert_eq!(line_of(0), 0);
        assert_eq!(line_of(63), 0);
        assert_eq!(line_of(64), 1);
        assert_eq!(page_of(4095), 0);
        assert_eq!(page_of(4096), 1);
    }

    #[test]
    fn line_span_covers_touched_lines() {
        assert_eq!(line_span(0, 1), (0, 0));
        assert_eq!(line_span(0, 64), (0, 0));
        assert_eq!(line_span(0, 65), (0, 1));
        assert_eq!(line_span(60, 8), (0, 1));
        // 160-byte row from a line boundary spans 3 lines
        assert_eq!(line_span(0x20000, 160), (0x800, 0x802));
        // zero-size accesses still touch their first line
        assert_eq!(line_span(130, 0), (2, 2));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "out of region")]
    fn out_of_bounds_access_is_caught() {
        let mut a = AddressSpace::new();
        let r = a.alloc("x", 8);
        let _ = r.at(8);
    }
}
