//! Batched, columnar trace transport: the block pipeline between
//! instrumented workloads and the simulators.
//!
//! The seed implementation delivered every [`Event`] through a
//! `&mut dyn Sink` virtual call — billions of vtable indirections plus an
//! enum match per event, exactly the per-element overhead the paper's
//! locality/batching guidelines (and its sklearn-vs-mlpack CPI gap) warn
//! about. This module replaces that hot path with a struct-of-arrays
//! [`EventBlock`] of [`BLOCK_EVENTS`] events: the recorder appends to
//! typed lanes with plain (inlineable) stores, and consumers receive whole
//! blocks through [`BlockSink::consume`] — one dynamic dispatch per ~4K
//! events instead of one per event, with each lane contiguous in memory.
//!
//! Event *order* still matters to the pipeline simulator (a load feeding a
//! branch must precede it), so a block keeps a compact `kinds` tag lane in
//! emission order alongside the payload lanes; order-sensitive consumers
//! walk the tags with per-lane cursors, while order-insensitive consumers
//! (instruction-mix counting) reduce whole lanes without touching the tags
//! at all.

use super::event::{Event, Sink};

/// Events per block. 4096 events × ≤16 B/lane keeps a block comfortably
/// inside L2 while amortizing the per-block virtual call to noise.
pub const BLOCK_EVENTS: usize = 4096;

/// Discriminant lane entry: which typed lane the next event lives in.
///
/// The discriminant values are part of the on-disk trace format
/// ([`crate::trace::store`]): they appear verbatim in the run-length
/// encoded tag lane, so variants must keep their positions (append-only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    Compute = 0,
    Serial = 1,
    Load = 2,
    Store = 3,
    Branch = 4,
    LoopBranch = 5,
    SwPrefetch = 6,
}

impl EventKind {
    /// Inverse of `kind as u8` (trace-store decode path).
    pub fn from_u8(v: u8) -> Option<EventKind> {
        Some(match v {
            0 => EventKind::Compute,
            1 => EventKind::Serial,
            2 => EventKind::Load,
            3 => EventKind::Store,
            4 => EventKind::Branch,
            5 => EventKind::LoopBranch,
            6 => EventKind::SwPrefetch,
            _ => return None,
        })
    }
}

/// Load lane record (`Event::Load` payload).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LoadRec {
    pub addr: u64,
    pub size: u32,
    pub feeds_branch: bool,
}

impl LoadRec {
    /// Touched-line span (the block consumers precompute these lane-wise).
    #[inline]
    pub fn line_span(&self) -> (u64, u64) {
        super::addr::line_span(self.addr, self.size)
    }
}

/// Store lane record (`Event::Store` payload).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StoreRec {
    pub addr: u64,
    pub size: u32,
}

impl StoreRec {
    /// Touched-line span (the block consumers precompute these lane-wise).
    #[inline]
    pub fn line_span(&self) -> (u64, u64) {
        super::addr::line_span(self.addr, self.size)
    }
}

/// Branch lane record (`Event::Branch` payload).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BranchRec {
    pub site: u32,
    pub taken: bool,
    pub conditional: bool,
}

/// Struct-of-arrays buffer of up to [`BLOCK_EVENTS`] trace events.
///
/// `kinds` records emission order; each payload lane holds only its own
/// event type, in emission order restricted to that type. Reconstruct the
/// interleaved stream with [`EventBlock::iter`].
#[derive(Debug, Default, Clone, PartialEq)]
pub struct EventBlock {
    kinds: Vec<EventKind>,
    pub compute: Vec<(u32, u32)>,
    pub serial: Vec<u32>,
    pub loads: Vec<LoadRec>,
    pub stores: Vec<StoreRec>,
    pub branches: Vec<BranchRec>,
    pub loop_branches: Vec<(u32, u32)>,
    pub prefetches: Vec<u64>,
}

impl EventBlock {
    /// Empty block with full lane capacity pre-reserved.
    pub fn with_capacity() -> Self {
        Self {
            kinds: Vec::with_capacity(BLOCK_EVENTS),
            compute: Vec::with_capacity(BLOCK_EVENTS),
            serial: Vec::new(),
            loads: Vec::with_capacity(BLOCK_EVENTS),
            stores: Vec::new(),
            branches: Vec::with_capacity(BLOCK_EVENTS),
            loop_branches: Vec::new(),
            prefetches: Vec::new(),
        }
    }

    /// Number of events held.
    #[inline]
    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    /// Whether the block has reached [`BLOCK_EVENTS`] and must be flushed.
    #[inline]
    pub fn is_full(&self) -> bool {
        self.kinds.len() >= BLOCK_EVENTS
    }

    /// Emission-order discriminant lane.
    #[inline]
    pub fn kinds(&self) -> &[EventKind] {
        &self.kinds
    }

    /// Clear all lanes, keeping capacity.
    pub fn clear(&mut self) {
        self.kinds.clear();
        self.compute.clear();
        self.serial.clear();
        self.loads.clear();
        self.stores.clear();
        self.branches.clear();
        self.loop_branches.clear();
        self.prefetches.clear();
    }

    #[inline]
    pub fn push_compute(&mut self, int_ops: u32, fp_ops: u32) {
        self.kinds.push(EventKind::Compute);
        self.compute.push((int_ops, fp_ops));
    }

    #[inline]
    pub fn push_serial(&mut self, ops: u32) {
        self.kinds.push(EventKind::Serial);
        self.serial.push(ops);
    }

    #[inline]
    pub fn push_load(&mut self, addr: u64, size: u32, feeds_branch: bool) {
        self.kinds.push(EventKind::Load);
        self.loads.push(LoadRec { addr, size, feeds_branch });
    }

    #[inline]
    pub fn push_store(&mut self, addr: u64, size: u32) {
        self.kinds.push(EventKind::Store);
        self.stores.push(StoreRec { addr, size });
    }

    #[inline]
    pub fn push_branch(&mut self, site: u32, taken: bool, conditional: bool) {
        self.kinds.push(EventKind::Branch);
        self.branches.push(BranchRec { site, taken, conditional });
    }

    #[inline]
    pub fn push_loop_branch(&mut self, site: u32, count: u32) {
        self.kinds.push(EventKind::LoopBranch);
        self.loop_branches.push((site, count));
    }

    #[inline]
    pub fn push_prefetch(&mut self, addr: u64) {
        self.kinds.push(EventKind::SwPrefetch);
        self.prefetches.push(addr);
    }

    /// Append one enum-form event (adapters, tests).
    pub fn push_event(&mut self, ev: Event) {
        match ev {
            Event::Compute { int_ops, fp_ops } => self.push_compute(int_ops, fp_ops),
            Event::Serial { ops } => self.push_serial(ops),
            Event::Load { addr, size, feeds_branch } => self.push_load(addr, size, feeds_branch),
            Event::Store { addr, size } => self.push_store(addr, size),
            Event::Branch { site, taken, conditional } => {
                self.push_branch(site, taken, conditional)
            }
            Event::LoopBranch { site, count } => self.push_loop_branch(site, count),
            Event::SwPrefetch { addr } => self.push_prefetch(addr),
        }
    }

    /// Append `run` copies of `kind` to the tag lane **only** — the bulk
    /// materialization step of the trace-store decoder, which replays an
    /// RLE run as one `resize` fill instead of `run` per-event pushes.
    /// The caller owns keeping the payload lanes consistent (the decoder
    /// fills each lane to the tag-lane counts before handing the block
    /// out).
    #[inline]
    pub fn extend_kind_run(&mut self, kind: EventKind, run: usize) {
        self.kinds.resize(self.kinds.len() + run, kind);
    }

    /// Reconstruct the interleaved event stream in emission order.
    pub fn iter(&self) -> EventBlockIter<'_> {
        EventBlockIter { block: self, pos: 0, cur: LaneCursors::default() }
    }

    /// Reassemble a block from already-separated lanes without paying a
    /// per-event re-dispatch through [`EventBlock::push_event`]. (The
    /// trace-store decoder once built blocks this way; it now decodes
    /// into an existing block's lanes in place — see
    /// [`decode_block`](crate::trace::store::decode_block) — so this
    /// remains for adapters and tests that assemble lanes wholesale.)
    /// The per-kind counts in `kinds` must match the lane lengths; this
    /// is debug-asserted.
    #[allow(clippy::too_many_arguments)] // one parameter per lane, by design
    pub fn from_lanes(
        kinds: Vec<EventKind>,
        compute: Vec<(u32, u32)>,
        serial: Vec<u32>,
        loads: Vec<LoadRec>,
        stores: Vec<StoreRec>,
        branches: Vec<BranchRec>,
        loop_branches: Vec<(u32, u32)>,
        prefetches: Vec<u64>,
    ) -> Self {
        debug_assert_eq!(
            kinds.len(),
            compute.len()
                + serial.len()
                + loads.len()
                + stores.len()
                + branches.len()
                + loop_branches.len()
                + prefetches.len(),
            "lane lengths must sum to the tag-lane length"
        );
        Self { kinds, compute, serial, loads, stores, branches, loop_branches, prefetches }
    }
}

/// Per-lane read positions for an order-preserving walk of a block.
#[derive(Debug, Default, Clone, Copy)]
pub struct LaneCursors {
    pub compute: usize,
    pub serial: usize,
    pub load: usize,
    pub store: usize,
    pub branch: usize,
    pub loop_branch: usize,
    pub prefetch: usize,
}

/// Iterator yielding enum-form events in emission order.
pub struct EventBlockIter<'a> {
    block: &'a EventBlock,
    pos: usize,
    cur: LaneCursors,
}

impl Iterator for EventBlockIter<'_> {
    type Item = Event;

    fn next(&mut self) -> Option<Event> {
        let b = self.block;
        let kind = *b.kinds.get(self.pos)?;
        self.pos += 1;
        let c = &mut self.cur;
        Some(match kind {
            EventKind::Compute => {
                let (int_ops, fp_ops) = b.compute[c.compute];
                c.compute += 1;
                Event::Compute { int_ops, fp_ops }
            }
            EventKind::Serial => {
                let ops = b.serial[c.serial];
                c.serial += 1;
                Event::Serial { ops }
            }
            EventKind::Load => {
                let l = b.loads[c.load];
                c.load += 1;
                Event::Load { addr: l.addr, size: l.size, feeds_branch: l.feeds_branch }
            }
            EventKind::Store => {
                let s = b.stores[c.store];
                c.store += 1;
                Event::Store { addr: s.addr, size: s.size }
            }
            EventKind::Branch => {
                let br = b.branches[c.branch];
                c.branch += 1;
                Event::Branch { site: br.site, taken: br.taken, conditional: br.conditional }
            }
            EventKind::LoopBranch => {
                let (site, count) = b.loop_branches[c.loop_branch];
                c.loop_branch += 1;
                Event::LoopBranch { site, count }
            }
            EventKind::SwPrefetch => {
                let addr = b.prefetches[c.prefetch];
                c.prefetch += 1;
                Event::SwPrefetch { addr }
            }
        })
    }
}

/// Consumer of a batched trace stream. The block-pipeline counterpart of
/// [`Sink`]: simulators, counters, and composition adapters implement this
/// and receive ~[`BLOCK_EVENTS`] events per call.
pub trait BlockSink {
    /// Observe one block of events (in emission order within the block).
    fn consume(&mut self, block: &EventBlock);

    /// Called once at end-of-trace so sinks can drain internal state.
    fn finalize(&mut self) {}
}

/// Adapter driving a legacy per-event [`Sink`] from the block pipeline
/// (migration path, and the reference side of the parity tests).
pub struct PerEvent<'a>(pub &'a mut dyn Sink);

impl BlockSink for PerEvent<'_> {
    fn consume(&mut self, block: &EventBlock) {
        for ev in block.iter() {
            self.0.event(ev);
        }
    }

    fn finalize(&mut self) {
        self.0.finish();
    }
}

/// Fan-out adapter: forwards every block to both sinks (block-pipeline
/// counterpart of [`super::event::Tee`]).
pub struct BlockTee<'a> {
    pub a: &'a mut dyn BlockSink,
    pub b: &'a mut dyn BlockSink,
}

impl BlockSink for BlockTee<'_> {
    fn consume(&mut self, block: &EventBlock) {
        self.a.consume(block);
        self.b.consume(block);
    }

    fn finalize(&mut self) {
        self.a.finalize();
        self.b.finalize();
    }
}

impl BlockSink for super::event::NullSink {
    #[inline]
    fn consume(&mut self, _block: &EventBlock) {}
}

impl BlockSink for super::event::VecSink {
    fn consume(&mut self, block: &EventBlock) {
        self.events.extend(block.iter());
    }

    fn finalize(&mut self) {
        self.finished = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::VecSink;

    fn sample_events() -> Vec<Event> {
        vec![
            Event::Compute { int_ops: 2, fp_ops: 1 },
            Event::Load { addr: 0x40, size: 8, feeds_branch: true },
            Event::Branch { site: 3, taken: true, conditional: true },
            Event::Serial { ops: 4 },
            Event::Store { addr: 0x80, size: 16 },
            Event::LoopBranch { site: 9, count: 20 },
            Event::SwPrefetch { addr: 0x1000 },
        ]
    }

    #[test]
    fn iter_reconstructs_emission_order() {
        let mut b = EventBlock::with_capacity();
        for ev in sample_events() {
            b.push_event(ev);
        }
        assert_eq!(b.len(), 7);
        assert_eq!(b.iter().collect::<Vec<_>>(), sample_events());
    }

    #[test]
    fn clear_keeps_capacity_and_empties_lanes() {
        let mut b = EventBlock::with_capacity();
        for ev in sample_events() {
            b.push_event(ev);
        }
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.iter().count(), 0);
        assert!(b.compute.is_empty() && b.loads.is_empty() && b.branches.is_empty());
    }

    #[test]
    fn is_full_at_capacity() {
        let mut b = EventBlock::with_capacity();
        for _ in 0..BLOCK_EVENTS {
            b.push_compute(1, 0);
        }
        assert!(b.is_full());
    }

    #[test]
    fn per_event_adapter_forwards_in_order() {
        let mut b = EventBlock::with_capacity();
        for ev in sample_events() {
            b.push_event(ev);
        }
        let mut v = VecSink::default();
        {
            let mut adapter = PerEvent(&mut v);
            adapter.consume(&b);
            adapter.finalize();
        }
        assert_eq!(v.events, sample_events());
        assert!(v.finished);
    }

    #[test]
    fn block_tee_duplicates_blocks() {
        let mut b = EventBlock::with_capacity();
        b.push_load(0x40, 8, false);
        b.push_compute(1, 1);
        let mut x = VecSink::default();
        let mut y = VecSink::default();
        {
            let mut t = BlockTee { a: &mut x, b: &mut y };
            t.consume(&b);
            t.finalize();
        }
        assert_eq!(x.events, y.events);
        assert_eq!(x.events.len(), 2);
        assert!(x.finished && y.finished);
    }
}
