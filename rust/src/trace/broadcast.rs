//! Broadcast replay: decode a block stream once, feed N sinks.
//!
//! Per-cell replay pays the block-stream walk (and, for file traces, the
//! full read + checksum + columnar decode) once per simulator
//! configuration. [`Broadcast`] collapses that to once per *capture*: it
//! is a [`BlockSink`] that forwards every consumed block to each of its
//! inner sinks in order, so one pass over a [`CapturedTrace`] or one
//! [`PipelinedIngest`] stream drives any number of simulator instances.
//! Each inner sink still observes the exact block sequence it would have
//! seen alone, so per-sink results are bit-identical to per-cell replay
//! (`tests/broadcast.rs` gates this).
//!
//! The n-ary generalization of [`BlockTee`](super::BlockTee), plus the
//! consume counters the one-decode assertions need: after a replay,
//! [`Broadcast::blocks_broadcast`] equals the number of blocks decoded —
//! independent of the fan-out width.
//!
//! [`CapturedTrace`]: super::CapturedTrace
//! [`PipelinedIngest`]: super::PipelinedIngest

use super::block::{BlockSink, EventBlock};

/// Fan one consumed block stream out to N sinks (see the module docs).
pub struct Broadcast<'a> {
    sinks: Vec<&'a mut dyn BlockSink>,
    blocks: u64,
    events: u64,
}

impl<'a> Broadcast<'a> {
    pub fn new(sinks: Vec<&'a mut dyn BlockSink>) -> Self {
        Self { sinks, blocks: 0, events: 0 }
    }

    /// Number of inner sinks.
    pub fn fan_out(&self) -> usize {
        self.sinks.len()
    }

    /// Blocks consumed so far — the stream was walked this many times in
    /// total, regardless of how many sinks it fed.
    pub fn blocks_broadcast(&self) -> u64 {
        self.blocks
    }

    /// Events carried by the consumed blocks.
    pub fn events_broadcast(&self) -> u64 {
        self.events
    }
}

impl BlockSink for Broadcast<'_> {
    fn consume(&mut self, block: &EventBlock) {
        self.blocks += 1;
        self.events += block.len() as u64;
        for sink in &mut self.sinks {
            sink.consume(block);
        }
    }

    fn finalize(&mut self) {
        for sink in &mut self.sinks {
            sink.finalize();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{CapturedTrace, VecSink};

    fn sample_trace() -> CapturedTrace {
        let mut t = CapturedTrace::default();
        for i in 0..3u64 {
            let mut b = EventBlock::with_capacity();
            b.push_compute(2, 1);
            b.push_load(i * 4096, 8, false);
            b.push_store(i * 4096 + 64, 8);
            t.consume(&b);
        }
        t.finalize();
        t
    }

    #[test]
    fn every_sink_sees_the_identical_stream() {
        let trace = sample_trace();
        let mut solo = VecSink::default();
        trace.replay_into(&mut solo);

        let mut a = VecSink::default();
        let mut b = VecSink::default();
        let mut c = VecSink::default();
        let mut bc = Broadcast::new(vec![&mut a, &mut b, &mut c]);
        trace.replay_into(&mut bc);
        assert_eq!(bc.fan_out(), 3);
        assert_eq!(bc.blocks_broadcast(), 3, "one consume per block, not per sink");
        assert_eq!(bc.events_broadcast(), 9);
        for fanned in [&a, &b, &c] {
            assert_eq!(fanned.events, solo.events);
        }
    }

    #[test]
    fn zero_sinks_still_counts() {
        let trace = sample_trace();
        let mut bc = Broadcast::new(Vec::new());
        trace.replay_into(&mut bc);
        assert_eq!(bc.blocks_broadcast(), 3);
    }
}
