//! Typed error taxonomy for the trace layer.
//!
//! The store and pipelined-ingest paths classify every failure into a
//! [`TraceErrorKind`] so callers can *recover* instead of aborting: the
//! reader retries transient I/O errors with bounded backoff
//! ([`MAX_IO_RETRIES`], [`retry_backoff`]), the grid driver quarantines
//! cells whose captures fail permanently, and the CLI renders a one-line
//! message instead of a panic backtrace. [`TraceError`] implements
//! `std::error::Error`, so `?` still converts it into the crate-wide
//! [`Error`](crate::util::error::Error) at the boundaries that don't
//! care about the kind.

use std::fmt;
use std::time::Duration;

/// Bounded retry budget for transient I/O errors: a frame read is
/// retried at most this many times (with [`retry_backoff`] between
/// attempts) before the error is surfaced as permanent.
pub const MAX_IO_RETRIES: u32 = 3;

/// Backoff before retry `attempt` (1-based): 100µs doubling per
/// attempt — long enough to let an EINTR-class hiccup clear, short
/// enough that a full budget costs under a millisecond.
pub fn retry_backoff(attempt: u32) -> Duration {
    Duration::from_micros(100u64 << attempt.saturating_sub(1).min(10))
}

/// What class of failure a [`TraceError`] is — the axis recovery
/// policy dispatches on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceErrorKind {
    /// Data failed validation (checksum mismatch, bad marker, undecodable
    /// payload) at a known block index. Permanent: the artifact is bad.
    Corrupt {
        /// Index of the block being read when corruption surfaced.
        block: u64,
    },
    /// The stream ended before its trailer (torn tail, partial file).
    /// Permanent, but the prefix up to the tear was validated.
    Truncated,
    /// The file is a trace of a format version this build does not read.
    VersionMismatch {
        /// Version the file claims.
        found: u32,
    },
    /// An I/O error. `transient: true` marks EINTR-class errors
    /// (interrupted, would-block, timed out) that a bounded retry may
    /// clear; everything else is permanent.
    Io {
        /// Whether a retry may succeed.
        transient: bool,
    },
    /// Malformed header or metadata (bad magic, bad profile byte, …).
    Format,
    /// A worker thread (pipelined-ingest decoder) panicked; converted
    /// to an error instead of tearing down the process.
    WorkerPanic,
    /// The serve daemon's admission queue was full: the request was shed
    /// immediately instead of queueing unboundedly. Permanent for this
    /// request — the client may retry against a less-loaded daemon.
    Overloaded,
    /// The request's deadline expired before (or while) the daemon could
    /// answer it. Permanent for this request.
    DeadlineExceeded,
}

/// A classified trace-layer failure: a [`TraceErrorKind`] plus a
/// human-readable, single-line message.
#[derive(Debug, Clone)]
pub struct TraceError {
    kind: TraceErrorKind,
    msg: String,
}

impl TraceError {
    /// Corrupt data at `block`.
    pub fn corrupt(block: u64, msg: impl Into<String>) -> Self {
        TraceError { kind: TraceErrorKind::Corrupt { block }, msg: msg.into() }
    }

    /// Stream ended early.
    pub fn truncated(msg: impl Into<String>) -> Self {
        TraceError { kind: TraceErrorKind::Truncated, msg: msg.into() }
    }

    /// Unreadable format version.
    pub fn version(found: u32, msg: impl Into<String>) -> Self {
        TraceError { kind: TraceErrorKind::VersionMismatch { found }, msg: msg.into() }
    }

    /// I/O failure, transient or permanent.
    pub fn io(transient: bool, msg: impl Into<String>) -> Self {
        TraceError { kind: TraceErrorKind::Io { transient }, msg: msg.into() }
    }

    /// Malformed header/metadata.
    pub fn format(msg: impl Into<String>) -> Self {
        TraceError { kind: TraceErrorKind::Format, msg: msg.into() }
    }

    /// A caught worker-thread panic.
    pub fn worker_panic(msg: impl Into<String>) -> Self {
        TraceError { kind: TraceErrorKind::WorkerPanic, msg: msg.into() }
    }

    /// Admission queue full — the daemon shed this request.
    pub fn overloaded(msg: impl Into<String>) -> Self {
        TraceError { kind: TraceErrorKind::Overloaded, msg: msg.into() }
    }

    /// The request's deadline expired before an answer was produced.
    pub fn deadline(msg: impl Into<String>) -> Self {
        TraceError { kind: TraceErrorKind::DeadlineExceeded, msg: msg.into() }
    }

    /// Classify a `std::io::Error`: EINTR-class kinds are transient,
    /// unexpected EOF is a truncation, the rest are permanent I/O.
    pub fn from_io(e: std::io::Error, what: &str) -> Self {
        use std::io::ErrorKind as K;
        match e.kind() {
            K::Interrupted | K::WouldBlock | K::TimedOut => {
                TraceError::io(true, format!("{what}: {e}"))
            }
            K::UnexpectedEof => TraceError::truncated(format!("{what}: {e}")),
            _ => TraceError::io(false, format!("{what}: {e}")),
        }
    }

    /// The failure class.
    pub fn kind(&self) -> TraceErrorKind {
        self.kind
    }

    /// True for errors a bounded retry may clear.
    pub fn is_transient(&self) -> bool {
        matches!(self.kind, TraceErrorKind::Io { transient: true })
    }

    /// Stable lowercase tag for reports (`failures.json`).
    pub fn kind_str(&self) -> &'static str {
        match self.kind {
            TraceErrorKind::Corrupt { .. } => "corrupt",
            TraceErrorKind::Truncated => "truncated",
            TraceErrorKind::VersionMismatch { .. } => "version-mismatch",
            TraceErrorKind::Io { transient: true } => "io-transient",
            TraceErrorKind::Io { transient: false } => "io",
            TraceErrorKind::Format => "format",
            TraceErrorKind::WorkerPanic => "worker-panic",
            TraceErrorKind::Overloaded => "overloaded",
            TraceErrorKind::DeadlineExceeded => "deadline-exceeded",
        }
    }

    /// Prepend an outer context frame (`"{ctx}: {msg}"`), keeping the kind.
    pub fn ctx(mut self, ctx: impl fmt::Display) -> Self {
        self.msg = format!("{ctx}: {}", self.msg);
        self
    }
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for TraceError {}

impl From<std::io::Error> for TraceError {
    fn from(e: std::io::Error) -> Self {
        TraceError::from_io(e, "trace I/O")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_errors_classify_by_kind() {
        use std::io::{Error as IoError, ErrorKind as K};
        let t = TraceError::from_io(IoError::new(K::Interrupted, "eintr"), "read");
        assert!(t.is_transient());
        assert_eq!(t.kind_str(), "io-transient");

        let eof = TraceError::from_io(IoError::new(K::UnexpectedEof, "eof"), "read");
        assert_eq!(eof.kind(), TraceErrorKind::Truncated);
        assert!(!eof.is_transient());

        let perm = TraceError::from_io(IoError::new(K::PermissionDenied, "no"), "open");
        assert_eq!(perm.kind(), TraceErrorKind::Io { transient: false });
        assert_eq!(perm.kind_str(), "io");
    }

    #[test]
    fn context_preserves_kind_and_chains_message() {
        let e = TraceError::corrupt(7, "checksum mismatch").ctx("reading x.mlt");
        assert_eq!(e.kind(), TraceErrorKind::Corrupt { block: 7 });
        assert_eq!(e.to_string(), "reading x.mlt: checksum mismatch");
    }

    #[test]
    fn converts_into_the_crate_error_via_question_mark() {
        fn inner() -> Result<(), TraceError> {
            Err(TraceError::version(9, "trace format version 9 unsupported"))
        }
        fn outer() -> crate::util::error::Result<()> {
            inner()?;
            Ok(())
        }
        let msg = outer().unwrap_err().to_string();
        assert!(msg.contains("version 9"), "{msg}");
    }

    #[test]
    fn serve_rejections_are_typed_and_permanent() {
        let shed = TraceError::overloaded("queue full (depth 64)");
        assert_eq!(shed.kind(), TraceErrorKind::Overloaded);
        assert_eq!(shed.kind_str(), "overloaded");
        assert!(!shed.is_transient(), "a shed request must not be auto-retried");

        let late = TraceError::deadline("deadline 50ms exceeded").ctx("query KMeans/baseline");
        assert_eq!(late.kind(), TraceErrorKind::DeadlineExceeded);
        assert_eq!(late.kind_str(), "deadline-exceeded");
        assert!(!late.is_transient());
        assert_eq!(late.to_string(), "query KMeans/baseline: deadline 50ms exceeded");
    }

    #[test]
    fn backoff_is_bounded_and_monotone() {
        assert!(retry_backoff(1) < retry_backoff(2));
        assert!(retry_backoff(MAX_IO_RETRIES) < Duration::from_millis(5));
        // saturates rather than overflowing for absurd attempts
        assert!(retry_backoff(u32::MAX) <= Duration::from_millis(200));
    }
}
