//! Trace event model.
//!
//! Instrumented workloads emit a stream of `Event`s describing their
//! dynamic instruction behaviour at the granularity the simulators need:
//! aggregated compute uops, sized memory accesses (a whole feature-vector
//! read is one event; the cache model expands it to line touches), branch
//! outcomes with stable per-site ids, and explicit software prefetches.
//!
//! This mirrors what the paper collects with `perf`/`perf mem`/VTune on
//! real silicon: instruction mix, memory reference stream, branch stream.

/// One dynamic event in a workload's execution trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// `int_ops` simple integer/address uops and `fp_ops` floating-point
    /// uops executed since the previous event (aggregated for compactness).
    Compute { int_ops: u32, fp_ops: u32 },
    /// `ops` *serialized* bookkeeping uops forming a dependency chain
    /// (interpreter/Cython-style per-element overhead: refcounts, bounds
    /// checks through pointers). They retire at ~1 per ALU latency rather
    /// than at issue width — the mechanism behind the sklearn-vs-mlpack
    /// CPI gap in Fig. 1.
    Serial { ops: u32 },
    /// A data read of `size` bytes at virtual address `addr`.
    /// `feeds_branch` marks loads whose value a conditional branch consumes
    /// immediately (the paper's "branch result depends on a memory-resident
    /// operand" — Figs. 16/22 attribute bad-speculation reduction to faster
    /// resolution of exactly these).
    Load { addr: u64, size: u32, feeds_branch: bool },
    /// A data write of `size` bytes at virtual address `addr`.
    Store { addr: u64, size: u32 },
    /// A branch instruction at static site `site` (stable id standing in
    /// for the PC). `conditional` distinguishes conditional branches
    /// (Fig. 6); `taken` is the outcome the predictor must guess.
    Branch { site: u32, taken: bool, conditional: bool },
    /// A counted inner loop's back-edge executed `count` times
    /// (`count-1` taken + 1 fall-through). Compiled distance/dot-product
    /// loops emit these; they are what pushes the neighbour/tree
    /// workloads to the paper's ~20-25% dynamic branch fraction (Fig. 5)
    /// while remaining mostly well-predicted.
    LoopBranch { site: u32, count: u32 },
    /// A software prefetch (`_mm_prefetch`-equivalent) of the line at
    /// `addr`, targeting the L2 per the paper's Section V-C.
    SwPrefetch { addr: u64 },
}

/// Consumer of a trace stream. Simulators, counters, and composition
/// adapters all implement this.
pub trait Sink {
    /// Observe one event.
    fn event(&mut self, ev: Event);
    /// Called once at end-of-trace so sinks can drain internal state.
    fn finish(&mut self) {}
}

/// Fan-out adapter: forwards every event to both sinks.
pub struct Tee<'a> {
    pub a: &'a mut dyn Sink,
    pub b: &'a mut dyn Sink,
}

impl<'a> Sink for Tee<'a> {
    fn event(&mut self, ev: Event) {
        self.a.event(ev);
        self.b.event(ev);
    }
    fn finish(&mut self) {
        self.a.finish();
        self.b.finish();
    }
}

/// Sink that discards everything (workload dry-runs / accuracy-only runs).
#[derive(Default)]
pub struct NullSink;

impl Sink for NullSink {
    #[inline]
    fn event(&mut self, _ev: Event) {}
}

/// Sink that stores the raw stream (tests and small diagnostics only —
/// real runs stream straight into the simulators).
#[derive(Default)]
pub struct VecSink {
    pub events: Vec<Event>,
    pub finished: bool,
}

impl Sink for VecSink {
    fn event(&mut self, ev: Event) {
        self.events.push(ev);
    }
    fn finish(&mut self) {
        self.finished = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tee_duplicates_events() {
        let mut a = VecSink::default();
        let mut b = VecSink::default();
        {
            let mut t = Tee { a: &mut a, b: &mut b };
            t.event(Event::Compute { int_ops: 1, fp_ops: 2 });
            t.event(Event::SwPrefetch { addr: 64 });
            t.finish();
        }
        assert_eq!(a.events, b.events);
        assert_eq!(a.events.len(), 2);
        assert!(a.finished && b.finished);
    }

    #[test]
    fn null_sink_accepts_everything() {
        let mut n = NullSink;
        n.event(Event::Store { addr: 0, size: 8 });
        n.finish();
    }
}
