//! Instruction-mix accounting (Figs. 5 and 6 of the paper).
//!
//! Counts dynamic instructions by class. An `Event::Compute` contributes
//! `int_ops + fp_ops` instructions; each memory access and each branch is
//! one instruction (a reasonable x86 uop-to-instruction mapping for the
//! compiled loops the paper studies).

use super::block::{BlockSink, EventBlock};
use super::event::{Event, Sink};

/// Dynamic instruction mix counters.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct InstructionMix {
    pub int_ops: u64,
    pub fp_ops: u64,
    pub loads: u64,
    pub stores: u64,
    pub branches: u64,
    pub cond_branches: u64,
    pub sw_prefetches: u64,
    pub bytes_loaded: u64,
    pub bytes_stored: u64,
}

impl InstructionMix {
    /// Total dynamic instructions.
    pub fn instructions(&self) -> u64 {
        self.int_ops + self.fp_ops + self.loads + self.stores + self.branches
            + self.sw_prefetches
    }

    /// Fraction of instructions that are branches (Fig. 5).
    pub fn branch_fraction(&self) -> f64 {
        let n = self.instructions();
        if n == 0 {
            0.0
        } else {
            self.branches as f64 / n as f64
        }
    }

    /// Fraction of branches that are conditional (Fig. 6).
    pub fn conditional_branch_fraction(&self) -> f64 {
        if self.branches == 0 {
            0.0
        } else {
            self.cond_branches as f64 / self.branches as f64
        }
    }

    /// Fraction of instructions that touch memory.
    pub fn memory_fraction(&self) -> f64 {
        let n = self.instructions();
        if n == 0 {
            0.0
        } else {
            (self.loads + self.stores) as f64 / n as f64
        }
    }

    /// Columnar accumulation of a whole [`EventBlock`]: each counter is a
    /// lane reduction, with no per-event tag dispatch. Produces exactly
    /// the counts that feeding the block's events one at a time would
    /// (all counters are integers, so the equality is bit-for-bit).
    pub fn add_block(&mut self, b: &EventBlock) {
        for &(int_ops, fp_ops) in &b.compute {
            self.int_ops += int_ops as u64;
            self.fp_ops += fp_ops as u64;
        }
        for &ops in &b.serial {
            self.int_ops += ops as u64;
        }
        self.loads += b.loads.len() as u64;
        for l in &b.loads {
            self.bytes_loaded += l.size as u64;
        }
        self.stores += b.stores.len() as u64;
        for s in &b.stores {
            self.bytes_stored += s.size as u64;
        }
        self.branches += b.branches.len() as u64;
        self.cond_branches += b.branches.iter().filter(|br| br.conditional).count() as u64;
        for &(_, count) in &b.loop_branches {
            self.branches += count as u64;
            self.cond_branches += count as u64;
        }
        self.sw_prefetches += b.prefetches.len() as u64;
    }
}

impl BlockSink for InstructionMix {
    fn consume(&mut self, block: &EventBlock) {
        self.add_block(block);
    }
}

impl Sink for InstructionMix {
    fn event(&mut self, ev: Event) {
        match ev {
            Event::Compute { int_ops, fp_ops } => {
                self.int_ops += int_ops as u64;
                self.fp_ops += fp_ops as u64;
            }
            Event::Serial { ops } => self.int_ops += ops as u64,
            Event::Load { size, .. } => {
                self.loads += 1;
                self.bytes_loaded += size as u64;
            }
            Event::Store { size, .. } => {
                self.stores += 1;
                self.bytes_stored += size as u64;
            }
            Event::Branch { conditional, .. } => {
                self.branches += 1;
                if conditional {
                    self.cond_branches += 1;
                }
            }
            Event::LoopBranch { count, .. } => {
                self.branches += count as u64;
                self.cond_branches += count as u64;
            }
            Event::SwPrefetch { .. } => self.sw_prefetches += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_each_class() {
        let mut m = InstructionMix::default();
        m.event(Event::Compute { int_ops: 3, fp_ops: 2 });
        m.event(Event::Load { addr: 0, size: 8, feeds_branch: false });
        m.event(Event::Load { addr: 8, size: 16, feeds_branch: true });
        m.event(Event::Store { addr: 0, size: 8 });
        m.event(Event::Branch { site: 1, taken: true, conditional: true });
        m.event(Event::Branch { site: 2, taken: true, conditional: false });
        m.event(Event::SwPrefetch { addr: 0 });
        assert_eq!(m.instructions(), 3 + 2 + 2 + 1 + 2 + 1);
        assert_eq!(m.bytes_loaded, 24);
        assert_eq!(m.bytes_stored, 8);
        assert_eq!(m.cond_branches, 1);
    }

    #[test]
    fn fractions() {
        let mut m = InstructionMix::default();
        for _ in 0..2 {
            m.event(Event::Branch { site: 1, taken: false, conditional: true });
        }
        m.event(Event::Branch { site: 2, taken: true, conditional: false });
        m.event(Event::Compute { int_ops: 7, fp_ops: 0 });
        assert!((m.branch_fraction() - 0.3).abs() < 1e-12);
        assert!((m.conditional_branch_fraction() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn add_block_matches_per_event() {
        let events = [
            Event::Compute { int_ops: 3, fp_ops: 2 },
            Event::Serial { ops: 5 },
            Event::Load { addr: 0, size: 8, feeds_branch: false },
            Event::Load { addr: 8, size: 16, feeds_branch: true },
            Event::Store { addr: 0, size: 8 },
            Event::Branch { site: 1, taken: true, conditional: true },
            Event::Branch { site: 2, taken: true, conditional: false },
            Event::LoopBranch { site: 3, count: 12 },
            Event::SwPrefetch { addr: 0 },
        ];
        let mut per_event = InstructionMix::default();
        let mut block = EventBlock::with_capacity();
        for ev in events {
            per_event.event(ev);
            block.push_event(ev);
        }
        let mut batched = InstructionMix::default();
        batched.add_block(&block);
        assert_eq!(per_event, batched);
    }

    #[test]
    fn empty_mix_is_zero() {
        let m = InstructionMix::default();
        assert_eq!(m.instructions(), 0);
        assert_eq!(m.branch_fraction(), 0.0);
        assert_eq!(m.conditional_branch_fraction(), 0.0);
        assert_eq!(m.memory_fraction(), 0.0);
    }
}
