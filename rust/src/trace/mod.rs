//! Trace substrate: the event model connecting instrumented workloads to
//! the micro-architectural simulators. Equivalent role to the paper's
//! `perf` / `perf mem` / VTune collection layer.
//!
//! Delivery is batched and columnar: workloads record through
//! [`Recorder`] into struct-of-arrays [`EventBlock`]s consumed whole by
//! [`BlockSink`]s (see [`block`]). The per-event [`Sink`] trait remains
//! for tests, diagnostics, and the [`PerEvent`] migration adapter.
//!
//! Traces are also durable artifacts: [`store`] persists the block
//! stream to a compact columnar file (record once) and [`ReplaySource`] /
//! [`CapturedTrace`] feed it back into any [`BlockSink`] (replay many) —
//! the foundation of the grid driver's record-once/replay-many mode.
//! [`pipeline`] overlaps that ingest: an I/O thread and a decoder pool
//! feed the consuming sink in recorded order ([`PipelinedIngest`]), with
//! scratch recycled through a [`BlockPool`], for the same bit-identical
//! block stream at multi-threaded throughput.
//!
//! [`broadcast`] fans one decoded stream out to N sinks (decode once,
//! simulate many): the grid driver batches scenario cells that share a
//! capture into a single [`Broadcast`] replay, and file traces reach the
//! same fan-out through [`PipelinedIngest`].

pub mod addr;
pub mod block;
pub mod broadcast;
pub mod error;
pub mod event;
pub mod mix;
pub mod pipeline;
pub mod recorder;
pub mod store;

pub use addr::{line_of, line_span, page_of, AddressSpace, Region, LINE_SIZE, PAGE_SIZE};
pub use block::{
    BlockSink, BlockTee, BranchRec, EventBlock, EventKind, LaneCursors, LoadRec, PerEvent,
    StoreRec, BLOCK_EVENTS,
};
pub use broadcast::Broadcast;
pub use error::{retry_backoff, TraceError, TraceErrorKind, MAX_IO_RETRIES};
pub use event::{Event, NullSink, Sink, Tee, VecSink};
pub use mix::InstructionMix;
pub use pipeline::{resolve_ingest_threads, BlockPool, PipelinedIngest};
pub use recorder::Recorder;
pub use store::{
    CapturedTrace, ReplaySource, ReplayStats, TraceMeta, TraceReader, TraceSummary, TraceWriter,
};
