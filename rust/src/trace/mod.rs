//! Trace substrate: the event model connecting instrumented workloads to
//! the micro-architectural simulators. Equivalent role to the paper's
//! `perf` / `perf mem` / VTune collection layer.

pub mod addr;
pub mod event;
pub mod mix;
pub mod recorder;

pub use addr::{line_of, page_of, AddressSpace, Region, LINE_SIZE, PAGE_SIZE};
pub use event::{Event, NullSink, Sink, Tee, VecSink};
pub use mix::InstructionMix;
pub use recorder::Recorder;
