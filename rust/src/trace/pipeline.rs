//! Staged, overlapped trace ingest: I/O, checksum, and columnar decode
//! run concurrently with the consuming simulator, delivering the exact
//! block sequence of the synchronous path.
//!
//! The synchronous replay path ([`ReplaySource`](super::ReplaySource))
//! interleaves four serial phases per block on one thread — read bytes,
//! checksum, varint-decode, simulate — so the simulator stalls on ingest
//! and ingest stalls on the simulator: the serialized-ingest bottleneck
//! the I/O-pipeline literature characterizes for ML training input
//! pipelines. This module splits the phases across threads:
//!
//! ```text
//!  I/O thread           decoder pool (N-1 threads)        calling thread
//!  ──────────           ──────────────────────────        ──────────────
//!  read frame ──buf──▶  decode payload → EventBlock ──▶   reorder by seq
//!  verify fnv           (any order, one block each)       deliver in order
//!  (seq tagged)                                           sink.consume()
//!       ▲                        ▲      │                      │
//!       └────── byte buffers ────┴──────┴──── EventBlocks ─────┘
//!                        recycled through BlockPool
//! ```
//!
//! **Ordering / parity.** Every frame carries a sequence number; the
//! consumer holds a small reorder buffer and releases blocks strictly in
//! sequence, so the sink observes the identical block stream — same
//! blocks, same boundaries, same order — as a synchronous read, and any
//! [`Metrics`](crate::sim::Metrics) computed downstream are bit-identical
//! (asserted by `rust/tests/ingest.rs`).
//!
//! **Backpressure.** Both channels are bounded (2 slots per decoder),
//! and the I/O thread additionally stops reading once it is a fixed
//! reorder window ahead of in-order delivery — without that window, a
//! single stalled decoder would let its peers race ahead and grow the
//! consumer's reorder buffer without bound. In-flight memory is
//! therefore bounded by the window plus the channel depths regardless
//! of trace size.
//!
//! **Allocation.** Payload buffers and decoded blocks cycle through a
//! shared [`BlockPool`]; after warm-up, steady-state ingest performs no
//! heap allocation (decode refills lane buffers in place — see
//! [`decode_block`](super::store::decode_block)).

use super::block::{BlockSink, EventBlock};
use super::error::TraceError;
use super::store::{decode_block, Frame, ReplayStats, TraceMeta, TraceReader};
use crate::util::error::panic_message;
use crate::util::fault;
use crate::util::telemetry::{self, Counter, Stage};
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TryRecvError};
use std::sync::Mutex;

/// Recycling pool for ingest scratch: decoded [`EventBlock`]s and raw
/// payload byte buffers. Blocks are **cleared on return** (capacity
/// kept), so a pooled block is indistinguishable from a fresh one; both
/// sides are `Mutex`-guarded free lists, touched once per ~4K events —
/// far off any hot path.
#[derive(Debug, Default)]
pub struct BlockPool {
    blocks: Mutex<Vec<EventBlock>>,
    bufs: Mutex<Vec<Vec<u8>>>,
}

impl BlockPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty block, recycled if one is pooled.
    pub fn get_block(&self) -> EventBlock {
        match self.blocks.lock().unwrap().pop() {
            Some(b) => {
                telemetry::add(Counter::PoolHit, 1);
                b
            }
            None => {
                telemetry::add(Counter::PoolMiss, 1);
                EventBlock::with_capacity()
            }
        }
    }

    /// Return a block for reuse; it is cleared here so every `get_block`
    /// hands out an empty one.
    pub fn put_block(&self, mut b: EventBlock) {
        b.clear();
        self.blocks.lock().unwrap().push(b);
    }

    /// A payload byte buffer, recycled if one is pooled. Unlike blocks,
    /// buffers keep their previous **length**, not just capacity: the
    /// frame reader `resize`s to the exact payload length and
    /// `read_exact` overwrites every byte, so zeroing here would only
    /// force a full memset per block on the I/O thread (resize from 0
    /// re-zero-fills everything; resize from a similar length fills
    /// nothing).
    pub fn get_buf(&self) -> Vec<u8> {
        self.bufs.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a byte buffer for reuse (length and capacity kept — see
    /// [`BlockPool::get_buf`]).
    pub fn put_buf(&self, v: Vec<u8>) {
        self.bufs.lock().unwrap().push(v);
    }

    /// Blocks currently pooled (tests / diagnostics).
    pub fn pooled_blocks(&self) -> usize {
        self.blocks.lock().unwrap().len()
    }

    /// Byte buffers currently pooled (tests / diagnostics).
    pub fn pooled_bufs(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }
}

/// Resolve the `--ingest-threads` knob: `0` means auto — one thread per
/// available core, capped at 4 (an I/O thread plus up to three decoders
/// saturates ingest well before that; beyond it the lock on the work
/// channel starts to show). The result counts **total** ingest threads;
/// `1` means the synchronous path.
pub fn resolve_ingest_threads(requested: usize) -> usize {
    if requested != 0 {
        return requested;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 4)
}

/// Record the first failure and raise the abort flag; later failures are
/// dropped (the first is the root cause, the rest are fallout).
fn set_fail(fail: &Mutex<Option<TraceError>>, failed: &AtomicBool, e: TraceError) {
    let mut slot = fail.lock().unwrap();
    if slot.is_none() {
        *slot = Some(e);
    }
    failed.store(true, Ordering::Relaxed);
}

/// Staged, overlapped reader over a recorded trace file — the pipelined
/// counterpart of [`ReplaySource`](super::ReplaySource), with the same
/// open-then-replay shape and bit-identical delivery.
pub struct PipelinedIngest {
    reader: TraceReader,
    decoders: usize,
}

impl PipelinedIngest {
    /// Open `path` for pipelined replay with `threads` total ingest
    /// threads (`0` = auto). Callers wanting the synchronous path for
    /// `threads == 1` should branch before constructing this —
    /// constructing it with 1 thread still pipelines with one decoder.
    pub fn open(path: &Path, threads: usize) -> Result<PipelinedIngest, TraceError> {
        let reader = TraceReader::open(path)?;
        let decoders = resolve_ingest_threads(threads).saturating_sub(1).max(1);
        Ok(PipelinedIngest { reader, decoders })
    }

    /// Header metadata of the underlying trace.
    pub fn meta(&self) -> &TraceMeta {
        self.reader.meta()
    }

    /// Decoder threads this ingest will run (informational).
    pub fn decoder_threads(&self) -> usize {
        self.decoders
    }

    /// Stream every block into `sink` in recorded order (finalizing it at
    /// end-of-trace) and report how much was replayed. The sink runs on
    /// the calling thread; I/O and decode overlap with it on `decoders`+1
    /// background threads.
    ///
    /// Delivery is Result-based end to end: decode failures *and decoder
    /// panics* are caught, classified as [`TraceError`]s, and returned —
    /// a bad block or a dying worker never takes the process down. (The
    /// drop-guard drain below only covers the one case that must unwind:
    /// the caller's own sink panicking on the consuming thread.)
    pub fn replay_into<S: BlockSink + ?Sized>(
        self,
        sink: &mut S,
    ) -> Result<ReplayStats, TraceError> {
        let PipelinedIngest { mut reader, decoders } = self;
        let pool = BlockPool::new();
        let depth = decoders * 2;
        // reorder-window width, in blocks: how far the I/O thread may
        // run ahead of in-order delivery (bounds the consumer's reorder
        // buffer even if one decoder stalls while its peers race ahead)
        let window = (8 * decoders as u64).max(32);
        let (work_tx, work_rx) = sync_channel::<(u64, Vec<u8>)>(depth);
        let work_rx: Mutex<Receiver<(u64, Vec<u8>)>> = Mutex::new(work_rx);
        let (out_tx, out_rx) = sync_channel::<(u64, EventBlock)>(depth);
        let fail: Mutex<Option<TraceError>> = Mutex::new(None);
        let failed = AtomicBool::new(false);
        // blocks delivered in order so far (consumer-written)
        let delivered = AtomicU64::new(0);
        let totals: Mutex<Option<(u64, u64)>> = Mutex::new(None);

        std::thread::scope(|scope| -> Result<ReplayStats, TraceError> {
            // --- stage 1: I/O thread — read + checksum framed payloads ---
            let (pool_r, fail_r, failed_r, totals_r) = (&pool, &fail, &failed, &totals);
            let delivered_r = &delivered;
            let io_reader = &mut reader;
            scope.spawn(move || {
                telemetry::lane("io");
                let mut seq = 0u64;
                loop {
                    if failed_r.load(Ordering::Relaxed) {
                        break;
                    }
                    let mut buf = pool_r.get_buf();
                    let read = telemetry::span(Stage::IoRead);
                    let frame = io_reader.next_frame_into(&mut buf);
                    drop(read);
                    match frame {
                        Ok(Frame::Block) => {
                            // hold at the reorder window (rare: only a
                            // stalled decoder or a consumer far behind
                            // opens this gap); sleep, don't spin — a
                            // block takes ~ms downstream
                            if delivered_r.load(Ordering::Relaxed) + window <= seq {
                                let _bp = telemetry::span(Stage::Backpressure);
                                while delivered_r.load(Ordering::Relaxed) + window <= seq
                                    && !failed_r.load(Ordering::Relaxed)
                                {
                                    std::thread::sleep(std::time::Duration::from_micros(100));
                                }
                            }
                            // send fails only when the pipeline is being
                            // torn down after a failure
                            if work_tx.send((seq, buf)).is_err() {
                                break;
                            }
                            seq += 1;
                        }
                        Ok(Frame::End { events, blocks }) => {
                            pool_r.put_buf(buf);
                            *totals_r.lock().unwrap() = Some((events, blocks));
                            break;
                        }
                        Err(e) => {
                            pool_r.put_buf(buf);
                            set_fail(fail_r, failed_r, e);
                            break;
                        }
                    }
                }
                // dropping work_tx closes the work channel; decoders
                // drain and exit
            });

            // --- stage 2: decoder pool — payload bytes → EventBlocks ---
            for d in 0..decoders {
                let out_tx = out_tx.clone();
                let (work_rx, pool_r, fail_r, failed_r) = (&work_rx, &pool, &fail, &failed);
                scope.spawn(move || {
                    telemetry::lane_with(|| format!("decode-{d}"));
                    loop {
                        // holding the lock across the blocking recv is
                        // fine: a parked holder only blocks peers that
                        // would also have nothing to do
                        let item = work_rx.lock().unwrap().recv();
                        let Ok((seq, buf)) = item else { break };
                        if failed_r.load(Ordering::Relaxed) {
                            pool_r.put_buf(buf);
                            continue; // drain so the I/O thread never wedges
                        }
                        let mut block = pool_r.get_block();
                        if let Some(ms) = fault::fired(fault::Site::Stall) {
                            // slow-stage straggler: the reorder window
                            // must absorb it without changing delivery
                            // order
                            std::thread::sleep(std::time::Duration::from_millis(ms));
                        }
                        // a panicking decoder is converted to a typed
                        // error here rather than unwinding through the
                        // scope and tearing down the whole process
                        let dec_span = telemetry::span(Stage::Decode);
                        let decoded = catch_unwind(AssertUnwindSafe(|| {
                            if fault::fired(fault::Site::DecodePanic).is_some() {
                                panic!("injected decoder panic at block {seq}");
                            }
                            decode_block(&buf, &mut block)
                        }));
                        drop(dec_span);
                        match decoded {
                            Ok(Ok(())) => {
                                pool_r.put_buf(buf);
                                if out_tx.send((seq, block)).is_err() {
                                    break;
                                }
                            }
                            Ok(Err(e)) => {
                                pool_r.put_buf(buf);
                                pool_r.put_block(block);
                                set_fail(
                                    fail_r,
                                    failed_r,
                                    TraceError::corrupt(
                                        seq,
                                        format!("decoding block {seq}: {e}"),
                                    ),
                                );
                            }
                            Err(payload) => {
                                pool_r.put_buf(buf);
                                pool_r.put_block(block);
                                set_fail(
                                    fail_r,
                                    failed_r,
                                    TraceError::worker_panic(format!(
                                        "decoder thread panicked at block {seq}: {}",
                                        panic_message(payload.as_ref())
                                    )),
                                );
                            }
                        }
                    }
                });
            }
            // the consumer's clone must go, or out_rx never closes
            drop(out_tx);

            // --- stage 3: consumer (this thread) — in-order delivery ---

            /// If the consumer unwinds (a panicking sink), raise the
            /// abort flag and drain the result channel until the
            /// decoders disconnect: they may be parked in a send on the
            /// full bounded channel, and `thread::scope` joins every
            /// spawned thread before resuming the unwind — without the
            /// drain the process would hang instead of panicking.
            struct DrainOnPanic<'a> {
                failed: &'a AtomicBool,
                out_rx: &'a Receiver<(u64, EventBlock)>,
                armed: bool,
            }
            impl Drop for DrainOnPanic<'_> {
                fn drop(&mut self) {
                    if !self.armed {
                        return;
                    }
                    self.failed.store(true, Ordering::Relaxed);
                    loop {
                        match self.out_rx.try_recv() {
                            Ok(_) => {}
                            Err(TryRecvError::Empty) => std::thread::yield_now(),
                            Err(TryRecvError::Disconnected) => break,
                        }
                    }
                }
            }
            let mut drain_guard =
                DrainOnPanic { failed: &failed, out_rx: &out_rx, armed: true };

            let mut pending: BTreeMap<u64, EventBlock> = BTreeMap::new();
            let mut next_seq = 0u64;
            let mut blocks = 0u64;
            let mut events = 0u64;
            while let Ok((seq, block)) = out_rx.recv() {
                pending.insert(seq, block);
                while let Some(block) = pending.remove(&next_seq) {
                    let consume = telemetry::span(Stage::Consume);
                    sink.consume(&block);
                    drop(consume);
                    telemetry::add(Counter::BlocksDecoded, 1);
                    events += block.len() as u64;
                    blocks += 1;
                    next_seq += 1;
                    pool.put_block(block);
                }
                // publish the watermark that releases the I/O thread's
                // reorder-window hold
                delivered.store(next_seq, Ordering::Relaxed);
            }
            drain_guard.armed = false;
            // out channel closed: every decoder has exited
            if let Some(e) = fail.lock().unwrap().take() {
                return Err(e);
            }
            debug_assert!(pending.is_empty(), "gap in sequence without a recorded failure");
            let Some((t_events, t_blocks)) = *totals.lock().unwrap() else {
                return Err(TraceError::truncated("trace ended without a trailer"));
            };
            if blocks != t_blocks || events != t_events {
                return Err(TraceError::corrupt(
                    blocks,
                    format!(
                        "trace trailer mismatch: trailer says {t_blocks} blocks / {t_events} \
                         events, pipeline delivered {blocks} / {events}"
                    ),
                ));
            }
            sink.finalize();
            Ok(ReplayStats { blocks, events })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::VecSink;
    use crate::trace::store::{TraceWriter, TRACE_VERSION};
    use crate::trace::{BlockSink, Event, PerEvent};
    use crate::workloads::LibraryProfile;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("mlperf-pipeline-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn meta() -> TraceMeta {
        TraceMeta {
            workload: "KMeans".into(),
            profile: LibraryProfile::Sklearn,
            sw_prefetch: false,
            rows: 100,
            features: 4,
            iterations: 1,
            seed: 7,
            dataset_bytes: 100 * 5 * 8,
        }
    }

    fn varied_block(i: u64) -> EventBlock {
        let mut b = EventBlock::with_capacity();
        for j in 0..64 {
            b.push_load(0x1000 + i * 4096 + j * 64, 8, j % 3 == 0);
            b.push_compute(1 + i as u32, 2);
            b.push_branch(9, j % 2 == 0, true);
        }
        b.push_store(0x9000 + i * 64, 64);
        b
    }

    fn write_trace(path: &std::path::Path, blocks: u64) -> u64 {
        let mut w = TraceWriter::create(path, &meta()).unwrap();
        let mut events = 0;
        for i in 0..blocks {
            let b = varied_block(i);
            events += b.len() as u64;
            w.consume(&b);
        }
        w.finalize();
        w.finish().unwrap();
        events
    }

    #[test]
    fn pool_recycles_cleared_blocks_and_bufs() {
        let pool = BlockPool::new();
        let mut b = pool.get_block();
        b.push_compute(1, 2);
        b.push_load(0x40, 8, true);
        assert_eq!(b.len(), 2);
        pool.put_block(b);
        assert_eq!(pool.pooled_blocks(), 1);
        let b = pool.get_block();
        assert!(b.is_empty(), "recycled block must come back cleared");
        assert!(b.compute.is_empty() && b.loads.is_empty());
        assert_eq!(pool.pooled_blocks(), 0);

        let mut v = pool.get_buf();
        v.extend_from_slice(b"payload");
        let cap = v.capacity();
        pool.put_buf(v);
        let v = pool.get_buf();
        // buffers deliberately keep their length (no clear → no memset
        // when the frame reader resizes to the next payload length);
        // only the capacity guarantee matters
        assert!(v.capacity() >= cap, "capacity must be retained");
    }

    #[test]
    fn resolve_threads_has_floor_and_explicit_passthrough() {
        assert!(resolve_ingest_threads(0) >= 1);
        assert!(resolve_ingest_threads(0) <= 4);
        assert_eq!(resolve_ingest_threads(1), 1);
        assert_eq!(resolve_ingest_threads(7), 7);
    }

    #[test]
    fn pipelined_delivery_matches_synchronous_order() {
        let p = tmpfile("order.mlt");
        write_trace(&p, 23);

        let mut sync_sink = VecSink::default();
        {
            let mut adapter = PerEvent(&mut sync_sink);
            crate::trace::ReplaySource::open(&p).unwrap().replay_into(&mut adapter).unwrap();
        }
        let mut pipe_sink = VecSink::default();
        let stats = {
            let mut adapter = PerEvent(&mut pipe_sink);
            PipelinedIngest::open(&p, 3).unwrap().replay_into(&mut adapter).unwrap()
        };
        assert_eq!(stats.blocks, 23);
        assert_eq!(
            sync_sink.events.len() as u64,
            stats.events,
            "event totals must agree"
        );
        assert_eq!(
            sync_sink.events,
            pipe_sink.events,
            "pipelined ingest reordered or altered the stream"
        );
        assert!(pipe_sink.finished);
    }

    /// Sink that records block boundaries, proving the *block sequence*
    /// (not just the flattened events) is identical.
    #[derive(Default)]
    struct BlockLens {
        lens: Vec<usize>,
        finalized: bool,
    }
    impl BlockSink for BlockLens {
        fn consume(&mut self, block: &EventBlock) {
            self.lens.push(block.len());
        }
        fn finalize(&mut self) {
            self.finalized = true;
        }
    }

    #[test]
    fn pipelined_block_boundaries_match_synchronous() {
        let p = tmpfile("bounds.mlt");
        write_trace(&p, 9);
        let mut a = BlockLens::default();
        crate::trace::ReplaySource::open(&p).unwrap().replay_into(&mut a).unwrap();
        let mut b = BlockLens::default();
        PipelinedIngest::open(&p, 0).unwrap().replay_into(&mut b).unwrap();
        assert_eq!(a.lens, b.lens);
        assert!(a.finalized && b.finalized);
    }

    #[test]
    fn empty_trace_pipelines_cleanly() {
        let p = tmpfile("empty.mlt");
        write_trace(&p, 0);
        let mut sink = BlockLens::default();
        let stats = PipelinedIngest::open(&p, 2).unwrap().replay_into(&mut sink).unwrap();
        assert_eq!(stats.blocks, 0);
        assert_eq!(stats.events, 0);
        assert!(sink.finalized, "finalize must fire even for an empty trace");
    }

    #[test]
    fn corruption_surfaces_as_error_not_hang() {
        let p = tmpfile("corrupt.mlt");
        write_trace(&p, 8);
        let mut bytes = std::fs::read(&p).unwrap();
        // flip a bit midway through the file body (past the header)
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        let mut sink = BlockLens::default();
        let err = PipelinedIngest::open(&p, 3).unwrap().replay_into(&mut sink);
        assert!(err.is_err(), "corruption must fail the pipelined replay");
        assert!(!sink.finalized, "a failed replay must not finalize the sink");
    }

    #[test]
    fn truncated_trace_surfaces_as_error() {
        let p = tmpfile("trunc.mlt");
        write_trace(&p, 8);
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 9]).unwrap(); // lose the trailer
        let mut sink = BlockLens::default();
        let err = PipelinedIngest::open(&p, 2).unwrap().replay_into(&mut sink);
        assert!(err.is_err(), "missing trailer must fail");
    }

    #[test]
    fn version_gate_still_applies() {
        let p = tmpfile("version.mlt");
        write_trace(&p, 1);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[8] = TRACE_VERSION as u8 + 9;
        std::fs::write(&p, &bytes).unwrap();
        assert!(PipelinedIngest::open(&p, 2).is_err());
    }

    #[test]
    fn single_ingest_thread_still_works() {
        // threads=1 resolves to one decoder — degenerate but valid
        let p = tmpfile("one.mlt");
        let events = write_trace(&p, 5);
        let mut sink = BlockLens::default();
        let stats = PipelinedIngest::open(&p, 1).unwrap().replay_into(&mut sink).unwrap();
        assert_eq!(stats.events, events);
        assert_eq!(sink.lens.len(), 5);
    }

    #[test]
    fn events_reconstructable_via_iter() {
        // sanity: the varied blocks carry real mixed-lane content
        let b = varied_block(3);
        let evs: Vec<Event> = b.iter().collect();
        assert_eq!(evs.len(), b.len());
    }
}
