//! Ergonomic instrumentation facade used inside workload inner loops.
//!
//! `Recorder` provides the idioms the workloads need — row reads,
//! compare-and-branch, indirect `A[B[i]]` loads, and optional software
//! prefetching that can be toggled per run (the paper's before / after
//! comparison runs the *same* code with prefetching on or off) — and
//! buffers what they emit into a columnar [`EventBlock`], delivering it to
//! a [`BlockSink`] one full block at a time. The per-event cost inside a
//! workload loop is therefore a pair of lane appends, not a virtual call:
//! the batching discipline the paper prescribes, applied to our own
//! measurement substrate.
//!
//! `Recorder` is generic over its sink so benches and other call sites
//! that know the concrete consumer get a fully monomorphized pipeline;
//! the default type parameter keeps `&mut Recorder` (as the [`Workload`]
//! trait uses it) spelled exactly as before, erased to
//! `dyn BlockSink` — one virtual call per [`BLOCK_EVENTS`] events.
//!
//! [`Workload`]: crate::workloads::Workload
//! [`BLOCK_EVENTS`]: super::block::BLOCK_EVENTS

use super::addr::{Region, LINE_SIZE};
use super::block::{BlockSink, EventBlock};

/// Instrumentation handle passed to a workload for one traced run.
pub struct Recorder<'a, S: BlockSink + ?Sized = dyn BlockSink + 'a> {
    /// Workload-unique namespace for branch site ids.
    ns: u32,
    /// Whether `prefetch*` calls emit events (Section V-C on/off switch).
    pub sw_prefetch_enabled: bool,
    /// Per-inner-loop-element bookkeeping uops of the library profile
    /// (Cython-generated C carries more per-element overhead than lean
    /// templated C++ — the sklearn-vs-mlpack CPI gap of Fig. 1). Shared
    /// substrates (spatial trees, CART) read this instead of taking a
    /// profile parameter.
    pub profile_overhead: u32,
    events: u64,
    buf: EventBlock,
    sink: &'a mut S,
}

impl<'a> Recorder<'a> {
    /// New recorder over a type-erased sink with branch-site namespace
    /// `ns` (one per workload). Any `&mut impl BlockSink` coerces here;
    /// use [`Recorder::typed`] to keep the sink monomorphized.
    pub fn new(sink: &'a mut (dyn BlockSink + 'a), ns: u32) -> Self {
        Recorder::typed(sink, ns)
    }
}

impl<'a, S: BlockSink + ?Sized> Recorder<'a, S> {
    /// New recorder statically bound to sink type `S`: block delivery
    /// monomorphizes and the whole pipeline inlines (no dynamic dispatch
    /// at any granularity).
    pub fn typed(sink: &'a mut S, ns: u32) -> Self {
        Self {
            ns,
            sw_prefetch_enabled: false,
            profile_overhead: 2,
            events: 0,
            buf: EventBlock::with_capacity(),
            sink,
        }
    }

    /// Deliver the buffered partial block to the sink, if any.
    pub fn flush(&mut self) {
        if !self.buf.is_empty() {
            self.sink.consume(&self.buf);
            self.buf.clear();
        }
    }

    #[inline]
    fn emitted(&mut self) {
        self.events += 1;
        if self.buf.is_full() {
            self.flush();
        }
    }

    /// Number of events emitted so far.
    pub fn events_emitted(&self) -> u64 {
        self.events
    }

    /// Aggregated compute uops.
    #[inline]
    pub fn compute(&mut self, int_ops: u32, fp_ops: u32) {
        self.buf.push_compute(int_ops, fp_ops);
        self.emitted();
    }

    /// The library profile's per-element serialized bookkeeping chain
    /// (see [`Event::Serial`]); call once per instrumented inner-loop
    /// element.
    ///
    /// [`Event::Serial`]: super::event::Event::Serial
    #[inline]
    pub fn profile_tick(&mut self) {
        let ops = self.profile_overhead;
        if ops > 0 {
            self.buf.push_serial(ops);
            self.emitted();
        }
    }

    /// A plain load of `size` bytes.
    #[inline]
    pub fn load(&mut self, addr: u64, size: u32) {
        self.buf.push_load(addr, size, false);
        self.emitted();
    }

    /// A load whose result immediately feeds a conditional branch.
    #[inline]
    pub fn load_for_branch(&mut self, addr: u64, size: u32) {
        self.buf.push_load(addr, size, true);
        self.emitted();
    }

    /// A store of `size` bytes.
    #[inline]
    pub fn store(&mut self, addr: u64, size: u32) {
        self.buf.push_store(addr, size);
        self.emitted();
    }

    /// Read one f64 element.
    #[inline]
    pub fn load_f64(&mut self, region: Region, idx: usize) {
        self.load(region.f64(idx), 8);
    }

    /// Write one f64 element.
    #[inline]
    pub fn store_f64(&mut self, region: Region, idx: usize) {
        self.store(region.f64(idx), 8);
    }

    /// Read a full feature row (`cols` f64s) of the row-major matrix that
    /// `region` models, accounting `2*cols` fp uops of follow-on arithmetic
    /// by default at the call sites that need it (callers add their own).
    #[inline]
    pub fn load_row(&mut self, region: Region, row: usize, cols: usize) {
        self.load(region.f64(row * cols), (cols * 8) as u32);
    }

    /// Write a full feature row.
    #[inline]
    pub fn store_row(&mut self, region: Region, row: usize, cols: usize) {
        self.store(region.f64(row * cols), (cols * 8) as u32);
    }

    /// Indirect load `A[B[i]]`: reads the index element (4-byte i32, the
    /// paper's index arrays) then the target row. The *index* load feeds
    /// address generation, not a branch.
    #[inline]
    pub fn load_indirect_row(
        &mut self,
        index_arr: Region,
        i: usize,
        data: Region,
        target_row: usize,
        cols: usize,
    ) {
        self.load(index_arr.elem(i, 4), 4);
        self.compute(1, 0); // address generation
        self.load_row(data, target_row, cols);
    }

    /// Conditional branch at site `site` with outcome `cond`; returns
    /// `cond` so call sites read naturally:
    /// `if r.branch(SITE_X, a < b) { ... }`.
    #[inline]
    pub fn branch(&mut self, site: u32, cond: bool) -> bool {
        self.buf.push_branch(self.ns << 16 | site, cond, true);
        self.emitted();
        cond
    }

    /// Compare-then-branch: one int uop for the compare plus the branch.
    #[inline]
    pub fn cmp_branch(&mut self, site: u32, cond: bool) -> bool {
        self.compute(1, 0);
        self.branch(site, cond)
    }

    /// fp compare-then-branch (tree splits, distance threshold tests).
    #[inline]
    pub fn fcmp_branch(&mut self, site: u32, cond: bool) -> bool {
        self.compute(0, 1);
        self.branch(site, cond)
    }

    /// Load a value that is immediately compared and branched on — the
    /// `A[B[i]] <= θ` pattern of tree traversal and neighbour pruning.
    #[inline]
    pub fn load_cmp_branch(&mut self, site: u32, addr: u64, size: u32, cond: bool) -> bool {
        self.load_for_branch(addr, size);
        self.fcmp_branch(site, cond)
    }

    /// Unconditional branch (loop back-edges, calls).
    #[inline]
    pub fn jump(&mut self, site: u32) {
        self.buf.push_branch(self.ns << 16 | site, true, false);
        self.emitted();
    }

    /// A counted inner loop executing `count` back-edge branches (e.g. a
    /// compiled distance loop over the feature dimension).
    #[inline]
    pub fn loop_branch(&mut self, site: u32, count: u32) {
        if count > 0 {
            self.buf.push_loop_branch(self.ns << 16 | site, count);
            self.emitted();
        }
    }

    /// Software prefetch of the line(s) covering `[addr, addr+size)`; no-op
    /// unless `sw_prefetch_enabled`.
    #[inline]
    pub fn prefetch(&mut self, addr: u64, size: u32) {
        if self.sw_prefetch_enabled {
            let first = addr / LINE_SIZE;
            let last = (addr + size.max(1) as u64 - 1) / LINE_SIZE;
            for line in first..=last {
                self.buf.push_prefetch(line * LINE_SIZE);
                self.emitted();
            }
        }
    }

    /// Prefetch a whole matrix row.
    #[inline]
    pub fn prefetch_row(&mut self, region: Region, row: usize, cols: usize) {
        if self.sw_prefetch_enabled {
            self.prefetch(region.f64(row * cols), (cols * 8) as u32);
        }
    }

    /// End-of-trace marker; flushes the partial block and finalizes the
    /// sink.
    pub fn finish(&mut self) {
        self.flush();
        self.sink.finalize();
    }
}

/// Dropping a recorder flushes any buffered partial block (but does not
/// finalize the sink), so sinks inspected after the recorder goes out of
/// scope — the idiom throughout the tests — observe the complete stream.
impl<S: BlockSink + ?Sized> Drop for Recorder<'_, S> {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::addr::AddressSpace;
    use crate::trace::block::BLOCK_EVENTS;
    use crate::trace::event::{Event, VecSink};

    #[test]
    fn branch_returns_condition_and_namespaces_site() {
        let mut v = VecSink::default();
        {
            let mut r = Recorder::new(&mut v, 7);
            assert!(r.branch(3, true));
            assert!(!r.branch(3, false));
        }
        match v.events[0] {
            Event::Branch { site, taken, conditional } => {
                assert_eq!(site, 7 << 16 | 3);
                assert!(taken && conditional);
            }
            _ => panic!("expected branch"),
        }
    }

    #[test]
    fn prefetch_disabled_by_default() {
        let mut v = VecSink::default();
        {
            let mut r = Recorder::new(&mut v, 0);
            r.prefetch(0x1000, 64);
        }
        assert!(v.events.is_empty());
    }

    #[test]
    fn prefetch_expands_to_lines() {
        let mut v = VecSink::default();
        {
            let mut r = Recorder::new(&mut v, 0);
            r.sw_prefetch_enabled = true;
            r.prefetch(0x1000 + 32, 64); // straddles two lines
        }
        assert_eq!(
            v.events,
            vec![
                Event::SwPrefetch { addr: 0x1000 },
                Event::SwPrefetch { addr: 0x1040 },
            ]
        );
    }

    #[test]
    fn indirect_load_emits_index_then_row() {
        let mut space = AddressSpace::new();
        let idx = space.alloc("idx", 400);
        let data = space.alloc_matrix("x", 10, 4);
        let mut v = VecSink::default();
        {
            let mut r = Recorder::new(&mut v, 1);
            r.load_indirect_row(idx, 5, data, 3, 4);
        }
        assert_eq!(v.events.len(), 3);
        assert_eq!(
            v.events[0],
            Event::Load { addr: idx.elem(5, 4), size: 4, feeds_branch: false }
        );
        assert_eq!(
            v.events[2],
            Event::Load { addr: data.f64(12), size: 32, feeds_branch: false }
        );
    }

    #[test]
    fn load_cmp_branch_marks_feeding_load() {
        let mut v = VecSink::default();
        {
            let mut r = Recorder::new(&mut v, 1);
            r.load_cmp_branch(9, 0x2000, 8, true);
        }
        assert!(matches!(
            v.events[0],
            Event::Load { feeds_branch: true, .. }
        ));
        assert!(matches!(v.events[2], Event::Branch { conditional: true, .. }));
    }

    #[test]
    fn event_count_tracks() {
        let mut v = VecSink::default();
        let mut r = Recorder::new(&mut v, 1);
        r.compute(1, 1);
        r.load(0x40, 8);
        assert_eq!(r.events_emitted(), 2);
    }

    /// Blocks are delivered at capacity boundaries; the tail arrives on
    /// drop/finish. Event order must survive the batching exactly.
    #[test]
    fn batching_preserves_order_across_block_boundaries() {
        let n = 2 * BLOCK_EVENTS + 100;
        let mut v = VecSink::default();
        {
            let mut r = Recorder::new(&mut v, 1);
            for i in 0..n {
                match i % 3 {
                    0 => r.load(i as u64 * 8, 8),
                    1 => r.compute(1, 2),
                    _ => {
                        r.branch(1, i % 2 == 0);
                    }
                }
            }
            assert_eq!(r.events_emitted(), n as u64);
            r.finish();
        }
        assert!(v.finished);
        assert_eq!(v.events.len(), n);
        for (i, ev) in v.events.iter().enumerate() {
            match i % 3 {
                0 => assert_eq!(
                    *ev,
                    Event::Load { addr: i as u64 * 8, size: 8, feeds_branch: false }
                ),
                1 => assert_eq!(*ev, Event::Compute { int_ops: 1, fp_ops: 2 }),
                _ => assert!(matches!(ev, Event::Branch { conditional: true, .. })),
            }
        }
    }

    /// A monomorphized recorder behaves identically to the erased one.
    #[test]
    fn typed_recorder_matches_dyn_recorder() {
        let drive = |r: &mut Recorder<VecSink>| {
            r.load(0x100, 64);
            r.cmp_branch(2, true);
            r.loop_branch(3, 17);
            r.finish();
        };
        let mut a = VecSink::default();
        drive(&mut Recorder::typed(&mut a, 5));
        let mut b = VecSink::default();
        {
            let mut r = Recorder::new(&mut b, 5);
            r.load(0x100, 64);
            r.cmp_branch(2, true);
            r.loop_branch(3, 17);
            r.finish();
        }
        assert_eq!(a.events, b.events);
    }
}
