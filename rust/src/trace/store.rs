//! On-disk columnar trace persistence: record a workload's event stream
//! once, replay it into any [`BlockSink`] many times.
//!
//! The paper's methodology is trace-driven — one instrumented execution
//! feeds many cache/DRAM/prefetch configurations — and re-executing a
//! workload per scenario cell just to regenerate a bit-identical event
//! stream is the dominant cost of a sweep. This module makes the trace
//! itself the reusable artifact:
//!
//! - [`TraceWriter`] is a [`BlockSink`]: hang it off a
//!   [`Recorder`](super::Recorder) (alone or behind a
//!   [`BlockTee`](super::BlockTee)) and every [`EventBlock`] streams to
//!   disk as it is flushed.
//! - [`TraceReader`] streams blocks back, validating the per-block
//!   checksums and the end-of-trace totals.
//! - [`ReplaySource`] pumps a stored trace into any `BlockSink`
//!   (typically a [`PipelineSim`](crate::sim::PipelineSim)) without ever
//!   touching the workload layer.
//! - [`CapturedTrace`] is the in-memory equivalent used by the
//!   record-once/replay-many grid driver
//!   ([`crate::coordinator::driver::run_jobs_replayed`]), where one
//!   capture fans out to all scenario cells of a workload.
//!
//! # File format (version 1)
//!
//! All integers are little-endian; `varint` is LEB128, `ivarint` is
//! zigzag-mapped LEB128 (see [`crate::util::binio`]).
//!
//! ```text
//! header   magic "MLTRACE1" (8) · version u32 · meta
//! meta     u16 name_len · workload name (utf-8) · profile u8
//!          (0 = sklearn, 1 = mlpack) · sw_prefetch u8 · rows u64 ·
//!          features u64 · iterations u64 · seed u64 · dataset_bytes u64
//! blocks   repeated: 0xB1 · payload_len u32 · fnv1a64 checksum u64 ·
//!          payload
//! trailer  0xE7 · total_events u64 · total_blocks u64
//! ```
//!
//! Each block payload is self-contained (delta bases reset per block):
//!
//! ```text
//! varint n_events
//! tag lane      RLE runs of (kind u8, varint run_len) summing to n_events
//! compute lane  per record: varint int_ops · varint fp_ops
//! serial lane   per record: varint ops
//! load lane     per record: ivarint Δaddr · varint (size << 1 | feeds_branch)
//! store lane    per record: ivarint Δaddr · varint size
//! branch lane   per record: ivarint Δsite · flags u8 (taken | conditional << 1)
//! loop lane     per record: ivarint Δsite · varint count
//! prefetch lane per record: ivarint Δaddr
//! ```
//!
//! Compatibility rules: the magic identifies the family; a reader accepts
//! exactly its own `TRACE_VERSION` and tells the user to re-record
//! otherwise (traces are cheap to regenerate — they are caches of
//! executions, not primary data). Any lane or header change bumps the
//! version; `EventKind` discriminants are append-only because they appear
//! verbatim in the tag lane.

use super::block::{BlockSink, BranchRec, EventBlock, EventKind, LoadRec, StoreRec, BLOCK_EVENTS};
use super::error::{retry_backoff, TraceError, MAX_IO_RETRIES};
use crate::util::binio::{
    fnv1a64, put_ivarint, put_uvarint, read_u16, read_u64, read_u8, write_u64, ByteCursor,
};
use crate::util::error::{Context, Result};
use crate::util::fault;
use crate::workloads::LibraryProfile;
use crate::{anyhow, bail};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

/// File magic for the columnar trace container.
pub const TRACE_MAGIC: &[u8; 8] = b"MLTRACE1";
/// Format version written and accepted by this build.
pub const TRACE_VERSION: u32 = 1;

const BLOCK_MARKER: u8 = 0xB1;
const END_MARKER: u8 = 0xE7;
/// Upper bound on an encoded block payload. The worst-case encoding of a
/// full 4096-event block is under 100 KiB; anything larger is corruption.
const MAX_PAYLOAD: usize = 1 << 20;

/// Provenance carried in the trace header: everything replay needs to
/// reproduce the recording run's simulator configuration (notably
/// `dataset_bytes`, which drives `auto_shrink`) and everything a human
/// needs to know what the file is.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceMeta {
    /// Paper workload name (e.g. "KMeans").
    pub workload: String,
    /// Library profile the recording ran under.
    pub profile: LibraryProfile,
    /// Whether software prefetching was enabled (prefetch events change
    /// the trace, so the on/off variants are distinct recordings).
    pub sw_prefetch: bool,
    /// Dataset rows the recording used.
    pub rows: u64,
    /// Dataset feature count.
    pub features: u64,
    /// Training iterations.
    pub iterations: u64,
    /// RNG seed of the recording run.
    pub seed: u64,
    /// Modelled dataset footprint in bytes (input to `auto_shrink`).
    pub dataset_bytes: u64,
}

fn profile_to_u8(p: LibraryProfile) -> u8 {
    match p {
        LibraryProfile::Sklearn => 0,
        LibraryProfile::Mlpack => 1,
    }
}

fn profile_from_u8(v: u8) -> Result<LibraryProfile> {
    match v {
        0 => Ok(LibraryProfile::Sklearn),
        1 => Ok(LibraryProfile::Mlpack),
        other => Err(anyhow!("invalid profile byte {other} in trace header")),
    }
}

fn write_meta<W: Write>(w: &mut W, meta: &TraceMeta) -> Result<u64> {
    let name = meta.workload.as_bytes();
    if name.len() > u16::MAX as usize {
        bail!("workload name too long for trace header");
    }
    w.write_all(&(name.len() as u16).to_le_bytes())?;
    w.write_all(name)?;
    w.write_all(&[profile_to_u8(meta.profile), u8::from(meta.sw_prefetch)])?;
    for v in [meta.rows, meta.features, meta.iterations, meta.seed, meta.dataset_bytes] {
        write_u64(w, v)?;
    }
    Ok(2 + name.len() as u64 + 2 + 5 * 8)
}

fn read_meta<R: Read>(r: &mut R) -> Result<TraceMeta> {
    let name_len = read_u16(r).context("reading trace meta")? as usize;
    if name_len > 4096 {
        bail!("trace header claims a {name_len}-byte workload name — corrupt");
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name).context("reading trace meta")?;
    let workload = String::from_utf8(name).context("workload name is not utf-8")?;
    let profile = profile_from_u8(read_u8(r)?)?;
    let sw_prefetch = match read_u8(r)? {
        0 => false,
        1 => true,
        other => bail!("invalid sw_prefetch byte {other} in trace header"),
    };
    Ok(TraceMeta {
        workload,
        profile,
        sw_prefetch,
        rows: read_u64(r)?,
        features: read_u64(r)?,
        iterations: read_u64(r)?,
        seed: read_u64(r)?,
        dataset_bytes: read_u64(r)?,
    })
}

/// Append the columnar encoding of `block` to `buf` (which the caller
/// clears; the writer reuses one scratch buffer across blocks).
pub fn encode_block(block: &EventBlock, buf: &mut Vec<u8>) {
    put_uvarint(buf, block.len() as u64);

    // Tag lane, run-length encoded: inner loops emit long runs of the
    // same kind (a counted loop is one LoopBranch run; a row scan is a
    // Load/Compute alternation), so runs compress the order information
    // far below one byte per event. Run detection scans a subslice per
    // run (one bounds check up front, not one per element).
    let kinds = block.kinds();
    let mut i = 0;
    while i < kinds.len() {
        let k = kinds[i];
        let run = 1 + kinds[i + 1..].iter().take_while(|&&x| x == k).count();
        buf.push(k as u8);
        put_uvarint(buf, run as u64);
        i += run;
    }

    for &(int_ops, fp_ops) in &block.compute {
        put_uvarint(buf, u64::from(int_ops));
        put_uvarint(buf, u64::from(fp_ops));
    }
    for &ops in &block.serial {
        put_uvarint(buf, u64::from(ops));
    }
    let mut prev = 0u64;
    for l in &block.loads {
        put_ivarint(buf, l.addr.wrapping_sub(prev) as i64);
        prev = l.addr;
        put_uvarint(buf, (u64::from(l.size) << 1) | u64::from(l.feeds_branch));
    }
    let mut prev = 0u64;
    for s in &block.stores {
        put_ivarint(buf, s.addr.wrapping_sub(prev) as i64);
        prev = s.addr;
        put_uvarint(buf, u64::from(s.size));
    }
    let mut prev = 0u64;
    for b in &block.branches {
        put_ivarint(buf, u64::from(b.site).wrapping_sub(prev) as i64);
        prev = u64::from(b.site);
        buf.push(u8::from(b.taken) | (u8::from(b.conditional) << 1));
    }
    let mut prev = 0u64;
    for &(site, count) in &block.loop_branches {
        put_ivarint(buf, u64::from(site).wrapping_sub(prev) as i64);
        prev = u64::from(site);
        put_uvarint(buf, u64::from(count));
    }
    let mut prev = 0u64;
    for &addr in &block.prefetches {
        put_ivarint(buf, addr.wrapping_sub(prev) as i64);
        prev = addr;
    }
}

fn u32_field(cur: &mut ByteCursor<'_>, what: &str) -> Result<u32> {
    let v = cur.uvarint()?;
    u32::try_from(v).map_err(|_| anyhow!("{what} {v} overflows u32"))
}

fn delta_base(cur: &mut ByteCursor<'_>, prev: &mut u64) -> Result<u64> {
    *prev = prev.wrapping_add(cur.ivarint()? as u64);
    Ok(*prev)
}

/// Decode one payload (as produced by [`encode_block`]) into `out`,
/// replacing its contents **in place**: `out`'s lane buffers are cleared
/// and refilled, so a caller that reuses one block (or a
/// [`BlockPool`](super::pipeline::BlockPool)-recycled one) decodes an
/// entire trace without any steady-state allocation. Every field is
/// validated; a malformed payload yields an error, never a panic or a
/// silently wrong block — on error `out` is left partially filled and
/// must not be read.
pub fn decode_block(buf: &[u8], out: &mut EventBlock) -> Result<()> {
    out.clear();
    let cur = &mut ByteCursor::new(buf);
    let n = cur.uvarint()? as usize;
    if n > BLOCK_EVENTS {
        bail!("block claims {n} events (format max {BLOCK_EVENTS})");
    }

    // Tag lane: each RLE run materializes as one bulk fill.
    let mut counts = [0usize; 7];
    while out.len() < n {
        let kb = cur.u8().map_err(|_| anyhow!("truncated tag lane"))?;
        let kind =
            EventKind::from_u8(kb).ok_or_else(|| anyhow!("invalid event kind byte {kb}"))?;
        let run = cur.uvarint()? as usize;
        if run == 0 || run > n - out.len() {
            bail!("tag-lane run of {run} inconsistent with event count {n}");
        }
        counts[kb as usize] += run;
        out.extend_kind_run(kind, run);
    }

    let n_compute = counts[EventKind::Compute as usize];
    out.compute.reserve(n_compute);
    for _ in 0..n_compute {
        let int_ops = u32_field(cur, "int_ops")?;
        let fp_ops = u32_field(cur, "fp_ops")?;
        out.compute.push((int_ops, fp_ops));
    }

    let n_serial = counts[EventKind::Serial as usize];
    out.serial.reserve(n_serial);
    for _ in 0..n_serial {
        out.serial.push(u32_field(cur, "serial ops")?);
    }

    let n_loads = counts[EventKind::Load as usize];
    out.loads.reserve(n_loads);
    let mut prev = 0u64;
    for _ in 0..n_loads {
        let addr = delta_base(cur, &mut prev)?;
        let raw = cur.uvarint()?;
        let size = u32::try_from(raw >> 1).map_err(|_| anyhow!("load size overflows u32"))?;
        out.loads.push(LoadRec { addr, size, feeds_branch: raw & 1 != 0 });
    }

    let n_stores = counts[EventKind::Store as usize];
    out.stores.reserve(n_stores);
    let mut prev = 0u64;
    for _ in 0..n_stores {
        let addr = delta_base(cur, &mut prev)?;
        let size = u32_field(cur, "store size")?;
        out.stores.push(StoreRec { addr, size });
    }

    let n_branches = counts[EventKind::Branch as usize];
    out.branches.reserve(n_branches);
    let mut prev = 0u64;
    for _ in 0..n_branches {
        let site_w = delta_base(cur, &mut prev)?;
        let site = u32::try_from(site_w).map_err(|_| anyhow!("branch site overflows u32"))?;
        let flags = cur.u8().map_err(|_| anyhow!("truncated branch flags"))?;
        if flags > 0b11 {
            bail!("invalid branch flags byte {flags:#x}");
        }
        out.branches.push(BranchRec { site, taken: flags & 1 != 0, conditional: flags & 2 != 0 });
    }

    let n_loops = counts[EventKind::LoopBranch as usize];
    out.loop_branches.reserve(n_loops);
    let mut prev = 0u64;
    for _ in 0..n_loops {
        let site_w = delta_base(cur, &mut prev)?;
        let site = u32::try_from(site_w).map_err(|_| anyhow!("loop site overflows u32"))?;
        let count = u32_field(cur, "loop count")?;
        out.loop_branches.push((site, count));
    }

    let n_prefetches = counts[EventKind::SwPrefetch as usize];
    out.prefetches.reserve(n_prefetches);
    let mut prev = 0u64;
    for _ in 0..n_prefetches {
        out.prefetches.push(delta_base(cur, &mut prev)?);
    }

    if !cur.is_empty() {
        bail!("{} trailing bytes after block payload", cur.remaining());
    }
    Ok(())
}

/// What a completed recording looked like on disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceSummary {
    pub blocks: u64,
    pub events: u64,
    /// Total file size, header and trailer included.
    pub bytes: u64,
}

/// Streaming trace recorder: a [`BlockSink`] that encodes each consumed
/// block and appends it to the file.
///
/// `BlockSink::consume` cannot return errors, so I/O failures are stashed
/// and surfaced by [`TraceWriter::finish`] — always call it (after the
/// recorder has flushed) to learn whether the file is complete.
pub struct TraceWriter {
    out: BufWriter<File>,
    scratch: Vec<u8>,
    blocks: u64,
    events: u64,
    bytes: u64,
    finalized: bool,
    error: Option<crate::util::error::Error>,
}

impl TraceWriter {
    /// Create `path`, write the header, and return a writer ready to
    /// consume blocks.
    pub fn create(path: &Path, meta: &TraceMeta) -> Result<TraceWriter> {
        let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
        let mut out = BufWriter::new(f);
        out.write_all(TRACE_MAGIC)?;
        out.write_all(&TRACE_VERSION.to_le_bytes())?;
        let meta_bytes = write_meta(&mut out, meta)?;
        Ok(TraceWriter {
            out,
            scratch: Vec::new(),
            blocks: 0,
            events: 0,
            bytes: 12 + meta_bytes,
            finalized: false,
            error: None,
        })
    }

    fn try_consume(&mut self, block: &EventBlock) -> Result<()> {
        // one scratch buffer reused across every block (cleared, never
        // reallocated at steady state), one write for the whole 13-byte
        // frame header instead of three
        self.scratch.clear();
        encode_block(block, &mut self.scratch);
        let mut head = [0u8; 13];
        head[0] = BLOCK_MARKER;
        head[1..5].copy_from_slice(&(self.scratch.len() as u32).to_le_bytes());
        head[5..13].copy_from_slice(&fnv1a64(&self.scratch).to_le_bytes());
        if fault::fired(fault::Site::TornTail).is_some() {
            // model a crash mid-frame: emit the header plus a prefix of
            // the payload, then report the write as failed so the torn
            // tail stays on disk for the reader to recover from
            self.out.write_all(&head)?;
            self.out.write_all(&self.scratch[..self.scratch.len() / 2])?;
            self.out.flush()?;
            bail!("injected torn tail write at block {}", self.blocks);
        }
        self.out.write_all(&head)?;
        self.out.write_all(&self.scratch)?;
        self.blocks += 1;
        self.events += block.len() as u64;
        self.bytes += head.len() as u64 + self.scratch.len() as u64;
        Ok(())
    }

    fn write_end(&mut self) {
        if self.finalized {
            return;
        }
        self.finalized = true;
        if self.error.is_some() {
            return;
        }
        let r = (|| -> Result<()> {
            self.out.write_all(&[END_MARKER])?;
            write_u64(&mut self.out, self.events)?;
            write_u64(&mut self.out, self.blocks)?;
            self.out.flush()?;
            Ok(())
        })();
        match r {
            Ok(()) => self.bytes += 1 + 8 + 8,
            Err(e) => self.error = Some(e),
        }
    }

    /// Seal the trace (end marker + totals trailer) and report what was
    /// written, or the first I/O error encountered anywhere in the
    /// recording.
    pub fn finish(mut self) -> Result<TraceSummary> {
        self.write_end();
        if let Some(e) = self.error.take() {
            return Err(e.context("writing trace"));
        }
        Ok(TraceSummary { blocks: self.blocks, events: self.events, bytes: self.bytes })
    }
}

impl BlockSink for TraceWriter {
    fn consume(&mut self, block: &EventBlock) {
        if block.is_empty() || self.error.is_some() || self.finalized {
            return;
        }
        if let Err(e) = self.try_consume(block) {
            self.error = Some(e);
        }
    }

    fn finalize(&mut self) {
        self.write_end();
    }
}

/// Read exactly `N` bytes, classifying failures via [`TraceError::from_io`].
fn read_arr<const N: usize>(inp: &mut BufReader<File>, what: &str) -> Result<[u8; N], TraceError> {
    let mut b = [0u8; N];
    inp.read_exact(&mut b).map_err(|e| TraceError::from_io(e, what))?;
    Ok(b)
}

/// Streaming reader over a recorded trace file.
///
/// Frame reads are retried on transient I/O errors (EINTR-class, as
/// classified by [`TraceError::from_io`] or injected through
/// [`fault::Site::ReadTransient`] / [`fault::Site::ReadShort`]): the
/// reader remembers each frame's start offset, rewinds, backs off
/// ([`retry_backoff`]) and re-reads, up to [`MAX_IO_RETRIES`] attempts.
/// Permanent failures ([`TraceError::is_transient`] false) surface
/// immediately with their [`TraceErrorKind`](super::TraceErrorKind).
pub struct TraceReader {
    inp: BufReader<File>,
    meta: TraceMeta,
    payload: Vec<u8>,
    blocks_read: u64,
    events_read: u64,
    /// Logical offset of the next unread byte — maintained without
    /// syscalls so a transient failure can rewind to the frame start.
    pos: u64,
    transient_retries: u32,
    done: bool,
}

impl TraceReader {
    /// Open `path`, validating magic, version, and header. Missing and
    /// zero-length files get dedicated one-line diagnoses (the latter is
    /// what a crash before the first flush leaves behind).
    pub fn open(path: &Path) -> Result<TraceReader, TraceError> {
        let f = File::open(path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                TraceError::io(false, format!("trace file not found: {}", path.display()))
            } else {
                TraceError::from_io(e, &format!("open {}", path.display()))
            }
        })?;
        let file_len = f
            .metadata()
            .map_err(|e| TraceError::from_io(e, &format!("stat {}", path.display())))?
            .len();
        if file_len == 0 {
            return Err(TraceError::truncated(format!(
                "{}: empty trace file (0 bytes) — not a recorded trace; re-record it",
                path.display()
            )));
        }
        let mut inp = BufReader::new(f);
        let magic: [u8; 8] = read_arr(&mut inp, &format!("reading header of {}", path.display()))?;
        if &magic != TRACE_MAGIC {
            return Err(TraceError::format(format!(
                "{}: bad magic (not an mlperf trace file)",
                path.display()
            )));
        }
        let version =
            u32::from_le_bytes(read_arr(&mut inp, "reading trace format version")?);
        if version != TRACE_VERSION {
            return Err(TraceError::version(
                version,
                format!(
                    "{}: trace format version {version} unsupported (this build reads version \
                     {TRACE_VERSION}); re-record the trace",
                    path.display()
                ),
            ));
        }
        let meta = read_meta(&mut inp)
            .map_err(|e| TraceError::format(format!("{}: {e}", path.display())))?;
        let pos = inp
            .stream_position()
            .map_err(|e| TraceError::from_io(e, "locating first frame"))?;
        Ok(TraceReader {
            inp,
            meta,
            payload: Vec::new(),
            blocks_read: 0,
            events_read: 0,
            pos,
            transient_retries: 0,
            done: false,
        })
    }

    /// Header metadata.
    pub fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    /// Blocks decoded so far.
    pub fn blocks_read(&self) -> u64 {
        self.blocks_read
    }

    /// Events decoded so far.
    pub fn events_read(&self) -> u64 {
        self.events_read
    }

    /// Transient I/O errors absorbed by the retry loop so far.
    pub fn transient_retries(&self) -> u32 {
        self.transient_retries
    }

    /// Read the next frame into `payload` (replacing its contents),
    /// verifying the per-block checksum but **not** decoding — the split
    /// that lets the pipelined ingest's I/O thread read and checksum
    /// while a decoder pool does the columnar work
    /// ([`super::pipeline::PipelinedIngest`]). Validates the trailer's
    /// block count; the caller owns checking the trailer's event total
    /// against what it decodes. Transient I/O errors are rewound and
    /// retried with backoff, up to [`MAX_IO_RETRIES`] times per frame.
    pub(crate) fn next_frame_into(
        &mut self,
        payload: &mut Vec<u8>,
    ) -> Result<Frame, TraceError> {
        let mut attempt = 0u32;
        loop {
            let frame_start = self.pos;
            match self.read_frame_once(payload) {
                Ok(frame) => return Ok(frame),
                Err(e) if e.is_transient() && attempt < MAX_IO_RETRIES => {
                    attempt += 1;
                    self.transient_retries += 1;
                    std::thread::sleep(retry_backoff(attempt));
                    self.inp.seek(SeekFrom::Start(frame_start)).map_err(|se| {
                        TraceError::from_io(se, "rewinding after transient I/O error")
                    })?;
                    self.pos = frame_start;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// One read attempt of the next frame. `self.pos` only advances on
    /// success, so the retry loop can always rewind to the frame start
    /// no matter how many bytes a failed attempt consumed.
    fn read_frame_once(&mut self, payload: &mut Vec<u8>) -> Result<Frame, TraceError> {
        if fault::fired(fault::Site::ReadTransient).is_some() {
            return Err(TraceError::io(
                true,
                "injected transient I/O error (EINTR) reading trace frame",
            ));
        }
        let marker = read_arr::<1>(&mut self.inp, "reading block marker")?[0];
        match marker {
            BLOCK_MARKER => {
                let len =
                    u32::from_le_bytes(read_arr(&mut self.inp, "reading block length")?) as usize;
                if len > MAX_PAYLOAD {
                    return Err(TraceError::corrupt(
                        self.blocks_read,
                        format!(
                            "block {}: payload length {len} exceeds format cap",
                            self.blocks_read
                        ),
                    ));
                }
                let checksum =
                    u64::from_le_bytes(read_arr(&mut self.inp, "reading block checksum")?);
                // reuse the buffer's capacity: resize only zero-fills a
                // grown region, and read_exact overwrites it anyway
                payload.resize(len, 0);
                if fault::fired(fault::Site::ReadShort).is_some() {
                    // consume part of the payload, then report the read
                    // as interrupted — the retry path must rewind past
                    // these bytes for the re-read to line up
                    let half = len / 2;
                    self.inp
                        .read_exact(&mut payload[..half])
                        .map_err(|e| TraceError::from_io(e, "short-read prefix"))?;
                    return Err(TraceError::io(
                        true,
                        "injected short read of trace frame payload",
                    ));
                }
                self.inp.read_exact(payload).map_err(|e| {
                    TraceError::from_io(
                        e,
                        &format!("block {}: truncated payload", self.blocks_read),
                    )
                })?;
                if fault::fired(fault::Site::FrameBitflip).is_some() {
                    if let Some(byte) = payload.get_mut(len / 2) {
                        *byte ^= 0x20;
                    }
                }
                if fnv1a64(payload) != checksum {
                    return Err(TraceError::corrupt(
                        self.blocks_read,
                        format!(
                            "block {}: checksum mismatch (corrupted trace)",
                            self.blocks_read
                        ),
                    ));
                }
                self.blocks_read += 1;
                self.pos += 13 + len as u64;
                Ok(Frame::Block)
            }
            END_MARKER => {
                let events = u64::from_le_bytes(read_arr(&mut self.inp, "reading trailer")?);
                let blocks = u64::from_le_bytes(read_arr(&mut self.inp, "reading trailer")?);
                if blocks != self.blocks_read {
                    return Err(TraceError::corrupt(
                        self.blocks_read,
                        format!(
                            "trace trailer mismatch: trailer says {blocks} blocks, stream held {}",
                            self.blocks_read
                        ),
                    ));
                }
                self.done = true;
                self.pos += 17;
                Ok(Frame::End { events, blocks })
            }
            other => Err(TraceError::corrupt(
                self.blocks_read,
                format!("corrupt trace: unexpected marker byte {other:#04x}"),
            )),
        }
    }

    /// Decode the next block into `block` (replacing its contents).
    /// Returns `Ok(false)` once the validated end-of-trace trailer has
    /// been consumed; every error path names what was inconsistent.
    pub fn next_block(&mut self, block: &mut EventBlock) -> Result<bool, TraceError> {
        if self.done {
            return Ok(false);
        }
        let mut payload = std::mem::take(&mut self.payload);
        let frame = self.next_frame_into(&mut payload);
        self.payload = payload;
        match frame? {
            Frame::Block => {
                decode_block(&self.payload, block).map_err(|e| {
                    TraceError::corrupt(
                        self.blocks_read - 1,
                        format!("decoding block {}: {e}", self.blocks_read - 1),
                    )
                })?;
                self.events_read += block.len() as u64;
                Ok(true)
            }
            Frame::End { events, .. } => {
                if events != self.events_read {
                    return Err(TraceError::corrupt(
                        self.blocks_read,
                        format!(
                            "trace trailer mismatch: trailer says {events} events, stream held {}",
                            self.events_read
                        ),
                    ));
                }
                Ok(false)
            }
        }
    }
}

/// One framed record of the on-disk stream, as surfaced by
/// [`TraceReader::next_frame_into`].
pub(crate) enum Frame {
    /// A checksum-verified block payload now sits in the caller's buffer.
    Block,
    /// The end-of-trace trailer (totals as written; block count already
    /// validated against the stream).
    End { events: u64, blocks: u64 },
}

/// Outcome of one replay pump.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplayStats {
    pub blocks: u64,
    pub events: u64,
}

/// Feeds a stored trace into any [`BlockSink`] — the simulator stack sees
/// exactly the block stream the recording run produced, so `Metrics` are
/// bit-identical to direct execution, with the workload layer never
/// involved.
pub struct ReplaySource {
    reader: TraceReader,
}

impl ReplaySource {
    /// Open a trace file for replay.
    pub fn open(path: &Path) -> Result<ReplaySource, TraceError> {
        Ok(ReplaySource { reader: TraceReader::open(path)? })
    }

    /// Header metadata of the underlying trace.
    pub fn meta(&self) -> &TraceMeta {
        &self.reader.meta
    }

    /// Stream every block into `sink` (finalizing it at end-of-trace) and
    /// report how much was replayed.
    pub fn replay_into<S: BlockSink + ?Sized>(
        mut self,
        sink: &mut S,
    ) -> Result<ReplayStats, TraceError> {
        let mut block = EventBlock::with_capacity();
        while self.reader.next_block(&mut block)? {
            sink.consume(&block);
        }
        sink.finalize();
        Ok(ReplayStats { blocks: self.reader.blocks_read, events: self.reader.events_read })
    }
}

/// In-memory recorded trace: the capture side of the grid driver's
/// record-once/replay-many mode. Blocks are stored exactly as the
/// recorder flushed them, so a replay delivers the identical block
/// stream (and therefore bit-identical `Metrics`) to every consumer.
#[derive(Debug, Default, Clone)]
pub struct CapturedTrace {
    blocks: Vec<EventBlock>,
    events: u64,
    finalized: bool,
}

impl CapturedTrace {
    /// Events captured.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Blocks captured.
    pub fn blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Whether the producing recorder finalized the stream.
    pub fn is_finalized(&self) -> bool {
        self.finalized
    }

    /// Feed the captured stream into `sink`, finalizing it at the end.
    pub fn replay_into<S: BlockSink + ?Sized>(&self, sink: &mut S) {
        for b in &self.blocks {
            sink.consume(b);
        }
        sink.finalize();
    }

    /// Persist the capture as a trace file.
    pub fn write_to(&self, path: &Path, meta: &TraceMeta) -> Result<TraceSummary> {
        let mut w = TraceWriter::create(path, meta)?;
        for b in &self.blocks {
            BlockSink::consume(&mut w, b);
        }
        w.finish()
    }
}

impl BlockSink for CapturedTrace {
    fn consume(&mut self, block: &EventBlock) {
        if block.is_empty() {
            return;
        }
        self.events += block.len() as u64;
        self.blocks.push(block.clone());
    }

    fn finalize(&mut self) {
        self.finalized = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::Event;

    fn mixed_block() -> EventBlock {
        let mut b = EventBlock::with_capacity();
        b.push_compute(2, 1);
        b.push_load(0x4000, 8, true);
        b.push_load(0x4040, 8, false); // +64 delta
        b.push_load(0x1000, 160, false); // negative delta
        b.push_branch(7 << 16 | 3, true, true);
        b.push_branch(7 << 16 | 1, false, true); // negative site delta
        b.push_serial(4);
        b.push_store(0x9000, 64);
        b.push_loop_branch(7 << 16 | 9, 20);
        b.push_prefetch(0x4080);
        b.push_prefetch(0x40C0);
        b.push_compute(u32::MAX, u32::MAX); // extreme lane values
        b
    }

    fn roundtrip(b: &EventBlock) -> EventBlock {
        let mut buf = Vec::new();
        encode_block(b, &mut buf);
        let mut out = EventBlock::with_capacity();
        decode_block(&buf, &mut out).unwrap();
        out
    }

    #[test]
    fn encode_decode_is_identity() {
        let b = mixed_block();
        let out = roundtrip(&b);
        assert_eq!(out, b);
        assert_eq!(out.iter().collect::<Vec<Event>>(), b.iter().collect::<Vec<Event>>());
    }

    #[test]
    fn empty_block_roundtrips() {
        let b = EventBlock::with_capacity();
        assert_eq!(roundtrip(&b), b);
    }

    #[test]
    fn long_runs_compress_below_a_byte_per_event() {
        let mut b = EventBlock::with_capacity();
        for i in 0..BLOCK_EVENTS {
            b.push_load(0x1_0000 + i as u64 * 64, 64, false);
        }
        let mut buf = Vec::new();
        encode_block(&b, &mut buf);
        // one RLE run for the whole tag lane + (2-byte Δ=64 zigzag varint
        // + 2-byte size<<1 varint) per load ≈ 4 B/event, vs 13 B raw
        assert!(
            buf.len() < 5 * BLOCK_EVENTS,
            "sequential-load block encoded to {} bytes",
            buf.len()
        );
        assert_eq!(roundtrip(&b), b);
    }

    #[test]
    fn decode_rejects_truncation_and_garbage() {
        let b = mixed_block();
        let mut buf = Vec::new();
        encode_block(&b, &mut buf);
        let mut out = EventBlock::with_capacity();
        // truncated at every prefix must error, never panic
        for cut in 0..buf.len() {
            assert!(
                decode_block(&buf[..cut], &mut out).is_err(),
                "prefix of {cut} bytes decoded successfully"
            );
        }
        // trailing garbage
        buf.push(0);
        assert!(decode_block(&buf, &mut out).is_err());
    }

    #[test]
    fn decode_rejects_bad_kind_and_oversized_count() {
        let mut buf = Vec::new();
        put_uvarint(&mut buf, 1);
        buf.push(99); // no such EventKind
        put_uvarint(&mut buf, 1);
        let mut out = EventBlock::with_capacity();
        let err = decode_block(&buf, &mut out).unwrap_err().to_string();
        assert!(err.contains("invalid event kind"), "{err}");

        let mut buf = Vec::new();
        put_uvarint(&mut buf, (BLOCK_EVENTS + 1) as u64);
        let err = decode_block(&buf, &mut out).unwrap_err().to_string();
        assert!(err.contains("format max"), "{err}");
    }

    #[test]
    fn captured_trace_replays_identically() {
        let mut cap = CapturedTrace::default();
        let b = mixed_block();
        cap.consume(&b);
        cap.finalize();
        assert!(cap.is_finalized());
        assert_eq!(cap.events(), b.len() as u64);

        let mut sink = crate::trace::event::VecSink::default();
        {
            let mut adapter = crate::trace::block::PerEvent(&mut sink);
            cap.replay_into(&mut adapter);
        }
        assert_eq!(sink.events, b.iter().collect::<Vec<Event>>());
        assert!(sink.finished);
    }

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("mlperf-store-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn meta() -> TraceMeta {
        TraceMeta {
            workload: "KMeans".into(),
            profile: LibraryProfile::Sklearn,
            sw_prefetch: false,
            rows: 1600,
            features: 8,
            iterations: 1,
            seed: 0xDA7A,
            dataset_bytes: 1600 * 9 * 8,
        }
    }

    #[test]
    fn file_roundtrip_preserves_meta_and_blocks() {
        let p = tmpfile("roundtrip.mlt");
        let b = mixed_block();
        let summary = {
            let mut w = TraceWriter::create(&p, &meta()).unwrap();
            w.consume(&b);
            w.consume(&b);
            w.finalize();
            w.finish().unwrap()
        };
        assert_eq!(summary.blocks, 2);
        assert_eq!(summary.events, 2 * b.len() as u64);
        assert_eq!(summary.bytes, std::fs::metadata(&p).unwrap().len());

        let mut r = TraceReader::open(&p).unwrap();
        assert_eq!(*r.meta(), meta());
        let mut got = EventBlock::with_capacity();
        let mut blocks = 0;
        while r.next_block(&mut got).unwrap() {
            assert_eq!(got, b);
            blocks += 1;
        }
        assert_eq!(blocks, 2);
        // idempotent at end
        assert!(!r.next_block(&mut got).unwrap());
    }

    #[test]
    fn reader_rejects_version_bump() {
        let p = tmpfile("version.mlt");
        {
            let mut w = TraceWriter::create(&p, &meta()).unwrap();
            w.consume(&mixed_block());
            w.finish().unwrap();
        }
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[8] = 0xFE; // version field, little-endian low byte
        std::fs::write(&p, &bytes).unwrap();
        let err = TraceReader::open(&p).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        assert!(err.contains("re-record"), "{err}");
    }

    #[test]
    fn reader_rejects_flipped_payload_bit() {
        let p = tmpfile("corrupt.mlt");
        {
            let mut w = TraceWriter::create(&p, &meta()).unwrap();
            w.consume(&mixed_block());
            w.finish().unwrap();
        }
        let mut bytes = std::fs::read(&p).unwrap();
        let header = 12 + 2 + "KMeans".len() + 2 + 40;
        let payload_at = header + 1 + 4 + 8;
        bytes[payload_at + 2] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let mut r = TraceReader::open(&p).unwrap();
        let mut got = EventBlock::with_capacity();
        let err = r.next_block(&mut got).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn reader_rejects_truncated_file() {
        let p = tmpfile("trunc.mlt");
        {
            let mut w = TraceWriter::create(&p, &meta()).unwrap();
            w.consume(&mixed_block());
            w.finish().unwrap();
        }
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 9]).unwrap(); // lose the trailer
        let mut r = TraceReader::open(&p).unwrap();
        let mut got = EventBlock::with_capacity();
        let mut res = Ok(true);
        while let Ok(true) = res {
            res = r.next_block(&mut got);
        }
        assert!(res.is_err(), "truncated trace must not read to a clean end");
    }

    #[test]
    fn reader_rejects_bad_magic() {
        let p = tmpfile("magic.mlt");
        std::fs::write(&p, b"NOTTRACE________________________").unwrap();
        let err = TraceReader::open(&p).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
    }
}
