//! Shared binary-encoding primitives for the crate's on-disk containers
//! (the dataset container in [`crate::data::io`] and the columnar trace
//! format in [`crate::trace::store`]).
//!
//! Everything here is little-endian and allocation-free on the encode
//! side: fixed-width integers, LEB128 varints, zigzag signed mapping, and
//! the FNV-1a 64-bit checksum the trace format uses per block. Decoders
//! are bounds-checked and return [`Error`]s instead of panicking so a
//! corrupted or truncated file surfaces as a clean CLI error.
//!
//! [`Error`]: crate::util::error::Error

use crate::bail;
use crate::util::error::Result;
use std::io::{Read, Write};

/// Read a little-endian `u64` from a stream.
pub fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read a little-endian `u32` from a stream.
pub fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Read a little-endian `u16` from a stream.
pub fn read_u16<R: Read>(r: &mut R) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

/// Read a single byte from a stream.
pub fn read_u8<R: Read>(r: &mut R) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

/// Write a little-endian `u64` to a stream.
pub fn write_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

/// Write a little-endian `u32` to a stream.
pub fn write_u32<W: Write>(w: &mut W, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

/// Append a LEB128 unsigned varint (1 byte for values < 128, up to 10
/// bytes for the full `u64` range).
pub fn put_uvarint(buf: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        buf.push((v as u8 & 0x7F) | 0x80);
        v >>= 7;
    }
    buf.push(v as u8);
}

/// Decode a LEB128 unsigned varint from `buf` at `*pos`, advancing `*pos`.
pub fn get_uvarint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let Some(&b) = buf.get(*pos) else {
            bail!("truncated varint at byte {}", *pos);
        };
        *pos += 1;
        // 10th byte sits at shift 63 and may only contribute bit 0; an
        // 11th byte (shift 70) can contribute nothing at all.
        if shift >= 64 || (shift == 63 && (b & 0x7F) > 1) {
            bail!("varint overflows u64 at byte {}", *pos);
        }
        v |= u64::from(b & 0x7F) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Borrowed slice cursor over an encoded buffer: the hot-path decode API.
///
/// [`get_uvarint`]-style free functions re-check `buf.get(*pos)` once per
/// byte inside a generic loop; the per-record decode loops in
/// [`crate::trace::store`] spend most of their time there. The cursor
/// keeps `(buf, pos)` together and gives varint decoding an unrolled
/// fast path for the 1–2-byte encodings (sizes, flags, small deltas —
/// the overwhelming majority of trace fields), falling back to the
/// reference loop only for wider values and for every error path, so the
/// two can never disagree (a property test pits them against each other
/// on random and adversarial inputs).
#[derive(Debug)]
pub struct ByteCursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteCursor<'a> {
    #[inline]
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes consumed so far.
    #[inline]
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes left unread.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.pos >= self.buf.len()
    }

    /// Read one raw byte.
    #[inline]
    pub fn u8(&mut self) -> Result<u8> {
        let Some(&b) = self.buf.get(self.pos) else {
            bail!("truncated field at byte {}", self.pos);
        };
        self.pos += 1;
        Ok(b)
    }

    /// Decode a LEB128 unsigned varint. Identical semantics to
    /// [`get_uvarint`]; the 1–2-byte encodings take the unrolled path.
    #[inline]
    pub fn uvarint(&mut self) -> Result<u64> {
        match &self.buf[self.pos..] {
            [b0, ..] if *b0 < 0x80 => {
                self.pos += 1;
                Ok(u64::from(*b0))
            }
            [b0, b1, ..] if *b1 < 0x80 => {
                self.pos += 2;
                Ok(u64::from(*b0 & 0x7F) | (u64::from(*b1) << 7))
            }
            _ => get_uvarint(self.buf, &mut self.pos),
        }
    }

    /// Decode a zigzag-varint signed delta (see [`get_ivarint`]).
    #[inline]
    pub fn ivarint(&mut self) -> Result<i64> {
        Ok(unzigzag(self.uvarint()?))
    }
}

/// Zigzag-map a signed delta so small magnitudes of either sign encode to
/// short varints (0 → 0, -1 → 1, 1 → 2, -2 → 3, ...).
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Append a zigzag-varint signed delta.
pub fn put_ivarint(buf: &mut Vec<u8>, v: i64) {
    put_uvarint(buf, zigzag(v));
}

/// Decode a zigzag-varint signed delta.
pub fn get_ivarint(buf: &[u8], pos: &mut usize) -> Result<i64> {
    Ok(unzigzag(get_uvarint(buf, pos)?))
}

/// FNV-1a 64-bit hash — the trace format's per-block checksum. Not
/// cryptographic; it exists to catch torn writes, truncation, and bit rot.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uvarint_roundtrip_across_ranges() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, 16_383, 16_384, u32::MAX as u64, u64::MAX];
        for &v in &vals {
            buf.clear();
            put_uvarint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_uvarint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len(), "value {v} left trailing bytes");
        }
    }

    #[test]
    fn uvarint_small_values_are_one_byte() {
        let mut buf = Vec::new();
        put_uvarint(&mut buf, 127);
        assert_eq!(buf.len(), 1);
        buf.clear();
        put_uvarint(&mut buf, 128);
        assert_eq!(buf.len(), 2);
    }

    #[test]
    fn truncated_varint_is_error() {
        let buf = [0x80u8, 0x80]; // continuation bits with no terminator
        let mut pos = 0;
        assert!(get_uvarint(&buf, &mut pos).is_err());
    }

    #[test]
    fn overlong_varint_is_error_not_silent_truncation() {
        // 10th byte may only carry bit 0 (u64::MAX ends in 0x01)
        let mut ok = vec![0xFFu8; 9];
        ok.push(0x01);
        let mut pos = 0;
        assert_eq!(get_uvarint(&ok, &mut pos).unwrap(), u64::MAX);

        let mut bad = vec![0x80u8; 9];
        bad.push(0x7E); // bits above bit 0 would be silently dropped
        let mut pos = 0;
        assert!(get_uvarint(&bad, &mut pos).is_err());

        let mut eleven = vec![0x80u8; 10];
        eleven.push(0x01);
        let mut pos = 0;
        assert!(get_uvarint(&eleven, &mut pos).is_err());
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, 1, -1, 2, -2, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }

    #[test]
    fn ivarint_roundtrip() {
        let mut buf = Vec::new();
        for v in [0i64, -1, 1, -1000, 1000, i64::MIN, i64::MAX] {
            buf.clear();
            put_ivarint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(get_ivarint(&buf, &mut pos).unwrap(), v);
        }
    }

    #[test]
    fn cursor_matches_reference_decoder() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, 16_383, 16_384, 1 << 21, u32::MAX as u64, u64::MAX];
        for &v in &vals {
            put_uvarint(&mut buf, v);
        }
        let mut cur = ByteCursor::new(&buf);
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(cur.uvarint().unwrap(), v);
            assert_eq!(get_uvarint(&buf, &mut pos).unwrap(), v);
            assert_eq!(cur.pos(), pos, "cursor and reference diverged after {v}");
        }
        assert!(cur.is_empty());
        assert_eq!(cur.remaining(), 0);
    }

    #[test]
    fn cursor_signed_and_raw_bytes() {
        let mut buf = Vec::new();
        put_ivarint(&mut buf, -77);
        buf.push(0xAB);
        put_ivarint(&mut buf, i64::MIN);
        let mut cur = ByteCursor::new(&buf);
        assert_eq!(cur.ivarint().unwrap(), -77);
        assert_eq!(cur.u8().unwrap(), 0xAB);
        assert_eq!(cur.ivarint().unwrap(), i64::MIN);
        assert!(cur.u8().is_err(), "reading past the end must error");
    }

    #[test]
    fn cursor_rejects_truncation_without_advancing_past_end() {
        // continuation bit set on the final byte: 1-byte and 2-byte
        // truncations exercise both unrolled arms' fallbacks
        for bad in [&[0x80u8][..], &[0x80, 0x80][..]] {
            let mut cur = ByteCursor::new(bad);
            assert!(cur.uvarint().is_err(), "{bad:?} decoded");
        }
        let mut eleven = vec![0x80u8; 10];
        eleven.push(0x01);
        let mut cur = ByteCursor::new(&eleven);
        assert!(cur.uvarint().is_err(), "11-byte varint must overflow");
    }

    #[test]
    fn fnv_known_vectors() {
        // Reference values for the 64-bit FNV-1a parameters.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"acb"));
    }

    #[test]
    fn fixed_width_roundtrip() {
        let mut buf = Vec::new();
        write_u64(&mut buf, 0xDEAD_BEEF_0102_0304).unwrap();
        write_u32(&mut buf, 77).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_u64(&mut cur).unwrap(), 0xDEAD_BEEF_0102_0304);
        assert_eq!(read_u32(&mut cur).unwrap(), 77);
    }
}
