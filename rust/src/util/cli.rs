//! Minimal command-line argument parser (no external crates available in
//! the offline build, so `clap` is replaced by this ~100-line equivalent).
//!
//! Grammar: `prog [subcommand] [--flag value | --switch] ...`.
//! Every `--name` either consumes the next token as its value or, if the
//! next token is absent/another flag, is recorded as a boolean switch.

use std::collections::BTreeMap;

/// Parsed command line: optional subcommand, flags, positional args.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let mut out = Args::default();
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                // `--name=value` form
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() && out.flags.is_empty()
            {
                out.subcommand = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse the process's own command line.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// String flag value.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Typed flag value with default; panics with a clear message on a
    /// malformed value (user error should fail loudly, not silently).
    pub fn get_parsed_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(s) => s
                .parse()
                .unwrap_or_else(|_| panic!("--{name}: cannot parse {s:?}")),
        }
    }

    /// Whether a boolean switch was given (`--verbose`).
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("characterize --rows 5000 --workload kmeans --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("characterize"));
        assert_eq!(a.get("rows"), Some("5000"));
        assert_eq!(a.get_or("workload", "x"), "kmeans");
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --rows=123 --name=abc");
        assert_eq!(a.get_parsed_or("rows", 0usize), 123);
        assert_eq!(a.get("name"), Some("abc"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("run");
        assert_eq!(a.get_parsed_or("rows", 42usize), 42);
        assert_eq!(a.get_parsed_or("scale", 1.5f64), 1.5);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn typed_malformed_panics() {
        let a = parse("run --rows abc");
        let _: usize = a.get_parsed_or("rows", 0);
    }

    #[test]
    fn trailing_switch() {
        let a = parse("bench --fast");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert!(a.has("fast"));
    }

    #[test]
    fn positional_after_subcommand_flag() {
        let a = parse("report --dir out fig1 fig2");
        // "out" is consumed as the value of --dir; fig1/fig2 positional.
        assert_eq!(a.get("dir"), Some("out"));
        assert_eq!(a.positional, vec!["fig1", "fig2"]);
    }

    #[test]
    fn no_subcommand_when_flag_first() {
        let a = parse("--rows 10 run");
        assert_eq!(a.subcommand, None);
        // "run" follows a consumed flag value, lands in positional.
        assert_eq!(a.positional, vec!["run"]);
    }
}
