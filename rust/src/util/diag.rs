//! Diagnostics discipline: every warning, notice, and status line goes
//! through here, and everything here writes to **stderr**.
//!
//! The CLI's stdout is a machine-readable surface — result tables,
//! `--json -` grid output, gate verdicts — and CI byte-compares it
//! (`cmp` in the chaos job, `python3 -m json.tool` in the telemetry
//! job). A stray `println!` warning interleaved with that stream is a
//! parser-breaking bug, so call sites use [`warn`]/[`note`] instead of
//! choosing a stream ad hoc. `tests/telemetry.rs` smokes the contract:
//! `grid --json -` must pipe clean through a JSON parser.

use std::fmt::Display;

/// A warning: something the user should act on (inert flag, vacuous
/// gate, quarantined cells). Prefixed `warning:`, written to stderr.
pub fn warn(msg: impl Display) {
    eprintln!("warning: {msg}");
}

/// A status notice: progress/context a human wants but a parser must
/// never see ("running 64 jobs...", "wrote results to ..."). Written
/// to stderr, unprefixed.
pub fn note(msg: impl Display) {
    eprintln!("{msg}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_display_types() {
        // compile-shape test: &str, String, and format_args all work
        warn("plain");
        note(format!("formatted {}", 42));
        note(std::path::Path::new("/tmp/x").display());
    }
}
