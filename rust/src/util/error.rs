//! Minimal error/context substrate standing in for `anyhow`.
//!
//! The offline build environment provides no external crates, so the few
//! fallible boundaries of the crate (dataset I/O, the CLI, the optional
//! PJRT runtime) use this ~80-line equivalent: a string-chain error type,
//! `anyhow!`/`bail!` macros, and a `Context` extension trait. Like
//! `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error` so the blanket `From<E: std::error::Error>`
//! conversion can exist without colliding with the reflexive `From`.

use std::fmt;

/// A boxed-string error with a chain of context frames.
pub struct Error {
    msg: String,
    /// Context frames, innermost first (display prints them outermost
    /// first, matching `anyhow`'s `{:#}` rendering).
    context: Vec<String>,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string(), context: Vec::new() }
    }

    /// Attach an outer context frame.
    pub fn context(mut self, ctx: impl fmt::Display) -> Self {
        self.context.push(ctx.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result alias (mirrors `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extract the human-readable message from a caught panic payload (as
/// returned by `std::panic::catch_unwind`): panics raised with a string
/// literal or a formatted message yield that text, anything else a
/// placeholder. Used by the typed-recovery paths that convert worker
/// panics into errors instead of tearing the process down.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "opaque panic payload"
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to any
/// result whose error converts into [`Error`].
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Format an [`Error`] in place (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(::std::format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`] (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fail_io() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e.into())
    }

    #[test]
    fn io_errors_convert_and_chain_context() {
        let err = fail_io()
            .context("reading header")
            .unwrap_err()
            .context("loading dataset");
        assert_eq!(err.to_string(), "loading dataset: reading header: gone");
    }

    #[test]
    fn macros_format() {
        let e = crate::anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn bails() -> Result<()> {
            crate::bail!("nope: {}", "reason");
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope: reason");
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let ok: Result<u32> = Ok(5u32);
        let v = ok.with_context(|| {
            called = true;
            "ctx"
        });
        assert_eq!(v.unwrap(), 5);
        assert!(!called, "context closure must not run on Ok");
    }
}
