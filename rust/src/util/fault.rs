//! Deterministic, seedable fault injection for chaos testing.
//!
//! A [`FaultPlan`] is parsed from `--chaos <spec>` (or the `MLPERF_CHAOS`
//! environment variable) and installed process-wide with [`install`].
//! Production code declares *named injection sites* ([`Site`]) at the
//! places that can realistically fail — trace reads, frame decodes,
//! ledger appends, grid workers — and asks [`fired`] whether the plan
//! wants that occurrence to fail. With no plan installed the check is a
//! single relaxed atomic load, so the healthy path stays bit-identical
//! and effectively free.
//!
//! Triggers are keyed by site plus either an *nth-occurrence* count
//! (`read-transient@3` fires on exactly the third trace read, once) or a
//! *seeded probability* (`read-transient%0.01` fires each occurrence
//! with probability 0.01, decided by a splitmix64 hash of
//! `(seed, site, occurrence)` so a given seed reproduces the same fault
//! schedule). Occurrence counters live inside the plan, so installing a
//! fresh plan resets them.
//!
//! Spec grammar (entries separated by `;`, whitespace ignored):
//!
//! ```text
//! spec   := entry (';' entry)*
//! entry  := 'seed=' u64
//!         | site '@' n ('=' param)?     fire on the nth occurrence
//!         | site '%' p ('=' param)?     fire with probability p in [0,1]
//! ```
//!
//! e.g. `--chaos "seed=7;capture-panic@2;ledger-io@3;stall@1=50"`.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::util::error::{Error, Result};

/// A named fault-injection site. Each variant marks one place in the
/// production code that consults the installed [`FaultPlan`]; the
/// sabotage applied on a hit is defined by the call site (documented
/// per variant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// Trace frame read fails with an EINTR-style transient I/O error
    /// before consuming any bytes (retryable).
    ReadTransient,
    /// Trace frame payload read delivers only part of the requested
    /// bytes, then errors transiently — exercises the rewind-and-retry
    /// path (retryable).
    ReadShort,
    /// One bit of a trace frame payload is flipped after the read, so
    /// the checksum verification fails (permanent corruption).
    FrameBitflip,
    /// The trace writer emits only a prefix of a frame, modelling a
    /// torn tail write from a crash mid-record.
    TornTail,
    /// A pipelined-ingest decoder thread panics while decoding a block.
    DecodePanic,
    /// A pipelined-ingest decoder stalls (sleeps `param` milliseconds)
    /// before decoding — a slow-stage straggler, not an error.
    Stall,
    /// A grid capture execution panics before recording its trace.
    CapturePanic,
    /// A claimed grid replay batch panics before simulating its cells.
    CellPanic,
    /// Ledger append fails with a transient I/O error before writing
    /// (retryable within the append's bounded retry budget).
    LedgerIo,
    /// Ledger append writes only a prefix of the record frame and
    /// reports a crash — unlike a real I/O error the torn bytes are
    /// deliberately *not* healed, modelling a process kill mid-append.
    LedgerAppendKill,
    /// Ledger compaction stops after writing + fsyncing the temp file
    /// but before the atomic rename, modelling a crash between the two.
    LedgerCompactKill,
    /// The process calls `std::process::abort()` immediately after the
    /// nth successful ledger append — a real mid-run kill for the
    /// crash/resume story (only reachable through the CLI).
    GridKill,
    /// The serve daemon drops a client connection without replying,
    /// modelling a client that vanished (or a network partition) mid
    /// request. The daemon must survive and keep serving its peers.
    ConnDrop,
    /// The serve daemon's connection handler sleeps `param` milliseconds
    /// before answering, modelling a slow client holding its admission
    /// slot — a straggler, not an error.
    SlowClient,
    /// The serve daemon calls `std::process::abort()` immediately after
    /// answering the nth request — a real mid-serve kill for the
    /// crash/restart-from-shards story.
    ServeKill,
}

/// Every site paired with its spec-grammar name, in parse priority order.
pub const SITES: &[(Site, &str)] = &[
    (Site::ReadTransient, "read-transient"),
    (Site::ReadShort, "read-short"),
    (Site::FrameBitflip, "frame-bitflip"),
    (Site::TornTail, "torn-tail"),
    (Site::DecodePanic, "decode-panic"),
    (Site::Stall, "stall"),
    (Site::CapturePanic, "capture-panic"),
    (Site::CellPanic, "cell-panic"),
    (Site::LedgerIo, "ledger-io"),
    (Site::LedgerAppendKill, "ledger-append-kill"),
    (Site::LedgerCompactKill, "ledger-compact-kill"),
    (Site::GridKill, "grid-kill"),
    (Site::ConnDrop, "conn-drop"),
    (Site::SlowClient, "slow-client"),
    (Site::ServeKill, "serve-kill"),
];

const SITE_COUNT: usize = 15;

impl Site {
    fn index(self) -> usize {
        SITES.iter().position(|&(s, _)| s == self).expect("site registered in SITES")
    }

    /// The spec-grammar name of this site (e.g. `"read-transient"`).
    pub fn name(self) -> &'static str {
        SITES[self.index()].1
    }

    fn by_name(name: &str) -> Option<Site> {
        SITES.iter().find(|&&(_, n)| n == name).map(|&(s, _)| s)
    }
}

impl fmt::Display for Site {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// When a rule fires relative to its site's occurrence counter.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Trigger {
    /// Fire on exactly the nth occurrence (1-based), once.
    Nth(u64),
    /// Fire each occurrence with this probability, decided by a seeded
    /// hash of `(seed, site, occurrence)`.
    Prob(f64),
}

/// One parsed `site@n` / `site%p` entry of a chaos spec.
#[derive(Debug, Clone, PartialEq)]
struct FaultRule {
    site: Site,
    trigger: Trigger,
    /// Site-specific parameter (currently: stall milliseconds).
    param: u64,
}

/// A parsed chaos spec: the fault schedule plus per-site occurrence
/// counters. Counters are interior-mutable so the plan can be shared
/// behind an `Arc` by every thread of a run.
pub struct FaultPlan {
    seed: u64,
    rules: Vec<FaultRule>,
    /// Occurrences observed per site (indexed by [`Site::index`]).
    occurrences: [AtomicU64; SITE_COUNT],
    /// Rules actually fired per site.
    fires: [AtomicU64; SITE_COUNT],
}

impl FaultPlan {
    /// An empty plan: no rules, never fires, reports [`FaultPlan::is_empty`].
    pub fn empty() -> Self {
        FaultPlan {
            seed: 0,
            rules: Vec::new(),
            occurrences: std::array::from_fn(|_| AtomicU64::new(0)),
            fires: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Parse a chaos spec (see the module docs for the grammar). An
    /// empty or all-whitespace spec parses to the empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::empty();
        for raw in spec.split(';') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            if let Some(seed) = entry.strip_prefix("seed=") {
                plan.seed = seed
                    .trim()
                    .parse::<u64>()
                    .map_err(|_| Error::msg(format!("chaos spec: bad seed {seed:?}")))?;
                continue;
            }
            plan.rules.push(Self::parse_rule(entry)?);
        }
        Ok(plan)
    }

    fn parse_rule(entry: &str) -> Result<FaultRule> {
        let at = entry.find('@');
        let pct = entry.find('%');
        let (name, rest, nth) = match (at, pct) {
            (Some(i), None) => (&entry[..i], &entry[i + 1..], true),
            (None, Some(i)) => (&entry[..i], &entry[i + 1..], false),
            _ => {
                return Err(Error::msg(format!(
                    "chaos spec: entry {entry:?} needs exactly one of '@n' or '%p'"
                )))
            }
        };
        let site = Site::by_name(name.trim()).ok_or_else(|| {
            let known: Vec<&str> = SITES.iter().map(|&(_, n)| n).collect();
            Error::msg(format!(
                "chaos spec: unknown site {:?} (known: {})",
                name.trim(),
                known.join(", ")
            ))
        })?;
        let (value, param) = match rest.find('=') {
            Some(i) => {
                let p = rest[i + 1..].trim().parse::<u64>().map_err(|_| {
                    Error::msg(format!("chaos spec: bad param in {entry:?}"))
                })?;
                (rest[..i].trim(), p)
            }
            None => (rest.trim(), default_param(site)),
        };
        let trigger = if nth {
            let n = value
                .parse::<u64>()
                .ok()
                .filter(|&n| n >= 1)
                .ok_or_else(|| {
                    let msg = format!("chaos spec: {entry:?} needs an occurrence count >= 1");
                    Error::msg(msg)
                })?;
            Trigger::Nth(n)
        } else {
            let p = value
                .parse::<f64>()
                .ok()
                .filter(|p| (0.0..=1.0).contains(p))
                .ok_or_else(|| {
                    let msg = format!("chaos spec: {entry:?} needs a probability in [0, 1]");
                    Error::msg(msg)
                })?;
            Trigger::Prob(p)
        };
        Ok(FaultRule { site, trigger, param })
    }

    /// True when the plan has no rules (and is therefore never armed).
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// The seed used for probabilistic triggers.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Number of rules in the schedule.
    pub fn rule_count(&self) -> usize {
        self.rules.len()
    }

    /// Record one occurrence at `site` and return `Some(param)` if a
    /// rule fires on it.
    fn check(&self, site: Site) -> Option<u64> {
        let idx = site.index();
        let occ = self.occurrences[idx].fetch_add(1, Ordering::SeqCst) + 1;
        for rule in self.rules.iter().filter(|r| r.site == site) {
            let hit = match rule.trigger {
                Trigger::Nth(n) => occ == n,
                Trigger::Prob(p) => unit_hash(self.seed, idx as u64, occ) < p,
            };
            if hit {
                self.fires[idx].fetch_add(1, Ordering::SeqCst);
                return Some(rule.param);
            }
        }
        None
    }

    /// How many times rules at `site` have fired so far.
    pub fn fires_at(&self, site: Site) -> u64 {
        self.fires[site.index()].load(Ordering::SeqCst)
    }

    /// How many occurrences `site` has recorded so far.
    pub fn occurrences_at(&self, site: Site) -> u64 {
        self.occurrences[site.index()].load(Ordering::SeqCst)
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seed={}", self.seed)?;
        for r in &self.rules {
            match r.trigger {
                Trigger::Nth(n) => write!(f, ";{}@{}", r.site, n)?,
                Trigger::Prob(p) => write!(f, ";{}%{}", r.site, p)?,
            }
            if r.param != default_param(r.site) {
                write!(f, "={}", r.param)?;
            }
        }
        Ok(())
    }
}

fn default_param(site: Site) -> u64 {
    match site {
        // stall / slow-client duration in milliseconds
        Site::Stall => 25,
        Site::SlowClient => 25,
        _ => 0,
    }
}

/// splitmix64 — deterministic 64-bit mixer for probabilistic triggers.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Map `(seed, site, occurrence)` to a uniform value in [0, 1).
fn unit_hash(seed: u64, site: u64, occ: u64) -> f64 {
    let h = splitmix64(seed ^ splitmix64(site.wrapping_shl(32) ^ occ));
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// Fast-path arm flag: false whenever no non-empty plan is installed,
/// so [`fired`] costs one relaxed load on the healthy path.
static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: RwLock<Option<Arc<FaultPlan>>> = RwLock::new(None);

/// Install (or clear, with `None` / an empty plan) the process-wide
/// fault plan. Replacing the plan resets all occurrence counters, since
/// they live inside the plan instance.
pub fn install(plan: Option<FaultPlan>) {
    let plan = plan.filter(|p| !p.is_empty()).map(Arc::new);
    let mut guard = PLAN.write().unwrap_or_else(|e| e.into_inner());
    ARMED.store(plan.is_some(), Ordering::SeqCst);
    *guard = plan;
}

/// True when a non-empty fault plan is installed.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Record one occurrence at `site` against the installed plan and
/// return `Some(param)` when the plan wants this occurrence to fail.
/// With no plan installed this is a single relaxed atomic load.
#[inline]
pub fn fired(site: Site) -> Option<u64> {
    if !armed() {
        return None;
    }
    fired_slow(site)
}

#[cold]
fn fired_slow(site: Site) -> Option<u64> {
    let guard = PLAN.read().unwrap_or_else(|e| e.into_inner());
    guard.as_ref().and_then(|p| p.check(site))
}

/// Fires recorded at `site` by the installed plan (0 when none is
/// installed) — lets tests assert an injection actually happened.
pub fn fires_at(site: Site) -> u64 {
    let guard = PLAN.read().unwrap_or_else(|e| e.into_inner());
    guard.as_ref().map_or(0, |p| p.fires_at(site))
}

/// Total fires across every site of the installed plan.
pub fn total_fires() -> u64 {
    let guard = PLAN.read().unwrap_or_else(|e| e.into_inner());
    guard.as_ref().map_or(0, |p| SITES.iter().map(|&(s, _)| p.fires_at(s)).sum())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_whitespace_specs_parse_to_inert_plans() {
        for spec in ["", "  ", ";;", " ; ; "] {
            let p = FaultPlan::parse(spec).unwrap();
            assert!(p.is_empty(), "{spec:?}");
        }
    }

    #[test]
    fn grammar_roundtrip_and_defaults() {
        let spec = "seed=7; read-transient@3 ;frame-bitflip%0.25;stall@2=50";
        let p = FaultPlan::parse(spec).unwrap();
        assert_eq!(p.seed(), 7);
        assert_eq!(p.rule_count(), 3);
        let want = "seed=7;read-transient@3;frame-bitflip%0.25;stall@2=50";
        assert_eq!(p.to_string(), want);
        // stall default param is 25ms when '=' is omitted
        let q = FaultPlan::parse("stall@1").unwrap();
        assert_eq!(q.check(Site::Stall), Some(25));
    }

    #[test]
    fn serve_sites_parse_and_roundtrip() {
        let spec = "seed=9;conn-drop@1;slow-client@2=100;serve-kill@3";
        let p = FaultPlan::parse(spec).unwrap();
        assert_eq!(p.rule_count(), 3);
        assert_eq!(p.to_string(), spec);
        // slow-client shares the stall default (25ms) when '=' is omitted
        let q = FaultPlan::parse("slow-client@1").unwrap();
        assert_eq!(q.check(Site::SlowClient), Some(25));
        assert_eq!(Site::ServeKill.name(), "serve-kill");
        assert_eq!(Site::by_name("conn-drop"), Some(Site::ConnDrop));
    }

    #[test]
    fn bad_specs_are_rejected_with_context() {
        for spec in [
            "bogus-site@1",
            "read-transient",
            "read-transient@0",
            "read-transient@x",
            "read-transient%1.5",
            "read-transient@1%0.5",
            "seed=abc",
            "stall@1=ms",
        ] {
            let err = FaultPlan::parse(spec).unwrap_err().to_string();
            assert!(err.contains("chaos spec"), "{spec:?}: {err}");
        }
    }

    #[test]
    fn nth_trigger_fires_exactly_once_at_the_nth_occurrence() {
        let p = FaultPlan::parse("read-transient@3").unwrap();
        let hits: Vec<bool> = (0..6).map(|_| p.check(Site::ReadTransient).is_some()).collect();
        assert_eq!(hits, [false, false, true, false, false, false]);
        assert_eq!(p.fires_at(Site::ReadTransient), 1);
        assert_eq!(p.occurrences_at(Site::ReadTransient), 6);
        // other sites are untouched
        assert_eq!(p.check(Site::FrameBitflip), None);
    }

    #[test]
    fn probabilistic_trigger_is_seed_deterministic() {
        let schedule = |seed: u64| -> Vec<bool> {
            let p = FaultPlan::parse(&format!("seed={seed};ledger-io%0.3")).unwrap();
            (0..64).map(|_| p.check(Site::LedgerIo).is_some()).collect()
        };
        assert_eq!(schedule(42), schedule(42), "same seed, same schedule");
        assert_ne!(schedule(42), schedule(43), "different seed, different schedule");
        let fired = schedule(42).iter().filter(|&&b| b).count();
        assert!((5..=30).contains(&fired), "p=0.3 over 64 draws fired {fired} times");
    }

    #[test]
    fn install_arms_and_clearing_disarms() {
        // Unit tests share one process, so this test only installs a
        // probability-0 rule: the fast path arms, but no concurrently
        // running test can ever draw a fault from it. Plans that
        // actually fire are exercised plan-locally above and globally
        // by the serialized tests/chaos.rs suite.
        install(Some(FaultPlan::parse("decode-panic%0.0").unwrap()));
        assert!(armed());
        assert_eq!(fired(Site::DecodePanic), None, "p=0 must never fire");
        assert_eq!(fires_at(Site::DecodePanic), 0);
        install(Some(FaultPlan::empty()));
        assert!(!armed(), "an empty plan must not arm the fast path");
        install(None);
        assert!(!armed());
        assert_eq!(fired(Site::DecodePanic), None);
    }
}
