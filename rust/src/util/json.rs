//! Minimal JSON tree: emitter + recursive-descent parser.
//!
//! The offline build has no `serde`, so the crate's JSON boundaries — the
//! [`analysis::Table`](crate::analysis::Table) JSON emitter, the
//! experiment-ledger export, and the `BENCH_grid_baseline.json` gate file
//! — share this ~200-line substitute. Numbers are `f64` and are emitted
//! with Rust's shortest-roundtrip formatting, so a value written by
//! [`Json::render`] and read back by [`Json::parse`] is bit-identical;
//! that exactness is what lets the regression gate distinguish "same
//! simulation" from "drift" without fuzzy thresholds.

use crate::bail;
use crate::util::error::Result;

/// One JSON value. Objects keep insertion order (a `Vec`, not a map), so
/// rendering is deterministic and diff-friendly.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Number constructor that maps non-finite values (which JSON cannot
    /// represent) to `null` instead of emitting invalid output.
    pub fn num(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(v)
        } else {
            Json::Null
        }
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact rendering (no whitespace outside strings).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                // shortest roundtrip; integers print without ".0"
                out.push_str(&format!("{v}"));
            }
            Json::Str(s) => render_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_string(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document; trailing non-whitespace is an error.
    pub fn parse(s: &str) -> Result<Json> {
        let b = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            bail!("trailing bytes after JSON value at offset {pos}");
        }
        Ok(v)
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while let Some(&c) = b.get(*pos) {
        if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
            *pos += 1;
        } else {
            break;
        }
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<()> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        bail!("expected {lit:?} at offset {}", *pos)
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => bail!("unexpected end of JSON input"),
        Some(&b'n') => {
            expect(b, pos, "null")?;
            Ok(Json::Null)
        }
        Some(&b't') => {
            expect(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        Some(&b'f') => {
            expect(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        Some(&b'"') => Ok(Json::Str(parse_string(b, pos)?)),
        Some(&b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => bail!("expected ',' or ']' at offset {}", *pos),
                }
            }
        }
        Some(&b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ":")?;
                let val = parse_value(b, pos)?;
                fields.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(&b',') => *pos += 1,
                    Some(&b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => bail!("expected ',' or '}}' at offset {}", *pos),
                }
            }
        }
        Some(_) => parse_number(b, pos),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if b.get(*pos) != Some(&b'"') {
        bail!("expected string at offset {}", *pos);
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else {
            bail!("unterminated JSON string");
        };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&esc) = b.get(*pos) else {
                    bail!("unterminated escape in JSON string");
                };
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let hi = parse_hex4(b, pos)?;
                        // combine a surrogate pair when present
                        let cp = if (0xD800..0xDC00).contains(&hi)
                            && b[*pos..].starts_with(b"\\u")
                        {
                            *pos += 2;
                            let lo = parse_hex4(b, pos)?;
                            0x10000 + ((hi - 0xD800) << 10) + (lo.wrapping_sub(0xDC00) & 0x3FF)
                        } else {
                            hi
                        };
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                    }
                    other => bail!("invalid escape \\{} in JSON string", other as char),
                }
            }
            c if c < 0x80 => out.push(c as char),
            _ => {
                // multi-byte UTF-8: re-decode from the original slice
                let start = *pos - 1;
                let len = utf8_len(c);
                let end = start + len;
                let Some(chunk) = b.get(start..end) else {
                    bail!("truncated UTF-8 in JSON string");
                };
                let s = std::str::from_utf8(chunk)
                    .map_err(|_| crate::anyhow!("invalid UTF-8 in JSON string"))?;
                out.push_str(s);
                *pos = end;
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_hex4(b: &[u8], pos: &mut usize) -> Result<u32> {
    let Some(chunk) = b.get(*pos..*pos + 4) else {
        bail!("truncated \\u escape");
    };
    let s = std::str::from_utf8(chunk).map_err(|_| crate::anyhow!("bad \\u escape"))?;
    let v = u32::from_str_radix(s, 16).map_err(|_| crate::anyhow!("bad \\u escape {s:?}"))?;
    *pos += 4;
    Ok(v)
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while let Some(&c) = b.get(*pos) {
        if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
            *pos += 1;
        } else {
            break;
        }
    }
    if *pos == start {
        bail!("expected JSON value at offset {start}");
    }
    let s = std::str::from_utf8(&b[start..*pos]).unwrap();
    let v: f64 = s
        .parse()
        .map_err(|_| crate::anyhow!("invalid JSON number {s:?} at offset {start}"))?;
    Ok(Json::Num(v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_structures() {
        let v = Json::Obj(vec![
            ("name".into(), Json::Str("KMeans".into())),
            ("cpi".into(), Json::Num(1.2345678901234567)),
            ("n".into(), Json::Num(42.0)),
            ("ok".into(), Json::Bool(true)),
            ("none".into(), Json::Null),
            (
                "rows".into(),
                Json::Arr(vec![Json::Num(1.0), Json::Str("a,b\"c".into())]),
            ),
        ]);
        let s = v.render();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn floats_roundtrip_exactly() {
        for v in [0.1, 1.0 / 3.0, 1e-300, 6.02214076e23, -0.0, 123456789.125] {
            let s = Json::Num(v).render();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{v} rendered as {s}");
        }
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(-7.0).render(), "-7");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::num(f64::NAN), Json::Null);
        assert_eq!(Json::num(f64::INFINITY), Json::Null);
        assert_eq!(Json::num(1.5), Json::Num(1.5));
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = v.render();
        assert_eq!(Json::parse(&s).unwrap(), v);
        assert!(s.contains("\\u0001"));
    }

    #[test]
    fn unicode_and_surrogates() {
        let v = Json::parse(r#""é😀é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" { \"a\" : [ 1 , 2 ] , \"b\" : null } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("b"), Some(&Json::Null));
    }
}
