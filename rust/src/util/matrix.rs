//! Dense row-major f64 matrix used throughout the workloads.
//!
//! Deliberately minimal: the instrumented workloads do their own loops so
//! they can emit memory-trace events per element access; this type only
//! provides storage, shape checking, and the handful of non-instrumented
//! helpers (used by dataset generation and by reference solutions inside
//! tests).

/// Row-major dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major vector (length must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    /// Identity matrix of size n.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Flat row-major data.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Flat mutable row-major data.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Matrix transpose (fresh allocation).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Matrix product self * other (naive; test/reference use only).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "inner dimensions must agree");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Reorder rows by permutation `perm`: new row i = old row perm[i].
    pub fn permute_rows(&self, perm: &[usize]) -> Matrix {
        assert_eq!(perm.len(), self.rows);
        let mut out = Matrix::zeros(self.rows, self.cols);
        for (new_i, &old_i) in perm.iter().enumerate() {
            out.row_mut(new_i).copy_from_slice(self.row(old_i));
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Solve the symmetric positive-definite system `A x = b` via Cholesky.
/// Reference implementation for tests and small closed-form solvers.
pub fn solve_spd(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    assert_eq!(b.len(), n);
    // Cholesky factorization A = L L^T.
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return None; // not positive definite
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    // Forward solve L y = b.
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    // Backward solve L^T x = y.
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Matrix::zeros(3, 4);
        m[(2, 3)] = 7.5;
        m[(0, 0)] = -1.0;
        assert_eq!(m[(2, 3)], 7.5);
        assert_eq!(m[(0, 0)], -1.0);
        assert_eq!(m.row(2)[3], 7.5);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose()[(2, 1)], 6.0);
    }

    #[test]
    fn eye_is_matmul_identity() {
        let a = Matrix::from_vec(2, 2, vec![3., -1., 2., 0.5]);
        assert_eq!(a.matmul(&Matrix::eye(2)), a);
        assert_eq!(Matrix::eye(2).matmul(&a), a);
    }

    #[test]
    fn permute_rows_moves_rows() {
        let a = Matrix::from_vec(3, 2, vec![0., 0., 1., 1., 2., 2.]);
        let p = a.permute_rows(&[2, 0, 1]);
        assert_eq!(p.row(0), &[2., 2.]);
        assert_eq!(p.row(1), &[0., 0.]);
        assert_eq!(p.row(2), &[1., 1.]);
    }

    #[test]
    fn solve_spd_recovers_solution() {
        // A = M^T M + I is SPD.
        let m = Matrix::from_vec(3, 3, vec![1., 2., 0., -1., 1., 3., 0.5, 0., 1.]);
        let mut a = m.transpose().matmul(&m);
        for i in 0..3 {
            a[(i, i)] += 1.0;
        }
        let x_true = [1.0, -2.0, 0.5];
        let b: Vec<f64> = (0..3)
            .map(|i| (0..3).map(|j| a[(i, j)] * x_true[j]).sum())
            .collect();
        let x = solve_spd(&a, &b).expect("SPD");
        for (xi, ti) in x.iter().zip(x_true.iter()) {
            assert!((xi - ti).abs() < 1e-10);
        }
    }

    #[test]
    fn solve_spd_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        assert!(solve_spd(&a, &[1.0, 1.0]).is_none());
    }
}
