//! Self-contained utility substrate: deterministic RNG, dense matrices,
//! statistics, and a CLI parser. The offline build environment provides no
//! external crates beyond `xla`/`anyhow`, so these are implemented here.

pub mod cli;
pub mod matrix;
pub mod rng;
pub mod stats;

pub use cli::Args;
pub use matrix::{solve_spd, Matrix};
pub use rng::Pcg64;
