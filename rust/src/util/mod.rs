//! Self-contained utility substrate: deterministic RNG, dense matrices,
//! statistics, a CLI parser, and an error/context type. The offline build
//! environment provides no external crates, so these are implemented here.

pub mod binio;
pub mod cli;
pub mod diag;
pub mod error;
pub mod fault;
pub mod json;
pub mod matrix;
pub mod rng;
pub mod stats;
pub mod telemetry;

pub use cli::Args;
pub use error::{Context, Error, Result};
pub use json::Json;
pub use matrix::{solve_spd, Matrix};
pub use rng::Pcg64;
