//! Deterministic pseudo-random number generation.
//!
//! The crate cannot depend on external RNG crates (offline build), so we
//! implement PCG64 (the `pcg_xsl_rr_128_64` variant used by NumPy's default
//! `Generator` bit stream) plus the distribution helpers the dataset
//! generators and workloads need. Determinism is part of the public
//! contract: every experiment in EXPERIMENTS.md records its seed, and a run
//! with the same seed reproduces the same trace bit-for-bit.

/// PCG64: 128-bit LCG state with XSL-RR output to 64 bits.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id; distinct streams from
    /// the same seed are statistically independent (used to give each core
    /// of the multicore simulator its own stream).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0, "below(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value is deliberately
    /// *not* kept so the stream is a pure function of call count).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Sample from a symmetric Dirichlet(alpha) over `k` categories.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s > 0.0 {
            for v in &mut g {
                *v /= s;
            }
        } else {
            g.iter_mut().for_each(|v| *v = 1.0 / k as f64);
        }
        g
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; valid for any shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: gamma(a) = gamma(a+1) * U^(1/a)
            let u = self.next_f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        // Floyd's algorithm for small k, shuffle for large k.
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.index(j + 1);
                let pick = if chosen.contains(&t) { j } else { t };
                chosen.insert(pick);
                out.push(pick);
            }
            self.shuffle(&mut out);
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::with_stream(7, 0);
        let mut b = Pcg64::with_stream(7, 1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_approx() {
        let mut r = Pcg64::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_support() {
        let mut r = Pcg64::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - 10_000.0).abs() < 600.0,
                "bucket {i} count {c} deviates"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Pcg64::new(7);
        for &shape in &[0.5, 1.0, 2.5, 9.0] {
            let n = 50_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(1.0),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg64::new(8);
        for _ in 0..100 {
            let p = r.dirichlet(0.3, 5);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg64::new(10);
        for &(n, k) in &[(100, 3), (100, 50), (10, 10), (1000, 999)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }
}
