//! Small statistics helpers shared by analysis and tests.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean of strictly positive values; 0.0 for an empty slice.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|&x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Population variance; 0.0 for slices shorter than 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Sample (Bessel-corrected, n−1) variance; 0.0 for slices shorter
/// than 2. This is the estimator the sampled-simulation confidence
/// intervals use: the detailed windows are a sample of the run, not
/// the population.
pub fn sample_variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation (n−1 denominator).
pub fn sample_stddev(xs: &[f64]) -> f64 {
    sample_variance(xs).sqrt()
}

/// Two-sided 95% Student-t critical value for `df` degrees of freedom
/// (table lookup, linearly interpolated between tabulated rows; the
/// asymptotic 1.960 beyond df = 60). `df == 0` returns +inf — a single
/// observation carries no variance information.
pub fn t95(df: usize) -> f64 {
    const TABLE: [(usize, f64); 16] = [
        (1, 12.706),
        (2, 4.303),
        (3, 3.182),
        (4, 2.776),
        (5, 2.571),
        (6, 2.447),
        (7, 2.365),
        (8, 2.306),
        (9, 2.262),
        (10, 2.228),
        (12, 2.179),
        (15, 2.131),
        (20, 2.086),
        (30, 2.042),
        (60, 2.000),
        (usize::MAX, 1.960),
    ];
    if df == 0 {
        return f64::INFINITY;
    }
    let mut prev = TABLE[0];
    for &(d, t) in &TABLE {
        if df == d {
            return t;
        }
        if df < d {
            // linear interpolation between the bracketing rows (the last
            // row's df is a sentinel: clamp to the asymptotic value)
            if d == usize::MAX {
                return t;
            }
            let (d0, t0) = prev;
            let frac = (df - d0) as f64 / (d - d0) as f64;
            return t0 + frac * (t - t0);
        }
        prev = (d, t);
    }
    1.960
}

/// Pearson correlation coefficient; 0.0 when either side is constant.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Percentile via linear interpolation on the sorted copy, p in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Squared Euclidean distance between two equal-length vectors.
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// argmin over f64 values; None for an empty iterator.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
}

/// argmax over f64 values; None for an empty iterator.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
}

/// log(sum(exp(xs))) computed stably.
pub fn logsumexp(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NEG_INFINITY;
    }
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m.is_infinite() {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((stddev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn sample_variance_uses_bessel_correction() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        // population variance 1.25 → sample variance 1.25 * 4/3
        assert!((sample_variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
        assert!((sample_stddev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(sample_variance(&[7.0]), 0.0);
        assert_eq!(sample_variance(&[]), 0.0);
    }

    #[test]
    fn t95_table_and_interpolation() {
        assert_eq!(t95(1), 12.706);
        assert_eq!(t95(4), 2.776);
        assert_eq!(t95(30), 2.042);
        assert_eq!(t95(60), 2.000);
        // beyond the table: asymptotic normal quantile
        assert_eq!(t95(61), 1.960);
        assert_eq!(t95(10_000), 1.960);
        // interpolated between df=10 (2.228) and df=12 (2.179)
        let t11 = t95(11);
        assert!(t11 < 2.228 && t11 > 2.179, "t95(11) = {t11}");
        // df=0: no variance information
        assert!(t95(0).is_infinite());
        // monotone non-increasing over a sweep
        let mut last = f64::INFINITY;
        for df in 1..100 {
            let t = t95(df);
            assert!(t <= last + 1e-12, "t95 must not increase: df={df}");
            last = t;
        }
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(argmin(&[]), None);
        assert_eq!(logsumexp(&[]), f64::NEG_INFINITY);
    }

    #[test]
    fn geomean_of_speedups() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [10.0, 20.0, 30.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&xs, &[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn argminmax() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(argmin(&xs), Some(1));
        assert_eq!(argmax(&xs), Some(0));
    }

    #[test]
    fn sqdist_known() {
        assert_eq!(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn logsumexp_stable() {
        let xs = [1000.0, 1000.0];
        let l = logsumexp(&xs);
        assert!((l - (1000.0 + 2f64.ln())).abs() < 1e-9);
        // matches naive for small values
        let ys = [0.1f64, 0.2, 0.3];
        let naive = ys.iter().map(|x| x.exp()).sum::<f64>().ln();
        assert!((logsumexp(&ys) - naive).abs() < 1e-12);
    }
}
