//! Zero-cost-when-off telemetry: scoped spans, named counters, and
//! per-cell rows for the replay stack.
//!
//! The harness characterizes workloads but was itself a black box: a
//! grid run emitted final metrics with no account of where its wall
//! clock went (decode vs simulate vs reorder-buffer waits vs ledger
//! I/O). This module is the process-global spine that fixes that,
//! built on the same arming discipline as [`crate::util::fault`]:
//!
//! - **Off by default, off means off.** Nothing is recorded unless
//!   [`install`] was called with an output directory (the CLI's
//!   `--telemetry [<dir>]` / `MLPERF_TELEMETRY`). Every probe —
//!   [`span`], [`add`], [`cell`] — short-circuits on a single relaxed
//!   atomic load and allocates nothing. The off path is therefore
//!   provably inert: it cannot perturb metrics, fingerprints, or the
//!   byte-exact grid results JSON (`tests/telemetry.rs` gates all
//!   three, and the `grid_replay` bench gates the off-mode overhead).
//! - **Spans are RAII.** [`span`] returns a guard that records
//!   `(lane, stage, start, duration)` on drop. Guards live on the
//!   stack, so per-thread span streams are properly nested by
//!   construction — which is what lets the Chrome-trace exporter
//!   ([`crate::obs::chrome`]) emit balanced B/E event pairs.
//! - **Counters are fixed-slot atomics.** Like `fault::Site`, the
//!   [`Counter`] set is a closed enum with a name table ([`COUNTERS`])
//!   backed by one `AtomicU64` per slot: bumping is lock-free and
//!   allocation-free even when armed.
//! - **Determinism.** Telemetry is observational only. Counters that
//!   mirror simulation structure (blocks decoded, ledger hits) are
//!   seed-deterministic; timing values naturally vary run to run, but
//!   nothing here feeds back into simulation or fingerprints.
//!
//! Exporters live in [`crate::obs`]; this module only collects.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Span taxonomy: one variant per instrumented stage of the stack.
/// The closed set keeps per-stage totals in fixed atomic slots (no
/// hashing, no allocation on the hot path) and gives the exporters a
/// stable vocabulary (see the DESIGN.md span taxonomy table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Pipelined ingest I/O thread: one frame read from disk.
    IoRead,
    /// Pipelined ingest I/O thread: blocked on the reorder window
    /// (decoders/consumer behind; only recorded when a wait happened).
    Backpressure,
    /// Decoder pool: one columnar block decode.
    Decode,
    /// Ingest consumer: one in-order `sink.consume` delivery.
    Consume,
    /// Driver: one workload execution captured as a replayable trace.
    Capture,
    /// Driver: one replay unit — a broadcast batch or a direct cell.
    CellRun,
    /// Ledger: open (including torn-tail scan/recovery).
    LedgerOpen,
    /// Ledger: one record append (including any I/O retries).
    LedgerAppend,
    /// Ledger: one compaction (rewrite + rename + reopen).
    LedgerCompact,
    /// Sampled simulation: one detailed window, open to close.
    Window,
    /// Cache-geometry sweep: one workload's single-pass stack profile.
    SweepCell,
    /// Serve daemon: one client connection, accept to close.
    ServeConn,
    /// Serve daemon: one admitted request, admission to reply.
    ServeRequest,
    /// Serve daemon: one coalesced miss simulation (leader only).
    ServeSim,
}

/// Name table for [`Stage`] (exporter vocabulary), index-aligned with
/// the per-stage atomic slots.
pub const STAGES: &[(Stage, &str)] = &[
    (Stage::IoRead, "io-read"),
    (Stage::Backpressure, "backpressure"),
    (Stage::Decode, "decode"),
    (Stage::Consume, "consume"),
    (Stage::Capture, "capture"),
    (Stage::CellRun, "cell-run"),
    (Stage::LedgerOpen, "ledger-open"),
    (Stage::LedgerAppend, "ledger-append"),
    (Stage::LedgerCompact, "ledger-compact"),
    (Stage::Window, "sample-window"),
    (Stage::SweepCell, "sweep-cell"),
    (Stage::ServeConn, "serve-conn"),
    (Stage::ServeRequest, "serve-request"),
    (Stage::ServeSim, "serve-sim"),
];

const STAGE_COUNT: usize = 14;

impl Stage {
    /// Stable exporter name (see [`STAGES`]).
    pub fn name(self) -> &'static str {
        STAGES[self as usize].1
    }
}

/// Named counters: fixed slots, relaxed atomic bumps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Blocks delivered in order to the sink by pipelined ingest.
    /// Deterministic: equals the trace's block count on success.
    BlocksDecoded,
    /// `BlockPool::get_block` served from the pool.
    PoolHit,
    /// `BlockPool::get_block` fell through to a fresh allocation.
    PoolMiss,
    /// Total nanoseconds replay workers spent waiting for a runnable
    /// unit (scheduler queue-wait, aggregated across workers).
    QueueWaitNanos,
    /// Total nanoseconds spent acquiring the scheduler lock
    /// (contention indicator, aggregated across workers).
    SchedLockNanos,
    /// Sum of broadcast batch widths (cells per shared replay pass).
    BatchWidthSum,
    /// Widest broadcast batch observed.
    BatchWidthMax,
    /// Number of broadcast batch replays.
    Batches,
    /// Ledgered grid cells satisfied from the ledger without running.
    /// Deterministic: equals `DriverReport::cached_cells`.
    LedgerHit,
    /// Ledger append I/O retries (transient error, will back off).
    LedgerRetry,
    /// Total nanoseconds slept in ledger append backoff.
    BackoffNanos,
    /// Spans discarded because the buffer hit its cap (`MAX_SPANS`);
    /// per-stage totals still include them.
    SpansDropped,
    /// Serve: requests admitted past admission control.
    ServeAdmitted,
    /// Serve: requests shed with a typed `Overloaded` rejection.
    ServeShed,
    /// Serve: requests rejected (or abandoned) on an expired deadline.
    ServeDeadline,
    /// Serve: queries answered from the sharded ledger without running.
    ServeHit,
    /// Serve: queries that required a simulation (coalition leaders).
    ServeMiss,
    /// Serve: queries that rode another in-flight simulation of the
    /// same fingerprint instead of starting their own.
    ServeCoalesced,
    /// Serve: deepest concurrent admission depth observed (maximize).
    ServeQueueMax,
}

/// Name table for [`Counter`], index-aligned with the atomic slots.
pub const COUNTERS: &[(Counter, &str)] = &[
    (Counter::BlocksDecoded, "blocks_decoded"),
    (Counter::PoolHit, "pool_hit"),
    (Counter::PoolMiss, "pool_miss"),
    (Counter::QueueWaitNanos, "queue_wait_nanos"),
    (Counter::SchedLockNanos, "sched_lock_nanos"),
    (Counter::BatchWidthSum, "batch_width_sum"),
    (Counter::BatchWidthMax, "batch_width_max"),
    (Counter::Batches, "batches"),
    (Counter::LedgerHit, "ledger_hit"),
    (Counter::LedgerRetry, "ledger_retry"),
    (Counter::BackoffNanos, "backoff_nanos"),
    (Counter::SpansDropped, "spans_dropped"),
    (Counter::ServeAdmitted, "serve_admitted"),
    (Counter::ServeShed, "serve_shed"),
    (Counter::ServeDeadline, "serve_deadline"),
    (Counter::ServeHit, "serve_hit"),
    (Counter::ServeMiss, "serve_miss"),
    (Counter::ServeCoalesced, "serve_coalesced"),
    (Counter::ServeQueueMax, "serve_queue_max"),
];

const COUNTER_COUNT: usize = 19;

impl Counter {
    /// Stable exporter name (see [`COUNTERS`]).
    pub fn name(self) -> &'static str {
        COUNTERS[self as usize].1
    }
}

/// One closed span, as recorded for the Chrome-trace exporter.
#[derive(Debug, Clone)]
pub struct SpanRec {
    /// Timeline lane (one per participating thread; see [`lane`]).
    pub lane: u32,
    /// Which stage of the stack this span covers.
    pub stage: Stage,
    /// Free-form label (workload name, batch description); empty means
    /// the exporter falls back to the stage name.
    pub label: String,
    /// Start, nanoseconds since [`install`].
    pub start_ns: u64,
    /// Duration in nanoseconds (never negative by construction).
    pub dur_ns: u64,
    /// Position of the span's *open* in the collector-wide event
    /// sequence. One shared counter serves opens and closes, so
    /// sorting a lane's B/E events by sequence reproduces the exact
    /// real-time stack discipline the RAII guards enforced — the
    /// timestamps alone cannot (independent clock reads can tie or
    /// jitter by nanoseconds).
    pub open_seq: u64,
    /// Position of the span's *close* in the same sequence.
    pub close_seq: u64,
}

/// One grid cell's outcome row for the `telemetry.json` summary.
#[derive(Debug, Clone)]
pub struct CellRow {
    /// Ledger fingerprint (`v1:...`), or empty when not computed.
    pub fingerprint: String,
    /// Workload name.
    pub workload: String,
    /// Scenario name.
    pub scenario: String,
    /// `"run"`, `"cached"`, or `"failed"`.
    pub status: String,
    /// Wall nanoseconds attributed to the cell (amortized over its
    /// broadcast batch for shared-pass replays).
    pub wall_nanos: u64,
    /// Trace blocks replayed for the cell (0 when unknown/cached).
    pub blocks: u64,
    /// Retries consumed before the recorded outcome.
    pub retries: u32,
}

/// Span-buffer cap: a grid run records thousands of coarse spans and
/// (at small scales) tens of thousands of per-block spans; the cap
/// bounds memory and trace size on pathological runs. Overflow is
/// counted in [`Counter::SpansDropped`], never silent.
const MAX_SPANS: usize = 1 << 20;

struct Telemetry {
    epoch: Instant,
    gen: u64,
    out_dir: PathBuf,
    /// Shared open/close event sequence (see [`SpanRec::open_seq`]).
    seq: AtomicU64,
    counters: [AtomicU64; COUNTER_COUNT],
    stage_nanos: [AtomicU64; STAGE_COUNT],
    stage_counts: [AtomicU64; STAGE_COUNT],
    spans: Mutex<Vec<SpanRec>>,
    lanes: Mutex<Vec<String>>,
    cells: Mutex<Vec<CellRow>>,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static CURRENT: RwLock<Option<Arc<Telemetry>>> = RwLock::new(None);
/// Bumped on every install so stale thread-local lane assignments from
/// a previous collector are detected and reallocated.
static GEN: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static LANE: std::cell::Cell<(u64, u32)> = const { std::cell::Cell::new((0, 0)) };
}

/// Install (or clear, with `None`) the process-global collector.
/// Mirrors [`crate::util::fault::install`]: last call wins, and the
/// armed flag plus collector swap atomically under one lock so probes
/// never observe a half-installed state.
pub fn install(out_dir: Option<PathBuf>) {
    let t = out_dir.map(|d| {
        Arc::new(Telemetry {
            epoch: Instant::now(),
            gen: GEN.fetch_add(1, Ordering::SeqCst) + 1,
            out_dir: d,
            seq: AtomicU64::new(0),
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            stage_nanos: std::array::from_fn(|_| AtomicU64::new(0)),
            stage_counts: std::array::from_fn(|_| AtomicU64::new(0)),
            spans: Mutex::new(Vec::new()),
            lanes: Mutex::new(Vec::new()),
            cells: Mutex::new(Vec::new()),
        })
    });
    let mut guard = CURRENT.write().unwrap_or_else(|e| e.into_inner());
    ARMED.store(t.is_some(), Ordering::SeqCst);
    *guard = t;
}

/// Is a collector installed? Single relaxed load — this is the entire
/// cost of every probe on an untelemetered run.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

fn current() -> Option<Arc<Telemetry>> {
    CURRENT.read().unwrap_or_else(|e| e.into_inner()).clone()
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn lane_of(t: &Arc<Telemetry>) -> u32 {
    LANE.with(|c| {
        let (gen, lane) = c.get();
        if gen == t.gen {
            return lane;
        }
        let mut lanes = lock(&t.lanes);
        let idx = lanes.len() as u32;
        lanes.push(format!("thread-{idx}"));
        c.set((t.gen, idx));
        idx
    })
}

/// Name the calling thread's timeline lane (e.g. `"io"`,
/// `"decode-0"`); a no-op when telemetry is off. Unnamed lanes render
/// as `thread-N`.
pub fn lane(name: &str) {
    if !armed() {
        return;
    }
    if let Some(t) = current() {
        let idx = lane_of(&t) as usize;
        lock(&t.lanes)[idx] = name.to_string();
    }
}

/// [`lane`] with a lazily built name: the closure (and its allocation)
/// only runs when telemetry is armed.
pub fn lane_with(f: impl FnOnce() -> String) {
    if !armed() {
        return;
    }
    if let Some(t) = current() {
        let idx = lane_of(&t) as usize;
        lock(&t.lanes)[idx] = f();
    }
}

/// RAII span guard: records its stage's duration (and a [`SpanRec`]
/// for the timeline) when dropped. Inactive guards — the off path, or
/// a placeholder from [`Span::inactive`] — carry no data and do
/// nothing on drop.
#[derive(Default)]
pub struct Span {
    data: Option<SpanData>,
}

struct SpanData {
    t: Arc<Telemetry>,
    stage: Stage,
    label: String,
    lane: u32,
    start: Instant,
    start_ns: u64,
    open_seq: u64,
}

impl Span {
    /// A guard that records nothing; useful as a field placeholder
    /// (e.g. the sampled simulator's open-window span).
    pub const fn inactive() -> Self {
        Span { data: None }
    }

    /// Is this guard actually recording?
    pub fn active(&self) -> bool {
        self.data.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(d) = self.data.take() {
            let dur = d.start.elapsed().as_nanos() as u64;
            let close_seq = d.t.seq.fetch_add(1, Ordering::Relaxed);
            let si = d.stage as usize;
            d.t.stage_nanos[si].fetch_add(dur, Ordering::Relaxed);
            d.t.stage_counts[si].fetch_add(1, Ordering::Relaxed);
            let mut spans = lock(&d.t.spans);
            if spans.len() < MAX_SPANS {
                spans.push(SpanRec {
                    lane: d.lane,
                    stage: d.stage,
                    label: d.label,
                    start_ns: d.start_ns,
                    dur_ns: dur,
                    open_seq: d.open_seq,
                    close_seq,
                });
            } else {
                drop(spans);
                d.t.counters[Counter::SpansDropped as usize].fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Open a scoped span for `stage` on the calling thread. Off path:
/// one relaxed load, returns an inactive guard, no allocation.
#[inline]
pub fn span(stage: Stage) -> Span {
    if !armed() {
        return Span::inactive();
    }
    span_slow(stage, "")
}

/// [`span`] with a free-form label (workload name, batch description).
/// The label is only materialized when telemetry is armed.
#[inline]
pub fn span_labeled(stage: Stage, label: &str) -> Span {
    if !armed() {
        return Span::inactive();
    }
    span_slow(stage, label)
}

#[cold]
fn span_slow(stage: Stage, label: &str) -> Span {
    match current() {
        None => Span::inactive(),
        Some(t) => {
            let lane = lane_of(&t);
            let open_seq = t.seq.fetch_add(1, Ordering::Relaxed);
            let start_ns = t.epoch.elapsed().as_nanos() as u64;
            Span {
                data: Some(SpanData {
                    stage,
                    label: label.to_string(),
                    lane,
                    start: Instant::now(),
                    start_ns,
                    open_seq,
                    t,
                }),
            }
        }
    }
}

/// Bump a counter by `v`. Off path: one relaxed load.
#[inline]
pub fn add(c: Counter, v: u64) {
    if !armed() {
        return;
    }
    add_slow(c, v);
}

#[cold]
fn add_slow(c: Counter, v: u64) {
    if let Some(t) = current() {
        t.counters[c as usize].fetch_add(v, Ordering::Relaxed);
    }
}

/// Raise a counter to at least `v` (monotonic max, e.g. widest batch).
#[inline]
pub fn maximize(c: Counter, v: u64) {
    if !armed() {
        return;
    }
    maximize_slow(c, v);
}

#[cold]
fn maximize_slow(c: Counter, v: u64) {
    if let Some(t) = current() {
        t.counters[c as usize].fetch_max(v, Ordering::Relaxed);
    }
}

/// Current value of a counter (0 when telemetry is off). Reads the
/// live collector; exporters should prefer one [`snapshot`].
pub fn counter(c: Counter) -> u64 {
    current().map_or(0, |t| t.counters[c as usize].load(Ordering::Relaxed))
}

/// Append a per-cell outcome row for the summary exporter. Off path:
/// one relaxed load; the row is only constructed by armed callers
/// (guard call sites with [`armed`] to avoid building strings for
/// nothing).
pub fn cell(row: CellRow) {
    if !armed() {
        return;
    }
    if let Some(t) = current() {
        lock(&t.cells).push(row);
    }
}

/// The output directory the collector was installed with, if armed.
pub fn out_dir() -> Option<PathBuf> {
    current().map(|t| t.out_dir.clone())
}

/// Point-in-time copy of everything collected, for the exporters.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Nanoseconds since [`install`] — the run's telemetry wall clock.
    pub wall_nanos: u64,
    /// Where the exporters should write.
    pub out_dir: PathBuf,
    /// Lane names, index-aligned with [`SpanRec::lane`].
    pub lanes: Vec<String>,
    /// All recorded spans, in completion order.
    pub spans: Vec<SpanRec>,
    /// `(name, value)` for every counter, in [`COUNTERS`] order.
    pub counters: Vec<(&'static str, u64)>,
    /// `(name, total_nanos, count)` per stage, in [`STAGES`] order.
    pub stages: Vec<(&'static str, u64, u64)>,
    /// Per-cell outcome rows, in completion order.
    pub cells: Vec<CellRow>,
}

impl Snapshot {
    /// Value of a counter by its [`COUNTERS`] name (0 if absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(n, _)| *n == name).map_or(0, |&(_, v)| v)
    }
}

/// Snapshot the installed collector, or `None` when telemetry is off.
pub fn snapshot() -> Option<Snapshot> {
    let t = current()?;
    Some(Snapshot {
        wall_nanos: t.epoch.elapsed().as_nanos() as u64,
        out_dir: t.out_dir.clone(),
        lanes: lock(&t.lanes).clone(),
        spans: lock(&t.spans).clone(),
        counters: COUNTERS
            .iter()
            .map(|&(c, n)| (n, t.counters[c as usize].load(Ordering::Relaxed)))
            .collect(),
        stages: STAGES
            .iter()
            .map(|&(s, n)| {
                (
                    n,
                    t.stage_nanos[s as usize].load(Ordering::Relaxed),
                    t.stage_counts[s as usize].load(Ordering::Relaxed),
                )
            })
            .collect(),
        cells: lock(&t.cells).clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_tables_are_aligned_and_unique() {
        assert_eq!(STAGES.len(), STAGE_COUNT);
        assert_eq!(COUNTERS.len(), COUNTER_COUNT);
        for (i, &(s, n)) in STAGES.iter().enumerate() {
            assert_eq!(s as usize, i, "stage slot misaligned: {n}");
            assert_eq!(s.name(), n);
        }
        for (i, &(c, n)) in COUNTERS.iter().enumerate() {
            assert_eq!(c as usize, i, "counter slot misaligned: {n}");
            assert_eq!(c.name(), n);
        }
        let mut names: Vec<&str> = STAGES.iter().map(|&(_, n)| n).collect();
        names.extend(COUNTERS.iter().map(|&(_, n)| n));
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), names.len(), "duplicate telemetry name");
    }

    /// One combined lifecycle test: cargo runs unit tests in threads
    /// within a single process, so a single test owns the global
    /// collector end to end (the CLI-level behaviour is exercised in
    /// `tests/telemetry.rs`, which serializes via its own lock).
    #[test]
    fn collector_lifecycle() {
        // off: probes are inert and cheap
        assert!(!armed());
        add(Counter::PoolHit, 5);
        let g = span(Stage::Decode);
        assert!(!g.active());
        drop(g);
        assert!(snapshot().is_none());

        install(Some(PathBuf::from("target/tmp-telemetry-test")));
        assert!(armed());
        lane("unit-test");
        add(Counter::PoolHit, 2);
        add(Counter::PoolHit, 3);
        maximize(Counter::BatchWidthMax, 4);
        maximize(Counter::BatchWidthMax, 2);
        {
            let _outer = span_labeled(Stage::CellRun, "outer");
            let inner = span(Stage::Decode);
            assert!(inner.active());
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let snap = snapshot().expect("armed");
        assert_eq!(snap.counter("pool_hit"), 5);
        assert_eq!(snap.counter("batch_width_max"), 4);
        assert_eq!(counter(Counter::PoolHit), 5);
        assert_eq!(snap.spans.len(), 2);
        // inner span closed first; both nonzero duration, same lane
        assert_eq!(snap.spans[0].stage, Stage::Decode);
        assert_eq!(snap.spans[1].stage, Stage::CellRun);
        assert_eq!(snap.spans[1].label, "outer");
        assert_eq!(snap.spans[0].lane, snap.spans[1].lane);
        assert!(snap.spans[0].start_ns >= snap.spans[1].start_ns);
        assert!(snap.spans[1].dur_ns >= snap.spans[0].dur_ns);
        // open/close sequencing reflects the nesting exactly
        assert!(snap.spans[0].open_seq > snap.spans[1].open_seq);
        assert!(snap.spans[0].close_seq < snap.spans[1].close_seq);
        assert!(snap.spans[0].open_seq < snap.spans[0].close_seq);
        assert_eq!(snap.lanes[snap.spans[0].lane as usize], "unit-test");
        let cell_total =
            snap.stages.iter().find(|&&(n, _, _)| n == "cell-run").map(|&(_, t, _)| t).unwrap();
        assert!(cell_total > 0);

        // spans from another thread land in their own lane
        std::thread::scope(|s| {
            s.spawn(|| {
                let _g = span(Stage::IoRead);
            });
        });
        let snap2 = snapshot().unwrap();
        assert_eq!(snap2.spans.len(), 3);
        assert_ne!(snap2.spans[2].lane, snap2.spans[0].lane);

        // cell rows accumulate only while armed
        cell(CellRow {
            fingerprint: "v1:dead".into(),
            workload: "KMeans".into(),
            scenario: "baseline".into(),
            status: "run".into(),
            wall_nanos: 10,
            blocks: 3,
            retries: 0,
        });
        assert_eq!(snapshot().unwrap().cells.len(), 1);

        install(None);
        assert!(!armed());
        assert!(snapshot().is_none());
        add(Counter::PoolHit, 9);
        cell(CellRow {
            fingerprint: String::new(),
            workload: String::new(),
            scenario: String::new(),
            status: "run".into(),
            wall_nanos: 0,
            blocks: 0,
            retries: 0,
        });
        assert!(snapshot().is_none());

        // a fresh install starts from zero (new generation, new lanes)
        install(Some(PathBuf::from("target/tmp-telemetry-test2")));
        let snap3 = snapshot().unwrap();
        assert_eq!(snap3.counter("pool_hit"), 0);
        assert!(snap3.spans.is_empty());
        assert!(snap3.cells.is_empty());
        let _g = span(Stage::Decode);
        drop(_g);
        assert_eq!(snapshot().unwrap().spans[0].lane, 0, "lanes restart per install");
        install(None);
    }
}
