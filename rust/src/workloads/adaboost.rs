//! Adaboost (SAMME) [FS99] — tree-based workload.
//!
//! Boosted shallow CART trees with per-round sample reweighting, as in
//! scikit-learn's `AdaBoostClassifier` and mlpack's `AdaBoost`. Every
//! round re-scans the full dataset through the index array with the
//! updated weight vector — the repeated-pass pattern that makes Adaboost
//! the paper's prime candidate for one-time expensive data reorderings
//! (Table IX: "ensemble based workloads such as Adaboost and Random
//! Forests"). Quality: weighted-vote train accuracy.

use super::dtree::{fit_cart, CartParams, CartRegions, CartTree};
use super::{Category, RunContext, RunResult, Workload};
use crate::data::{make_classification, Dataset};
use crate::trace::{AddressSpace, Recorder};
use crate::util::Pcg64;

const SITE_MISCLASS: u32 = 1;

/// Adaboost workload.
pub struct Adaboost {
    /// Boosting rounds ("training iterations" scale this).
    pub rounds_per_iter: usize,
    /// Weak-learner depth (stumps-ish, as sklearn's default depth-1..3).
    pub weak_depth: usize,
}

impl Default for Adaboost {
    fn default() -> Self {
        Self { rounds_per_iter: 4, weak_depth: 2 }
    }
}

impl Workload for Adaboost {
    fn name(&self) -> &'static str {
        "Adaboost"
    }

    fn category(&self) -> Category {
        Category::TreeBased
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        make_classification(rows, features, (features * 3 / 4).max(2), 2, 0.1, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let n = ds.n_samples();
        let m = ds.n_features();
        let n_classes = ds.n_classes.max(2);
        let mut space = AddressSpace::new();
        let regions = CartRegions::alloc(&mut space, n, m, "ada");
        let r_w = space.alloc_f64("ada.weights", n);
        let mut rng = Pcg64::new(ctx.seed);
        let params = CartParams {
            max_depth: self.weak_depth,
            min_samples_leaf: 5,
            max_features: None,
            n_thresholds: 8,
        };

        let mut weights = vec![1.0 / n as f64; n];
        let mut learners: Vec<(CartTree, f64)> = Vec::new();
        let rounds = self.rounds_per_iter * ctx.iterations.max(1);
        for _round in 0..rounds {
            let mut idx: Vec<u32> = (0..n as u32).collect();
            let tree = fit_cart(
                &ds.x,
                &ds.y,
                n_classes,
                &mut idx,
                Some(&weights),
                &params,
                &regions,
                rec,
                &mut rng,
                ctx.profile.loop_overhead_uops(),
            );
            // weighted error: traced prediction + weight pass
            let mut err = 0.0;
            let mut miss = vec![false; n];
            for i in 0..n {
                rec.load_f64(r_w, i);
                let pred = tree.predict_traced(&ds.x, i, &regions, rec);
                let wrong = pred != ds.y[i] as usize;
                rec.fcmp_branch(SITE_MISCLASS, wrong);
                if wrong {
                    err += weights[i];
                    miss[i] = true;
                }
            }
            let err = err.clamp(1e-10, 1.0 - 1e-10);
            if err >= 1.0 - 1.0 / n_classes as f64 {
                break; // weak learner no better than chance
            }
            // SAMME learner weight
            let alpha = ((1.0 - err) / err).ln() + (n_classes as f64 - 1.0).ln();
            // reweight + normalize (streaming weight pass)
            rec.load(r_w.f64(0), (n * 8) as u32);
            rec.store(r_w.f64(0), (n * 8) as u32);
            rec.compute(0, (3 * n) as u32);
            let mut z = 0.0;
            for i in 0..n {
                if miss[i] {
                    weights[i] *= alpha.exp();
                }
                z += weights[i];
            }
            weights.iter_mut().for_each(|w| *w /= z);
            learners.push((tree, alpha));
            if err < 1e-9 {
                break;
            }
        }

        // final weighted vote on the training set (untraced: quality only)
        let mut correct = 0usize;
        let mut score = vec![0.0; n_classes];
        for i in 0..n {
            score.iter_mut().for_each(|s| *s = 0.0);
            for (t, a) in &learners {
                score[t.predict(ds.x.row(i))] += a;
            }
            let pred = crate::util::stats::argmax(&score).unwrap_or(0);
            if pred == ds.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        RunResult {
            quality: acc,
            detail: format!("train accuracy {acc:.4}, {} rounds", learners.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn boosting_beats_a_single_stump() {
        let ds = Adaboost::default().make_dataset(800, 8, 47);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let stump = Adaboost { rounds_per_iter: 1, weak_depth: 1 }
            .run(&ds, &RunContext { iterations: 1, ..Default::default() }, &mut rec);
        let boosted = Adaboost { rounds_per_iter: 12, weak_depth: 1 }
            .run(&ds, &RunContext { iterations: 1, ..Default::default() }, &mut rec);
        assert!(
            boosted.quality >= stump.quality,
            "{} vs {}",
            stump.quality,
            boosted.quality
        );
        assert!(boosted.quality > 0.7, "{}", boosted.quality);
    }

    #[test]
    fn accuracy_reasonable_on_noisy_labels() {
        let w = Adaboost::default();
        let ds = w.make_dataset(600, 10, 48);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext::default(), &mut rec);
        // 10% label flips cap the achievable train accuracy near 0.9
        assert!(res.quality > 0.75, "{} ({})", res.quality, res.detail);
    }

    #[test]
    fn weights_stay_normalized_implicitly() {
        // smoke: repeated runs deterministic and finite
        let w = Adaboost::default();
        let ds = w.make_dataset(200, 5, 49);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let a = w.run(&ds, &RunContext::default(), &mut rec);
        assert!(a.quality.is_finite());
        let b = w.run(&ds, &RunContext::default(), &mut rec);
        assert_eq!(a.quality, b.quality);
    }
}
