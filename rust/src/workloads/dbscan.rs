//! DBSCAN density clustering [Est+96] — neighbour-based workload.
//!
//! Classic DBSCAN over tree-accelerated region queries (scikit-learn uses
//! a K-D tree, mlpack a binary-space tree). The outer point loop honours
//! [`RunContext::visit_order`]; every region query walks the tree and
//! scans leaves through the index array (`A[B[i]]`), making DBSCAN the
//! most DRAM-bound workload in the paper's Table III (48.5%). Quality
//! metric: fraction of points assigned to a cluster (non-noise), with
//! the cluster count in the detail string.

use super::kdtree::TraceTree;
use super::knn::tree_kind;
use super::{Category, RunContext, RunResult, Workload};
use crate::data::{make_blobs, Dataset};
use crate::trace::{AddressSpace, Recorder};

const SITE_CORE: u32 = 1;
const SITE_UNVISITED: u32 = 2;

/// Cluster label for noise points.
pub const NOISE: i32 = -1;

/// DBSCAN workload.
pub struct Dbscan {
    /// Squared neighbourhood radius (scaled to the blob geometry in
    /// [`Dbscan::eps_sq_for`] when left at 0.0).
    pub eps_sq: f64,
    pub min_pts: usize,
    pub leaf_size: usize,
    pub lookahead: usize,
}

impl Default for Dbscan {
    fn default() -> Self {
        Self { eps_sq: 0.0, min_pts: 5, leaf_size: 30, lookahead: 8 }
    }
}

impl Dbscan {
    /// Default eps²: tuned so blob clusters (std 1.0) connect — points of
    /// the same blob sit at E||a-b||² = 2·m, so a radius of 1.5·m splits
    /// intra-blob (connected through dense cores) from inter-blob
    /// (centers are ~tens apart in each dim).
    fn eps_sq_for(&self, features: usize) -> f64 {
        if self.eps_sq > 0.0 {
            self.eps_sq
        } else {
            1.5 * features as f64
        }
    }
}

/// Run DBSCAN, returning per-point labels (`NOISE` or cluster id).
pub fn dbscan_labels(
    ds: &Dataset,
    eps_sq: f64,
    min_pts: usize,
    leaf_size: usize,
    lookahead: usize,
    ctx: &RunContext,
    rec: &mut Recorder,
) -> Vec<i32> {
    let n = ds.n_samples();
    let mut space = AddressSpace::new();
    let r_x = space.alloc_matrix("dbscan.x", n, ds.n_features());
    let r_labels = space.alloc("dbscan.labels", n as u64 * 4);
    let tree = TraceTree::build(&ds.x, r_x, &mut space, tree_kind(ctx.profile), leaf_size, rec);

    let default_order: Vec<usize> = (0..n).collect();
    let order = ctx.visit_order.as_deref().unwrap_or(&default_order);
    assert_eq!(order.len(), n, "visit order must cover all samples");

    let mut labels = vec![NOISE - 1; n]; // -2 = unvisited
    let mut cluster = 0i32;
    let mut neigh = Vec::new();
    let mut frontier = Vec::new();
    for &p in order {
        rec.load_for_branch(r_labels.elem(p, 4), 4);
        if !rec.cmp_branch(SITE_UNVISITED, labels[p] == NOISE - 1) {
            continue;
        }
        rec.load_row(r_x, p, ds.n_features());
        neigh.clear();
        tree.radius(&ds.x, ds.x.row(p), eps_sq, rec, &mut neigh, lookahead);
        if !rec.cmp_branch(SITE_CORE, neigh.len() >= min_pts) {
            labels[p] = NOISE;
            rec.store(r_labels.elem(p, 4), 4);
            continue;
        }
        // new cluster: BFS expansion
        labels[p] = cluster;
        rec.store(r_labels.elem(p, 4), 4);
        frontier.clear();
        frontier.extend(neigh.iter().copied());
        while let Some(q) = frontier.pop() {
            let q = q as usize;
            rec.load_for_branch(r_labels.elem(q, 4), 4);
            let unvisited = labels[q] == NOISE - 1;
            let was_noise = labels[q] == NOISE;
            if !rec.cmp_branch(SITE_UNVISITED, unvisited || was_noise) {
                continue;
            }
            labels[q] = cluster;
            rec.store(r_labels.elem(q, 4), 4);
            if was_noise {
                continue; // border point: do not expand
            }
            rec.load_row(r_x, q, ds.n_features());
            neigh.clear();
            tree.radius(&ds.x, ds.x.row(q), eps_sq, rec, &mut neigh, lookahead);
            if rec.cmp_branch(SITE_CORE, neigh.len() >= min_pts) {
                frontier.extend(neigh.iter().copied());
            }
        }
        cluster += 1;
    }
    labels
}

impl Workload for Dbscan {
    fn name(&self) -> &'static str {
        "DBSCAN"
    }

    fn category(&self) -> Category {
        Category::NeighbourBased
    }

    fn supports_visit_order(&self) -> bool {
        true
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        make_blobs(rows, features, 4, 1.0, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let eps_sq = self.eps_sq_for(ds.n_features());
        let labels =
            dbscan_labels(ds, eps_sq, self.min_pts, self.leaf_size, self.lookahead, ctx, rec);
        let clustered = labels.iter().filter(|&&l| l >= 0).count();
        let n_clusters = labels.iter().filter(|&&l| l >= 0).max().map(|&m| m + 1).unwrap_or(0);
        let frac = clustered as f64 / labels.len() as f64;
        RunResult {
            quality: frac,
            detail: format!("{n_clusters} clusters, {frac:.3} clustered"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{InstructionMix, NullSink};

    #[test]
    fn finds_the_blobs() {
        let w = Dbscan::default();
        let ds = w.make_dataset(600, 6, 33);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext::default(), &mut rec);
        assert!(res.quality > 0.9, "clustered fraction {} ({})", res.quality, res.detail);
        assert!(res.detail.starts_with("4 clusters"), "{}", res.detail);
    }

    #[test]
    fn labels_agree_with_ground_truth_blobs() {
        let w = Dbscan::default();
        let ds = w.make_dataset(500, 5, 34);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let labels = dbscan_labels(
            &ds,
            1.5 * 5.0,
            5,
            30,
            0,
            &RunContext::default(),
            &mut rec,
        );
        // same-blob pairs should mostly share a cluster label
        let mut same_ok = 0;
        let mut same_tot = 0;
        for i in 0..200 {
            for j in (i + 1)..200 {
                if ds.y[i] == ds.y[j] && labels[i] >= 0 && labels[j] >= 0 {
                    same_tot += 1;
                    if labels[i] == labels[j] {
                        same_ok += 1;
                    }
                }
            }
        }
        assert!(same_ok as f64 / same_tot.max(1) as f64 > 0.95);
    }

    #[test]
    fn tiny_eps_marks_everything_noise() {
        let w = Dbscan { eps_sq: 1e-9, ..Default::default() };
        let ds = w.make_dataset(200, 5, 35);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext::default(), &mut rec);
        assert_eq!(res.quality, 0.0, "{}", res.detail);
    }

    #[test]
    fn visit_order_preserves_clustering_structure() {
        let w = Dbscan::default();
        let ds = w.make_dataset(300, 5, 36);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let a = w.run(&ds, &RunContext::default(), &mut rec);
        let rev: Vec<usize> = (0..300).rev().collect();
        let b = w.run(
            &ds,
            &RunContext { visit_order: Some(rev), ..Default::default() },
            &mut rec,
        );
        // cluster ids are order-dependent but count and coverage are not
        assert_eq!(a.detail.split(' ').next(), b.detail.split(' ').next());
        assert!((a.quality - b.quality).abs() < 0.02);
    }

    #[test]
    fn branchy_irregular_trace() {
        let w = Dbscan::default();
        let ds = w.make_dataset(400, 5, 37);
        let mut mix = InstructionMix::default();
        {
            let mut rec = Recorder::new(&mut mix, 0);
            w.run(&ds, &RunContext::default(), &mut rec);
        }
        assert!(mix.branch_fraction() > 0.10, "{}", mix.branch_fraction());
    }
}
