//! Decision-tree induction (CART [Qui86]) — tree-based workload, plus the
//! shared trainer reused by Random Forests and Adaboost.
//!
//! The trainer mirrors scikit-learn's depth-first `Splitter`: each node
//! owns a range of a **sample-index array**; split search scans the range
//! through `X[idx[i]][feature]` (the paper's Section IV observation: "in
//! these workloads the index array B[i] is used to group samples into
//! different nodes of the decision tree") and partitioning swaps indices
//! in place. Split comparisons branch on effectively-random data — the
//! source of the tree category's dominant bad-speculation bound
//! (Figs. 3–4: 22–28% bad-spec, mispredict-heavy). Quality: train
//! accuracy.

use super::{Category, RunContext, RunResult, Workload};
use crate::data::{make_classification, Dataset};
use crate::trace::{AddressSpace, Recorder, Region};
use crate::util::{Matrix, Pcg64};

const SITE_SCAN_LE: u32 = 1;
const SITE_PART: u32 = 2;
const SITE_TRAVERSE: u32 = 3;

/// CART hyper-parameters.
#[derive(Debug, Clone)]
pub struct CartParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Features examined per node (None = all; forests use sqrt(m)).
    pub max_features: Option<usize>,
    /// Candidate thresholds per feature.
    pub n_thresholds: usize,
}

impl Default for CartParams {
    fn default() -> Self {
        Self { max_depth: 10, min_samples_leaf: 10, max_features: None, n_thresholds: 8 }
    }
}

/// A fitted CART tree.
pub struct CartTree {
    nodes: Vec<CNode>,
    pub n_classes: usize,
}

enum CNode {
    Leaf { label: usize },
    Split { feat: usize, thresh: f64, left: usize, right: usize },
}

/// Modelled regions used by a CART fit/predict pass.
pub struct CartRegions {
    pub r_x: Region,
    pub r_y: Region,
    pub r_idx: Region,
    pub r_nodes: Region,
}

impl CartRegions {
    pub fn alloc(space: &mut AddressSpace, n: usize, m: usize, tag: &str) -> Self {
        Self {
            r_x: space.alloc_matrix(&format!("{tag}.x"), n, m),
            r_y: space.alloc(&format!("{tag}.y"), n as u64 * 4),
            r_idx: space.alloc(&format!("{tag}.idx"), n as u64 * 4),
            r_nodes: space.alloc(&format!("{tag}.nodes"), 4096 * 32),
        }
    }
}

/// Weighted Gini impurity of a class-count vector.
fn gini(counts: &[f64]) -> f64 {
    let total: f64 = counts.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    1.0 - counts.iter().map(|c| (c / total) * (c / total)).sum::<f64>()
}

/// Fit a CART tree on the samples listed in `idx` (modified in place —
/// the index-array grouping the paper describes). `weights` enables
/// Adaboost's reweighted rounds.
#[allow(clippy::too_many_arguments)]
pub fn fit_cart(
    x: &Matrix,
    y: &[f64],
    n_classes: usize,
    idx: &mut [u32],
    weights: Option<&[f64]>,
    params: &CartParams,
    regions: &CartRegions,
    rec: &mut Recorder,
    rng: &mut Pcg64,
    profile_overhead: u32,
) -> CartTree {
    let mut nodes = Vec::new();
    let n = idx.len();
    fit_rec(
        x, y, n_classes, idx, 0, n, weights, params, regions, rec, rng, &mut nodes, 0,
        profile_overhead,
    );
    CartTree { nodes, n_classes }
}

#[allow(clippy::too_many_arguments)]
fn fit_rec(
    x: &Matrix,
    y: &[f64],
    n_classes: usize,
    idx: &mut [u32],
    lo: usize,
    hi: usize,
    weights: Option<&[f64]>,
    params: &CartParams,
    regions: &CartRegions,
    rec: &mut Recorder,
    rng: &mut Pcg64,
    nodes: &mut Vec<CNode>,
    depth: usize,
    overhead: u32,
) -> usize {
    let me = nodes.len();
    let m = x.cols();
    let wt = |i: u32| weights.map_or(1.0, |w| w[i as usize]);

    // class histogram of the node (one indirect scan)
    let mut counts = vec![0.0; n_classes];
    for i in lo..hi {
        rec.load(regions.r_idx.elem(i, 4), 4);
        rec.load(regions.r_y.elem(idx[i] as usize, 4), 4);
        let _ = overhead;
        rec.profile_tick();
        counts[y[idx[i] as usize] as usize] += wt(idx[i]);
    }
    let node_gini = gini(&counts);
    let majority = crate::util::stats::argmax(&counts).unwrap_or(0);

    if depth >= params.max_depth
        || hi - lo <= params.min_samples_leaf
        || node_gini < 1e-9
    {
        nodes.push(CNode::Leaf { label: majority });
        return me;
    }

    // feature subset (forests) or all features (plain CART)
    let n_feat = params.max_features.unwrap_or(m).min(m);
    let feats = if n_feat == m {
        (0..m).collect::<Vec<_>>()
    } else {
        rng.sample_indices(m, n_feat)
    };

    // candidate thresholds from a value subsample
    let total_w: f64 = counts.iter().sum();
    let mut best = (f64::INFINITY, 0usize, 0.0f64); // (weighted child gini, feat, thresh)
    let mut left_counts = vec![0.0; n_classes];
    for &f in &feats {
        // threshold candidates: quantiles of ~64 sampled values
        let mut sample: Vec<f64> = (0..64.min(hi - lo))
            .map(|_| x[(idx[lo + rng.index(hi - lo)] as usize, f)])
            .collect();
        sample.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let mut cand = Vec::with_capacity(params.n_thresholds);
        for t in 1..=params.n_thresholds {
            let q = sample[(t * (sample.len() - 1)) / (params.n_thresholds + 1)];
            if cand.last() != Some(&q) {
                cand.push(q);
            }
        }
        // per-candidate class counts in one indirect scan of the node
        let mut left = vec![vec![0.0; n_classes]; cand.len()];
        for i in lo..hi {
            if i + 8 < hi {
                // _mm_prefetch(&X[idx[i+8]][f]) — Section V-C insertion
                rec.prefetch(regions.r_x.f64(idx[i + 8] as usize * m + f), 8);
            }
            let s = idx[i] as usize;
            rec.load(regions.r_idx.elem(i, 4), 4);
            rec.load_for_branch(regions.r_x.f64(s * m + f), 8);
            rec.load(regions.r_y.elem(s, 4), 4);
            rec.compute(overhead, 1);
            let v = x[(s, f)];
            let cls = y[s] as usize;
            let w = wt(idx[i]);
            // one data-dependent branch per element (against the median
            // candidate — how the compiled scan short-circuits); the
            // other candidate comparisons are branchless accumulations
            rec.profile_tick();
            // compiled scans short-circuit against the 75th-percentile
            // candidate: a biased (not 50/50) data-dependent branch
            rec.fcmp_branch(SITE_SCAN_LE, v <= cand[3 * cand.len() / 4]);
            // unrolled candidate-accumulation loop back-edges
            rec.loop_branch(SITE_SCAN_LE + 8, (cand.len() / 4).max(2) as u32);
            rec.compute(0, cand.len() as u32);
            for (ci, &c) in cand.iter().enumerate() {
                if v <= c {
                    left[ci][cls] += w;
                }
            }
        }
        for (ci, lc) in left.iter().enumerate() {
            let lw: f64 = lc.iter().sum();
            let rw = total_w - lw;
            if lw <= 0.0 || rw <= 0.0 {
                continue;
            }
            left_counts.clone_from(lc);
            let rc: Vec<f64> = counts.iter().zip(lc).map(|(a, b)| a - b).collect();
            let score = (lw * gini(&left_counts) + rw * gini(&rc)) / total_w;
            if score < best.0 {
                best = (score, f, cand[ci]);
            }
        }
    }

    if best.0 >= node_gini - 1e-12 {
        nodes.push(CNode::Leaf { label: majority });
        return me;
    }
    let (_, f, thresh) = best;

    // in-place partition of the index range (Hoare-style)
    let mut store = lo;
    for i in lo..hi {
        if i + 8 < hi {
            rec.prefetch(regions.r_x.f64(idx[i + 8] as usize * m + f), 8);
        }
        let s = idx[i] as usize;
        rec.load(regions.r_idx.elem(i, 4), 4);
        rec.load_for_branch(regions.r_x.f64(s * m + f), 8);
        if rec.fcmp_branch(SITE_PART, x[(s, f)] <= thresh) {
            idx.swap(i, store);
            rec.store(regions.r_idx.elem(store, 4), 4);
            rec.store(regions.r_idx.elem(i, 4), 4);
            store += 1;
        }
    }
    let mid = store;
    if mid == lo || mid == hi {
        nodes.push(CNode::Leaf { label: majority });
        return me;
    }
    nodes.push(CNode::Leaf { label: usize::MAX }); // placeholder
    let left = fit_rec(
        x, y, n_classes, idx, lo, mid, weights, params, regions, rec, rng, nodes,
        depth + 1, overhead,
    );
    let right = fit_rec(
        x, y, n_classes, idx, mid, hi, weights, params, regions, rec, rng, nodes,
        depth + 1, overhead,
    );
    nodes[me] = CNode::Split { feat: f, thresh, left, right };
    me
}

impl CartTree {
    /// Untraced prediction (tests / quality computation).
    pub fn predict(&self, row: &[f64]) -> usize {
        let mut node = 0;
        loop {
            match &self.nodes[node] {
                CNode::Leaf { label } => return *label,
                CNode::Split { feat, thresh, left, right } => {
                    node = if row[*feat] <= *thresh { *left } else { *right };
                }
            }
        }
    }

    /// Traced prediction: node loads feed the traversal branches.
    pub fn predict_traced(
        &self,
        x: &Matrix,
        row_i: usize,
        regions: &CartRegions,
        rec: &mut Recorder,
    ) -> usize {
        let m = x.cols();
        let mut node = 0;
        loop {
            rec.load_for_branch(regions.r_nodes.at((node as u64 * 32) % regions.r_nodes.len()), 32);
            match &self.nodes[node] {
                CNode::Leaf { label } => return *label,
                CNode::Split { feat, thresh, left, right } => {
                    rec.load_for_branch(regions.r_x.f64(row_i * m + feat), 8);
                    let go_left = x[(row_i, *feat)] <= *thresh;
                    rec.fcmp_branch(SITE_TRAVERSE, go_left);
                    node = if go_left { *left } else { *right };
                }
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn depth_hint(&self) -> usize {
        // nodes were pushed depth-first; a rough bound suffices for tests
        (self.nodes.len() as f64).log2().ceil() as usize
    }
}

/// The Decision Tree workload.
pub struct DecisionTree {
    pub params: CartParams,
}

impl Default for DecisionTree {
    fn default() -> Self {
        Self { params: CartParams::default() }
    }
}

impl Workload for DecisionTree {
    fn name(&self) -> &'static str {
        "Decision Tree"
    }

    fn category(&self) -> Category {
        Category::TreeBased
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        make_classification(rows, features, (features * 3 / 4).max(2), 4, 0.05, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let n = ds.n_samples();
        let mut space = AddressSpace::new();
        let regions = CartRegions::alloc(&mut space, n, ds.n_features(), "dtree");
        let mut rng = Pcg64::new(ctx.seed);
        let mut idx: Vec<u32> = (0..n as u32).collect();
        let tree = fit_cart(
            &ds.x,
            &ds.y,
            ds.n_classes.max(2),
            &mut idx,
            None,
            &self.params,
            &regions,
            rec,
            &mut rng,
            ctx.profile.loop_overhead_uops(),
        );
        // traced prediction pass (the paper's trained-model usage phase)
        let mut correct = 0usize;
        for i in 0..n {
            let pred = tree.predict_traced(&ds.x, i, &regions, rec);
            if pred == ds.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        RunResult {
            quality: acc,
            detail: format!("train accuracy {acc:.4}, {} nodes", tree.n_nodes()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{InstructionMix, NullSink};

    #[test]
    fn tree_fits_separable_data() {
        let w = DecisionTree::default();
        let ds = w.make_dataset(1000, 10, 41);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext::default(), &mut rec);
        assert!(res.quality > 0.8, "accuracy {} ({})", res.quality, res.detail);
    }

    #[test]
    fn deeper_trees_fit_train_data_better() {
        let ds = DecisionTree::default().make_dataset(800, 8, 42);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let shallow = DecisionTree {
            params: CartParams { max_depth: 2, ..Default::default() },
        }
        .run(&ds, &RunContext::default(), &mut rec);
        let deep = DecisionTree {
            params: CartParams { max_depth: 12, ..Default::default() },
        }
        .run(&ds, &RunContext::default(), &mut rec);
        assert!(deep.quality >= shallow.quality, "{} vs {}", shallow.quality, deep.quality);
    }

    #[test]
    fn gini_extremes() {
        assert_eq!(gini(&[10.0, 0.0]), 0.0);
        assert!((gini(&[5.0, 5.0]) - 0.5).abs() < 1e-12);
        assert_eq!(gini(&[]), 0.0);
        assert_eq!(gini(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn pure_node_becomes_leaf() {
        let mut x = Matrix::zeros(20, 2);
        let y = vec![1.0; 20];
        for i in 0..20 {
            x[(i, 0)] = i as f64;
        }
        let mut space = AddressSpace::new();
        let regions = CartRegions::alloc(&mut space, 20, 2, "t");
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let mut rng = Pcg64::new(1);
        let mut idx: Vec<u32> = (0..20).collect();
        let t = fit_cart(
            &x, &y, 2, &mut idx, None, &CartParams::default(), &regions, &mut rec,
            &mut rng, 1,
        );
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.predict(&[0.0, 0.0]), 1);
    }

    #[test]
    fn weights_bias_the_majority() {
        // two overlapping classes; upweighting class 1 samples must make
        // a depth-0-ish tree prefer label 1
        let mut x = Matrix::zeros(10, 1);
        let mut y = vec![0.0; 10];
        for i in 0..10 {
            x[(i, 0)] = (i % 2) as f64; // useless feature
            y[i] = (i < 4) as usize as f64; // 4 ones, 6 zeros
        }
        let mut w = vec![1.0; 10];
        for (i, wi) in w.iter_mut().enumerate() {
            if y[i] == 1.0 {
                *wi = 10.0;
            }
        }
        let mut space = AddressSpace::new();
        let regions = CartRegions::alloc(&mut space, 10, 1, "t");
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let mut rng = Pcg64::new(2);
        let mut idx: Vec<u32> = (0..10).collect();
        let params = CartParams { max_depth: 0, ..Default::default() };
        let t = fit_cart(&x, &y, 2, &mut idx, Some(&w), &params, &regions, &mut rec, &mut rng, 1);
        assert_eq!(t.predict(&[0.0]), 1);
    }

    #[test]
    fn branch_heavy_poorly_predicted_trace() {
        let w = DecisionTree::default();
        let ds = w.make_dataset(600, 8, 43);
        let mut mix = InstructionMix::default();
        {
            let mut rec = Recorder::new(&mut mix, 0);
            w.run(&ds, &RunContext::default(), &mut rec);
        }
        // paper Fig. 5: tree workloads ~20-25% branches
        assert!(mix.branch_fraction() > 0.12, "{}", mix.branch_fraction());
    }
}
