//! Gaussian Mixture Model [SM92] — neighbour-based workload.
//!
//! Expectation–Maximization with diagonal covariances (scikit-learn's
//! `GaussianMixture(covariance_type="diag")`, mlpack's `GMM`): each EM
//! iteration streams every sample, evaluates k log-densities (FP-heavy),
//! normalizes responsibilities, and accumulates sufficient statistics.
//! Honours [`RunContext::visit_order`]. Quality metric: mean per-sample
//! log-likelihood (increases monotonically under EM).

use super::{Category, RunContext, RunResult, Workload};
use crate::data::{make_blobs, Dataset};
use crate::trace::{AddressSpace, Recorder};
use crate::util::stats::logsumexp;
use crate::util::Pcg64;

const LOG_2PI: f64 = 1.8378770664093453;

/// GMM workload.
pub struct Gmm {
    pub k: usize,
    /// Variance floor for numerical stability.
    pub reg: f64,
}

impl Default for Gmm {
    fn default() -> Self {
        Self { k: 5, reg: 1e-6 }
    }
}

impl Workload for Gmm {
    fn name(&self) -> &'static str {
        "GMM"
    }

    fn category(&self) -> Category {
        Category::NeighbourBased
    }

    fn supports_visit_order(&self) -> bool {
        true
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        make_blobs(rows, features, self.k, 1.2, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let (n, m) = (ds.n_samples(), ds.n_features());
        let k = self.k.min(n);
        let mut space = AddressSpace::new();
        let r_x = space.alloc_matrix("gmm.x", n, m);
        let r_params = space.alloc_matrix("gmm.params", k, 2 * m + 1);
        let r_resp = space.alloc_matrix("gmm.resp", n, k);
        let overhead = ctx.profile.loop_overhead_uops();

        // init means at random rows, unit variances, uniform weights
        let mut rng = Pcg64::new(ctx.seed);
        let init = rng.sample_indices(n, k);
        let mut means: Vec<Vec<f64>> = init.iter().map(|&i| ds.x.row(i).to_vec()).collect();
        let mut vars: Vec<Vec<f64>> = vec![vec![1.0; m]; k];
        let mut weights = vec![1.0 / k as f64; k];

        let default_order: Vec<usize> = (0..n).collect();
        let order = ctx.visit_order.as_deref().unwrap_or(&default_order);
        assert_eq!(order.len(), n, "visit order must cover all samples");

        let mut mean_ll = f64::NEG_INFINITY;
        let mut logp = vec![0.0; k];
        for _iter in 0..ctx.iterations.max(1) {
            let mut w_acc = vec![0.0; k];
            let mut mu_acc = vec![vec![0.0; m]; k];
            let mut var_acc = vec![vec![0.0; m]; k];
            let mut ll_sum = 0.0;
            for &i in order {
                rec.load_row(r_x, i, m);
                // parameter block is small and cache-resident
                rec.load(r_params.at(0), (k * (2 * m + 1) * 8) as u32);
                let _ = overhead;
                rec.profile_tick();
                rec.compute(2, (k * (4 * m + 6)) as u32);
                // sklearn materializes the (n, k) responsibility matrix
                rec.store(r_resp.at((i * k * 8) as u64), (k * 8) as u32);
                let row = ds.x.row(i);
                for c in 0..k {
                    rec.loop_branch(1, (m / 2).max(1) as u32);
                    let mut lp = weights[c].max(1e-300).ln();
                    for j in 0..m {
                        let v = vars[c][j];
                        let d = row[j] - means[c][j];
                        lp += -0.5 * (LOG_2PI + v.ln() + d * d / v);
                    }
                    logp[c] = lp;
                }
                let z = logsumexp(&logp);
                ll_sum += z;
                for c in 0..k {
                    let resp = (logp[c] - z).exp();
                    w_acc[c] += resp;
                    for j in 0..m {
                        mu_acc[c][j] += resp * row[j];
                        var_acc[c][j] += resp * row[j] * row[j];
                    }
                }
                rec.compute(0, (3 * k * m) as u32);
            }
            // M-step (in-cache parameter update)
            rec.store(r_params.at(0), (k * (2 * m + 1) * 8) as u32);
            rec.compute(0, (3 * k * m) as u32);
            for c in 0..k {
                let wc = w_acc[c].max(1e-12);
                weights[c] = wc / n as f64;
                for j in 0..m {
                    means[c][j] = mu_acc[c][j] / wc;
                    vars[c][j] =
                        (var_acc[c][j] / wc - means[c][j] * means[c][j]).max(self.reg);
                }
            }
            mean_ll = ll_sum / n as f64;
        }
        RunResult {
            quality: mean_ll,
            detail: format!("mean log-lik {mean_ll:.4}, k={k}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    fn run_gmm(iters: usize, seed: u64) -> RunResult {
        let w = Gmm { k: 3, reg: 1e-6 };
        let ds = w.make_dataset(600, 6, seed);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        w.run(&ds, &RunContext { iterations: iters, ..Default::default() }, &mut rec)
    }

    #[test]
    fn loglik_improves_with_em() {
        let r1 = run_gmm(1, 24);
        let r10 = run_gmm(10, 24);
        assert!(r10.quality > r1.quality, "{} -> {}", r1.quality, r10.quality);
    }

    #[test]
    fn fits_blobs_reasonably() {
        let r = run_gmm(15, 25);
        // 6 dims of unit-ish variance: per-dim NLL about -(0.5 ln 2πe) ≈ -1.42
        assert!(r.quality > -13.0, "mean ll {}", r.quality);
    }

    #[test]
    fn visit_order_invariant() {
        let w = Gmm { k: 3, reg: 1e-6 };
        let ds = w.make_dataset(300, 5, 26);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let a = w.run(&ds, &RunContext { iterations: 4, ..Default::default() }, &mut rec);
        let rev: Vec<usize> = (0..300).rev().collect();
        let b = w.run(
            &ds,
            &RunContext { iterations: 4, visit_order: Some(rev), ..Default::default() },
            &mut rec,
        );
        assert!((a.quality - b.quality).abs() < 1e-6, "{} vs {}", a.quality, b.quality);
    }

    #[test]
    fn fp_heavy_low_branch_trace() {
        let w = Gmm::default();
        let ds = w.make_dataset(300, 6, 27);
        let mut mix = crate::trace::InstructionMix::default();
        {
            let mut rec = Recorder::new(&mut mix, 0);
            w.run(&ds, &RunContext { iterations: 2, ..Default::default() }, &mut rec);
        }
        assert!(mix.fp_ops > 10 * mix.branches, "GMM is FP-dominated");
    }
}
