//! Spatial-tree substrate for the neighbour-based workloads.
//!
//! scikit-learn's `neighbors` module stores neighbourhood information in a
//! **K-D tree** [Ben75]; mlpack uses a **binary space tree** [Tót05]. Both
//! keep a permuted *index array* whose entries point at dataset rows — the
//! `A[B[i]]` indirect access pattern the paper identifies as the
//! neighbour-based workloads' main bottleneck (Section IV, Fig. 11).
//!
//! The tree here is both *real* (returns exact nearest neighbours /
//! radius sets, verified against brute force in tests) and *instrumented*
//! (emits node loads, split-comparison branches and indirect row loads,
//! plus the optional software-prefetch events of Section V-C).

use crate::trace::{AddressSpace, Recorder, Region};
use crate::util::stats::sqdist;
use crate::util::Matrix;

/// Splitting rule: K-D median split (sklearn) or widest-dimension
/// midpoint binary-space split (mlpack).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TreeKind {
    KdTree,
    BallTree,
}

/// Tree node: an internal split or a leaf range of the index array.
#[derive(Debug, Clone)]
enum Node {
    Split { dim: usize, thresh: f64, left: usize, right: usize },
    Leaf { start: usize, end: usize },
}

// Branch-site ids within this substrate's namespace.
const SITE_DESCEND: u32 = 1;
const SITE_LEAF_BETTER: u32 = 2;
const SITE_PRUNE: u32 = 3;
const SITE_RADIUS_IN: u32 = 4;
const SITE_BUILD_PART: u32 = 5;
const SITE_DIST_LOOP: u32 = 6;

/// Bytes of one packed node record in the modelled layout
/// (dim + threshold + children + bounds ≈ 48 B).
const NODE_BYTES: u64 = 48;

/// An instrumented spatial tree over the rows of a dataset matrix.
pub struct TraceTree {
    nodes: Vec<Node>,
    /// Permuted row indices — the paper's Fig. 11 "indices of the dataset
    /// rows of the samples lying in a certain geometric partition".
    idx: Vec<u32>,
    kind: TreeKind,
    /// Modelled regions: node array, index array, data matrix.
    pub r_nodes: Region,
    pub r_idx: Region,
    pub r_data: Region,
    cols: usize,
}

impl TraceTree {
    /// Build over `data` (whose modelled region is `r_data`), emitting the
    /// build trace into `rec`. `space` allocates the tree's own arrays.
    pub fn build(
        data: &Matrix,
        r_data: Region,
        space: &mut AddressSpace,
        kind: TreeKind,
        leaf_size: usize,
        rec: &mut Recorder,
    ) -> Self {
        let n = data.rows();
        assert!(n > 0, "cannot build a tree over zero rows");
        let leaf_size = leaf_size.max(2);
        let mut idx: Vec<u32> = (0..n as u32).collect();
        let mut nodes = Vec::new();
        let r_idx = space.alloc("tree.idx", n as u64 * 4);
        build_rec(data, r_data, r_idx, kind, leaf_size, &mut idx, 0, n, &mut nodes, rec);
        let r_nodes = space.alloc("tree.nodes", nodes.len() as u64 * NODE_BYTES);
        Self { nodes, idx, kind, r_nodes, r_idx, r_data, cols: data.cols() }
    }

    /// Number of tree nodes.
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Which splitting rule built this tree.
    pub fn kind(&self) -> TreeKind {
        self.kind
    }

    /// The permuted index array (leaf order = spatial order; used by the
    /// first-touch inspector).
    pub fn leaf_order(&self) -> &[u32] {
        &self.idx
    }

    /// k nearest neighbours of `q`: (sqdist, row) pairs sorted ascending.
    /// `lookahead > 0` enables software prefetching of the dataset row
    /// `lookahead` leaf entries ahead (Section V-C's optimization).
    pub fn knn(
        &self,
        data: &Matrix,
        q: &[f64],
        k: usize,
        rec: &mut Recorder,
        lookahead: usize,
    ) -> Vec<(f64, u32)> {
        assert!(k > 0);
        let mut best: Vec<(f64, u32)> = Vec::with_capacity(k + 1);
        self.knn_rec(0, data, q, k, &mut best, rec, lookahead);
        best
    }

    #[allow(clippy::too_many_arguments)]
    fn knn_rec(
        &self,
        node: usize,
        data: &Matrix,
        q: &[f64],
        k: usize,
        best: &mut Vec<(f64, u32)>,
        rec: &mut Recorder,
        lookahead: usize,
    ) {
        // the node record is loaded and its fields feed the branches below
        rec.load_for_branch(self.r_nodes.at(node as u64 * NODE_BYTES), NODE_BYTES as u32);
        match &self.nodes[node] {
            Node::Leaf { start, end } => {
                self.scan_leaf(*start, *end, data, q, k, best, rec, lookahead);
            }
            Node::Split { dim, thresh, left, right } => {
                let go_left = q[*dim] <= *thresh;
                rec.fcmp_branch(SITE_DESCEND, go_left);
                let (near, far) = if go_left { (*left, *right) } else { (*right, *left) };
                self.knn_rec(near, data, q, k, best, rec, lookahead);
                // visit the far side only if the splitting plane is closer
                // than the current worst neighbour (K-D pruning rule; the
                // ball/BSP rule differs only in the bound it computes)
                let plane = q[*dim] - *thresh;
                let need_far = best.len() < k || plane * plane < best.last().unwrap().0;
                rec.compute(0, 2);
                if rec.fcmp_branch(SITE_PRUNE, need_far) {
                    self.knn_rec(far, data, q, k, best, rec, lookahead);
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn scan_leaf(
        &self,
        start: usize,
        end: usize,
        data: &Matrix,
        q: &[f64],
        k: usize,
        best: &mut Vec<(f64, u32)>,
        rec: &mut Recorder,
        lookahead: usize,
    ) {
        let cols = self.cols;
        for i in start..end {
            if lookahead > 0 && i + lookahead < end {
                // _mm_prefetch(&X[idx[i+d]][0]) — index is in cache (the
                // idx array streams), the target row usually is not
                let ahead = self.idx[i + lookahead] as usize;
                rec.prefetch(self.r_data.f64(ahead * cols), (cols * 8) as u32);
            }
            let row = self.idx[i] as usize;
            rec.load_indirect_row(self.r_idx, i, self.r_data, row, cols);
            rec.profile_tick();
            rec.compute(2, (2 * cols) as u32);
            rec.loop_branch(SITE_DIST_LOOP, (cols / 2).max(1) as u32);
            let d = sqdist(q, data.row(row));
            let better = best.len() < k || d < best.last().unwrap().0;
            if rec.fcmp_branch(SITE_LEAF_BETTER, better) {
                let pos = best.partition_point(|(bd, _)| *bd < d);
                best.insert(pos, (d, row as u32));
                if best.len() > k {
                    best.pop();
                }
            }
        }
    }

    /// All rows within squared distance `eps_sq` of `q`, appended to `out`.
    pub fn radius(
        &self,
        data: &Matrix,
        q: &[f64],
        eps_sq: f64,
        rec: &mut Recorder,
        out: &mut Vec<u32>,
        lookahead: usize,
    ) {
        self.radius_rec(0, data, q, eps_sq, rec, out, lookahead);
    }

    #[allow(clippy::too_many_arguments)]
    fn radius_rec(
        &self,
        node: usize,
        data: &Matrix,
        q: &[f64],
        eps_sq: f64,
        rec: &mut Recorder,
        out: &mut Vec<u32>,
        lookahead: usize,
    ) {
        rec.load_for_branch(self.r_nodes.at(node as u64 * NODE_BYTES), NODE_BYTES as u32);
        match &self.nodes[node] {
            Node::Leaf { start, end } => {
                let cols = self.cols;
                for i in *start..*end {
                    if lookahead > 0 && i + lookahead < *end {
                        let ahead = self.idx[i + lookahead] as usize;
                        rec.prefetch(self.r_data.f64(ahead * cols), (cols * 8) as u32);
                    }
                    let row = self.idx[i] as usize;
                    rec.load_indirect_row(self.r_idx, i, self.r_data, row, cols);
                    rec.profile_tick();
                    rec.compute(2, (2 * cols) as u32);
                    rec.loop_branch(SITE_DIST_LOOP, (cols / 2).max(1) as u32);
                    let d = sqdist(q, data.row(row));
                    if rec.fcmp_branch(SITE_RADIUS_IN, d <= eps_sq) {
                        out.push(row as u32);
                    }
                }
            }
            Node::Split { dim, thresh, left, right } => {
                let eps = eps_sq.sqrt();
                let delta = q[*dim] - *thresh;
                rec.compute(0, 2);
                if rec.fcmp_branch(SITE_DESCEND, delta <= eps) {
                    self.radius_rec(*left, data, q, eps_sq, rec, out, lookahead);
                }
                if rec.fcmp_branch(SITE_DESCEND, delta >= -eps) {
                    self.radius_rec(*right, data, q, eps_sq, rec, out, lookahead);
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn build_rec(
    data: &Matrix,
    r_data: Region,
    r_idx: Region,
    kind: TreeKind,
    leaf_size: usize,
    idx: &mut Vec<u32>,
    lo: usize,
    hi: usize,
    nodes: &mut Vec<Node>,
    rec: &mut Recorder,
) -> usize {
    let me = nodes.len();
    if hi - lo <= leaf_size {
        nodes.push(Node::Leaf { start: lo, end: hi });
        return me;
    }
    let cols = data.cols();
    // Choose the widest-spread dimension (sampled to bound build cost —
    // both real libraries use cheap spread estimates).
    let stride = ((hi - lo) / 64).max(1);
    let mut best_dim = 0;
    let mut best_spread = -1.0;
    for d in 0..cols {
        let mut mn = f64::INFINITY;
        let mut mx = f64::NEG_INFINITY;
        let mut i = lo;
        while i < hi {
            let v = data[(idx[i] as usize, d)];
            rec.load(r_idx.elem(i, 4), 4);
            rec.load(r_data.f64(idx[i] as usize * cols + d), 8);
            mn = mn.min(v);
            mx = mx.max(v);
            i += stride;
        }
        rec.compute(2, 2);
        if mx - mn > best_spread {
            best_spread = mx - mn;
            best_dim = d;
        }
    }
    let dim = best_dim;

    // Partition point and a *valid separator* threshold: every element in
    // [lo, mid) has value <= thresh and every element in [mid, hi) has
    // value >= thresh — required for the pruning bound to be sound.
    let (mid, thresh) = match kind {
        TreeKind::KdTree => {
            let mid = lo + (hi - lo) / 2;
            idx[lo..hi].select_nth_unstable_by(mid - lo, |&a, &b| {
                data[(a as usize, dim)]
                    .partial_cmp(&data[(b as usize, dim)])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            (mid, data[(idx[mid] as usize, dim)])
        }
        TreeKind::BallTree => {
            // midpoint split with a degenerate-partition fallback
            let mut mn = f64::INFINITY;
            let mut mx = f64::NEG_INFINITY;
            for i in lo..hi {
                let v = data[(idx[i] as usize, dim)];
                mn = mn.min(v);
                mx = mx.max(v);
            }
            let pivot = 0.5 * (mn + mx);
            let seg = &mut idx[lo..hi];
            let mut store = 0usize;
            for i in 0..seg.len() {
                if data[(seg[i] as usize, dim)] < pivot {
                    seg.swap(i, store);
                    store += 1;
                }
            }
            if store == 0 || store == seg.len() {
                let m = seg.len() / 2;
                seg.select_nth_unstable_by(m, |&a, &b| {
                    data[(a as usize, dim)]
                        .partial_cmp(&data[(b as usize, dim)])
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
                (lo + m, data[(idx[lo + m] as usize, dim)])
            } else {
                // pivot separates the two sides by construction
                (lo + store, pivot)
            }
        }
    };

    // Trace the partition pass: one indirect scalar load plus one
    // compare-branch per element (outcome pattern ~data-dependent).
    for i in lo..hi {
        rec.load(r_idx.elem(i, 4), 4);
        rec.load_for_branch(r_data.f64(idx[i] as usize * cols + dim), 8);
        rec.fcmp_branch(SITE_BUILD_PART, i < mid);
    }
    nodes.push(Node::Leaf { start: 0, end: 0 }); // placeholder, patched below
    let left = build_rec(data, r_data, r_idx, kind, leaf_size, idx, lo, mid, nodes, rec);
    let right = build_rec(data, r_data, r_idx, kind, leaf_size, idx, mid, hi, nodes, rec);
    nodes[me] = Node::Split { dim, thresh, left, right };
    me
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::make_blobs;
    use crate::trace::{NullSink, VecSink};

    fn brute_knn(data: &Matrix, q: &[f64], k: usize) -> Vec<(f64, u32)> {
        let mut all: Vec<(f64, u32)> = (0..data.rows())
            .map(|i| (sqdist(q, data.row(i)), i as u32))
            .collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        all.truncate(k);
        all
    }

    fn build_tree(kind: TreeKind, n: usize) -> (Matrix, TraceTree) {
        let ds = make_blobs(n, 5, 4, 2.0, 21);
        let mut space = AddressSpace::new();
        let r_data = space.alloc_matrix("x", ds.x.rows(), ds.x.cols());
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 99);
        let t = TraceTree::build(&ds.x, r_data, &mut space, kind, 16, &mut rec);
        (ds.x, t)
    }

    #[test]
    fn kd_knn_matches_brute_force() {
        let (x, t) = build_tree(TreeKind::KdTree, 500);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 99);
        for qi in [0usize, 13, 250, 499] {
            let got = t.knn(&x, x.row(qi), 5, &mut rec, 0);
            let want = brute_knn(&x, x.row(qi), 5);
            assert_eq!(got.len(), 5);
            for (g, w) in got.iter().zip(want.iter()) {
                assert!((g.0 - w.0).abs() < 1e-9, "dist mismatch {g:?} {w:?}");
            }
            // nearest neighbour of a dataset point is itself
            assert_eq!(got[0].1 as usize, qi);
        }
    }

    #[test]
    fn ball_knn_matches_brute_force() {
        let (x, t) = build_tree(TreeKind::BallTree, 500);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 99);
        for qi in [7usize, 100, 333] {
            let got = t.knn(&x, x.row(qi), 3, &mut rec, 0);
            let want = brute_knn(&x, x.row(qi), 3);
            for (g, w) in got.iter().zip(want.iter()) {
                assert!((g.0 - w.0).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn radius_matches_brute_force() {
        let (x, t) = build_tree(TreeKind::KdTree, 400);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 99);
        let eps_sq = 4.0;
        for qi in [0usize, 200, 399] {
            let mut got = Vec::new();
            t.radius(&x, x.row(qi), eps_sq, &mut rec, &mut got, 0);
            got.sort_unstable();
            let mut want: Vec<u32> = (0..x.rows() as u32)
                .filter(|&i| sqdist(x.row(qi), x.row(i as usize)) <= eps_sq)
                .collect();
            want.sort_unstable();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn tree_prunes_compared_to_brute() {
        // the traced leaf scans must touch far fewer rows than brute force
        let (x, t) = build_tree(TreeKind::KdTree, 2000);
        let mut sink = VecSink::default();
        {
            let mut rec = Recorder::new(&mut sink, 99);
            t.knn(&x, x.row(77), 5, &mut rec, 0);
        }
        let row_loads = sink
            .events
            .iter()
            .filter(|e| matches!(e, crate::trace::Event::Load { size, .. } if *size == 40))
            .count();
        assert!(
            row_loads < 2000 / 3,
            "tree visited {row_loads} rows of 2000 — no pruning?"
        );
        assert!(row_loads > 5, "must at least scan some leaves");
    }

    #[test]
    fn query_emits_branches_and_indirect_loads() {
        let (x, t) = build_tree(TreeKind::KdTree, 300);
        let mut sink = VecSink::default();
        {
            let mut rec = Recorder::new(&mut sink, 99);
            t.knn(&x, x.row(3), 4, &mut rec, 0);
        }
        let branches = sink
            .events
            .iter()
            .filter(|e| matches!(e, crate::trace::Event::Branch { .. }))
            .count();
        let idx_loads = sink
            .events
            .iter()
            .filter(|e| matches!(e, crate::trace::Event::Load { size: 4, .. }))
            .count();
        assert!(branches > 10);
        assert!(idx_loads > 10, "A[B[i]] index loads expected");
    }

    #[test]
    fn lookahead_emits_sw_prefetches_only_when_enabled() {
        let (x, t) = build_tree(TreeKind::KdTree, 300);
        let count_pf = |enable: bool| {
            let mut sink = VecSink::default();
            {
                let mut rec = Recorder::new(&mut sink, 99);
                rec.sw_prefetch_enabled = enable;
                t.knn(&x, x.row(3), 4, &mut rec, 4);
            }
            sink.events
                .iter()
                .filter(|e| matches!(e, crate::trace::Event::SwPrefetch { .. }))
                .count()
        };
        assert_eq!(count_pf(false), 0);
        assert!(count_pf(true) > 0);
    }

    #[test]
    fn leaf_order_is_permutation() {
        let (_, t) = build_tree(TreeKind::BallTree, 257);
        let mut sorted: Vec<u32> = t.leaf_order().to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<u32>>());
    }

    #[test]
    fn single_leaf_tree_works() {
        let ds = make_blobs(5, 3, 1, 1.0, 2);
        let mut space = AddressSpace::new();
        let r = space.alloc_matrix("x", 5, 3);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 99);
        let t = TraceTree::build(&ds.x, r, &mut space, TreeKind::KdTree, 16, &mut rec);
        assert_eq!(t.n_nodes(), 1);
        let got = t.knn(&ds.x, ds.x.row(2), 2, &mut rec, 0);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].1, 2);
    }
}
