//! KMeans clustering [Llo82] — neighbour-based workload.
//!
//! Lloyd's algorithm (scikit-learn's `KMeans(algorithm="lloyd")`, mlpack's
//! `kmeans`): each iteration streams every sample, computes distances to
//! all k centroids (argmin with a data-dependent compare-branch per
//! centroid — the source of KMeans' branch traffic), then recomputes
//! centroids. The per-sample outer loop honours
//! [`RunContext::visit_order`], making KMeans a computation-reordering
//! target (paper Section VI). Quality metric: **negative inertia** (so
//! larger = better, consistent across workloads).

use super::{Category, RunContext, RunResult, Workload};
use crate::data::{make_blobs, Dataset};
use crate::trace::{AddressSpace, Recorder};
use crate::util::stats::sqdist;
use crate::util::Pcg64;

const SITE_BETTER: u32 = 1;
const SITE_MOVED: u32 = 2;
const SITE_DIST_LOOP: u32 = 3;

/// KMeans workload.
pub struct KMeans {
    pub k: usize,
}

impl Default for KMeans {
    fn default() -> Self {
        Self { k: 8 }
    }
}

impl Workload for KMeans {
    fn name(&self) -> &'static str {
        "KMeans"
    }

    fn category(&self) -> Category {
        Category::NeighbourBased
    }

    fn supports_visit_order(&self) -> bool {
        true
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        make_blobs(rows, features, self.k, 1.0, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let (n, m) = (ds.n_samples(), ds.n_features());
        let k = self.k.min(n);
        let mut space = AddressSpace::new();
        let r_x = space.alloc_matrix("kmeans.x", n, m);
        let r_c = space.alloc_matrix("kmeans.centroids", k, m);
        let r_assign = space.alloc("kmeans.assign", n as u64 * 4);
        let overhead = ctx.profile.loop_overhead_uops();

        // init: k distinct random rows (sklearn "random" init)
        let mut rng = Pcg64::new(ctx.seed);
        let init = rng.sample_indices(n, k);
        let mut centroids: Vec<Vec<f64>> = init.iter().map(|&i| ds.x.row(i).to_vec()).collect();
        let mut assign = vec![0u32; n];
        let default_order: Vec<usize> = (0..n).collect();
        let order = ctx.visit_order.as_deref().unwrap_or(&default_order);
        assert_eq!(order.len(), n, "visit order must cover all samples");

        let mut inertia = 0.0;
        for _iter in 0..ctx.iterations.max(1) {
            inertia = 0.0;
            let mut sums = vec![vec![0.0; m]; k];
            let mut counts = vec![0usize; k];
            for &i in order {
                rec.load_row(r_x, i, m);
                let _ = overhead;
                rec.profile_tick();
                let row = ds.x.row(i);
                let mut best = 0usize;
                let mut best_d = f64::INFINITY;
                for (c, ctr) in centroids.iter().enumerate() {
                    // centroid rows are tiny and hot in cache
                    rec.load_row(r_c, c, m);
                    rec.compute(1, (2 * m) as u32);
                    rec.loop_branch(SITE_DIST_LOOP, (m / 2).max(1) as u32);
                    let d = sqdist(row, ctr);
                    // the argmin update branch — data-dependent
                    if rec.fcmp_branch(SITE_BETTER, d < best_d) {
                        best_d = d;
                        best = c;
                    }
                }
                // label store + "assignment changed" check (sklearn tracks
                // movement for convergence)
                rec.load_for_branch(r_assign.elem(i, 4), 4);
                rec.cmp_branch(SITE_MOVED, assign[i] != best as u32);
                rec.store(r_assign.elem(i, 4), 4);
                assign[i] = best as u32;
                inertia += best_d;
                counts[best] += 1;
                for (j, s) in sums[best].iter_mut().enumerate() {
                    *s += row[j];
                }
                rec.compute(0, m as u32);
            }
            // M-step: recompute centroids (k×m, in cache)
            rec.load(r_c.at(0), (k * m * 8) as u32);
            rec.store(r_c.at(0), (k * m * 8) as u32);
            rec.compute(0, (k * m) as u32);
            for c in 0..k {
                if counts[c] > 0 {
                    for j in 0..m {
                        centroids[c][j] = sums[c][j] / counts[c] as f64;
                    }
                }
            }
        }
        RunResult {
            quality: -inertia,
            detail: format!("inertia {inertia:.1}, k={k}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{InstructionMix, NullSink};

    fn run_kmeans(iters: usize) -> (RunResult, Dataset) {
        let w = KMeans { k: 4 };
        let ds = w.make_dataset(800, 8, 20);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext { iterations: iters, ..Default::default() }, &mut rec);
        (res, ds)
    }

    #[test]
    fn inertia_improves_with_iterations() {
        let (r1, _) = run_kmeans(1);
        let (r10, _) = run_kmeans(10);
        assert!(r10.quality >= r1.quality, "{} -> {}", r1.quality, r10.quality);
    }

    #[test]
    fn clusters_blobs_tightly() {
        let (res, ds) = run_kmeans(15);
        // inertia per point should be near m * std² = 8 for converged blobs
        let per_point = -res.quality / ds.n_samples() as f64;
        // random init can merge blobs into a local optimum; bound loosely
        assert!(per_point < 80.0, "per-point inertia {per_point}");
    }

    #[test]
    fn visit_order_does_not_change_result() {
        let w = KMeans { k: 3 };
        let ds = w.make_dataset(300, 5, 21);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let base = w.run(&ds, &RunContext { iterations: 5, ..Default::default() }, &mut rec);
        let rev: Vec<usize> = (0..300).rev().collect();
        let ctx = RunContext { iterations: 5, visit_order: Some(rev), ..Default::default() };
        let reordered = w.run(&ds, &ctx, &mut rec);
        assert!(
            (base.quality - reordered.quality).abs() < 1e-6 * base.quality.abs().max(1.0),
            "{} vs {}",
            base.quality,
            reordered.quality
        );
    }

    #[test]
    fn branch_heavy_trace() {
        let w = KMeans::default();
        let ds = w.make_dataset(400, 8, 22);
        let mut mix = InstructionMix::default();
        {
            let mut rec = Recorder::new(&mut mix, 0);
            w.run(&ds, &RunContext { iterations: 2, ..Default::default() }, &mut rec);
        }
        // one branch per centroid per sample → branches are a visible
        // fraction of the mix (paper Fig. 5: ~20% for neighbour workloads)
        assert!(mix.branch_fraction() > 0.02, "{}", mix.branch_fraction());
        assert!(mix.conditional_branch_fraction() > 0.8);
    }

    #[test]
    #[should_panic(expected = "visit order")]
    fn wrong_order_length_panics() {
        let w = KMeans::default();
        let ds = w.make_dataset(50, 4, 23);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let ctx = RunContext { visit_order: Some(vec![0, 1, 2]), ..Default::default() };
        w.run(&ds, &ctx, &mut rec);
    }
}
